//! Self-measuring serving trajectory: sweep the sharded server over
//! shard counts × graph classes × every registered algorithm and emit
//! one machine-readable JSON document (`pasgal-bench-serve/1`) built
//! entirely from [`crate::coordinator::Metrics::snapshot`] — the bench
//! consumes the same observability surface operators scrape, so a
//! regression in the metrics path is a regression here too.
//!
//! Each sweep cell runs a **fresh** `Coordinator` + `ShardServer`
//! (nothing leaks between cells: caches cold, histograms empty) over a
//! deterministic request mix covering every swept spec. Shard counts
//! are the sweep axis because the worker pool is configured once per
//! process (`PASGAL_THREADS`) — threads cannot vary within a run, but
//! router width can.
//!
//! The emitted document is schema-checked by [`validate`], which CI
//! runs on the artifact it uploads: well-formed JSON, the schema tag,
//! a `latency` series, and one `exec/<label>` series for every swept
//! registry algorithm in every cell — a new registry entry that the
//! serving path silently drops fails the bench.
//!
//! Documents are also **trend-gated** ([`trend_regressions`]): a fresh
//! document diffs against a committed previous artifact cell by cell
//! (same shard count, graph, algorithm), and any exec series whose
//! mean regressed past 2× the previous mean (plus an absolute noise
//! floor — sub-50µs wiggle never trips it) is reported. The bench
//! binary runs the gate when `PASGAL_TRAJ_PREV` names the previous
//! artifact; series present in only one document are ignored, so
//! adding or retiring an algorithm never fails the gate.

use crate::algo::api::{self, AlgoSpec, ParseArgs};
use crate::coordinator::metrics::json_escape;
use crate::coordinator::{Coordinator, JobRequest, ShardConfig, ShardServer, Summary};
use crate::graph::gen;
use crate::V;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Schema tag stamped into every emitted document.
pub const SCHEMA: &str = "pasgal-bench-serve/1";

/// Sweep configuration. Env knobs (`PASGAL_TRAJ_SIDE`,
/// `PASGAL_TRAJ_REQS`, `PASGAL_TRAJ_SHARDS`) let CI shrink the sweep
/// to smoke size without a separate code path.
#[derive(Debug, Clone)]
pub struct TrajectoryConfig {
    /// Road grid is `side × 2·side` vertices; the social graph's scale
    /// is derived to roughly match that vertex count.
    pub side: usize,
    /// Requests issued per (graph, algorithm) pair in each cell.
    pub reqs_per_algo: usize,
    /// Shard counts to sweep (deduplicated, ≥ 1 each).
    pub shard_counts: Vec<usize>,
}

impl TrajectoryConfig {
    /// Smoke-sized sweep for tests and CI.
    pub fn tiny() -> Self {
        TrajectoryConfig {
            side: 8,
            reqs_per_algo: 2,
            shard_counts: vec![1, 2],
        }
    }

    /// Default bench sweep: up to the worker-pool width.
    pub fn default_sweep() -> Self {
        let max = crate::parallel::num_threads().max(1);
        let mut shard_counts = vec![1, 2, max];
        shard_counts.sort_unstable();
        shard_counts.dedup();
        TrajectoryConfig {
            side: 48,
            reqs_per_algo: 6,
            shard_counts,
        }
    }

    /// Default sweep overridden by env knobs
    /// (`PASGAL_TRAJ_SHARDS` is a comma list, e.g. `1,2,4`).
    pub fn from_env() -> Self {
        let mut cfg = Self::default_sweep();
        cfg.side = super::env_usize("PASGAL_TRAJ_SIDE", cfg.side).max(2);
        cfg.reqs_per_algo = super::env_usize("PASGAL_TRAJ_REQS", cfg.reqs_per_algo).max(1);
        if let Ok(s) = std::env::var("PASGAL_TRAJ_SHARDS") {
            let parsed: Vec<usize> = s
                .split(',')
                .filter_map(|t| t.trim().parse().ok())
                .filter(|&n| n >= 1)
                .collect();
            if !parsed.is_empty() {
                cfg.shard_counts = parsed;
            }
        }
        cfg
    }
}

/// The registry specs the driver sweeps: every algorithm except the
/// AOT-engine-gated ones (a bench checkout has no dense artifacts).
pub fn swept_specs() -> Vec<&'static AlgoSpec> {
    api::all()
        .iter()
        .copied()
        .filter(|s| !s.needs_engine)
        .collect()
}

/// Graph classes the driver sweeps: the paper's two diameter regimes.
pub const GRAPH_CLASSES: [&str; 2] = ["road", "social"];

fn build_graph(class: &str, side: usize) -> crate::graph::Graph {
    match class {
        "road" => gen::road(side, 2 * side, 1),
        _ => {
            // Match the road graph's vertex count (2·side²) in scale.
            let n = (2 * side * side).max(2);
            let scale = (usize::BITS - (n - 1).leading_zeros()).max(4);
            gen::social(scale, 8, 2)
        }
    }
}

struct Cell {
    shards: usize,
    graph: String,
    n: usize,
    jobs: usize,
    failed: usize,
    wall: Duration,
    counters: Vec<(String, u64)>,
    series: Vec<(String, Summary)>,
    cache_hit_rate: f64,
    fused_fraction: f64,
}

/// One sweep cell: fresh coordinator, one graph, every swept spec,
/// `reqs_per_algo` requests each, served through `shards` workers.
fn run_cell(cfg: &TrajectoryConfig, shards: usize, class: &str) -> Cell {
    let coord = Arc::new(Coordinator::new());
    let g = build_graph(class, cfg.side);
    let n = g.n();
    coord.load_graph(class, g);
    let pargs = ParseArgs { tau: 64, block: 64 };
    let mut reqs: Vec<JobRequest> = Vec::new();
    let mut id = 0u64;
    for spec in swept_specs() {
        for _ in 0..cfg.reqs_per_algo {
            let r = JobRequest::parse(id, class, spec.label, &pargs)
                .expect("registry label must parse")
                .with_source(((id * 131) % n as u64) as V);
            reqs.push(r);
            id += 1;
        }
    }
    let config = ShardConfig {
        shards,
        fusion_window: Duration::from_micros(100),
        max_batch: 64,
        inbox_cap: 0,            // unbounded: no shedding mid-sweep
        stall_limit: Duration::ZERO, // no watchdog noise in a bench
        breaker_cooldown: Duration::ZERO,
        steal: true,             // the production default is what we track
        fusion_window_max: Duration::ZERO,
    };
    let (req_tx, req_rx) = channel();
    let (res_tx, res_rx) = channel();
    for r in &reqs {
        req_tx.send(r.clone()).unwrap();
    }
    drop(req_tx);
    let t0 = Instant::now();
    let _per_shard = ShardServer::new(Arc::clone(&coord), config).serve(req_rx, res_tx);
    let wall = t0.elapsed();
    let mut jobs = 0usize;
    let mut failed = 0usize;
    for res in res_rx {
        jobs += 1;
        if matches!(res.output, crate::coordinator::JobOutput::Failed { .. }) {
            failed += 1;
        }
    }
    // Per-shard registries merged into the global one at serve() exit.
    let snap = coord.metrics.snapshot();
    Cell {
        shards,
        graph: class.to_string(),
        n,
        jobs,
        failed,
        wall,
        counters: snap.counters,
        series: snap.series,
        cache_hit_rate: snap.cache_hit_rate,
        fused_fraction: snap.fused_fraction,
    }
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "0".to_string()
    }
}

fn push_summary(out: &mut String, s: &Summary) {
    out.push_str(&format!(
        "{{\"count\":{},\"mean_ms\":{},\"p50_ms\":{},\"p95_ms\":{},\"p99_ms\":{},\"max_ms\":{}}}",
        s.count,
        fmt_f64(s.mean_ms),
        fmt_f64(s.p50_ms),
        fmt_f64(s.p95_ms),
        fmt_f64(s.p99_ms),
        fmt_f64(s.max_ms),
    ));
}

/// Run the full sweep and render the `pasgal-bench-serve/1` document.
pub fn run(cfg: &TrajectoryConfig) -> String {
    let specs = swept_specs();
    let mut labels: Vec<&str> = specs.iter().map(|s| s.label).collect();
    labels.sort_unstable();
    let mut cells: Vec<Cell> = Vec::new();
    for &shards in &cfg.shard_counts {
        for class in GRAPH_CLASSES {
            cells.push(run_cell(cfg, shards.max(1), class));
        }
    }

    let mut out = String::from("{\"schema\":\"");
    out.push_str(SCHEMA);
    out.push_str("\",\"threads\":");
    out.push_str(&crate::parallel::num_threads().to_string());
    out.push_str(&format!(
        ",\"config\":{{\"side\":{},\"reqs_per_algo\":{},\"shard_counts\":[{}],\"graphs\":[",
        cfg.side,
        cfg.reqs_per_algo,
        cfg.shard_counts
            .iter()
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join(",")
    ));
    for (i, class) in GRAPH_CLASSES.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('"');
        json_escape(class, &mut out);
        out.push('"');
    }
    out.push_str("]},\"algos\":[");
    for (i, l) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('"');
        json_escape(l, &mut out);
        out.push('"');
    }
    out.push_str("],\"cells\":[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"shards\":{},\"graph\":\"{}\",\"n\":{},\"jobs\":{},\"failed\":{},\"wall_ms\":{},\"jobs_per_sec\":{},\"cache_hit_rate\":{},\"fused_fraction\":{},\"counters\":{{",
            c.shards,
            c.graph,
            c.n,
            c.jobs,
            c.failed,
            fmt_f64(c.wall.as_secs_f64() * 1e3),
            fmt_f64(c.jobs as f64 / c.wall.as_secs_f64().max(1e-9)),
            fmt_f64(c.cache_hit_rate),
            fmt_f64(c.fused_fraction),
        ));
        for (j, (name, v)) in c.counters.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push('"');
            json_escape(name, &mut out);
            out.push_str(&format!("\":{v}"));
        }
        out.push_str("},\"series\":{");
        for (j, (name, s)) in c.series.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push('"');
            json_escape(name, &mut out);
            out.push_str("\":");
            push_summary(&mut out, s);
        }
        out.push_str("}}");
    }
    out.push_str("],\"derived\":[");
    // The paper's headline comparison, derived from the snapshot the
    // same way a dashboard would: VGC BFS vs frontier BFS mean exec.
    let mut first = true;
    for c in &cells {
        let mean = |label: &str| {
            let needle = format!("exec/{label}");
            c.series
                .iter()
                .find(|(n, _)| *n == needle)
                .map(|(_, s)| s.mean_ms)
        };
        if let (Some(vgc), Some(frontier)) = (mean("bfs-vgc"), mean("bfs-frontier")) {
            if vgc > 0.0 {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!(
                    "{{\"graph\":\"{}\",\"shards\":{},\"metric\":\"vgc_vs_frontier_speedup\",\"value\":{}}}",
                    c.graph,
                    c.shards,
                    fmt_f64(frontier / vgc),
                ));
            }
        }
    }
    out.push_str("]}");
    out
}

/// Minimal structural JSON check (no parser crate offline): balanced
/// braces/brackets outside strings, valid string escapes, object at
/// the top level. Shared with the trace-line tests.
pub fn json_well_formed(s: &str) -> bool {
    let mut stack: Vec<char> = Vec::new();
    let mut in_string = false;
    let mut escaped = false;
    let trimmed = s.trim();
    if !trimmed.starts_with('{') {
        return false;
    }
    for c in trimmed.chars() {
        if in_string {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_string = false;
            } else if (c as u32) < 0x20 {
                return false; // raw control char inside a string
            }
            continue;
        }
        match c {
            '"' => in_string = true,
            '{' | '[' => stack.push(c),
            '}' => {
                if stack.pop() != Some('{') {
                    return false;
                }
            }
            ']' => {
                if stack.pop() != Some('[') {
                    return false;
                }
            }
            _ => {}
        }
    }
    !in_string && stack.is_empty()
}

/// Schema-validate an emitted document. Returns every problem found
/// (empty ⇒ valid) so CI failures name all the missing pieces at once.
pub fn validate(json: &str) -> Result<(), Vec<String>> {
    let mut problems = Vec::new();
    if !json_well_formed(json) {
        problems.push("document is not well-formed JSON".to_string());
    }
    if !json.contains(&format!("\"schema\":\"{SCHEMA}\"")) {
        problems.push(format!("missing schema tag {SCHEMA:?}"));
    }
    for key in ["\"config\":", "\"algos\":", "\"cells\":", "\"derived\":"] {
        if !json.contains(key) {
            problems.push(format!("missing top-level key {key}"));
        }
    }
    if !json.contains("\"latency\":") {
        problems.push("no latency series in any cell".to_string());
    }
    // Every cell publishes its graph through the metered load path, so
    // the publish-cost series must appear — this is how the trajectory
    // tracks graph-load regressions alongside query latency.
    if !json.contains("\"graph_load_us\":") {
        problems.push("no graph_load_us series in any cell".to_string());
    }
    for spec in swept_specs() {
        let needle = format!("\"exec/{}\":", spec.label);
        if !json.contains(&needle) {
            problems.push(format!(
                "registry algorithm {:?} has no exec series in the document",
                spec.label
            ));
        }
    }
    if problems.is_empty() {
        Ok(())
    } else {
        Err(problems)
    }
}

/// One `exec/<algo>` measurement extracted from a trajectory document,
/// keyed by its sweep cell.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecPoint {
    pub shards: u64,
    pub graph: String,
    pub algo: String,
    pub mean_ms: f64,
}

/// Parse the number starting at the front of `s` (optionally signed,
/// decimal, exponent), or `None` if none is there.
fn leading_number(s: &str) -> Option<f64> {
    let end = s
        .find(|c: char| !matches!(c, '0'..='9' | '-' | '+' | '.' | 'e' | 'E'))
        .unwrap_or(s.len());
    s[..end].parse().ok()
}

/// The string literal starting at the front of `s` (which must begin
/// right after the opening quote). Trajectory keys are emitted through
/// `json_escape`, so only `\"` and `\\` escapes occur in practice;
/// other escapes pass through verbatim rather than failing the scan.
fn leading_string(s: &str) -> Option<(String, usize)> {
    let mut out = String::new();
    let mut chars = s.char_indices();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Some((out, i + 1)),
            '\\' => match chars.next() {
                Some((_, esc)) => out.push(esc),
                None => return None,
            },
            _ => out.push(c),
        }
    }
    None
}

/// Extract every per-cell `exec/<algo>` mean from a
/// `pasgal-bench-serve/1` document.
///
/// This is a targeted scan of the schema this module emits, not a
/// general JSON parser (the offline crate set has none): cells are the
/// only objects that open with `{"shards":`, and each one carries its
/// `"graph"` key and `"series"` map before the next cell begins.
/// Malformed fragments are skipped, never panicked on — the gate
/// should fail with a diagnostic, not a crash, on a corrupt baseline.
pub fn exec_points(json: &str) -> Vec<ExecPoint> {
    let mut points = Vec::new();
    let cell_open = "{\"shards\":";
    let mut starts: Vec<usize> = Vec::new();
    let mut from = 0;
    while let Some(pos) = json[from..].find(cell_open) {
        starts.push(from + pos);
        from += pos + cell_open.len();
    }
    for (k, &start) in starts.iter().enumerate() {
        let end = starts.get(k + 1).copied().unwrap_or(json.len());
        let cell = &json[start..end];
        let Some(shards) = leading_number(&cell[cell_open.len()..]) else {
            continue;
        };
        let Some(gpos) = cell.find("\"graph\":\"") else {
            continue;
        };
        let Some((graph, _)) = leading_string(&cell[gpos + 9..]) else {
            continue;
        };
        // Series only: an `exec/...` match inside the counters map
        // (e.g. a future counter named exec/x) must not be misread.
        let Some(spos) = cell.find("\"series\":{") else {
            continue;
        };
        let series = &cell[spos..];
        let mut sfrom = 0;
        while let Some(pos) = series[sfrom..].find("\"exec/") {
            let at = sfrom + pos + 6;
            let Some((algo, used)) = leading_string(&series[at..]) else {
                break;
            };
            let rest = &series[at + used..];
            sfrom = at + used;
            // Stay inside this entry's flat summary object: an entry
            // missing its mean must not read the next entry's.
            let entry_end = rest.find('}').unwrap_or(rest.len());
            let Some(mpos) = rest[..entry_end].find("\"mean_ms\":") else {
                continue;
            };
            if let Some(mean_ms) = leading_number(&rest[mpos + 10..]) {
                points.push(ExecPoint {
                    shards: shards as u64,
                    graph: graph.clone(),
                    algo,
                    mean_ms,
                });
            }
        }
    }
    points
}

/// Regression factor the trend gate fails on: a cell's exec mean more
/// than doubling versus the committed previous artifact.
pub const TREND_FACTOR: f64 = 2.0;

/// Absolute slack under which the trend gate never fires: sub-50µs
/// means are timer wiggle on a smoke-sized sweep, and 2× of almost
/// nothing is still almost nothing.
pub const TREND_NOISE_FLOOR_MS: f64 = 0.05;

/// Diff a freshly generated document against a previous artifact and
/// report every algorithm exec series that regressed past
/// [`TREND_FACTOR`]× (plus [`TREND_NOISE_FLOOR_MS`] of absolute
/// slack) in the same (shards, graph) cell. Empty ⇒ the trend holds.
/// Cells or series present in only one document are ignored, so sweep
/// or registry changes never fail the gate spuriously.
pub fn trend_regressions(current: &str, previous: &str) -> Vec<String> {
    let cur = exec_points(current);
    let prev = exec_points(previous);
    let mut problems = Vec::new();
    for c in &cur {
        let Some(p) = prev
            .iter()
            .find(|p| p.shards == c.shards && p.graph == c.graph && p.algo == c.algo)
        else {
            continue;
        };
        if c.mean_ms > p.mean_ms * TREND_FACTOR + TREND_NOISE_FLOOR_MS {
            problems.push(format!(
                "exec/{} on {} @ {} shard(s): mean {:.4}ms vs previous {:.4}ms (> {}x)",
                c.algo, c.graph, c.shards, c.mean_ms, p.mean_ms, TREND_FACTOR
            ));
        }
    }
    problems
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_well_formed_accepts_and_rejects() {
        assert!(json_well_formed("{\"a\":[1,2,{\"b\":\"x\\\"y\"}]}"));
        assert!(json_well_formed("{}"));
        assert!(!json_well_formed("{\"a\":1"), "unbalanced brace");
        assert!(!json_well_formed("[1,2]"), "top level must be an object");
        assert!(!json_well_formed("{\"a\":\"unterminated}"));
        assert!(!json_well_formed("{\"a\":[1}]"), "mismatched nesting");
    }

    #[test]
    fn swept_specs_cover_the_registry_minus_engine_gated() {
        let swept = swept_specs();
        let total = api::all().len();
        let engine_gated = api::all().iter().filter(|s| s.needs_engine).count();
        assert_eq!(swept.len(), total - engine_gated);
        assert!(swept.len() >= 10, "the registry holds ≥10 CPU algorithms");
    }

    #[test]
    fn config_from_env_defaults_are_sane() {
        let cfg = TrajectoryConfig::tiny();
        assert!(cfg.side >= 2 && cfg.reqs_per_algo >= 1);
        assert!(cfg.shard_counts.iter().all(|&s| s >= 1));
    }

    fn doc(cells: &[(u64, &str, &[(&str, f64)])]) -> String {
        let mut out = String::from("{\"schema\":\"pasgal-bench-serve/1\",\"cells\":[");
        for (i, (shards, graph, series)) in cells.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"shards\":{shards},\"graph\":\"{graph}\",\"counters\":{{\"x\":1}},\"series\":{{"
            ));
            for (j, (algo, mean)) in series.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "\"exec/{algo}\":{{\"count\":4,\"mean_ms\":{mean:.4},\"p50_ms\":0,\"p95_ms\":0,\"p99_ms\":0,\"max_ms\":0}}"
                ));
            }
            out.push_str("}}");
        }
        out.push_str("],\"derived\":[{\"graph\":\"road\",\"shards\":1,\"metric\":\"m\",\"value\":1}]}");
        out
    }

    #[test]
    fn exec_points_extracts_per_cell_series() {
        let d = doc(&[
            (1, "road", &[("bfs-vgc", 1.5), ("cc", 0.25)]),
            (2, "social", &[("bfs-vgc", 0.75)]),
        ]);
        let pts = exec_points(&d);
        assert_eq!(pts.len(), 3);
        assert!(pts.contains(&ExecPoint {
            shards: 1,
            graph: "road".into(),
            algo: "bfs-vgc".into(),
            mean_ms: 1.5,
        }));
        assert!(pts.contains(&ExecPoint {
            shards: 2,
            graph: "social".into(),
            algo: "bfs-vgc".into(),
            mean_ms: 0.75,
        }));
        // The derived section's {"graph":..,"shards":..} entries are
        // not cells and must contribute nothing.
        assert!(pts.iter().all(|p| p.shards <= 2));
    }

    #[test]
    fn exec_points_reads_a_real_emitted_document() {
        let cfg = TrajectoryConfig {
            side: 6,
            reqs_per_algo: 1,
            shard_counts: vec![1],
        };
        let json = run(&cfg);
        let pts = exec_points(&json);
        // One point per (cell, swept algorithm): 2 graphs × registry.
        assert_eq!(pts.len(), 2 * swept_specs().len());
        assert!(pts.iter().all(|p| p.shards == 1 && p.mean_ms >= 0.0));
        assert!(pts.iter().any(|p| p.graph == "road"));
        assert!(pts.iter().any(|p| p.graph == "social"));
    }

    #[test]
    fn trend_gate_fires_only_past_double_plus_noise_floor() {
        let prev = doc(&[(1, "road", &[("bfs-vgc", 1.0), ("cc", 0.01)])]);
        // 1.9x: holds.
        let ok = doc(&[(1, "road", &[("bfs-vgc", 1.9), ("cc", 0.01)])]);
        assert!(trend_regressions(&ok, &prev).is_empty());
        // >2x: fails, naming the cell.
        let bad = doc(&[(1, "road", &[("bfs-vgc", 2.2), ("cc", 0.01)])]);
        let problems = trend_regressions(&bad, &prev);
        assert_eq!(problems.len(), 1);
        assert!(problems[0].contains("exec/bfs-vgc"), "{problems:?}");
        assert!(problems[0].contains("road"), "{problems:?}");
        // 5x on a sub-noise-floor series: timer wiggle, holds.
        let tiny = doc(&[(1, "road", &[("bfs-vgc", 1.0), ("cc", 0.05)])]);
        assert!(trend_regressions(&tiny, &prev).is_empty());
    }

    #[test]
    fn trend_gate_ignores_one_sided_cells_and_series() {
        let prev = doc(&[(1, "road", &[("bfs-vgc", 1.0)])]);
        // New algorithm, new shard count, new graph: all ignored.
        let cur = doc(&[
            (1, "road", &[("kcore", 99.0)]),
            (4, "road", &[("bfs-vgc", 99.0)]),
            (1, "social", &[("bfs-vgc", 99.0)]),
        ]);
        assert!(trend_regressions(&cur, &prev).is_empty());
        // And a corrupt previous artifact yields no points, not a
        // panic — the gate degrades to a no-op diff.
        assert!(exec_points("{\"cells\":[{\"shards\":oops").is_empty());
        assert!(trend_regressions(&cur, "not json at all").is_empty());
    }
}
