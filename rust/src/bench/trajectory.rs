//! Self-measuring serving trajectory: sweep the sharded server over
//! shard counts × graph classes × every registered algorithm and emit
//! one machine-readable JSON document (`pasgal-bench-serve/1`) built
//! entirely from [`crate::coordinator::Metrics::snapshot`] — the bench
//! consumes the same observability surface operators scrape, so a
//! regression in the metrics path is a regression here too.
//!
//! Each sweep cell runs a **fresh** `Coordinator` + `ShardServer`
//! (nothing leaks between cells: caches cold, histograms empty) over a
//! deterministic request mix covering every swept spec. Shard counts
//! are the sweep axis because the worker pool is configured once per
//! process (`PASGAL_THREADS`) — threads cannot vary within a run, but
//! router width can.
//!
//! The emitted document is schema-checked by [`validate`], which CI
//! runs on the artifact it uploads: well-formed JSON, the schema tag,
//! a `latency` series, and one `exec/<label>` series for every swept
//! registry algorithm in every cell — a new registry entry that the
//! serving path silently drops fails the bench.

use crate::algo::api::{self, AlgoSpec, ParseArgs};
use crate::coordinator::metrics::json_escape;
use crate::coordinator::{Coordinator, JobRequest, ShardConfig, ShardServer, Summary};
use crate::graph::gen;
use crate::V;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Schema tag stamped into every emitted document.
pub const SCHEMA: &str = "pasgal-bench-serve/1";

/// Sweep configuration. Env knobs (`PASGAL_TRAJ_SIDE`,
/// `PASGAL_TRAJ_REQS`, `PASGAL_TRAJ_SHARDS`) let CI shrink the sweep
/// to smoke size without a separate code path.
#[derive(Debug, Clone)]
pub struct TrajectoryConfig {
    /// Road grid is `side × 2·side` vertices; the social graph's scale
    /// is derived to roughly match that vertex count.
    pub side: usize,
    /// Requests issued per (graph, algorithm) pair in each cell.
    pub reqs_per_algo: usize,
    /// Shard counts to sweep (deduplicated, ≥ 1 each).
    pub shard_counts: Vec<usize>,
}

impl TrajectoryConfig {
    /// Smoke-sized sweep for tests and CI.
    pub fn tiny() -> Self {
        TrajectoryConfig {
            side: 8,
            reqs_per_algo: 2,
            shard_counts: vec![1, 2],
        }
    }

    /// Default bench sweep: up to the worker-pool width.
    pub fn default_sweep() -> Self {
        let max = crate::parallel::num_threads().max(1);
        let mut shard_counts = vec![1, 2, max];
        shard_counts.sort_unstable();
        shard_counts.dedup();
        TrajectoryConfig {
            side: 48,
            reqs_per_algo: 6,
            shard_counts,
        }
    }

    /// Default sweep overridden by env knobs
    /// (`PASGAL_TRAJ_SHARDS` is a comma list, e.g. `1,2,4`).
    pub fn from_env() -> Self {
        let mut cfg = Self::default_sweep();
        cfg.side = super::env_usize("PASGAL_TRAJ_SIDE", cfg.side).max(2);
        cfg.reqs_per_algo = super::env_usize("PASGAL_TRAJ_REQS", cfg.reqs_per_algo).max(1);
        if let Ok(s) = std::env::var("PASGAL_TRAJ_SHARDS") {
            let parsed: Vec<usize> = s
                .split(',')
                .filter_map(|t| t.trim().parse().ok())
                .filter(|&n| n >= 1)
                .collect();
            if !parsed.is_empty() {
                cfg.shard_counts = parsed;
            }
        }
        cfg
    }
}

/// The registry specs the driver sweeps: every algorithm except the
/// AOT-engine-gated ones (a bench checkout has no dense artifacts).
pub fn swept_specs() -> Vec<&'static AlgoSpec> {
    api::all()
        .iter()
        .copied()
        .filter(|s| !s.needs_engine)
        .collect()
}

/// Graph classes the driver sweeps: the paper's two diameter regimes.
pub const GRAPH_CLASSES: [&str; 2] = ["road", "social"];

fn build_graph(class: &str, side: usize) -> crate::graph::Graph {
    match class {
        "road" => gen::road(side, 2 * side, 1),
        _ => {
            // Match the road graph's vertex count (2·side²) in scale.
            let n = (2 * side * side).max(2);
            let scale = (usize::BITS - (n - 1).leading_zeros()).max(4);
            gen::social(scale, 8, 2)
        }
    }
}

struct Cell {
    shards: usize,
    graph: String,
    n: usize,
    jobs: usize,
    failed: usize,
    wall: Duration,
    counters: Vec<(String, u64)>,
    series: Vec<(String, Summary)>,
    cache_hit_rate: f64,
    fused_fraction: f64,
}

/// One sweep cell: fresh coordinator, one graph, every swept spec,
/// `reqs_per_algo` requests each, served through `shards` workers.
fn run_cell(cfg: &TrajectoryConfig, shards: usize, class: &str) -> Cell {
    let coord = Arc::new(Coordinator::new());
    let g = build_graph(class, cfg.side);
    let n = g.n();
    coord.load_graph(class, g);
    let pargs = ParseArgs { tau: 64, block: 64 };
    let mut reqs: Vec<JobRequest> = Vec::new();
    let mut id = 0u64;
    for spec in swept_specs() {
        for _ in 0..cfg.reqs_per_algo {
            let r = JobRequest::parse(id, class, spec.label, &pargs)
                .expect("registry label must parse")
                .with_source(((id * 131) % n as u64) as V);
            reqs.push(r);
            id += 1;
        }
    }
    let config = ShardConfig {
        shards,
        fusion_window: Duration::from_micros(100),
        max_batch: 64,
        inbox_cap: 0,            // unbounded: no shedding mid-sweep
        stall_limit: Duration::ZERO, // no watchdog noise in a bench
        breaker_cooldown: Duration::ZERO,
    };
    let (req_tx, req_rx) = channel();
    let (res_tx, res_rx) = channel();
    for r in &reqs {
        req_tx.send(r.clone()).unwrap();
    }
    drop(req_tx);
    let t0 = Instant::now();
    let _per_shard = ShardServer::new(Arc::clone(&coord), config).serve(req_rx, res_tx);
    let wall = t0.elapsed();
    let mut jobs = 0usize;
    let mut failed = 0usize;
    for res in res_rx {
        jobs += 1;
        if matches!(res.output, crate::coordinator::JobOutput::Failed { .. }) {
            failed += 1;
        }
    }
    // Per-shard registries merged into the global one at serve() exit.
    let snap = coord.metrics.snapshot();
    Cell {
        shards,
        graph: class.to_string(),
        n,
        jobs,
        failed,
        wall,
        counters: snap.counters,
        series: snap.series,
        cache_hit_rate: snap.cache_hit_rate,
        fused_fraction: snap.fused_fraction,
    }
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "0".to_string()
    }
}

fn push_summary(out: &mut String, s: &Summary) {
    out.push_str(&format!(
        "{{\"count\":{},\"mean_ms\":{},\"p50_ms\":{},\"p95_ms\":{},\"p99_ms\":{},\"max_ms\":{}}}",
        s.count,
        fmt_f64(s.mean_ms),
        fmt_f64(s.p50_ms),
        fmt_f64(s.p95_ms),
        fmt_f64(s.p99_ms),
        fmt_f64(s.max_ms),
    ));
}

/// Run the full sweep and render the `pasgal-bench-serve/1` document.
pub fn run(cfg: &TrajectoryConfig) -> String {
    let specs = swept_specs();
    let mut labels: Vec<&str> = specs.iter().map(|s| s.label).collect();
    labels.sort_unstable();
    let mut cells: Vec<Cell> = Vec::new();
    for &shards in &cfg.shard_counts {
        for class in GRAPH_CLASSES {
            cells.push(run_cell(cfg, shards.max(1), class));
        }
    }

    let mut out = String::from("{\"schema\":\"");
    out.push_str(SCHEMA);
    out.push_str("\",\"threads\":");
    out.push_str(&crate::parallel::num_threads().to_string());
    out.push_str(&format!(
        ",\"config\":{{\"side\":{},\"reqs_per_algo\":{},\"shard_counts\":[{}],\"graphs\":[",
        cfg.side,
        cfg.reqs_per_algo,
        cfg.shard_counts
            .iter()
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join(",")
    ));
    for (i, class) in GRAPH_CLASSES.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('"');
        json_escape(class, &mut out);
        out.push('"');
    }
    out.push_str("]},\"algos\":[");
    for (i, l) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('"');
        json_escape(l, &mut out);
        out.push('"');
    }
    out.push_str("],\"cells\":[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"shards\":{},\"graph\":\"{}\",\"n\":{},\"jobs\":{},\"failed\":{},\"wall_ms\":{},\"jobs_per_sec\":{},\"cache_hit_rate\":{},\"fused_fraction\":{},\"counters\":{{",
            c.shards,
            c.graph,
            c.n,
            c.jobs,
            c.failed,
            fmt_f64(c.wall.as_secs_f64() * 1e3),
            fmt_f64(c.jobs as f64 / c.wall.as_secs_f64().max(1e-9)),
            fmt_f64(c.cache_hit_rate),
            fmt_f64(c.fused_fraction),
        ));
        for (j, (name, v)) in c.counters.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push('"');
            json_escape(name, &mut out);
            out.push_str(&format!("\":{v}"));
        }
        out.push_str("},\"series\":{");
        for (j, (name, s)) in c.series.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push('"');
            json_escape(name, &mut out);
            out.push_str("\":");
            push_summary(&mut out, s);
        }
        out.push_str("}}");
    }
    out.push_str("],\"derived\":[");
    // The paper's headline comparison, derived from the snapshot the
    // same way a dashboard would: VGC BFS vs frontier BFS mean exec.
    let mut first = true;
    for c in &cells {
        let mean = |label: &str| {
            let needle = format!("exec/{label}");
            c.series
                .iter()
                .find(|(n, _)| *n == needle)
                .map(|(_, s)| s.mean_ms)
        };
        if let (Some(vgc), Some(frontier)) = (mean("bfs-vgc"), mean("bfs-frontier")) {
            if vgc > 0.0 {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!(
                    "{{\"graph\":\"{}\",\"shards\":{},\"metric\":\"vgc_vs_frontier_speedup\",\"value\":{}}}",
                    c.graph,
                    c.shards,
                    fmt_f64(frontier / vgc),
                ));
            }
        }
    }
    out.push_str("]}");
    out
}

/// Minimal structural JSON check (no parser crate offline): balanced
/// braces/brackets outside strings, valid string escapes, object at
/// the top level. Shared with the trace-line tests.
pub fn json_well_formed(s: &str) -> bool {
    let mut stack: Vec<char> = Vec::new();
    let mut in_string = false;
    let mut escaped = false;
    let trimmed = s.trim();
    if !trimmed.starts_with('{') {
        return false;
    }
    for c in trimmed.chars() {
        if in_string {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_string = false;
            } else if (c as u32) < 0x20 {
                return false; // raw control char inside a string
            }
            continue;
        }
        match c {
            '"' => in_string = true,
            '{' | '[' => stack.push(c),
            '}' => {
                if stack.pop() != Some('{') {
                    return false;
                }
            }
            ']' => {
                if stack.pop() != Some('[') {
                    return false;
                }
            }
            _ => {}
        }
    }
    !in_string && stack.is_empty()
}

/// Schema-validate an emitted document. Returns every problem found
/// (empty ⇒ valid) so CI failures name all the missing pieces at once.
pub fn validate(json: &str) -> Result<(), Vec<String>> {
    let mut problems = Vec::new();
    if !json_well_formed(json) {
        problems.push("document is not well-formed JSON".to_string());
    }
    if !json.contains(&format!("\"schema\":\"{SCHEMA}\"")) {
        problems.push(format!("missing schema tag {SCHEMA:?}"));
    }
    for key in ["\"config\":", "\"algos\":", "\"cells\":", "\"derived\":"] {
        if !json.contains(key) {
            problems.push(format!("missing top-level key {key}"));
        }
    }
    if !json.contains("\"latency\":") {
        problems.push("no latency series in any cell".to_string());
    }
    // Every cell publishes its graph through the metered load path, so
    // the publish-cost series must appear — this is how the trajectory
    // tracks graph-load regressions alongside query latency.
    if !json.contains("\"graph_load_us\":") {
        problems.push("no graph_load_us series in any cell".to_string());
    }
    for spec in swept_specs() {
        let needle = format!("\"exec/{}\":", spec.label);
        if !json.contains(&needle) {
            problems.push(format!(
                "registry algorithm {:?} has no exec series in the document",
                spec.label
            ));
        }
    }
    if problems.is_empty() {
        Ok(())
    } else {
        Err(problems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_well_formed_accepts_and_rejects() {
        assert!(json_well_formed("{\"a\":[1,2,{\"b\":\"x\\\"y\"}]}"));
        assert!(json_well_formed("{}"));
        assert!(!json_well_formed("{\"a\":1"), "unbalanced brace");
        assert!(!json_well_formed("[1,2]"), "top level must be an object");
        assert!(!json_well_formed("{\"a\":\"unterminated}"));
        assert!(!json_well_formed("{\"a\":[1}]"), "mismatched nesting");
    }

    #[test]
    fn swept_specs_cover_the_registry_minus_engine_gated() {
        let swept = swept_specs();
        let total = api::all().len();
        let engine_gated = api::all().iter().filter(|s| s.needs_engine).count();
        assert_eq!(swept.len(), total - engine_gated);
        assert!(swept.len() >= 10, "the registry holds ≥10 CPU algorithms");
    }

    #[test]
    fn config_from_env_defaults_are_sane() {
        let cfg = TrajectoryConfig::tiny();
        assert!(cfg.side >= 2 && cfg.reqs_per_algo >= 1);
        assert!(cfg.shard_counts.iter().all(|&s| s >= 1));
    }
}
