//! Regeneration of every table and figure in the paper's evaluation
//! (see DESIGN.md §4 for the experiment index).
//!
//! Each function returns the rendered table so `cargo bench` targets,
//! the `pasgal` CLI, and tests all share one implementation. Absolute
//! numbers are this machine's (1 physical core); the paper's 96-core
//! behaviour is reproduced by replaying recorded execution traces on
//! the virtual multicore ([`crate::sim`]) — column `sim192` — while
//! `t1core` is the measured wall-clock.

use super::{fmt_duration, geomean, time_once, Table};
use crate::algo::{bcc, bfs, scc, sssp};
use crate::graph::gen::{suite, Scale, SuiteEntry};
use crate::graph::{io, stats, Graph};
use crate::sim::{makespan, AlgoTrace, CostModel};
use crate::V;

/// Scale from `PASGAL_SCALE` (tiny by default: every bench target
/// must finish in CI time; EXPERIMENTS.md records `small` runs).
pub fn env_scale() -> Scale {
    std::env::var("PASGAL_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Tiny)
}

/// Simulated processor count for the paper's parallel columns.
pub const SIM_P: usize = 192;

/// The suite with graphs built (and disk-cached under
/// `artifacts/graphs/`).
pub struct BuiltSuite {
    pub entries: Vec<(SuiteEntry, Graph)>,
    pub scale: Scale,
}

impl BuiltSuite {
    pub fn build(scale: Scale) -> BuiltSuite {
        let cache = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .join("graphs");
        let entries = suite()
            .into_iter()
            .map(|e| {
                let g = io::cached_suite_graph(&cache, &e, scale)
                    .unwrap_or_else(|err| panic!("building {}: {err:#}", e.name));
                (e, g)
            })
            .collect();
        BuiltSuite { entries, scale }
    }

    /// Only directed graphs (SCC applies).
    pub fn directed(&self) -> impl Iterator<Item = &(SuiteEntry, Graph)> {
        self.entries.iter().filter(|(e, _)| e.directed)
    }
}

/// Source vertex used for traversal benches (paper uses fixed seeds).
/// Deterministic: among a few candidates (vertex 0, the max-degree
/// hub, and two interior picks), choose the one reaching the most
/// vertices — a sink corner of a directed grid would otherwise make
/// the whole bench trivial.
fn bench_source(g: &Graph) -> V {
    let n = g.n();
    if n == 0 {
        return 0;
    }
    let hub = (0..n as V).max_by_key(|&v| g.degree(v)).unwrap_or(0);
    let candidates = [0 as V, hub, (n / 2) as V, (n / 7) as V];
    candidates
        .into_iter()
        .max_by_key(|&s| {
            crate::algo::bfs::seq_bfs(g, s)
                .iter()
                .filter(|&&d| d != u32::MAX)
                .count()
        })
        .unwrap_or(0)
}

fn speedup_of(trace: &AlgoTrace, model: &CostModel, g: &Graph, p: usize) -> f64 {
    model.seq_time(g.n() as u64, g.m() as u64) / makespan(trace, model, p)
}

// ---------------------------------------------------------------------------
// Table 1/2: graph inventory
// ---------------------------------------------------------------------------

/// Table 1/2: n, m, m', D', D (sampled lower bounds) per suite graph.
pub fn table1_graphs(scale: Scale) -> String {
    let built = BuiltSuite::build(scale);
    let mut t = Table::new(&["graph", "cat", "n", "m'", "m", "D'", "D", "maxdeg"]);
    for (e, g) in &built.entries {
        let sym = if g.symmetric { g.clone() } else { g.symmetrize() };
        let s_undir = stats::stats(&sym, 3, 0x7a);
        let (d_dir, _) = if e.directed {
            stats::estimate_diameter(g, 3, 0x7b)
        } else {
            (s_undir.diameter_lb, 0)
        };
        t.row(vec![
            e.name.to_string(),
            e.category.label().to_string(),
            g.n().to_string(),
            if e.directed {
                g.m().to_string()
            } else {
                "N/A".into()
            },
            sym.m().to_string(),
            if e.directed {
                d_dir.to_string()
            } else {
                "N/A".into()
            },
            s_undir.diameter_lb.to_string(),
            s_undir.max_degree.to_string(),
        ]);
    }
    format!(
        "Table 1/2 analog — graph inventory at scale `{}`\n(D, D' are sampled lower bounds, as in the paper)\n\n{}",
        scale.label(),
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Shared table scaffolding for Tables 3-5
// ---------------------------------------------------------------------------

struct Contender {
    name: &'static str,
    /// Run and return (wallclock seconds, optional trace).
    run: Box<dyn Fn(&Graph, V) -> (f64, Option<AlgoTrace>)>,
}

fn run_table(
    title: &str,
    _built: &BuiltSuite,
    graphs: Vec<(&SuiteEntry, Graph)>,
    contenders: Vec<Contender>,
    seq_name: &str,
    seq_run: Box<dyn Fn(&Graph, V) -> f64>,
) -> String {
    let model = CostModel::default();
    let mut header: Vec<String> = vec!["graph".into(), "cat".into()];
    for c in &contenders {
        header.push(format!("{}(t1core)", c.name));
        header.push(format!("{}(sim{})", c.name, SIM_P));
    }
    header.push(format!("{seq_name}*"));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&header_refs);

    // Per-category speedup collections for geomean rows.
    let mut per_cat: std::collections::HashMap<&str, Vec<Vec<f64>>> =
        std::collections::HashMap::new();

    for (e, g) in &graphs {
        let src = bench_source(g);
        let mut cells = vec![e.name.to_string(), e.category.label().to_string()];
        let seq_secs = seq_run(g, src);
        let mut sims: Vec<f64> = Vec::new();
        for c in &contenders {
            let (secs, trace) = (c.run)(g, src);
            let sim = trace
                .as_ref()
                .map(|tr| makespan(tr, &model, SIM_P) / 1e9)
                .unwrap_or(f64::NAN);
            sims.push(sim);
            cells.push(fmt_duration(std::time::Duration::from_secs_f64(secs)));
            cells.push(fmt_duration(std::time::Duration::from_secs_f64(
                sim.max(1e-9),
            )));
        }
        cells.push(fmt_duration(std::time::Duration::from_secs_f64(seq_secs)));
        t.row(cells);
        per_cat
            .entry(e.category.label())
            .or_default()
            .push(sims.iter().map(|s| seq_secs / s.max(1e-12)).collect());
    }

    // Geomean simulated-speedup-over-sequential per category.
    let mut g_table = Table::new(
        &std::iter::once("geomean speedup")
            .chain(contenders.iter().map(|c| c.name))
            .collect::<Vec<_>>(),
    );
    for cat in ["Social", "Web", "Road", "kNN", "Synthetic"] {
        if let Some(rows) = per_cat.get(cat) {
            let mut cells = vec![cat.to_string()];
            for i in 0..contenders.len() {
                let xs: Vec<f64> = rows.iter().map(|r| r[i]).collect();
                cells.push(format!("{:.2}x", geomean(&xs)));
            }
            g_table.row(cells);
        }
    }

    format!(
        "{title}\n(t1core = measured wall-clock on this 1-core box; sim{SIM_P} = \
trace replayed on {SIM_P} virtual processors; geomeans are simulated \
speedup over the sequential baseline)\n\n{}\n{}",
        t.render(),
        g_table.render()
    )
}

// ---------------------------------------------------------------------------
// Table 5: BFS
// ---------------------------------------------------------------------------

/// Table 5: BFS running times (PASGAL vs GBBS-like vs GAPBS-like vs
/// queue-based sequential).
pub fn table5_bfs(scale: Scale) -> String {
    let built = BuiltSuite::build(scale);
    let graphs: Vec<(&SuiteEntry, Graph)> =
        built.entries.iter().map(|(e, g)| (e, g.clone())).collect();
    let contenders = vec![
        Contender {
            name: "PASGAL",
            run: Box::new(|g: &Graph, src| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| bfs::vgc_bfs(g, src, 512, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
        Contender {
            name: "GBBS",
            run: Box::new(|g: &Graph, src| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| bfs::frontier_bfs(g, src, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
        Contender {
            name: "GAPBS",
            run: Box::new(|g: &Graph, src| {
                let mut tr = AlgoTrace::new();
                let gt = if g.symmetric { None } else { Some(g.transpose()) };
                let (_, d) =
                    time_once(|| bfs::diropt_bfs(g, gt.as_ref().or(Some(g)), src, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
    ];
    run_table(
        &format!("Table 5 analog — BFS, scale `{}`", scale.label()),
        &built,
        graphs,
        contenders,
        "Queue",
        Box::new(|g, src| time_once(|| bfs::seq_bfs(g, src)).1.as_secs_f64()),
    )
}

// ---------------------------------------------------------------------------
// Table 4: SCC
// ---------------------------------------------------------------------------

/// Table 4: SCC running times (PASGAL vs GBBS-like BGSS vs Multistep
/// vs Tarjan).
pub fn table4_scc(scale: Scale) -> String {
    let built = BuiltSuite::build(scale);
    let graphs: Vec<(&SuiteEntry, Graph)> = built
        .directed()
        .map(|(e, g)| (e, g.clone()))
        .collect();
    let contenders = vec![
        Contender {
            name: "PASGAL",
            run: Box::new(|g: &Graph, _| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| scc::vgc_scc(g, None, 512, 42, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
        Contender {
            name: "GBBS",
            run: Box::new(|g: &Graph, _| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| scc::bgss_scc(g, None, 42, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
        Contender {
            name: "Multistep",
            run: Box::new(|g: &Graph, _| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| scc::multistep_scc(g, None, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
    ];
    run_table(
        &format!("Table 4 analog — SCC, scale `{}`", scale.label()),
        &built,
        graphs,
        contenders,
        "Tarjan",
        Box::new(|g, _| time_once(|| scc::tarjan_scc(g)).1.as_secs_f64()),
    )
}

// ---------------------------------------------------------------------------
// Table 3: BCC
// ---------------------------------------------------------------------------

/// Table 3: BCC running times (PASGAL FAST-BCC vs GBBS-like vs
/// Tarjan-Vishkin vs Hopcroft-Tarjan) + aux-space column.
pub fn table3_bcc(scale: Scale) -> String {
    let built = BuiltSuite::build(scale);
    // BCC runs on the symmetrized graphs (as in the paper).
    let graphs: Vec<(&SuiteEntry, Graph)> = built
        .entries
        .iter()
        .map(|(e, g)| {
            let sym = if g.symmetric { g.clone() } else { g.symmetrize() };
            (e, sym)
        })
        .collect();
    let contenders = vec![
        Contender {
            name: "PASGAL",
            run: Box::new(|g: &Graph, _| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| bcc::fast_bcc(g, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
        Contender {
            name: "GBBS",
            run: Box::new(|g: &Graph, _| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| bcc::gbbs_bcc(g, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
        Contender {
            name: "TV",
            run: Box::new(|g: &Graph, _| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| bcc::tarjan_vishkin(g, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
    ];
    let mut out = run_table(
        &format!("Table 3 analog — BCC, scale `{}`", scale.label()),
        &built,
        graphs.clone(),
        contenders,
        "HT",
        Box::new(|g, _| time_once(|| bcc::hopcroft_tarjan(g)).1.as_secs_f64()),
    );

    // Space story: Tarjan-Vishkin's O(m) aux vs FAST-BCC's O(n).
    let mut space = Table::new(&["graph", "n", "m", "FAST-BCC aux", "TV aux", "ratio"]);
    for (e, g) in graphs.iter().take(8) {
        let fast = bcc::fast_bcc(g, None).aux_bytes;
        let tv = bcc::tarjan_vishkin(g, None).aux_bytes;
        space.row(vec![
            e.name.to_string(),
            g.n().to_string(),
            g.m().to_string(),
            format!("{} KB", fast / 1024),
            format!("{} KB", tv / 1024),
            format!("{:.1}x", tv as f64 / fast.max(1) as f64),
        ]);
    }
    out.push_str("\nAuxiliary space (the paper's o.o.m. column for TV):\n\n");
    out.push_str(&space.render());
    out
}

// ---------------------------------------------------------------------------
// SSSP table (paper §2.2; no table in the 4-pager, evaluated here)
// ---------------------------------------------------------------------------

/// SSSP running times (ρ-stepping/VGC vs Δ-stepping vs Dijkstra).
pub fn table_sssp(scale: Scale) -> String {
    let built = BuiltSuite::build(scale);
    let graphs: Vec<(&SuiteEntry, Graph)> = built
        .entries
        .iter()
        .map(|(e, g)| {
            let w = if g.weights().is_some() {
                g.clone()
            } else {
                crate::graph::gen::with_random_weights(g, 0x5e)
            };
            (e, w)
        })
        .collect();
    let contenders = vec![
        Contender {
            name: "PASGAL-rho",
            run: Box::new(|g: &Graph, src| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| sssp::rho_stepping(g, src, 512, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
        Contender {
            name: "Delta",
            run: Box::new(|g: &Graph, src| {
                let mut tr = AlgoTrace::new();
                let (_, d) = time_once(|| sssp::delta_stepping(g, src, None, Some(&mut tr)));
                (d.as_secs_f64(), Some(tr))
            }),
        },
    ];
    run_table(
        &format!("SSSP (paper §2.2) — scale `{}`", scale.label()),
        &built,
        graphs,
        contenders,
        "Dijkstra",
        Box::new(|g, src| time_once(|| sssp::dijkstra(g, src)).1.as_secs_f64()),
    )
}

// ---------------------------------------------------------------------------
// Fig. 1: SCC speedup vs processor count
// ---------------------------------------------------------------------------

/// Fig. 1: simulated SCC speedup over Tarjan for P in 1..=192 on two
/// small-diameter and two large-diameter graphs.
pub fn fig1_scc_scalability(scale: Scale) -> String {
    let built = BuiltSuite::build(scale);
    let model = CostModel::default();
    let picks = ["LJ", "SD", "AF", "REC"]; // social, web, road, grid
    let ps = [1usize, 2, 4, 8, 16, 32, 64, 96, 192];
    let mut out = format!(
        "Fig. 1 analog — SCC speedup vs #processors (simulated), scale `{}`\n\
(speedup over the modeled sequential Tarjan; the paper's shape: baselines\n\
flatten/invert on large-diameter graphs, PASGAL keeps scaling)\n\n",
        scale.label()
    );
    for name in picks {
        let Some((e, g)) = built.entries.iter().find(|(e, _)| e.name == name) else {
            continue;
        };
        if !e.directed {
            continue;
        }
        let mut traces: Vec<(&str, AlgoTrace)> = Vec::new();
        let mut tr = AlgoTrace::new();
        scc::vgc_scc(g, None, 512, 42, Some(&mut tr));
        traces.push(("PASGAL", tr));
        let mut tr = AlgoTrace::new();
        scc::bgss_scc(g, None, 42, Some(&mut tr));
        traces.push(("GBBS", tr));
        let mut tr = AlgoTrace::new();
        scc::multistep_scc(g, None, Some(&mut tr));
        traces.push(("Multistep", tr));

        let mut t = Table::new(
            &std::iter::once("P")
                .chain(traces.iter().map(|(n, _)| *n))
                .chain(std::iter::once("Tarjan"))
                .collect::<Vec<_>>(),
        );
        for &p in &ps {
            let mut cells = vec![p.to_string()];
            for (_, tr) in &traces {
                cells.push(format!("{:.2}", speedup_of(tr, &model, g, p)));
            }
            cells.push("1.00".into());
            t.row(cells);
        }
        out.push_str(&format!(
            "--- {} ({}; n={}, m={}) ---\n{}\n",
            name,
            e.category.label(),
            g.n(),
            g.m(),
            t.render()
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Fig. 2: speedup bars for SCC / BCC / BFS over all graphs
// ---------------------------------------------------------------------------

/// Fig. 2: simulated speedup over the sequential baseline at 192
/// virtual processors, for every suite graph and problem.
pub fn fig2_speedup(scale: Scale) -> String {
    let built = BuiltSuite::build(scale);
    let model = CostModel::default();
    let mut out = format!(
        "Fig. 2 analog — speedup over sequential at {SIM_P} simulated processors, \
scale `{}`\n(values < 1.0 mean the parallel algorithm loses to sequential — \
the paper's bars below the line)\n\n",
        scale.label()
    );

    // SCC (directed graphs only).
    let mut t = Table::new(&["graph", "cat", "PASGAL", "GBBS", "Multistep"]);
    for (e, g) in built.directed() {
        let mut row = vec![e.name.to_string(), e.category.label().to_string()];
        for f in [
            |g: &Graph, tr: &mut AlgoTrace| {
                scc::vgc_scc(g, None, 512, 42, Some(tr));
            },
            |g: &Graph, tr: &mut AlgoTrace| {
                scc::bgss_scc(g, None, 42, Some(tr));
            },
            |g: &Graph, tr: &mut AlgoTrace| {
                scc::multistep_scc(g, None, Some(tr));
            },
        ] {
            let mut tr = AlgoTrace::new();
            f(g, &mut tr);
            row.push(format!("{:.2}", speedup_of(&tr, &model, g, SIM_P)));
        }
        t.row(row);
    }
    out.push_str(&format!("== SCC ==\n{}\n", t.render()));

    // BCC (symmetrized).
    let mut t = Table::new(&["graph", "cat", "PASGAL", "GBBS", "TV"]);
    for (e, g) in &built.entries {
        let sym = if g.symmetric { g.clone() } else { g.symmetrize() };
        let mut row = vec![e.name.to_string(), e.category.label().to_string()];
        for f in [
            |g: &Graph, tr: &mut AlgoTrace| {
                bcc::fast_bcc(g, Some(tr));
            },
            |g: &Graph, tr: &mut AlgoTrace| {
                bcc::gbbs_bcc(g, Some(tr));
            },
            |g: &Graph, tr: &mut AlgoTrace| {
                bcc::tarjan_vishkin(g, Some(tr));
            },
        ] {
            let mut tr = AlgoTrace::new();
            f(&sym, &mut tr);
            row.push(format!("{:.2}", speedup_of(&tr, &model, &sym, SIM_P)));
        }
        t.row(row);
    }
    out.push_str(&format!("== BCC ==\n{}\n", t.render()));

    // BFS.
    let mut t = Table::new(&["graph", "cat", "PASGAL", "GBBS", "GAPBS"]);
    for (e, g) in &built.entries {
        let src = bench_source(g);
        let mut row = vec![e.name.to_string(), e.category.label().to_string()];
        let mut tr = AlgoTrace::new();
        bfs::vgc_bfs(g, src, 512, Some(&mut tr));
        row.push(format!("{:.2}", speedup_of(&tr, &model, g, SIM_P)));
        let mut tr = AlgoTrace::new();
        bfs::frontier_bfs(g, src, Some(&mut tr));
        row.push(format!("{:.2}", speedup_of(&tr, &model, g, SIM_P)));
        let mut tr = AlgoTrace::new();
        let gt = if g.symmetric { None } else { Some(g.transpose()) };
        bfs::diropt_bfs(g, gt.as_ref().or(Some(g)), src, Some(&mut tr));
        row.push(format!("{:.2}", speedup_of(&tr, &model, g, SIM_P)));
        t.row(row);
    }
    out.push_str(&format!("== BFS ==\n{}\n", t.render()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn built_suite_caches_and_builds() {
        let b = BuiltSuite::build(Scale::Tiny);
        assert_eq!(b.entries.len(), 22);
        assert!(b.directed().count() >= 10);
    }

    #[test]
    fn bench_source_reaches_everything_when_possible() {
        // Star: every candidate reaches all; any pick is acceptable.
        let g = crate::graph::gen::star(10).symmetrize();
        let s = bench_source(&g);
        let reached = crate::algo::bfs::seq_bfs(&g, s)
            .iter()
            .filter(|&&d| d != u32::MAX)
            .count();
        assert_eq!(reached, g.n());
        // Directed grid: must NOT pick a sink corner.
        let g = crate::graph::gen::grid(8, 8);
        let s = bench_source(&g);
        assert_eq!(s, 0, "only vertex 0 reaches the whole directed grid");
    }
}
