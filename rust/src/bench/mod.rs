//! In-tree benchmark harness (criterion is unavailable offline).
//!
//! [`time_once`]/[`bench`] measure wall-clock; [`Table`] prints the
//! aligned paper-style tables; [`suite`] holds the code that
//! regenerates every table and figure of the paper's evaluation
//! (shared between `cargo bench` targets and the `pasgal` CLI).

pub mod suite;
pub mod trajectory;

use std::time::{Duration, Instant};

/// Parse a `usize` bench knob from the environment (the ablation
/// benches use these for CI smoke-sized overrides).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Wall-clock one call.
pub fn time_once<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Statistics over repeated timings.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub reps: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

/// Run `f` `reps` times (after one warmup) and report stats.
pub fn bench<R>(reps: usize, mut f: impl FnMut() -> R) -> BenchStats {
    std::hint::black_box(f()); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    BenchStats {
        reps: times.len(),
        mean: times.iter().sum::<Duration>() / times.len() as u32,
        min: times.iter().min().copied().unwrap(),
        max: times.iter().max().copied().unwrap(),
    }
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Simple aligned-column table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render with right-aligned numeric columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("  {:>w$}", cells[i], w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format a duration in adaptive units.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_reps() {
        let s = bench(3, || 1 + 1);
        assert_eq!(s.reps, 3);
        assert!(s.min <= s.mean && s.mean <= s.max.max(s.mean));
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[5.0]) - 5.0).abs() < 1e-9);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Graph", "A", "B"]);
        t.row(vec!["LJ".into(), "0.1".into(), "12.5".into()]);
        t.row(vec!["ROADLONG".into(), "3".into(), "4".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("Graph"));
        assert!(lines[2].starts_with("LJ"));
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.00ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7us");
    }
}
