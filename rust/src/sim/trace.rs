//! Execution traces: what a parallel algorithm did, round by round.
//!
//! Algorithms record, for every synchronized round, the cost of each
//! parallel task (vertices expanded, edges scanned). Recording happens
//! inside parallel loops via pre-sized slot vectors (one slot per
//! task), so it is data-race free and nearly free when disabled.

use crate::parallel::vgc::SearchStats;

/// Cost of one parallel task within a round.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TaskCost {
    pub vertices: u64,
    pub edges: u64,
}

impl From<SearchStats> for TaskCost {
    fn from(s: SearchStats) -> Self {
        TaskCost {
            vertices: s.vertices,
            edges: s.edges,
        }
    }
}

/// One synchronized parallel round.
#[derive(Debug, Clone, Default)]
pub struct RoundTrace {
    pub tasks: Vec<TaskCost>,
}

impl RoundTrace {
    pub fn total_vertices(&self) -> u64 {
        self.tasks.iter().map(|t| t.vertices).sum()
    }

    pub fn total_edges(&self) -> u64 {
        self.tasks.iter().map(|t| t.edges).sum()
    }
}

/// A whole algorithm execution.
#[derive(Debug, Clone, Default)]
pub struct AlgoTrace {
    pub rounds: Vec<RoundTrace>,
}

impl AlgoTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a round from per-task stats, dropping empty tasks
    /// (chunks that found nothing claimable do no scheduling work
    /// worth modeling beyond the spawn cost — we keep them: a spawned
    /// no-op still pays the spawn cost, which is the paper's point).
    pub fn push_round(&mut self, tasks: Vec<TaskCost>) {
        self.rounds.push(RoundTrace { tasks });
    }

    /// Number of synchronized rounds (the paper's O(D) bottleneck).
    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Total work in vertex/edge units.
    pub fn total(&self) -> TaskCost {
        let mut t = TaskCost::default();
        for r in &self.rounds {
            t.vertices += r.total_vertices();
            t.edges += r.total_edges();
        }
        t
    }

    /// Vertices expanded by the busiest round (peak frontier size for
    /// frontier-synchronized algorithms).
    pub fn peak_round_vertices(&self) -> u64 {
        self.rounds
            .iter()
            .map(|r| r.total_vertices())
            .max()
            .unwrap_or(0)
    }

    /// Total parallel tasks spawned across all rounds — under VGC each
    /// task is one local search, so this counts local-search steps.
    pub fn total_tasks(&self) -> u64 {
        self.rounds.iter().map(|r| r.tasks.len() as u64).sum()
    }

    /// Largest single-task cost (span lower bound within rounds).
    pub fn max_task_edges(&self) -> u64 {
        self.rounds
            .iter()
            .flat_map(|r| r.tasks.iter())
            .map(|t| t.vertices + t.edges)
            .max()
            .unwrap_or(0)
    }
}

/// Optional recorder threaded through the algorithms: `None` in
/// production runs costs one branch per round.
pub type Recorder<'a> = Option<&'a mut AlgoTrace>;

/// Concurrent per-task stat slots for one round: each parallel chunk
/// writes its own slot; `finish` turns them into a round record.
pub struct RoundSlots {
    slots: Vec<std::sync::atomic::AtomicU64>,
}

impl RoundSlots {
    /// `tasks` slots, all zero. Each slot packs (vertices<<32|edges)
    /// capped at u32::MAX each — ample for per-task counts.
    pub fn new(tasks: usize) -> Self {
        RoundSlots {
            slots: (0..tasks)
                .map(|_| std::sync::atomic::AtomicU64::new(0))
                .collect(),
        }
    }

    /// Record task `i`'s cost.
    pub fn set(&self, i: usize, cost: TaskCost) {
        let packed = (cost.vertices.min(u32::MAX as u64) << 32)
            | cost.edges.min(u32::MAX as u64);
        self.slots[i].store(packed, std::sync::atomic::Ordering::Relaxed);
    }

    /// Convert to a round record.
    pub fn into_round(self) -> Vec<TaskCost> {
        self.slots
            .into_iter()
            .map(|s| {
                let p = s.into_inner();
                TaskCost {
                    vertices: p >> 32,
                    edges: p & 0xFFFF_FFFF,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_accumulate() {
        let mut t = AlgoTrace::new();
        t.push_round(vec![
            TaskCost {
                vertices: 2,
                edges: 5,
            },
            TaskCost {
                vertices: 1,
                edges: 3,
            },
        ]);
        t.push_round(vec![TaskCost {
            vertices: 4,
            edges: 0,
        }]);
        assert_eq!(t.num_rounds(), 2);
        assert_eq!(
            t.total(),
            TaskCost {
                vertices: 7,
                edges: 8
            }
        );
        assert_eq!(t.max_task_edges(), 7);
    }

    #[test]
    fn round_slots_pack_unpack() {
        let slots = RoundSlots::new(3);
        slots.set(
            0,
            TaskCost {
                vertices: 10,
                edges: 20,
            },
        );
        slots.set(
            2,
            TaskCost {
                vertices: 1,
                edges: 2,
            },
        );
        let round = slots.into_round();
        assert_eq!(
            round[0],
            TaskCost {
                vertices: 10,
                edges: 20
            }
        );
        assert_eq!(round[1], TaskCost::default());
        assert_eq!(
            round[2],
            TaskCost {
                vertices: 1,
                edges: 2
            }
        );
    }

    #[test]
    fn search_stats_converts() {
        let s = SearchStats {
            vertices: 3,
            edges: 9,
        };
        let t: TaskCost = s.into();
        assert_eq!(
            t,
            TaskCost {
                vertices: 3,
                edges: 9
            }
        );
    }
}
