//! Greedy list scheduling of a recorded trace onto P virtual
//! processors.
//!
//! Tasks within a round are independent (that is what a synchronized
//! round means), so the round's makespan under a work-stealing
//! scheduler is well-approximated by greedy list scheduling (Graham:
//! within 2× of optimal; work stealing achieves the same bound in
//! expectation). Between rounds we charge the barrier cost from the
//! model. Processing order: longest task first (LPT) mirrors the
//! steal-half / chunked splitting the real pool does.

use super::model::CostModel;
use super::trace::AlgoTrace;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulated wall-clock (ns) of `trace` on `p` virtual processors.
pub fn makespan(trace: &AlgoTrace, model: &CostModel, p: usize) -> f64 {
    let p = p.max(1);
    let mut total = 0.0f64;
    let mut times: Vec<f64> = Vec::new();
    for round in &trace.rounds {
        if round.tasks.is_empty() {
            total += model.sync_cost(p);
            continue;
        }
        times.clear();
        times.extend(round.tasks.iter().map(|&t| model.task_time(t)));
        // LPT: longest processing time first.
        times.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let span = if p == 1 {
            times.iter().sum::<f64>()
        } else if times.len() <= p {
            times[0]
        } else {
            // Greedy: assign each task to the earliest-free processor.
            let mut heap: BinaryHeap<Reverse<u64>> = (0..p).map(|_| Reverse(0u64)).collect();
            // Work in integer ns to keep the heap Ord.
            for &t in &times {
                let Reverse(earliest) = heap.pop().unwrap();
                heap.push(Reverse(earliest + t.max(0.0) as u64));
            }
            heap.into_iter().map(|Reverse(t)| t).max().unwrap_or(0) as f64
        };
        total += span + model.sync_cost(p);
    }
    total
}

/// Simulated speedup of `trace` at `p` processors over a modeled
/// sequential run touching `seq_vertices`/`seq_edges` once.
pub fn speedup(
    trace: &AlgoTrace,
    model: &CostModel,
    p: usize,
    seq_vertices: u64,
    seq_edges: u64,
) -> f64 {
    let seq = model.seq_time(seq_vertices, seq_edges);
    let par = makespan(trace, model, p);
    seq / par
}

#[cfg(test)]
mod tests {
    use super::super::trace::{AlgoTrace, TaskCost};
    use super::*;

    fn model() -> CostModel {
        CostModel {
            c_task: 100.0,
            c_vertex: 1.0,
            c_edge: 1.0,
            sync_base: 1000.0,
            sync_log: 0.0,
            sync_linear: 0.0,
        }
    }

    fn uniform_round(tasks: usize, edges: u64) -> Vec<TaskCost> {
        (0..tasks)
            .map(|_| TaskCost {
                vertices: 0,
                edges,
            })
            .collect()
    }

    #[test]
    fn perfect_parallelism_divides_work() {
        let mut t = AlgoTrace::new();
        t.push_round(uniform_round(64, 1000));
        let m = model();
        let t1 = makespan(&t, &m, 1);
        let t64 = makespan(&t, &m, 64);
        // 64 equal tasks on 64 procs: span = one task + sync.
        assert!((t64 - (1100.0 + 1000.0)).abs() < 1.0, "t64={t64}");
        assert!(t1 > 60.0 * 1100.0);
    }

    #[test]
    fn more_processors_never_slower_per_round_work() {
        let mut t = AlgoTrace::new();
        for _ in 0..10 {
            t.push_round(uniform_round(37, 313));
        }
        let m = model();
        let mut prev = f64::INFINITY;
        for p in [1, 2, 4, 8, 64] {
            let ms = makespan(&t, &m, p);
            assert!(ms <= prev + 1e-9);
            prev = ms;
        }
    }

    #[test]
    fn sync_cost_dominates_many_empty_rounds() {
        // The paper's large-diameter pathology: D rounds of tiny work.
        let m = CostModel::default();
        let mut many_rounds = AlgoTrace::new();
        for _ in 0..1000 {
            many_rounds.push_round(uniform_round(2, 3));
        }
        let mut one_round = AlgoTrace::new();
        one_round.push_round(uniform_round(2000, 3));
        let p = 96;
        let slow = makespan(&many_rounds, &m, p);
        let fast = makespan(&one_round, &m, p);
        assert!(
            slow > 10.0 * fast,
            "round-bound trace must dominate: {slow} vs {fast}"
        );
    }

    #[test]
    fn round_bound_trace_stops_scaling() {
        // Speedup curve flattens (and inverts) with P when rounds
        // dominate — the Fig. 1 shape for baselines on road graphs.
        let m = CostModel::default();
        let mut t = AlgoTrace::new();
        for _ in 0..5000 {
            t.push_round(uniform_round(4, 8));
        }
        let s1 = speedup(&t, &m, 1, 20_000, 40_000);
        let s192 = speedup(&t, &m, 192, 20_000, 40_000);
        assert!(
            s192 < s1 * 4.0,
            "no linear scaling when round-bound: s1={s1} s192={s192}"
        );
    }

    #[test]
    fn lpt_handles_skewed_tasks() {
        let m = model();
        let mut t = AlgoTrace::new();
        let mut tasks = uniform_round(63, 10);
        tasks.push(TaskCost {
            vertices: 0,
            edges: 100_000,
        });
        t.push_round(tasks);
        // One giant task bounds the round regardless of P.
        let ms = makespan(&t, &m, 64);
        assert!(ms >= 100_000.0);
        assert!(ms < 110_000.0 + 2000.0);
    }

    #[test]
    fn empty_trace_is_zero() {
        let t = AlgoTrace::new();
        assert_eq!(makespan(&t, &CostModel::default(), 8), 0.0);
    }
}
