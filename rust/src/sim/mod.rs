//! Deterministic virtual-multicore simulator.
//!
//! The paper's evaluation runs on a 96-core (192 hyperthread) machine;
//! this environment has one core. The phenomenon the paper studies —
//! *scheduling/synchronization overhead per round vs. useful work per
//! round* — is a property of the algorithm's task structure, so we
//! reproduce the scalability experiments by (1) instrumenting each
//! parallel algorithm to record its per-round task costs
//! ([`trace::AlgoTrace`]) and (2) replaying that trace on P virtual
//! processors under a calibrated cost model ([`model::CostModel`],
//! greedy list scheduling in [`sched`]).
//!
//! What this preserves and what it does not (DESIGN.md §1): speedup
//! *shapes* — round-bound flattening on large-diameter graphs, VGC's
//! round collapse, crossover points — are faithful; absolute times on
//! the authors' Xeon testbed are not claimed.

pub mod model;
pub mod sched;
pub mod trace;

pub use model::CostModel;
pub use sched::makespan;
pub use trace::{AlgoTrace, RoundTrace, TaskCost};
