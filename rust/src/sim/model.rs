//! Cost model for the virtual multicore.
//!
//! All constants are in nanoseconds. Defaults are calibrated on this
//! machine by [`CostModel::calibrate`] (invoked via `pasgal
//! calibrate`), which measures the *actual* per-edge scan cost and the
//! actual spawn/sync overhead of our own pool — the same machinery the
//! real runs use. The per-round barrier grows with log2(P) (tree
//! wakeup/combine), plus a per-processor wake term that models the
//! linear component observed in centralized fork-join barriers.

use crate::graph::gen;
use crate::parallel::{parallel_for, Pool};

/// Nanosecond cost constants for the virtual machine.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Fixed cost to schedule one task (push + steal amortized).
    pub c_task: f64,
    /// Cost per vertex expanded.
    pub c_vertex: f64,
    /// Cost per edge scanned.
    pub c_edge: f64,
    /// Per-round barrier: fixed part.
    pub sync_base: f64,
    /// Per-round barrier: coefficient on log2(P).
    pub sync_log: f64,
    /// Per-round barrier: coefficient on P (wake fan-out).
    pub sync_linear: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Calibrated 2026-07-10 on the container's Xeon-class core via
        // `pasgal calibrate` (measured: c_task=21.8, c_vertex=1.28,
        // c_edge=1.02, sync_base=1628 — see EXPERIMENTS.md
        // §Calibration); sync_log/sync_linear follow fork-join barrier
        // scaling from the literature since a 1-core box cannot
        // measure cross-core wakeup directly (DESIGN.md §1).
        CostModel {
            c_task: 25.0,
            c_vertex: 1.3,
            c_edge: 1.0,
            sync_base: 1_600.0,
            sync_log: 900.0,
            sync_linear: 30.0,
        }
    }
}

impl CostModel {
    /// Barrier cost for one synchronized round at P processors.
    #[inline]
    pub fn sync_cost(&self, p: usize) -> f64 {
        let p = p.max(1) as f64;
        self.sync_base + self.sync_log * p.log2().max(0.0) + self.sync_linear * p
    }

    /// Execution time of one task (ns).
    #[inline]
    pub fn task_time(&self, t: super::trace::TaskCost) -> f64 {
        self.c_task + self.c_vertex * t.vertices as f64 + self.c_edge * t.edges as f64
    }

    /// Modeled sequential time for an algorithm touching `vertices`
    /// and `edges` once with no scheduling overhead.
    #[inline]
    pub fn seq_time(&self, vertices: u64, edges: u64) -> f64 {
        self.c_vertex * vertices as f64 + self.c_edge * edges as f64
    }

    /// Measure c_edge / c_vertex / c_task / sync_base on this machine.
    ///
    /// - c_edge, c_vertex: timed sequential CSR sweep of an RMAT graph.
    /// - c_task: per-task overhead of `parallel_for` with grain 1 over
    ///   no-op bodies, minus the loop's sequential time.
    /// - sync_base: time of an empty `parallel_for` (one fork-join
    ///   round trip through the pool).
    pub fn calibrate(pool: &Pool) -> CostModel {
        let mut m = CostModel::default();
        // --- edge/vertex scan cost ---
        let g = gen::social(14, 16, 0xCA11);
        let n = g.n();
        let reps = 5;
        let t0 = std::time::Instant::now();
        let mut sink = 0u64;
        for _ in 0..reps {
            for v in 0..n as u32 {
                for &u in g.neighbors(v) {
                    sink = sink.wrapping_add(u as u64);
                }
            }
        }
        let per_edge = t0.elapsed().as_nanos() as f64 / (reps * g.m()) as f64;
        std::hint::black_box(sink);
        m.c_edge = per_edge.max(0.3);
        m.c_vertex = 1.25 * m.c_edge; // dist-array touch + claim CAS

        // --- per-task spawn overhead ---
        let tasks = 100_000usize;
        let t0 = std::time::Instant::now();
        pool.run(|| {
            parallel_for(0, tasks, 1, |i| {
                std::hint::black_box(i);
            });
        });
        let par = t0.elapsed().as_nanos() as f64;
        m.c_task = (par / tasks as f64).max(20.0);

        // --- per-round barrier ---
        let rounds = 2_000usize;
        let t0 = std::time::Instant::now();
        for _ in 0..rounds {
            pool.run(|| {
                parallel_for(0, 1, 1, |i| {
                    std::hint::black_box(i);
                });
            });
        }
        m.sync_base = (t0.elapsed().as_nanos() as f64 / rounds as f64).max(200.0);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::super::trace::TaskCost;
    use super::*;

    #[test]
    fn sync_cost_monotone_in_p() {
        let m = CostModel::default();
        let mut prev = 0.0;
        for p in [1usize, 2, 4, 16, 96, 192] {
            let c = m.sync_cost(p);
            assert!(c > prev, "sync cost must grow with P");
            prev = c;
        }
    }

    #[test]
    fn task_time_linear_in_work() {
        let m = CostModel::default();
        let small = m.task_time(TaskCost {
            vertices: 1,
            edges: 1,
        });
        let big = m.task_time(TaskCost {
            vertices: 1000,
            edges: 1000,
        });
        assert!(big > small * 5.0);
        // Fixed overhead dominates tiny tasks — the paper's premise.
        assert!(m.c_task > m.c_vertex + m.c_edge);
    }

    #[test]
    fn calibrate_produces_sane_constants() {
        let pool = Pool::new(2);
        let m = CostModel::calibrate(&pool);
        assert!(m.c_edge > 0.1 && m.c_edge < 100.0, "c_edge={}", m.c_edge);
        assert!(m.c_task >= 20.0 && m.c_task < 100_000.0, "c_task={}", m.c_task);
        assert!(m.sync_base >= 200.0, "sync_base={}", m.sync_base);
    }
}
