//! Dense-kernel runtime: load and execute the AOT-lowered dense
//! kernel configurations.
//!
//! `make artifacts` lowers the L2 JAX graphs (which call the L1 Pallas
//! tropical-semiring kernels) to HLO *text* under `artifacts/`, plus a
//! `manifest.txt` inventory (see `python/compile/aot.py`). This module
//! loads the manifest and exposes a typed execute-many API to the
//! coordinator's hot path; execution runs on the portable in-tree
//! interpreter (see [`engine`] — the offline crate set has no PJRT
//! bindings, so the reference kernels that unit-test the PJRT path
//! also serve as its stand-in backend). Python never runs here.

mod dense;
mod engine;
mod handle;
mod manifest;

pub use dense::{closure_ref, closure_ref_into, relax_ref, relax_ref_into, DenseTile};
pub use engine::{DenseEngine, DenseScratch, RelaxSpec};
pub use handle::EngineHandle;
pub use manifest::{Artifact, ArtifactKind, Manifest};

/// Sentinel infinite distance — must match `kernels/minplus.py::INF`.
pub const INF: f32 = crate::INF;

/// Object-safe closure executor: implemented by the same-thread
/// [`DenseEngine`] and the cross-thread [`EngineHandle`], so callers
/// (e.g. [`crate::coordinator::DenseBlock`]) are agnostic.
pub trait TileExecutor {
    /// All-pairs closure of one tile (output `c[u*t+v]` = dist v->u).
    fn closure_exec(&self, tile: &DenseTile) -> crate::error::Result<Vec<f32>>;
    /// Tile sizes with a compiled closure module.
    fn closure_sizes(&self) -> Vec<usize>;
}

impl TileExecutor for DenseEngine {
    fn closure_exec(&self, tile: &DenseTile) -> crate::error::Result<Vec<f32>> {
        self.closure(tile)
    }
    fn closure_sizes(&self) -> Vec<usize> {
        self.closure_tiles()
    }
}

impl TileExecutor for EngineHandle {
    fn closure_exec(&self, tile: &DenseTile) -> crate::error::Result<Vec<f32>> {
        self.closure(tile)
    }
    fn closure_sizes(&self) -> Vec<usize> {
        self.closure_tiles()
    }
}
