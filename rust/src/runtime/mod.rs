//! PJRT runtime: load and execute the AOT-compiled dense kernels.
//!
//! `make artifacts` lowers the L2 JAX graphs (which call the L1 Pallas
//! tropical-semiring kernels) to HLO *text* under `artifacts/`. This
//! module loads that text with [`xla::HloModuleProto::from_text_file`],
//! compiles each module once on the PJRT CPU client, and exposes a
//! typed execute-many API to the coordinator's hot path. Python never
//! runs here.
//!
//! Artifact inventory comes from `artifacts/manifest.txt`, a line-based
//! `key value` format (see `python/compile/aot.py`).

mod dense;
mod engine;
mod handle;
mod manifest;

pub use dense::{closure_ref, relax_ref, DenseTile};
pub use engine::{DenseEngine, RelaxSpec};
pub use handle::EngineHandle;
pub use manifest::{Artifact, ArtifactKind, Manifest};

/// Sentinel infinite distance — must match `kernels/minplus.py::INF`.
pub const INF: f32 = crate::INF;

/// Object-safe closure executor: implemented by the same-thread
/// [`DenseEngine`] and the cross-thread [`EngineHandle`], so callers
/// (e.g. [`crate::coordinator::DenseBlock`]) are agnostic.
pub trait TileExecutor {
    /// All-pairs closure of one tile (output `c[u*t+v]` = dist v->u).
    fn closure_exec(&self, tile: &DenseTile) -> anyhow::Result<Vec<f32>>;
    /// Tile sizes with a compiled closure module.
    fn closure_sizes(&self) -> Vec<usize>;
}

impl TileExecutor for DenseEngine {
    fn closure_exec(&self, tile: &DenseTile) -> anyhow::Result<Vec<f32>> {
        self.closure(tile)
    }
    fn closure_sizes(&self) -> Vec<usize> {
        self.closure_tiles()
    }
}

impl TileExecutor for EngineHandle {
    fn closure_exec(&self, tile: &DenseTile) -> anyhow::Result<Vec<f32>> {
        self.closure(tile)
    }
    fn closure_sizes(&self) -> Vec<usize> {
        self.closure_tiles()
    }
}
