//! Dense tiles + pure-Rust reference semantics for the PJRT kernels.
//!
//! [`DenseTile`] is the exchange format between the sparse graph world
//! (CSR subgraphs extracted by the coordinator) and the dense kernel
//! world (fixed-size f32 tiles the AOT modules expect). The `_ref`
//! functions are the oracle the PJRT path is integration-tested
//! against — and double as a fallback when artifacts are absent.

use crate::INF;

/// A t×t dense adjacency tile in the kernels' *panel convention*:
/// `w[u * t + v]` is the weight of edge `v -> u` (transposed adjacency)
/// so that one relaxation step is `d[u] = min_v w[u][v] + d[v]`.
#[derive(Debug, Clone)]
pub struct DenseTile {
    t: usize,
    w: Vec<f32>,
}

impl DenseTile {
    /// A tile with no edges (all INF) and zero diagonal.
    pub fn empty(t: usize) -> Self {
        let mut w = vec![INF; t * t];
        for i in 0..t {
            w[i * t + i] = 0.0;
        }
        DenseTile { t, w }
    }

    /// Build from explicit row-major panel data (`w[u*t+v] = w(v->u)`).
    pub fn from_raw(t: usize, w: Vec<f32>) -> Self {
        assert_eq!(w.len(), t * t, "tile data must be t*t");
        DenseTile { t, w }
    }

    /// Tile edge length.
    pub fn size(&self) -> usize {
        self.t
    }

    /// Raw panel data (row-major, length t*t).
    pub fn raw(&self) -> &[f32] {
        &self.w
    }

    /// Record a directed edge `from -> to` of weight `weight`
    /// (keeping the minimum on multi-edges).
    pub fn add_edge(&mut self, from: usize, to: usize, weight: f32) {
        assert!(from < self.t && to < self.t);
        let slot = &mut self.w[to * self.t + from];
        if weight < *slot {
            *slot = weight;
        }
    }

    /// Weight of edge `from -> to` (INF when absent).
    pub fn edge(&self, from: usize, to: usize) -> f32 {
        self.w[to * self.t + from]
    }
}

/// Pure-Rust reference of the L1 `multihop_relax` kernel: `hops`
/// rounds of `d[u] <- min(d[u], min_v w(v->u) + d[v])` over a
/// multi-source panel `dist[v * s + j]` (row-major, s sources).
pub fn relax_ref(tile: &DenseTile, dist: &[f32], sources: usize, hops: usize) -> Vec<f32> {
    let mut out = Vec::new();
    let mut tmp = Vec::new();
    relax_ref_into(tile, dist, sources, hops, &mut out, &mut tmp);
    out
}

/// [`relax_ref`] into caller-owned buffers: the result lands in `out`,
/// `tmp` is the double-buffer temporary. Warm calls allocate nothing.
pub fn relax_ref_into(
    tile: &DenseTile,
    dist: &[f32],
    sources: usize,
    hops: usize,
    out: &mut Vec<f32>,
    tmp: &mut Vec<f32>,
) {
    let t = tile.t;
    assert_eq!(dist.len(), t * sources, "panel must be t*s");
    out.clear();
    out.extend_from_slice(dist);
    tmp.clear();
    tmp.resize(dist.len(), 0.0);
    for _ in 0..hops {
        for u in 0..t {
            for j in 0..sources {
                let mut best = out[u * sources + j];
                for v in 0..t {
                    let w = tile.w[u * t + v];
                    if w < INF {
                        let cand = w + out[v * sources + j];
                        if cand < best {
                            best = cand;
                        }
                    }
                }
                tmp[u * sources + j] = best;
            }
        }
        std::mem::swap(out, tmp);
    }
}

/// Pure-Rust reference of the L2 `tile_closure` graph: all-pairs
/// shortest distances within the tile (Floyd–Warshall on the panel
/// convention; output `c[u*t+v]` = shortest distance `v -> u`,
/// matching the artifact's output layout).
pub fn closure_ref(tile: &DenseTile) -> Vec<f32> {
    let mut out = Vec::new();
    closure_ref_into(tile, &mut out);
    out
}

/// [`closure_ref`] into a caller-owned buffer (reused storage).
pub fn closure_ref_into(tile: &DenseTile, out: &mut Vec<f32>) {
    let t = tile.t;
    out.clear();
    out.extend_from_slice(&tile.w);
    let d = out;
    for i in 0..t {
        if d[i * t + i] > 0.0 {
            d[i * t + i] = 0.0;
        }
    }
    for k in 0..t {
        for u in 0..t {
            let duk = d[u * t + k];
            if duk >= INF {
                continue;
            }
            for v in 0..t {
                let cand = duk + d[k * t + v];
                if cand < d[u * t + v] {
                    d[u * t + v] = cand;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_tile(t: usize) -> DenseTile {
        let mut tile = DenseTile::empty(t);
        for v in 0..t - 1 {
            tile.add_edge(v, v + 1, 1.0);
        }
        tile
    }

    #[test]
    fn empty_tile_has_zero_diag_inf_off() {
        let t = DenseTile::empty(4);
        assert_eq!(t.edge(2, 2), 0.0);
        assert_eq!(t.edge(0, 1), INF);
    }

    #[test]
    fn add_edge_keeps_minimum() {
        let mut t = DenseTile::empty(4);
        t.add_edge(0, 1, 5.0);
        t.add_edge(0, 1, 3.0);
        t.add_edge(0, 1, 9.0);
        assert_eq!(t.edge(0, 1), 3.0);
    }

    #[test]
    fn relax_ref_chain_hop_semantics() {
        let t = 8;
        let tile = chain_tile(t);
        let mut dist = vec![INF; t];
        dist[0] = 0.0;
        for hops in [1usize, 3, 7] {
            let out = relax_ref(&tile, &dist, 1, hops);
            let reached = out.iter().filter(|&&d| d < INF).count();
            assert_eq!(reached, hops + 1);
            // distances along the chain are exact hop counts
            for (v, &d) in out.iter().enumerate().take(hops + 1) {
                assert_eq!(d, v as f32);
            }
        }
    }

    #[test]
    fn relax_ref_multi_source_panel() {
        let t = 6;
        let tile = chain_tile(t);
        let s = 2;
        let mut dist = vec![INF; t * s];
        dist[0 * s + 0] = 0.0; // source 0 at vertex 0
        dist[3 * s + 1] = 0.0; // source 1 at vertex 3
        let out = relax_ref(&tile, &dist, s, t);
        assert_eq!(out[5 * s + 0], 5.0);
        assert_eq!(out[5 * s + 1], 2.0);
        assert!(out[1 * s + 1] >= INF, "chain is directed; 3 cannot reach 1");
    }

    #[test]
    fn closure_ref_matches_relax_to_convergence() {
        // closure[u*t+v] = dist v->u must equal relaxing a point source.
        let t = 8;
        let mut tile = DenseTile::empty(t);
        // a little dag + a cycle
        tile.add_edge(0, 1, 2.0);
        tile.add_edge(1, 2, 2.0);
        tile.add_edge(2, 0, 2.0);
        tile.add_edge(2, 5, 1.0);
        tile.add_edge(5, 7, 4.0);
        let closure = closure_ref(&tile);
        for src in 0..t {
            let mut dist = vec![INF; t];
            dist[src] = 0.0;
            let out = relax_ref(&tile, &dist, 1, t);
            for u in 0..t {
                assert_eq!(out[u], closure[u * t + src], "src={src} u={u}");
            }
        }
    }

    #[test]
    fn closure_ref_zero_diagonal_even_with_positive_self_loop() {
        let mut tile = DenseTile::empty(3);
        tile.add_edge(1, 1, 7.0);
        let c = closure_ref(&tile);
        assert_eq!(c[1 * 3 + 1], 0.0);
    }
}
