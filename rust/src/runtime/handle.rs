//! Thread-safe handle to the dense engine.
//!
//! The engine gets a dedicated executor thread — the same shape a GPU
//! worker takes in an inference server, and the shape a PJRT backend
//! (whose client types are typically `!Send`) would require. The
//! [`EngineHandle`] is `Send + Sync` and can live inside the
//! coordinator; calls are synchronous RPCs over channels. The executor
//! thread owns a private [`super::DenseScratch`], so repeated dense
//! queries reuse their panel buffers.

use super::dense::DenseTile;
use super::engine::{DenseEngine, DenseScratch, RelaxSpec};
use crate::error::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};

enum Cmd {
    Relax {
        spec: RelaxSpec,
        tile: DenseTile,
        dist: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Closure {
        tile: DenseTile,
        reply: Sender<Result<Vec<f32>>>,
    },
    Info {
        reply: Sender<(Vec<RelaxSpec>, Vec<usize>, u64)>,
    },
    Shutdown,
}

/// Send+Sync handle to an engine running on its own thread.
pub struct EngineHandle {
    tx: Sender<Cmd>,
    // Keep the join handle so drop can reap the thread.
    join: Option<std::thread::JoinHandle<()>>,
}

impl EngineHandle {
    /// Spawn the executor thread, loading all artifacts from `dir`.
    /// Fails (synchronously) if loading/compiling fails.
    pub fn spawn(dir: PathBuf) -> Result<EngineHandle> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pasgal-pjrt".into())
            .spawn(move || {
                let engine = match DenseEngine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut scratch = DenseScratch::new();
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Relax {
                            spec,
                            tile,
                            dist,
                            reply,
                        } => {
                            let _ = reply.send(
                                engine
                                    .relax_with(spec, &tile, &dist, &mut scratch)
                                    .map(|out| out.to_vec()),
                            );
                        }
                        Cmd::Closure { tile, reply } => {
                            let _ = reply.send(
                                engine
                                    .closure_with(&tile, &mut scratch)
                                    .map(|out| out.to_vec()),
                            );
                        }
                        Cmd::Info { reply } => {
                            let _ = reply.send((
                                engine.relax_specs(),
                                engine.closure_tiles(),
                                engine.executions(),
                            ));
                        }
                        Cmd::Shutdown => return,
                    }
                }
            })
            .context("spawning pjrt executor thread")?;
        ready_rx
            .recv()
            .context("pjrt executor thread died during load")??;
        Ok(EngineHandle {
            tx,
            join: Some(join),
        })
    }

    /// Multi-hop relaxation on the executor thread.
    pub fn relax(&self, spec: RelaxSpec, tile: &DenseTile, dist: &[f32]) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Cmd::Relax {
                spec,
                tile: tile.clone(),
                dist: dist.to_vec(),
                reply,
            })
            .context("engine thread gone")?;
        rx.recv().context("engine thread dropped reply")?
    }

    /// Tile closure on the executor thread.
    pub fn closure(&self, tile: &DenseTile) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Cmd::Closure {
                tile: tile.clone(),
                reply,
            })
            .context("engine thread gone")?;
        rx.recv().context("engine thread dropped reply")?
    }

    /// (relax specs, closure tile sizes, execution count).
    pub fn info(&self) -> Result<(Vec<RelaxSpec>, Vec<usize>, u64)> {
        let (reply, rx) = channel();
        self.tx.send(Cmd::Info { reply }).context("engine thread gone")?;
        rx.recv().context("engine thread dropped reply")
    }

    /// Closure tile sizes available.
    pub fn closure_tiles(&self) -> Vec<usize> {
        self.info().map(|(_, c, _)| c).unwrap_or_default()
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{closure_ref, relax_ref};
    use crate::INF;

    fn artifacts_dir() -> PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn handle_roundtrip_matches_reference() {
        let h = EngineHandle::spawn(artifacts_dir()).expect("make artifacts first");
        let (specs, tiles, _) = h.info().unwrap();
        assert!(!specs.is_empty() && !tiles.is_empty());
        let spec = specs[specs.len() - 1];
        let mut tile = DenseTile::empty(spec.tile);
        for v in 0..spec.tile - 1 {
            tile.add_edge(v, v + 1, 1.0);
        }
        let mut dist = vec![INF; spec.tile * spec.sources];
        dist[0] = 0.0;
        let got = h.relax(spec, &tile, &dist).unwrap();
        let want = relax_ref(&tile, &dist, spec.sources, spec.hops);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0));
        }
        let t = tiles[tiles.len() - 1];
        let tile = DenseTile::empty(t);
        let got = h.closure(&tile).unwrap();
        let want = closure_ref(&tile);
        assert_eq!(got.len(), want.len());
    }

    #[test]
    fn handle_is_usable_from_many_threads() {
        let h = std::sync::Arc::new(EngineHandle::spawn(artifacts_dir()).unwrap());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    let tiles = h.closure_tiles();
                    let tile = DenseTile::empty(tiles[0]);
                    let out = h.closure(&tile).unwrap();
                    assert_eq!(out.len(), tiles[0] * tiles[0]);
                });
            }
        });
    }

    #[test]
    fn spawn_fails_cleanly_on_bad_dir() {
        assert!(EngineHandle::spawn(PathBuf::from("/nonexistent")).is_err());
    }
}
