//! The compile-once / execute-many dense engine.
//!
//! One [`DenseEngine`] owns every kernel configuration described by
//! the artifact manifest. Loading parses the manifest exactly once;
//! the coordinator then calls [`DenseEngine::relax`] /
//! [`DenseEngine::closure`] from its hot path with plain `f32` slices.
//!
//! The execution backend is the portable in-tree interpreter
//! ([`relax_ref`] / [`closure_ref`] in [`super::dense`]): the offline
//! crate set has no PJRT bindings, so the AOT `.hlo.txt` artifacts are
//! treated as the *specification* of each module (tile size, sources,
//! hops — recorded in `manifest.txt` by `python/compile/aot.py`) and
//! the tropical-semiring semantics are executed by the reference
//! kernels the PJRT path is unit-tested against. The API shape —
//! manifest-driven spec discovery, execute-many calls, execution
//! counting — is exactly what a PJRT-backed engine exposes, so
//! swapping the backend is a link-time concern, not an API change.
//!
//! For repeated dense queries, [`DenseScratch`] + the `_with` entry
//! points reuse the output/temporary panels across calls (the dense
//! analog of the sparse [`crate::algo::QueryWorkspace`]).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::bail;
use crate::error::{Context, Result};

use super::dense::{closure_ref_into, relax_ref_into, DenseTile};
use super::manifest::{ArtifactKind, Manifest};

/// The static configuration of one compiled relax module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RelaxSpec {
    /// Tile edge length (adjacency is tile×tile).
    pub tile: usize,
    /// Distance-panel width (number of sources per call).
    pub sources: usize,
    /// Hops advanced per execution (baked at lowering time).
    pub hops: usize,
}

/// Reusable panel buffers for the dense execute-many path: hold one
/// per worker and pass it to [`DenseEngine::relax_with`] /
/// [`DenseEngine::closure_with`] to answer repeated dense queries with
/// zero per-call allocation after warm-up.
#[derive(Default)]
pub struct DenseScratch {
    /// Output panel of the last call.
    pub out: Vec<f32>,
    /// Double-buffer temporary for the relaxation sweep.
    tmp: Vec<f32>,
}

impl DenseScratch {
    /// Fresh (cold) scratch.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Dense engine holding all kernel configurations from the manifest.
pub struct DenseEngine {
    relax: Vec<RelaxSpec>,
    closure: Vec<usize>,
    /// Total kernel executions (for coordinator metrics).
    executions: AtomicU64,
}

impl DenseEngine {
    /// Load every artifact described under `dir` (usually
    /// `artifacts/`), registering each module configuration once.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(&manifest)
    }

    /// Register all modules listed in an already-parsed manifest.
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let mut relax = Vec::new();
        let mut closure = Vec::new();
        for art in &manifest.artifacts {
            match art.kind {
                ArtifactKind::Relax => {
                    if art.sources == 0 || art.hops == 0 {
                        bail!("relax artifact {} missing sources/hops", art.name);
                    }
                    relax.push(RelaxSpec {
                        tile: art.tile,
                        sources: art.sources,
                        hops: art.hops,
                    });
                }
                ArtifactKind::Closure => closure.push(art.tile),
            }
        }
        // Largest tiles first so `best_relax` prefers doing more work
        // per launch when several configurations fit.
        relax.sort_by(|a, b| (b.tile, b.hops).cmp(&(a.tile, a.hops)));
        closure.sort_by(|a, b| b.cmp(a));
        closure.dedup();
        Ok(DenseEngine {
            relax,
            closure,
            executions: AtomicU64::new(0),
        })
    }

    /// Specs of all loaded relax modules (largest tile/hops first).
    pub fn relax_specs(&self) -> Vec<RelaxSpec> {
        self.relax.clone()
    }

    /// Tile sizes of all loaded closure modules (largest first).
    pub fn closure_tiles(&self) -> Vec<usize> {
        self.closure.clone()
    }

    /// Number of kernel executions so far.
    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }

    /// Run the relax module matching `spec` exactly: `spec.hops` rounds
    /// of tropical relaxation of the `dist` panel (row-major
    /// `tile × sources`) over `tile`. Returns the relaxed panel.
    pub fn relax(&self, spec: RelaxSpec, tile: &DenseTile, dist: &[f32]) -> Result<Vec<f32>> {
        let mut scratch = DenseScratch::new();
        self.relax_with(spec, tile, dist, &mut scratch)?;
        Ok(std::mem::take(&mut scratch.out))
    }

    /// [`Self::relax`] into reusable scratch: the result is left in
    /// `scratch.out` (also returned as a slice); warm calls allocate
    /// nothing.
    pub fn relax_with<'a>(
        &self,
        spec: RelaxSpec,
        tile: &DenseTile,
        dist: &[f32],
        scratch: &'a mut DenseScratch,
    ) -> Result<&'a [f32]> {
        self.relax
            .iter()
            .find(|s| **s == spec)
            .with_context(|| format!("no relax artifact for {spec:?}"))?;
        if tile.size() != spec.tile {
            bail!("tile size {} != artifact tile {}", tile.size(), spec.tile);
        }
        if dist.len() != spec.tile * spec.sources {
            bail!(
                "panel len {} != tile*sources {}",
                dist.len(),
                spec.tile * spec.sources
            );
        }
        relax_ref_into(
            tile,
            dist,
            spec.sources,
            spec.hops,
            &mut scratch.out,
            &mut scratch.tmp,
        );
        self.executions.fetch_add(1, Ordering::Relaxed);
        Ok(&scratch.out)
    }

    /// Pick the best loaded relax spec for a block of `block_size`
    /// vertices: smallest tile that fits (least padding waste).
    pub fn best_relax(&self, block_size: usize) -> Option<RelaxSpec> {
        self.relax
            .iter()
            .copied()
            .filter(|s| s.tile >= block_size)
            .min_by_key(|s| s.tile)
    }

    /// Run the closure module for `tile.size()`: all-pairs shortest
    /// distances within the tile (output `c[u*t+v]` = dist `v -> u`).
    pub fn closure(&self, tile: &DenseTile) -> Result<Vec<f32>> {
        let mut scratch = DenseScratch::new();
        self.closure_with(tile, &mut scratch)?;
        Ok(std::mem::take(&mut scratch.out))
    }

    /// [`Self::closure`] into reusable scratch (result in
    /// `scratch.out`; warm calls allocate nothing).
    pub fn closure_with<'a>(
        &self,
        tile: &DenseTile,
        scratch: &'a mut DenseScratch,
    ) -> Result<&'a [f32]> {
        let t = tile.size();
        if !self.closure.contains(&t) {
            bail!("no closure artifact for tile {t}");
        }
        closure_ref_into(tile, &mut scratch.out);
        self.executions.fetch_add(1, Ordering::Relaxed);
        Ok(&scratch.out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::dense::{closure_ref, relax_ref};
    use crate::INF;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> DenseEngine {
        DenseEngine::load(&artifacts_dir()).expect("artifacts/manifest.txt must be present")
    }

    fn random_tile(t: usize, seed: u64, density: f64) -> DenseTile {
        let mut tile = DenseTile::empty(t);
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for u in 0..t {
            for v in 0..t {
                if u != v && (next() % 1000) as f64 / 1000.0 < density {
                    tile.add_edge(u, v, (next() % 100 + 1) as f32);
                }
            }
        }
        tile
    }

    #[test]
    fn loads_all_artifacts() {
        let e = engine();
        assert!(!e.relax_specs().is_empty());
        assert!(!e.closure_tiles().is_empty());
    }

    #[test]
    fn relax_matches_rust_reference() {
        let e = engine();
        for spec in e.relax_specs() {
            let tile = random_tile(spec.tile, 42 + spec.tile as u64, 0.05);
            let mut dist = vec![INF; spec.tile * spec.sources];
            for j in 0..spec.sources {
                dist[(j * 7 % spec.tile) * spec.sources + j] = 0.0;
            }
            let got = e.relax(spec, &tile, &dist).unwrap();
            let want = relax_ref(&tile, &dist, spec.sources, spec.hops);
            assert_eq!(got.len(), want.len());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                    "spec {spec:?} idx {i}: engine={g} ref={w}"
                );
            }
        }
    }

    #[test]
    fn closure_matches_rust_reference() {
        let e = engine();
        for t in e.closure_tiles() {
            let tile = random_tile(t, 7 + t as u64, 0.04);
            let got = e.closure(&tile).unwrap();
            let want = closure_ref(&tile);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                let close = if *w >= INF {
                    *g >= INF
                } else {
                    (g - w).abs() <= 1e-3 * w.abs().max(1.0)
                };
                assert!(close, "tile {t} idx {i}: engine={g} ref={w}");
            }
        }
    }

    #[test]
    fn best_relax_prefers_smallest_fitting_tile() {
        let e = engine();
        let specs = e.relax_specs();
        let min_tile = specs.iter().map(|s| s.tile).min().unwrap();
        let max_tile = specs.iter().map(|s| s.tile).max().unwrap();
        assert_eq!(e.best_relax(1).unwrap().tile, min_tile);
        assert_eq!(e.best_relax(max_tile).unwrap().tile, max_tile);
        assert!(e.best_relax(max_tile + 1).is_none());
    }

    #[test]
    fn relax_rejects_wrong_shapes() {
        let e = engine();
        let spec = e.relax_specs()[0];
        let tile = DenseTile::empty(spec.tile + 1);
        let dist = vec![INF; (spec.tile + 1) * spec.sources];
        assert!(e.relax(spec, &tile, &dist).is_err());
    }

    #[test]
    fn execution_counter_increments() {
        let e = engine();
        let spec = e.relax_specs()[0];
        let tile = DenseTile::empty(spec.tile);
        let dist = vec![INF; spec.tile * spec.sources];
        let before = e.executions();
        e.relax(spec, &tile, &dist).unwrap();
        assert_eq!(e.executions(), before + 1);
    }

    #[test]
    fn scratch_reuse_matches_fresh_calls() {
        let e = engine();
        let mut scratch = DenseScratch::new();
        for t in e.closure_tiles() {
            let tile = random_tile(t, 100 + t as u64, 0.1);
            let warm = e.closure_with(&tile, &mut scratch).unwrap().to_vec();
            let fresh = e.closure(&tile).unwrap();
            assert_eq!(warm, fresh, "tile {t}");
        }
        let spec = e.relax_specs()[0];
        let tile = random_tile(spec.tile, 9, 0.1);
        let mut dist = vec![INF; spec.tile * spec.sources];
        dist[0] = 0.0;
        let warm = e.relax_with(spec, &tile, &dist, &mut scratch).unwrap().to_vec();
        let fresh = e.relax(spec, &tile, &dist).unwrap();
        assert_eq!(warm, fresh);
    }

    #[test]
    fn load_fails_on_missing_dir() {
        assert!(DenseEngine::load(Path::new("/nonexistent")).is_err());
    }
}
