//! The compile-once / execute-many PJRT engine.
//!
//! One [`DenseEngine`] owns a PJRT CPU client plus every executable
//! described by the artifact manifest. Loading compiles each HLO-text
//! module exactly once; the coordinator then calls [`DenseEngine::relax`]
//! / [`DenseEngine::closure`] from its hot path with plain `f32`
//! slices. All Literal packing/unpacking is contained here.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::dense::DenseTile;
use super::manifest::{ArtifactKind, Manifest};

/// The static configuration of one compiled relax module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RelaxSpec {
    /// Tile edge length (adjacency is tile×tile).
    pub tile: usize,
    /// Distance-panel width (number of sources per call).
    pub sources: usize,
    /// Hops advanced per execution (baked at lowering time).
    pub hops: usize,
}

struct RelaxExec {
    spec: RelaxSpec,
    exe: xla::PjRtLoadedExecutable,
}

struct ClosureExec {
    tile: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT engine holding all compiled dense kernels.
pub struct DenseEngine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    relax: Vec<RelaxExec>,
    closure: Vec<ClosureExec>,
    /// Total kernel executions (for coordinator metrics).
    executions: AtomicU64,
}

impl DenseEngine {
    /// Load every artifact under `dir` (usually `artifacts/`), compiling
    /// each module once on a fresh PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(&manifest)
    }

    /// Compile all modules listed in an already-parsed manifest.
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut relax = Vec::new();
        let mut closure = Vec::new();
        for art in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                art.path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {:?}", art.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", art.name))?;
            match art.kind {
                ArtifactKind::Relax => relax.push(RelaxExec {
                    spec: RelaxSpec {
                        tile: art.tile,
                        sources: art.sources,
                        hops: art.hops,
                    },
                    exe,
                }),
                ArtifactKind::Closure => closure.push(ClosureExec {
                    tile: art.tile,
                    exe,
                }),
            }
        }
        // Largest tiles first so `best_relax` prefers doing more work
        // per launch when several configurations fit.
        relax.sort_by(|a, b| (b.spec.tile, b.spec.hops).cmp(&(a.spec.tile, a.spec.hops)));
        closure.sort_by(|a, b| b.tile.cmp(&a.tile));
        Ok(DenseEngine {
            client,
            relax,
            closure,
            executions: AtomicU64::new(0),
        })
    }

    /// Specs of all loaded relax modules (largest tile/hops first).
    pub fn relax_specs(&self) -> Vec<RelaxSpec> {
        self.relax.iter().map(|r| r.spec).collect()
    }

    /// Tile sizes of all loaded closure modules (largest first).
    pub fn closure_tiles(&self) -> Vec<usize> {
        self.closure.iter().map(|c| c.tile).collect()
    }

    /// Number of kernel executions so far.
    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }

    /// Run the relax module matching `spec` exactly: `spec.hops` rounds
    /// of tropical relaxation of the `dist` panel (row-major
    /// `tile × sources`) over `tile`. Returns the relaxed panel.
    pub fn relax(&self, spec: RelaxSpec, tile: &DenseTile, dist: &[f32]) -> Result<Vec<f32>> {
        let entry = self
            .relax
            .iter()
            .find(|r| r.spec == spec)
            .with_context(|| format!("no relax artifact for {spec:?}"))?;
        if tile.size() != spec.tile {
            bail!("tile size {} != artifact tile {}", tile.size(), spec.tile);
        }
        if dist.len() != spec.tile * spec.sources {
            bail!(
                "panel len {} != tile*sources {}",
                dist.len(),
                spec.tile * spec.sources
            );
        }
        let t = spec.tile as i64;
        let s = spec.sources as i64;
        let adj_lit = xla::Literal::vec1(tile.raw()).reshape(&[t, t])?;
        let dist_lit = xla::Literal::vec1(dist).reshape(&[t, s])?;
        let out = entry.exe.execute::<xla::Literal>(&[adj_lit, dist_lit])?[0][0]
            .to_literal_sync()?;
        self.executions.fetch_add(1, Ordering::Relaxed);
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Pick the best loaded relax spec for a block of `block_size`
    /// vertices: smallest tile that fits (least padding waste).
    pub fn best_relax(&self, block_size: usize) -> Option<RelaxSpec> {
        self.relax
            .iter()
            .map(|r| r.spec)
            .filter(|s| s.tile >= block_size)
            .min_by_key(|s| s.tile)
    }

    /// Run the closure module for `tile.size()`: all-pairs shortest
    /// distances within the tile (output `c[u*t+v]` = dist `v -> u`).
    pub fn closure(&self, tile: &DenseTile) -> Result<Vec<f32>> {
        let t = tile.size();
        let entry = self
            .closure
            .iter()
            .find(|c| c.tile == t)
            .with_context(|| format!("no closure artifact for tile {t}"))?;
        let ti = t as i64;
        let adj_lit = xla::Literal::vec1(tile.raw()).reshape(&[ti, ti])?;
        let out = entry.exe.execute::<xla::Literal>(&[adj_lit])?[0][0].to_literal_sync()?;
        self.executions.fetch_add(1, Ordering::Relaxed);
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::dense::{closure_ref, relax_ref};
    use crate::INF;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> DenseEngine {
        DenseEngine::load(&artifacts_dir()).expect("run `make artifacts` before cargo test")
    }

    fn random_tile(t: usize, seed: u64, density: f64) -> DenseTile {
        let mut tile = DenseTile::empty(t);
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for u in 0..t {
            for v in 0..t {
                if u != v && (next() % 1000) as f64 / 1000.0 < density {
                    tile.add_edge(u, v, (next() % 100 + 1) as f32);
                }
            }
        }
        tile
    }

    #[test]
    fn loads_all_artifacts() {
        let e = engine();
        assert!(!e.relax_specs().is_empty());
        assert!(!e.closure_tiles().is_empty());
    }

    #[test]
    fn relax_matches_rust_reference() {
        let e = engine();
        for spec in e.relax_specs() {
            let tile = random_tile(spec.tile, 42 + spec.tile as u64, 0.05);
            let mut dist = vec![INF; spec.tile * spec.sources];
            for j in 0..spec.sources {
                dist[(j * 7 % spec.tile) * spec.sources + j] = 0.0;
            }
            let got = e.relax(spec, &tile, &dist).unwrap();
            let want = relax_ref(&tile, &dist, spec.sources, spec.hops);
            assert_eq!(got.len(), want.len());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                    "spec {spec:?} idx {i}: pjrt={g} ref={w}"
                );
            }
        }
    }

    #[test]
    fn closure_matches_rust_reference() {
        let e = engine();
        for t in e.closure_tiles() {
            let tile = random_tile(t, 7 + t as u64, 0.04);
            let got = e.closure(&tile).unwrap();
            let want = closure_ref(&tile);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                let close = if *w >= INF {
                    *g >= INF
                } else {
                    (g - w).abs() <= 1e-3 * w.abs().max(1.0)
                };
                assert!(close, "tile {t} idx {i}: pjrt={g} ref={w}");
            }
        }
    }

    #[test]
    fn best_relax_prefers_smallest_fitting_tile() {
        let e = engine();
        let specs = e.relax_specs();
        let min_tile = specs.iter().map(|s| s.tile).min().unwrap();
        let max_tile = specs.iter().map(|s| s.tile).max().unwrap();
        assert_eq!(e.best_relax(1).unwrap().tile, min_tile);
        assert_eq!(e.best_relax(max_tile).unwrap().tile, max_tile);
        assert!(e.best_relax(max_tile + 1).is_none());
    }

    #[test]
    fn relax_rejects_wrong_shapes() {
        let e = engine();
        let spec = e.relax_specs()[0];
        let tile = DenseTile::empty(spec.tile + 1);
        let dist = vec![INF; (spec.tile + 1) * spec.sources];
        assert!(e.relax(spec, &tile, &dist).is_err());
    }

    #[test]
    fn execution_counter_increments() {
        let e = engine();
        let spec = e.relax_specs()[0];
        let tile = DenseTile::empty(spec.tile);
        let dist = vec![INF; spec.tile * spec.sources];
        let before = e.executions();
        e.relax(spec, &tile, &dist).unwrap();
        assert_eq!(e.executions(), before + 1);
    }
}
