//! Parser for `artifacts/manifest.txt`.
//!
//! The manifest is a deliberately trivial line format (`key value`,
//! blank line between records) because the offline crate set has no
//! serde/JSON; see `python/compile/aot.py::main` for the writer.

use crate::bail;
use crate::error::{Context, Result};
use std::path::{Path, PathBuf};

/// What a compiled artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `relax_block`: multi-hop tropical relaxation of a distance panel.
    Relax,
    /// `tile_closure`: APSP closure of one adjacency tile.
    Closure,
}

/// One compiled HLO module described by the manifest.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    /// Path to the `.hlo.txt` file, resolved relative to the manifest.
    pub path: PathBuf,
    pub kind: ArtifactKind,
    /// Tile edge length t (adjacency is t×t).
    pub tile: usize,
    /// Number of distance-panel columns (relax only; 0 for closure).
    pub sources: usize,
    /// Hop count baked into the module (relax only; 0 for closure).
    pub hops: usize,
}

/// Parsed manifest: the artifact inventory for one `artifacts/` dir.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Load and parse `dir/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` anchors relative artifact paths.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut artifacts = Vec::new();
        let mut cur: Option<ArtifactBuilder> = None;
        for (lno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                if let Some(b) = cur.take() {
                    artifacts.push(b.build(dir).with_context(|| format!("line {}", lno + 1))?);
                }
                continue;
            }
            let (key, value) = line
                .split_once(' ')
                .with_context(|| format!("manifest line {} has no value: {line:?}", lno + 1))?;
            match key {
                "artifact" => {
                    if let Some(b) = cur.take() {
                        artifacts.push(b.build(dir)?);
                    }
                    cur = Some(ArtifactBuilder::new(value));
                }
                _ => {
                    let b = cur
                        .as_mut()
                        .with_context(|| format!("line {}: key before `artifact`", lno + 1))?;
                    b.set(key, value)?;
                }
            }
        }
        if let Some(b) = cur.take() {
            artifacts.push(b.build(dir)?);
        }
        Ok(Manifest { artifacts })
    }

    /// All artifacts of a given kind.
    pub fn of_kind(&self, kind: ArtifactKind) -> impl Iterator<Item = &Artifact> {
        self.artifacts.iter().filter(move |a| a.kind == kind)
    }
}

struct ArtifactBuilder {
    name: String,
    file: Option<String>,
    kind: Option<ArtifactKind>,
    tile: usize,
    sources: usize,
    hops: usize,
}

impl ArtifactBuilder {
    fn new(name: &str) -> Self {
        ArtifactBuilder {
            name: name.to_string(),
            file: None,
            kind: None,
            tile: 0,
            sources: 0,
            hops: 0,
        }
    }

    fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "file" => self.file = Some(value.to_string()),
            "kind" => {
                self.kind = Some(match value {
                    "relax" => ArtifactKind::Relax,
                    "closure" => ArtifactKind::Closure,
                    other => bail!("unknown artifact kind {other:?}"),
                })
            }
            "tile" => self.tile = value.parse().context("tile")?,
            "sources" => self.sources = value.parse().context("sources")?,
            "hops" => self.hops = value.parse().context("hops")?,
            other => bail!("unknown manifest key {other:?}"),
        }
        Ok(())
    }

    fn build(self, dir: &Path) -> Result<Artifact> {
        let file = self
            .file
            .with_context(|| format!("artifact {} missing `file`", self.name))?;
        let kind = self
            .kind
            .with_context(|| format!("artifact {} missing `kind`", self.name))?;
        if self.tile == 0 {
            bail!("artifact {} missing `tile`", self.name);
        }
        Ok(Artifact {
            name: self.name,
            path: dir.join(file),
            kind,
            tile: self.tile,
            sources: self.sources,
            hops: self.hops,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "artifact relax_t64_s4_h64\nfile relax_t64_s4_h64.hlo.txt\nkind relax\ntile 64\nsources 4\nhops 64\n\nartifact closure_t64\nfile closure_t64.hlo.txt\nkind closure\ntile 64\n";

    #[test]
    fn parses_two_records() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let r = &m.artifacts[0];
        assert_eq!(r.kind, ArtifactKind::Relax);
        assert_eq!((r.tile, r.sources, r.hops), (64, 4, 64));
        assert_eq!(r.path, Path::new("/tmp/a/relax_t64_s4_h64.hlo.txt"));
        let c = &m.artifacts[1];
        assert_eq!(c.kind, ArtifactKind::Closure);
        assert_eq!(c.tile, 64);
    }

    #[test]
    fn missing_kind_is_error() {
        let bad = "artifact x\nfile x.hlo.txt\ntile 64\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn unknown_key_is_error() {
        let bad = "artifact x\nfile x.hlo.txt\nkind relax\ntile 64\nwat 9\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn of_kind_filters() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert_eq!(m.of_kind(ArtifactKind::Relax).count(), 1);
        assert_eq!(m.of_kind(ArtifactKind::Closure).count(), 1);
    }

    #[test]
    fn trailing_record_without_blank_line() {
        let text = "artifact c\nfile c.hlo.txt\nkind closure\ntile 8";
        let m = Manifest::parse(text, Path::new(".")).unwrap();
        assert_eq!(m.artifacts.len(), 1);
    }
}
