//! # PASGAL — Parallel And Scalable Graph Algorithm Library (reproduction)
//!
//! A from-scratch reproduction of *PASGAL: Parallel And Scalable Graph
//! Algorithm Library* (Dong, Gu, Sun, Wang — SPAA 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the graph library and every substrate it
//!   needs: a work-stealing fork-join runtime ([`parallel`]), the
//!   concurrent hash-bag frontier structure ([`hashbag`]), CSR graphs,
//!   generators and I/O ([`graph`]), the paper's algorithms and all
//!   published baselines ([`algo`]), a deterministic virtual-multicore
//!   simulator for scalability studies ([`sim`]), an analysis-job
//!   coordinator ([`coordinator`]), and a dense-kernel runtime that
//!   executes the AOT-lowered kernel inventory ([`runtime`]).
//! * **L2/L1 (build time)** — JAX + Pallas tropical-semiring kernels,
//!   lowered once to `artifacts/*.hlo.txt` by `make artifacts`; Python
//!   never runs on the request path.
//!
//! The paper's core technique, **vertical granularity control (VGC)**,
//! is implemented in [`parallel::vgc`] and used by the PASGAL variants
//! of BFS ([`algo::bfs`]), SCC ([`algo::scc`]) and SSSP
//! ([`algo::sssp`]); BCC uses the FAST-BCC algorithm ([`algo::bcc`]).
//!
//! ## Query serving & workspaces
//!
//! Serving many queries over a fixed graph is dominated not by the
//! traversal but by per-query setup: allocating and zeroing O(n)
//! distance/visited arrays and O(n+m) frontier bags before the first
//! edge is scanned. This crate removes that cost with **epoch-stamped
//! workspaces**:
//!
//! * Every per-vertex scratch array is a [`parallel::StampedU32`] /
//!   [`parallel::StampedU64`]: each slot carries the epoch it was last
//!   written in and reads as a default value unless its stamp equals
//!   the array's current epoch. "Clearing" the array for the next
//!   query is a single epoch increment — O(1), no sweep, no
//!   allocation. (Epochs are never reused without a hard reset, so
//!   wraparound — once every ~4 billion queries — is safe; see
//!   [`parallel::workspace`].)
//! * Frontier [`hashbag::HashBag`]s are rebound per query with
//!   [`hashbag::HashBag::reset`] instead of reallocated; their lazily
//!   allocated chunk storage survives across queries.
//! * Graph-constant quantities (the mean edge weight that sizes
//!   ρ-/Δ-stepping admission windows) are computed once per graph by a
//!   parallel reduction and memoized
//!   ([`graph::Graph::weight_stats`]).
//!
//! Each algorithm family has a workspace struct
//! ([`algo::BfsWorkspace`], [`algo::SsspWorkspace`],
//! [`algo::SccWorkspace`], [`algo::CcWorkspace`],
//! [`algo::KcoreWorkspace`]) bundled into one
//! [`algo::QueryWorkspace`]; algorithms expose `_ws` entry points
//! (`vgc_bfs_ws`, `rho_stepping_ws`, `vgc_scc_ws`, `par_kcore_ws`,
//! ...) next to the classic allocate-per-call wrappers. **Hold one `QueryWorkspace` per
//! worker** — a workspace is exclusive to one in-flight query (the
//! `&mut` receiver enforces it), and after warm-up every query runs
//! with zero O(n)/O(m) allocation. The [`coordinator`] does exactly
//! this: requests check a workspace out of a pool and return it after
//! answering. SCC benefits doubly: one decomposition issues two
//! reachability sub-queries per pivot batch, all sharing the same
//! stamped mask arrays. `benches/ablation_workspace.rs` measures the
//! cold-vs-warm gap.
//!
//! ## Batched multi-source traversal & query fusion
//!
//! Serving workloads repeat the *same walk* for many sources: k BFS
//! queries on one graph pay the per-round scheduling overhead k times
//! — the overhead PASGAL exists to amortize. [`algo::multi`] answers
//! up to 64 sources with **one** frontier walk, generalizing the SCC
//! engine's 64-bit reachability masks to per-source distances:
//!
//! * **Lane-striped layout** — distances live at
//!   `dist[v * lanes + lane]` in one epoch-stamped array, one lane per
//!   source. The lane count is the *actual* batch width (a 4-source
//!   batch pays 4 lanes of storage, relaxation and export, not 64),
//!   and each vertex carries one [`parallel::StampedU64`] word of
//!   "active sources" so engines touch only lanes that ever improved.
//! * **One edge scan, many relaxations** — the VGC BFS engine
//!   ([`algo::multi::multi_bfs_vgc_ws`]) relaxes every expanding lane
//!   against each scanned neighbor; the direction-optimizing engine
//!   ([`algo::multi::multi_bfs_diropt_ws`]) tests whole mask words in
//!   its bottom-up step; batched ρ-stepping
//!   ([`algo::multi::multi_rho_ws`]) shares one θ-threshold bucket
//!   structure across all lanes. Per-lane results are bit-identical
//!   to the single-source `_ws` runs.
//!
//! **Fusion kicks in at the serving layer**: when a
//! [`coordinator::Coordinator::run_batch`] batch contains ≥ 2 requests
//! for the same graph and same algorithm (and the algorithm has a
//! batched engine — VGC BFS, direction-optimizing BFS, ρ-stepping),
//! the coordinator runs one multi-source walk per ≤ 64 sources and
//! demultiplexes per-lane results (a parallel strided export) back
//! into per-request responses, in submission order. The
//! `queries_fused` / `queries_solo` metrics report the split;
//! `benches/ablation_multi_source.rs` checks the batched walk does
//! strictly fewer rounds × edge scans than solo queries.
//!
//! ## Serving architecture
//!
//! Under concurrent load, delivered throughput is set by the serving
//! layer's scheduling, not just kernel speed. The sharded server
//! ([`coordinator::ShardServer`]) runs the pipeline
//!
//! ```text
//! router → shard worker → fusion window → run_batch → demux
//! ```
//!
//! * **Router** — hashes each request's graph name (stable FNV-1a,
//!   [`coordinator::JobRequest::route_hash`]) to one of N shard
//!   workers. Same graph ⇒ same shard: every request that could fuse
//!   is visible to one fusion window, and a graph's derived views and
//!   warm workspace arrays stay hot in one worker's cache.
//! * **Shard worker** — owns its hot path outright, so steady-state
//!   request execution takes **zero shared Mutex locks**: a
//!   plain-`Vec` [`algo::WorkspacePool`], shard-local metrics (merged
//!   into the global registry via [`coordinator::Metrics::merge`]
//!   when serving ends), and a lock-free registry view (next bullet).
//! * **Registry snapshots** — `load_graph` publishes immutable
//!   `Arc`-swapped snapshots of the [`coordinator::GraphDirectory`]
//!   under a writer Mutex and bumps a version counter; each shard
//!   holds a [`coordinator::SnapshotCache`] it refreshes only when
//!   the version moves (one atomic load per dispatch). Each
//!   dispatched batch resolves every graph against one immutable
//!   snapshot.
//! * **Fusion window** — on a fusable head request the worker keeps
//!   draining its inbox up to a deadline (default 200µs), the batch
//!   cap, or 64 accumulated same-(graph, spec id, params) lanes, then
//!   dispatches; non-fusable heads fall through immediately. Closing
//!   the request channel mid-window never drops accepted work. The
//!   `shard_dispatches` / `window_waits` / `window_timeouts` /
//!   `registry_snapshots` counters expose the admission behavior.
//! * **Adaptive window** — with `--fusion-window-max-us` set, the
//!   window deadline is load-driven:
//!   `window(depth) = floor + (max − floor) · min(depth, max_batch) / max_batch`
//!   with a ~20µs floor, so a shallow inbox dispatches almost
//!   immediately (latency) while a deep backlog waits out the full
//!   cap to fuse more lanes per dispatch (throughput). Every opened
//!   window is recorded in the `fusion_window_us` series.
//! * **Work stealing** — graph→shard affinity is what makes windows
//!   and result caches work, but it also means a skewed mix pins one
//!   shard while its siblings idle. A worker whose own inbox stays
//!   empty for 500µs picks the deepest sibling inbox (per-shard depth
//!   gauges), `try_lock`s its receiver — never waiting; the owner
//!   holds that lock whenever it is idle-blocked, so steals land
//!   exactly when the owner is mid-dispatch with backlog queued — and
//!   admits one whole batch through the normal window (a fusion
//!   window or 64-lane fused walk is never split). Stolen batches run
//!   on the thief's snapshot cache and workspace pool but read/write
//!   the **owner** shard's result cache and circuit breaker, so
//!   caching and breaker semantics are placement-invariant.
//!   `steal_attempts` / `steal_conflicts` / `batches_stolen` trace
//!   the protocol; `--no-steal` disables it
//!   ([`coordinator::ShardConfig::steal`]).
//! * **Lane compaction** — when ≥ 3/4 of a fused walk's lanes have
//!   converged, the multi-source engines re-pack the survivors into a
//!   dense low-lane prefix mid-walk, shrinking every later frontier
//!   word (`lane_compactions` counter); per-lane results stay
//!   bit-identical under the permutation.
//! * **Engine affinity** — when a dense-closure engine directory is
//!   known, each shard spawns its own engine replica at serve start
//!   (`engines_replicated` counter) so engine-gated analyses don't
//!   serialize shards through one shared process; shards whose spawn
//!   fails fall back to the shared handle transparently.
//! * **Result cache** — whole-graph analyses (SCC summary, CC,
//!   k-core, BCC: specs declaring [`algo::api::AlgoSpec::cacheable`])
//!   are answered from a shard-local [`coordinator::ResultCache`]
//!   when the same query repeats against an unchanged graph. Entries
//!   are keyed `(graph name, spec id, params)` and guarded by the
//!   [`coordinator::LoadedGraph`]'s publish version, so `load_graph`
//!   republishing invalidates by version comparison alone — no
//!   eviction protocol, no TTLs. Graph→shard affinity means the
//!   owning shard's cache sees every duplicate; `cache_hits` /
//!   `cache_misses` merge across shards like every other counter.
//!   Source-parameterized traversals (BFS/SSSP) never enter.
//! * **Demux** — the batch runs through the same execution core as
//!   the single-threaded loop ([`coordinator::Coordinator::serve`]),
//!   so fused per-lane results come back in submission order and are
//!   bit-identical to solo execution (and cache hits return the
//!   stored output itself — bit-identical by construction).
//!
//! `benches/ablation_serve_shards.rs` measures 1-shard-no-window vs
//! N-shard-windowed throughput on a mixed two-graph workload and
//! asserts `fused_fraction` rises once a window is in play;
//! `benches/ablation_result_cache.rs` asserts a duplicate-heavy
//! workload hits the cache and answers duplicates below fresh-compute
//! latency; `benches/ablation_steal.rs` runs a 90%-one-graph skew with
//! deterministic per-execution delays and asserts stealing strictly
//! beats no-stealing while recovering most of the gap to the uniform
//! ceiling.
//!
//! ## Failure semantics
//!
//! The serving layer's contract is **every accepted request is
//! answered exactly once** — on success with the algorithm's typed
//! output, on failure with [`algo::api::QueryOutput::Failed`] carrying
//! both the message and a machine-matchable
//! [`coordinator::FailKind`]:
//!
//! * **`DeadlineExceeded`** — the request carried a deadline
//!   ([`coordinator::JobRequest::with_budget`] /
//!   `with_deadline`; CLI `--deadline-ms`) and it passed before
//!   execution started. Checked at the shard router, at fusion-window
//!   admission (an expired head never opens a window), and once more
//!   at execution for mid-window expiry. Expired requests never touch
//!   an engine (`deadline_exceeded` counter).
//! * **`Overloaded`** — the shard router *shed* the request: its
//!   target shard already had [`coordinator::ShardConfig::inbox_cap`]
//!   requests queued (per-shard atomic depth gauges; `0` disables the
//!   bound). Shedding answers immediately at the router instead of
//!   letting an unbounded queue drag every queued request past its
//!   deadline (`shed` counter). `benches/ablation_overload.rs`
//!   measures bounded-vs-unbounded tail latency under oversubmission.
//! * **`EnginePanic`** — the engine panicked mid-query. Execution
//!   wraps every engine call (solo and fused) in
//!   `std::panic::catch_unwind`: the panic is contained to the one
//!   request, the possibly-corrupt workspace is dropped and replaced —
//!   never checked back into a pool — and the serving worker keeps
//!   running (`engine_panics`, `workspaces_dropped`). A
//!   per-`(graph, spec)` **circuit breaker**
//!   ([`coordinator::PanicBreaker`]) counts *consecutive* panics; at 3
//!   it opens and identical requests fail fast (also classified
//!   `EnginePanic`, `breaker_open` counter) without re-running the
//!   dying engine. A success closes it; republishing the graph
//!   (version bump) resets it — the same republish protocol that
//!   invalidates cached results. With a nonzero
//!   [`coordinator::ShardConfig::breaker_cooldown`] (CLI
//!   `--breaker-cooldown-ms`) an open breaker also *self-heals*: after
//!   the cooldown it admits exactly one **half-open probe**
//!   (`breaker_probes`); a successful probe closes it
//!   (`breaker_recoveries`), another panic re-opens it and restarts
//!   the cooldown:
//!
//!   ```text
//!              3 consecutive panics
//!    ┌────────┐ ──────────────────▶ ┌────────┐
//!    │ CLOSED │                     │  OPEN  │◀─┐
//!    └────────┘ ◀──┐                └────────┘  │ probe
//!         ▲        │ probe ok         │ cooldown│ panics
//!         │        │                  ▼ elapsed │
//!         │     ┌───────────────────────┐       │
//!         └─────│ HALF-OPEN (one probe) │───────┘
//!               └───────────────────────┘
//!   ```
//!
//!   A *first* solo panic (breaker streak 1) with deadline budget
//!   remaining is also retried **once** on a fresh workspace
//!   (`panic_retries`) — workspace-corruption panics heal invisibly;
//!   deterministic panics fail typed and feed the breaker. Caveat:
//!   `catch_unwind` catches panics that *unwind to the serving
//!   worker*; a panic on a fork-join pool thread is isolated only
//!   insofar as the pool propagates it back to the caller.
//! * **`EngineStalled`** — the router's **watchdog** (no extra
//!   threads; it patrols between `recv_timeout` ticks) found a shard
//!   worker whose dispatched batch ran past
//!   [`coordinator::ShardConfig::stall_limit`] (CLI
//!   `--stall-limit-ms`, default 30s, `0` disables). The watchdog
//!   condemns the worker's cancellation token, answers the stuck
//!   batch `EngineStalled` (`engine_stalled` per request,
//!   `workers_respawned` once) and spawns a fresh worker over the
//!   *same* inbox, so queued requests behind the stuck batch survive.
//!   Per-worker state machine: **healthy** (inflight slot empty or
//!   young) → **stalled** (slot past the limit; token condemned) →
//!   **respawned** (replacement owns the inbox; the condemned worker
//!   unwinds at its next cancellation point, finds its slot taken,
//!   discards its results and retires). Whoever takes the inflight
//!   slot answers the batch — that handoff keeps exactly-once.
//! * **`UnknownGraph`** / **`InvalidSource`** — the request named a
//!   graph that was never published, or a source vertex `>= n`. Both
//!   fail typed before any engine runs, and both are **negatively
//!   cached** in the shard-local result cache under the same version
//!   guard as positive entries (unknown graphs at a version-0
//!   sentinel, bad sources at the live graph's version), so a client
//!   retry loop hammering a bad name costs one registry probe, not
//!   repeated resolution (`negative_hits`; publishing the graph or a
//!   new version drops the stale negatives wholesale).
//! * **`InvalidGraph`** — [`coordinator::Coordinator::try_load_graph`]
//!   rejected a structurally invalid CSR (non-monotone offsets,
//!   out-of-range targets, wrong offset totals, weight-length
//!   mismatch) *before* publishing; serving state is untouched and the
//!   previously published graph, if any, keeps serving.
//!
//! **Cancellation points.** Deadlines and the watchdog act through
//! one mechanism: a [`algo::cancel::CancelToken`] (a shared
//! `AtomicU64` holding a deadline or the sticky condemned flag)
//! threaded from the request through
//! [`coordinator::ExecCore`] into every long-running engine loop.
//! Engines poll it **once per frontier round / bucket epoch, never
//! per edge**: the multi-source BFS/reach round loops, the ρ- and
//! Δ-stepping bucket loops, and the SCC trim/pivot phases all `break`
//! (never return) on a cancelled token, so the pooled workspace is
//! restored and stays reusable — an expired or abandoned query
//! releases its shard within one round. Fused batches carry the
//! *tightest* live lane deadline and re-walk surviving lanes when
//! only some expire (`fused_rewalks`), so one impatient client cannot
//! fail its batchmates.
//!
//! Coordinator-path Mutexes (pool, shared cache, directory writer,
//! metrics, breaker) recover from poisoning
//! (`PoisonError::into_inner`): each guards state that stays
//! structurally valid across a panic, and recovery beats turning one
//! panicked holder into a permanent denial of service.
//! `coordinator::faults` is the zero-dependency fault-injection
//! harness (panic-on-Nth-execution, slow-engine delays, malformed
//! graph bytes) behind `tests/robust_serving.rs`, the chaos test that
//! holds the exactly-once contract under injected panics, stalls and
//! overload.
//!
//! ## Query API — the open algorithm registry
//!
//! Every servable algorithm is described **once**, by a static
//! [`algo::api::AlgoSpec`] in the registry
//! ([`algo::api::registry`]): label + aliases, parameter parsing
//! ([`algo::api::ParseArgs`] → [`algo::api::Params`]), a solo engine
//! (one query against a [`coordinator::LoadedGraph`] +
//! [`algo::QueryWorkspace`] → typed [`algo::api::QueryOutput`]), an
//! optional batch engine (the ≤ 64-lane fused walk + per-lane demux),
//! an optional traced engine (CLI `run` / simulator), and the
//! `cacheable` flag feeding the result cache. A request is a
//! [`algo::api::Query`]`{ graph, algo: &'static AlgoSpec, source,
//! params }` — and that *is* the wire type: the channel protocol's
//! [`coordinator::JobRequest`] carries the same
//! `&'static AlgoSpec` + parsed `Params` plus a request id
//! ([`coordinator::JobRequest::from_query`] converts losslessly, and
//! [`coordinator::JobRequest::parse`] builds one straight from a
//! label or alias). Every front end — [`coordinator::Coordinator`]
//! execution and batching, the sharded server's fusion-window
//! grouping key `(graph, spec id, params)`, the CLI, the workload
//! generator, the bench harness — dispatches through the registry;
//! there are no per-algorithm match arms and no per-algorithm wire
//! enum anywhere (the deprecated wire-enum shim, the last closed
//! table, is deleted).
//!
//! **Registering an algorithm is one module touch**: implement its
//! engine functions in `algo/api/engines.rs`, add one `AlgoSpec`
//! line to `algo/api/registry.rs`, and it is parseable, servable
//! (solo loop *and* sharded, channel protocol included), metered,
//! cached if it declares so, and covered by the
//! registry-completeness tests. Connectivity (`cc`) and k-core
//! (`kcore`) were opened for serving exactly this way — try
//! `pasgal run --algo cc --graph g.bin` or a `serve --demo` trace.
//!
//! ## Graph storage
//!
//! Graphs persist in the versioned `pasgal-graph/1` binary CSR format
//! (`.pgr`, [`graph::store`]): an 8-byte magic + fixed header (n, m,
//! flags, encoding, total length), a checksummed section table, and
//! 64-byte-aligned little-endian sections —
//!
//! ```text
//! ┌────────────────────┬─────────────────────────────────────────┐
//! │ header (192 B)     │ magic · version · encoding · n · m ·    │
//! │                    │ flags · file len · FNV-1a checksums ·   │
//! │                    │ section table (offset, len, FNV) × 4    │
//! ├────────────────────┼─────────────────────────────────────────┤
//! │ OFFSETS            │ (n+1) × u64 CSR spine                   │
//! │ ADJ                │ m × u32 targets (plain) — or a varint   │
//! │                    │ byte stream (delta)                     │
//! │ WEIGHTS            │ m × f32 (weighted graphs only)          │
//! │ ADJ_INDEX          │ (n+1) × u64 byte index (delta only)     │
//! └────────────────────┴─────────────────────────────────────────┘
//! ```
//!
//! Two adjacency encodings share the container. **Plain** stores the
//! CSR arrays verbatim: [`graph::store::load`] does one bulk read
//! into a 64-byte-aligned arena and (on little-endian hosts)
//! publishes the graph as **zero-copy views into the file image** —
//! load cost is read + checksum + validation, nothing per-element.
//! **Delta** stores each sorted neighbor list GBBS-style as a zigzag
//! varint first-target relative to the source plus plain varint gaps
//! — 2–4× smaller adjacency on gap-friendly graphs, decoded in
//! parallel per vertex at publish time. Choose plain when load
//! latency or mmap-like sharing matters; choose delta when files are
//! shipped or stored. Either way the in-memory representation is the
//! same: [`graph::Graph`]'s arrays live behind
//! [`graph::CsrBacking`] (owned `Vec`s or arena views) and every
//! consumer reads slices through `offsets()` / `targets()` /
//! `weights()`.
//!
//! Loads are fail-closed: magic/version/encoding checks, header and
//! per-section FNV-1a checksums, section bounds/alignment/length
//! arithmetic, then the **same** [`graph::csr::validate_csr`]
//! invariant check the in-memory publish path uses — a corrupt or
//! truncated file is a typed `InvalidGraph` error and never replaces
//! a healthy published snapshot
//! ([`coordinator::Coordinator::load_graph_from_path`] publishes
//! under the normal Arc-swap version protocol, metering
//! `graph_load_us`, `graphs_loaded_bytes` and `store_decode_us`).
//! CLI: `pasgal pack` writes, `pasgal load --from-file` publishes and
//! serves; `benches/ablation_store.rs` measures publish-from-file vs
//! rebuild-from-edges; `tests/graph_store.rs` property-tests that
//! round-tripped graphs answer every registry algorithm
//! bit-identically and that random truncations/bit-flips are
//! rejected.
//!
//! ## Observability
//!
//! The serving path measures itself; nothing here samples wall-clock
//! unless asked, and nothing grows with the observation count.
//!
//! **Bounded-histogram metrics.** Every latency series in
//! [`coordinator::Metrics`] is a fixed-size log-bucketed atomic
//! histogram ([`coordinator::metrics::Histogram`]): 64 sub-buckets
//! per power-of-two octave of nanoseconds, ~30 KiB per series, total.
//! Recording is lock-free (one `fetch_add` per bucket hit plus exact
//! running count/sum/max), merging shard-local registries into the
//! global one is bucket-wise addition, and
//! [`coordinator::Metrics::summary`] reads percentiles straight from
//! the buckets — no clone, no sort, no allocation, with relative
//! error bounded by the bucket width (≤ 1/64 ≈ 1.6%; mean and max are
//! exact). `tests/metrics_alloc.rs` pins this down with a counting
//! global allocator: a million `observe` calls allocate zero bytes
//! after the first.
//!
//! **End-to-end query tracing.** Any [`coordinator::JobRequest`] can
//! ask for a [`coordinator::QueryTrace`]
//! ([`coordinator::JobRequest::with_trace`]; the CLI samples every
//! n-th request under `serve --trace-sample-n`). A trace is a stack
//! of nested wall-clock spans over the serving pipeline — cache
//! probe, engine run, fused walk, demux — sealed against the reported
//! latency so that a synthetic top-level `wait` span absorbs inbox /
//! fusion-window / queueing time and the top-level spans **sum
//! exactly to the reported latency**. Engines additionally feed
//! per-round [`coordinator::EngineTelemetry`] (rounds, peak frontier,
//! edges scanned, local-search task count) through the same optional
//! side-channel the simulator uses ([`sim::AlgoTrace`] via
//! [`algo::api::EngineCtx::recorder`]) — `None` costs nothing, and
//! unsampled requests are bit-identical to an untraced run. Traces
//! render as one JSON line each (`pasgal-trace/1`).
//!
//! **Machine-readable snapshots.** [`coordinator::Metrics::snapshot`]
//! freezes the whole registry into a sorted
//! [`coordinator::MetricsSnapshot`] and renders it as Prometheus text
//! or JSON (`pasgal-metrics/1`): `pasgal serve --metrics-out PATH`
//! rewrites it periodically (atomic rename), `pasgal stats --metrics`
//! prints one, and the `trajectory` bench sweeps shard counts × graph
//! classes × every registry algorithm into a schema-validated
//! `BENCH_serve.json` (`pasgal-bench-serve/1`,
//! [`bench::trajectory`]) that CI regenerates and uploads on every
//! push.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod algo;
pub mod bench;
pub mod coordinator;
pub mod error;
pub mod graph;
pub mod hashbag;
pub mod parallel;
pub mod prop;
pub mod runtime;
pub mod sim;

/// Vertex id type. 32-bit like the paper's default build (the paper
/// notes Multistep fails beyond 32-bit ids; we keep u32 and document
/// the same limit).
pub type V = u32;

/// Edge weight type for weighted algorithms.
pub type W = f32;

/// Sentinel "infinite" distance matching the L1 kernels' convention.
pub const INF: f32 = 1.0e18;
