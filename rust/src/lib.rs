//! # PASGAL — Parallel And Scalable Graph Algorithm Library (reproduction)
//!
//! A from-scratch reproduction of *PASGAL: Parallel And Scalable Graph
//! Algorithm Library* (Dong, Gu, Sun, Wang — SPAA 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the graph library and every substrate it
//!   needs: a work-stealing fork-join runtime ([`parallel`]), the
//!   concurrent hash-bag frontier structure ([`hashbag`]), CSR graphs,
//!   generators and I/O ([`graph`]), the paper's algorithms and all
//!   published baselines ([`algo`]), a deterministic virtual-multicore
//!   simulator for scalability studies ([`sim`]), an analysis-job
//!   coordinator ([`coordinator`]), and a PJRT runtime that executes
//!   AOT-compiled dense kernels ([`runtime`]).
//! * **L2/L1 (build time)** — JAX + Pallas tropical-semiring kernels,
//!   lowered once to `artifacts/*.hlo.txt` by `make artifacts`; Python
//!   never runs on the request path.
//!
//! The paper's core technique, **vertical granularity control (VGC)**,
//! is implemented in [`parallel::vgc`] and used by the PASGAL variants
//! of BFS ([`algo::bfs`]), SCC ([`algo::scc`]) and SSSP
//! ([`algo::sssp`]); BCC uses the FAST-BCC algorithm ([`algo::bcc`]).
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod algo;
pub mod bench;
pub mod coordinator;
pub mod graph;
pub mod hashbag;
pub mod parallel;
pub mod prop;
pub mod runtime;
pub mod sim;

/// Vertex id type. 32-bit like the paper's default build (the paper
/// notes Multistep fails beyond 32-bit ids; we keep u32 and document
/// the same limit).
pub type V = u32;

/// Edge weight type for weighted algorithms.
pub type W = f32;

/// Sentinel "infinite" distance matching the L1 kernels' convention.
pub const INF: f32 = 1.0e18;
