//! Chase–Lev work-stealing deque.
//!
//! Implementation follows Lê, Pop, Cohen & Zappa Nardelli, *Correct
//! and Efficient Work-Stealing for Weak Memory Models* (PPoPP'13),
//! specialized to single-word items ([`JobRef`]). The owner pushes and
//! pops at the bottom; thieves steal from the top with a CAS.
//!
//! Growth strategy: the owner doubles the circular buffer and *leaks*
//! the old one. A stale thief may still read a slot from a retired
//! buffer, but its subsequent CAS on `top` fails, so the value is
//! discarded; leaking keeps that read memory-safe without an epoch
//! reclamation scheme. Total leaked memory is bounded by twice the
//! final buffer size (geometric series), and deques live for the
//! process lifetime anyway.

use super::job::JobRef;
use std::sync::atomic::{fence, AtomicIsize, AtomicPtr, AtomicUsize, Ordering};

const INITIAL_CAP: usize = 256;

struct Buffer {
    cap: usize, // power of two
    slots: Box<[AtomicUsize]>,
}

impl Buffer {
    fn new(cap: usize) -> Box<Buffer> {
        assert!(cap.is_power_of_two());
        let slots = (0..cap).map(|_| AtomicUsize::new(0)).collect();
        Box::new(Buffer { cap, slots })
    }

    #[inline]
    fn get(&self, i: isize) -> JobRef {
        let raw = self.slots[(i as usize) & (self.cap - 1)].load(Ordering::Relaxed);
        JobRef(raw as *mut _)
    }

    #[inline]
    fn put(&self, i: isize, job: JobRef) {
        self.slots[(i as usize) & (self.cap - 1)].store(job.0 as usize, Ordering::Relaxed);
    }
}

/// Result of a steal attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum Steal {
    Empty,
    Retry,
    Success(JobRef),
}

/// The deque. Owner-side calls (`push`, `pop`) must come from one
/// thread; `steal` may be called from any thread.
pub struct Deque {
    top: AtomicIsize,
    bottom: AtomicIsize,
    buf: AtomicPtr<Buffer>,
}

unsafe impl Send for Deque {}
unsafe impl Sync for Deque {}

impl Deque {
    pub fn new() -> Self {
        Deque {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Box::into_raw(Buffer::new(INITIAL_CAP))),
        }
    }

    /// Approximate occupancy (monitoring only).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Owner: push a job at the bottom.
    pub fn push(&self, job: JobRef) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        if b - t >= buf.cap as isize - 1 {
            buf = self.grow(b, t, buf);
        }
        buf.put(b, job);
        fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Relaxed);
    }

    /// Owner: pop from the bottom (LIFO).
    pub fn pop(&self) -> Option<JobRef> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let job = buf.get(b);
            if t == b {
                // Last element: race the thieves for it.
                let won = self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                if won {
                    Some(job)
                } else {
                    None
                }
            } else {
                Some(job)
            }
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief: steal from the top (FIFO).
    pub fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t < b {
            let buf = unsafe { &*self.buf.load(Ordering::Acquire) };
            let job = buf.get(t);
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_err()
            {
                return Steal::Retry;
            }
            Steal::Success(job)
        } else {
            Steal::Empty
        }
    }

    /// Owner-only: double the buffer, copying live elements. Old
    /// buffer is intentionally leaked (see module docs).
    fn grow(&self, b: isize, t: isize, old: &Buffer) -> &Buffer {
        let new = Buffer::new(old.cap * 2);
        for i in t..b {
            new.put(i, old.get(i));
        }
        let ptr = Box::into_raw(new);
        self.buf.store(ptr, Ordering::Release);
        unsafe { &*ptr }
    }
}

impl Default for Deque {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::job::JobHeader;

    fn fake_job(i: usize) -> JobRef {
        // Tests only move pointers around; they never execute them.
        JobRef((i * 8 + 8) as *mut JobHeader)
    }

    #[test]
    fn lifo_for_owner() {
        let d = Deque::new();
        d.push(fake_job(1));
        d.push(fake_job(2));
        assert_eq!(d.pop(), Some(fake_job(2)));
        assert_eq!(d.pop(), Some(fake_job(1)));
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn fifo_for_thief() {
        let d = Deque::new();
        d.push(fake_job(1));
        d.push(fake_job(2));
        assert_eq!(d.steal(), Steal::Success(fake_job(1)));
        assert_eq!(d.steal(), Steal::Success(fake_job(2)));
        assert_eq!(d.steal(), Steal::Empty);
    }

    #[test]
    fn grow_preserves_contents() {
        let d = Deque::new();
        let n = INITIAL_CAP * 4;
        for i in 0..n {
            d.push(fake_job(i));
        }
        assert_eq!(d.len(), n);
        for i in (0..n).rev() {
            assert_eq!(d.pop(), Some(fake_job(i)));
        }
    }

    #[test]
    fn concurrent_steal_no_loss_no_dup() {
        use std::collections::HashSet;
        use std::sync::atomic::{AtomicBool, Ordering as O};
        use std::sync::Mutex;

        let d = Deque::new();
        let n = 20_000usize;
        let seen = Mutex::new(HashSet::new());
        let done = AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        match d.steal() {
                            Steal::Success(j) => local.push(j.0 as usize),
                            Steal::Retry => {}
                            Steal::Empty => {
                                if done.load(O::Acquire) {
                                    break;
                                }
                                std::hint::spin_loop();
                            }
                        }
                    }
                    let mut set = seen.lock().unwrap();
                    for v in local {
                        assert!(set.insert(v), "duplicate steal of {v:#x}");
                    }
                });
            }
            // Owner interleaves pushes and pops.
            let mut popped = Vec::new();
            for i in 0..n {
                d.push(fake_job(i));
                if i % 3 == 0 {
                    if let Some(j) = d.pop() {
                        popped.push(j.0 as usize);
                    }
                }
            }
            while let Some(j) = d.pop() {
                popped.push(j.0 as usize);
            }
            done.store(true, O::Release);
            // merge owner's pops after thieves finish
            s.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
            });
            let mut set = seen.lock().unwrap();
            for v in popped {
                assert!(set.insert(v), "duplicate pop of {v:#x}");
            }
        });
        let set = seen.lock().unwrap();
        assert_eq!(set.len(), n, "lost {} jobs", n - set.len());
    }
}
