//! Vertical granularity control (VGC) — the paper's core technique
//! (§2.1).
//!
//! Classic (horizontal) granularity control stops *creating* parallel
//! tasks below a size threshold. VGC instead *enlarges each task*: a
//! scheduled task processing a frontier vertex keeps going — a τ-budget
//! *local search* over an explicit stack, possibly advancing many hops
//! — before returning to the scheduler. On large-diameter graphs this
//! (1) collapses the O(D) synchronized rounds into far fewer rounds
//! and (2) inflates the frontier quickly, producing enough parallel
//! slack to occupy all processors.
//!
//! [`local_search`] is the shared driver used by VGC-BFS, VGC-SCC and
//! ρ-stepping SSSP: algorithms supply an `expand` closure that claims
//! a vertex's neighbors (pushing newly-claimed ones on the stack) and
//! the driver enforces the τ budget, returning leftover stack entries
//! for the caller to flush into the next frontier.

/// Work performed by one local search (feeds the simulator's cost
/// model and the coordinator's metrics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Vertices popped (i.e. expanded) by this search.
    pub vertices: u64,
    /// Edges scanned while expanding.
    pub edges: u64,
}

impl SearchStats {
    /// Accumulate another search's counts.
    #[inline]
    pub fn merge(&mut self, other: SearchStats) {
        self.vertices += other.vertices;
        self.edges += other.edges;
    }
}

/// Run a τ-budget local search.
///
/// Pops vertices from `stack` and calls `expand(v, stack)`, which
/// scans v's neighbors, pushes any newly claimed ones, and returns the
/// number of edges it scanned. Stops when the stack empties or at
/// least `tau` vertices have been expanded; whatever remains on
/// `stack` is the caller's to emit into the next frontier.
#[inline]
pub fn local_search<F>(stack: &mut Vec<u32>, tau: usize, mut expand: F) -> SearchStats
where
    F: FnMut(u32, &mut Vec<u32>) -> usize,
{
    let mut stats = SearchStats::default();
    while let Some(v) = stack.pop() {
        stats.vertices += 1;
        stats.edges += expand(v, stack) as u64;
        if stats.vertices as usize >= tau {
            break;
        }
    }
    stats
}

/// Convenience wrapper holding a reusable stack buffer, so hot loops
/// do not re-allocate per task.
#[derive(Default)]
pub struct LocalSearch {
    /// Explicit DFS-order stack (arbitrary visit order is the point:
    /// reachability-style algorithms don't need BFS order).
    pub stack: Vec<u32>,
}

impl LocalSearch {
    pub fn new() -> Self {
        LocalSearch { stack: Vec::new() }
    }

    /// Seed with one start vertex and run to the τ budget.
    pub fn run<F>(&mut self, seeds: &[u32], tau: usize, expand: F) -> SearchStats
    where
        F: FnMut(u32, &mut Vec<u32>) -> usize,
    {
        self.stack.clear();
        self.stack.extend_from_slice(seeds);
        local_search(&mut self.stack, tau, expand)
    }

    /// Vertices left unexpanded when the budget ran out.
    pub fn leftover(&self) -> &[u32] {
        &self.stack
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Chain 0 -> 1 -> ... -> n-1 expressed as an expand closure.
    fn chain_expand(n: u32) -> impl FnMut(u32, &mut Vec<u32>) -> usize {
        move |v, stack| {
            if v + 1 < n {
                stack.push(v + 1);
                1
            } else {
                0
            }
        }
    }

    #[test]
    fn unbounded_search_drains_chain() {
        let mut ls = LocalSearch::new();
        let stats = ls.run(&[0], usize::MAX, chain_expand(100));
        assert_eq!(stats.vertices, 100);
        assert_eq!(stats.edges, 99);
        assert!(ls.leftover().is_empty());
    }

    #[test]
    fn budget_stops_search_with_leftover() {
        let mut ls = LocalSearch::new();
        let stats = ls.run(&[0], 10, chain_expand(100));
        assert_eq!(stats.vertices, 10);
        assert_eq!(ls.leftover(), &[10]);
    }

    #[test]
    fn budget_one_expands_single_vertex() {
        // τ=1 degenerates to the classic one-vertex-per-task frontier
        // algorithm — the ablation baseline.
        let mut ls = LocalSearch::new();
        let stats = ls.run(&[5], 1, chain_expand(100));
        assert_eq!(stats.vertices, 1);
        assert_eq!(ls.leftover(), &[6]);
    }

    #[test]
    fn multiple_seeds_all_expanded() {
        let mut ls = LocalSearch::new();
        let stats = ls.run(&[0, 50, 99], usize::MAX, chain_expand(100));
        // 99 is expanded once from the seed and reached again from 50's
        // chain only if the closure re-pushes — ours doesn't dedupe;
        // the chain from 0 and from 50 both run to 99. Expansion counts:
        // seed 99: 1 vertex; seed 50: 50..=99 => 50; seed 0: 0..=99 => 100.
        assert_eq!(stats.vertices, 1 + 50 + 100);
        assert!(ls.leftover().is_empty());
    }

    #[test]
    fn stats_merge_adds() {
        let mut a = SearchStats {
            vertices: 3,
            edges: 7,
        };
        a.merge(SearchStats {
            vertices: 2,
            edges: 5,
        });
        assert_eq!(
            a,
            SearchStats {
                vertices: 5,
                edges: 12
            }
        );
    }
}
