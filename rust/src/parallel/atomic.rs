//! Lock-free helpers the graph algorithms lean on.
//!
//! All PASGAL frontier algorithms race to update per-vertex state
//! (tentative distance, label, visited bit) with `min`-style CAS loops
//! — the "write-min" primitive of the paper's framework.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};

/// Atomically `slot = min(slot, value)`. Returns `true` iff `value`
/// strictly improved the slot (the caller "won" and should propagate).
#[inline]
pub fn write_min_u32(slot: &AtomicU32, value: u32) -> bool {
    let mut cur = slot.load(Ordering::Relaxed);
    while value < cur {
        match slot.compare_exchange_weak(cur, value, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(seen) => cur = seen,
        }
    }
    false
}

/// Atomically `slot = min(slot, value)` on u64.
#[inline]
pub fn write_min_u64(slot: &AtomicU64, value: u64) -> bool {
    let mut cur = slot.load(Ordering::Relaxed);
    while value < cur {
        match slot.compare_exchange_weak(cur, value, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(seen) => cur = seen,
        }
    }
    false
}

/// Atomic f32 min via the order-preserving bit trick: for
/// non-negative finite floats, the IEEE-754 bit pattern ordering as
/// u32 equals the numeric ordering, so `write_min_u32` on `to_bits`
/// is a numeric min. All PASGAL distances are non-negative.
#[inline]
pub fn write_min_f32(slot: &AtomicU32, value: f32) -> bool {
    debug_assert!(value >= 0.0, "bit-trick min requires non-negative floats");
    write_min_u32(slot, value.to_bits())
}

/// Read an f32 stored with [`write_min_f32`].
#[inline]
pub fn load_f32(slot: &AtomicU32) -> f32 {
    f32::from_bits(slot.load(Ordering::Relaxed))
}

/// One-shot claim of a flag slot (e.g. BFS "visited"): returns true
/// for exactly one caller.
#[inline]
pub fn claim(slot: &AtomicU32, from: u32, to: u32) -> bool {
    slot.compare_exchange(from, to, Ordering::AcqRel, Ordering::Relaxed)
        .is_ok()
}

/// Fetch-add convenience on usize counters.
#[inline]
pub fn bump(counter: &AtomicUsize, by: usize) -> usize {
    counter.fetch_add(by, Ordering::Relaxed)
}

/// Reinterpret a `&mut [u32]` as `&[AtomicU32]` for a parallel phase.
///
/// Sound because `AtomicU32` has the same layout as `u32` and the
/// borrow is exclusive for its lifetime.
#[inline]
pub fn as_atomic_u32(slice: &mut [u32]) -> &[AtomicU32] {
    unsafe { &*(slice as *mut [u32] as *const [AtomicU32]) }
}

/// Reinterpret a `&mut [u64]` as `&[AtomicU64]`.
#[inline]
pub fn as_atomic_u64(slice: &mut [u64]) -> &[AtomicU64] {
    unsafe { &*(slice as *mut [u64] as *const [AtomicU64]) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_min_improves_only_downward() {
        let a = AtomicU32::new(10);
        assert!(write_min_u32(&a, 5));
        assert_eq!(a.load(Ordering::Relaxed), 5);
        assert!(!write_min_u32(&a, 7));
        assert_eq!(a.load(Ordering::Relaxed), 5);
        assert!(!write_min_u32(&a, 5));
    }

    #[test]
    fn f32_min_bit_trick_orders_correctly() {
        let a = AtomicU32::new(crate::INF.to_bits());
        assert!(write_min_f32(&a, 3.5));
        assert!((load_f32(&a) - 3.5).abs() < 1e-9);
        assert!(!write_min_f32(&a, 4.0));
        assert!(write_min_f32(&a, 0.25));
        assert!((load_f32(&a) - 0.25).abs() < 1e-9);
        assert!(write_min_f32(&a, 0.0));
        assert_eq!(load_f32(&a), 0.0);
    }

    #[test]
    fn claim_is_exclusive() {
        let a = AtomicU32::new(0);
        assert!(claim(&a, 0, 1));
        assert!(!claim(&a, 0, 2));
        assert_eq!(a.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn claim_under_contention_admits_exactly_one() {
        use std::sync::Arc;
        let a = Arc::new(AtomicU32::new(0));
        let wins: usize = std::thread::scope(|s| {
            (0..8)
                .map(|i| {
                    let a = Arc::clone(&a);
                    s.spawn(move || claim(&a, 0, i + 1) as usize)
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .sum()
        });
        assert_eq!(wins, 1);
    }

    #[test]
    fn concurrent_write_min_settles_at_global_min() {
        use std::sync::Arc;
        let a = Arc::new(AtomicU32::new(u32::MAX));
        std::thread::scope(|s| {
            for t in 0..8u32 {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for i in 0..1000u32 {
                        write_min_u32(&a, 1 + ((t * 1000 + i) % 997));
                    }
                });
            }
        });
        assert_eq!(a.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn as_atomic_roundtrip() {
        let mut v = vec![1u32, 2, 3];
        {
            let at = as_atomic_u32(&mut v);
            at[1].store(42, Ordering::Relaxed);
        }
        assert_eq!(v, vec![1, 42, 3]);
    }
}
