//! Parallel stable merge sort (the ParlayLib `sort` role).
//!
//! Used by Tarjan–Vishkin BCC (edge-list sorting), graph construction
//! (CSR building from edge lists) and the generators. Parallel
//! recursion with sequential leaves; the merge splits by binary search
//! so the span stays polylogarithmic.

use super::ops::SendPtr;
use super::pool::join;

const SORT_GRAIN: usize = 1 << 12;
const MERGE_GRAIN: usize = 1 << 13;

/// Sort `v` by `key`, stably, in parallel.
pub fn parallel_sort_by_key<T, K, F>(v: &mut [T], key: F)
where
    T: Send + Sync + Copy,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    let n = v.len();
    if n <= SORT_GRAIN {
        v.sort_by_key(|x| key(x));
        return;
    }
    let mut buf: Vec<T> = Vec::with_capacity(n);
    unsafe { buf.set_len(n) };
    sort_into(v, &mut buf, false, &key);
}

/// Recursive merge sort. If `to_buf`, the sorted result lands in
/// `buf`, else in `v` (ping-pong to avoid copies).
fn sort_into<T, K, F>(v: &mut [T], buf: &mut [T], to_buf: bool, key: &F)
where
    T: Send + Sync + Copy,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    let n = v.len();
    if n <= SORT_GRAIN {
        v.sort_by_key(|x| key(x));
        if to_buf {
            buf.copy_from_slice(v);
        }
        return;
    }
    let mid = n / 2;
    let (vl, vr) = v.split_at_mut(mid);
    let (bl, br) = buf.split_at_mut(mid);
    join(
        || sort_into(vl, bl, !to_buf, key),
        || sort_into(vr, br, !to_buf, key),
    );
    // Halves now live in (bl, br) if !to_buf was their destination.
    if to_buf {
        merge_par(vl, vr, buf, key);
    } else {
        let (bl, br) = buf.split_at(mid);
        merge_par(bl, br, v, key);
    }
}

/// Parallel stable merge of sorted `a`, `b` into `out`.
fn merge_par<T, K, F>(a: &[T], b: &[T], out: &mut [T], key: &F)
where
    T: Send + Sync + Copy,
    K: Ord,
    F: Fn(&T) -> K + Sync,
{
    debug_assert_eq!(a.len() + b.len(), out.len());
    if a.len() + b.len() <= MERGE_GRAIN {
        merge_seq(a, b, out, key);
        return;
    }
    // Split at the larger side's midpoint; binary-search the other.
    if a.len() >= b.len() {
        let am = a.len() / 2;
        // First index in b whose key is >= key(a[am]) keeps stability
        // (equal elements of `a` precede equal elements of `b`).
        let bm = b.partition_point(|x| key(x) < key(&a[am]));
        let (out_l, out_r) = out.split_at_mut(am + bm);
        join(
            || merge_par(&a[..am], &b[..bm], out_l, key),
            || merge_par(&a[am..], &b[bm..], out_r, key),
        );
    } else {
        let bm = b.len() / 2;
        let am = a.partition_point(|x| key(x) <= key(&b[bm]));
        let (out_l, out_r) = out.split_at_mut(am + bm);
        join(
            || merge_par(&a[..am], &b[..bm], out_l, key),
            || merge_par(&a[am..], &b[bm..], out_r, key),
        );
    }
}

fn merge_seq<T, K, F>(a: &[T], b: &[T], out: &mut [T], key: &F)
where
    T: Copy,
    K: Ord,
    F: Fn(&T) -> K,
{
    let (mut i, mut j) = (0, 0);
    let op = SendPtr(out.as_mut_ptr());
    let mut w = 0usize;
    unsafe {
        while i < a.len() && j < b.len() {
            if key(&a[i]) <= key(&b[j]) {
                *op.add(w) = a[i];
                i += 1;
            } else {
                *op.add(w) = b[j];
                j += 1;
            }
            w += 1;
        }
        while i < a.len() {
            *op.add(w) = a[i];
            i += 1;
            w += 1;
        }
        while j < b.len() {
            *op.add(w) = b[j];
            j += 1;
            w += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(state: &mut u64) -> u64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        *state
    }

    #[test]
    fn sorts_random_u64() {
        let mut s = 12345u64;
        let mut v: Vec<u64> = (0..200_000).map(|_| xorshift(&mut s) % 1_000).collect();
        let mut expect = v.clone();
        expect.sort();
        parallel_sort_by_key(&mut v, |&x| x);
        assert_eq!(v, expect);
    }

    #[test]
    fn sorts_already_sorted_and_reverse() {
        let mut v: Vec<u32> = (0..50_000).collect();
        parallel_sort_by_key(&mut v, |&x| x);
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
        let mut v: Vec<u32> = (0..50_000).rev().collect();
        parallel_sort_by_key(&mut v, |&x| x);
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn stable_on_equal_keys() {
        // (key, original index): after sorting by key, indices within a
        // key group must stay increasing.
        let mut s = 99u64;
        let mut v: Vec<(u8, u32)> = (0..100_000u32)
            .map(|i| ((xorshift(&mut s) % 16) as u8, i))
            .collect();
        parallel_sort_by_key(&mut v, |&(k, _)| k);
        for w in v.windows(2) {
            assert!(w[0].0 <= w[1].0);
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stability violated");
            }
        }
    }

    #[test]
    fn small_inputs() {
        let mut v: Vec<u32> = vec![];
        parallel_sort_by_key(&mut v, |&x| x);
        let mut v = vec![3u32, 1, 2];
        parallel_sort_by_key(&mut v, |&x| x);
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn sorts_by_extracted_key() {
        let mut v: Vec<(u32, &str)> = vec![(3, "c"), (1, "a"), (2, "b"), (1, "a2")];
        parallel_sort_by_key(&mut v, |&(k, _)| k);
        assert_eq!(
            v.iter().map(|&(_, s)| s).collect::<Vec<_>>(),
            vec!["a", "a2", "b", "c"]
        );
    }
}
