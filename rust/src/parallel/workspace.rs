//! Epoch-stamped scratch arrays — O(1) logical reset for reusable
//! per-query state.
//!
//! Every PASGAL traversal keeps O(n) per-vertex scratch (tentative
//! distances, expanded/settled marks, pending flags, reachability
//! masks). Allocating and initializing those arrays per query costs
//! O(n) before the first edge is scanned — which swamps the traversal
//! itself on repeated queries over the same graph (and inside SCC,
//! which issues many reachability sub-queries per decomposition).
//!
//! The fix is the classic epoch-stamp trick: each slot carries the
//! epoch it was last written in, and a slot only *counts* when its
//! stamp equals the array's current epoch — otherwise it reads as the
//! array's default value. "Clearing" is then a single epoch increment
//! ([`StampedU32::advance_epoch`]), not an O(n) sweep. Storage is
//! allocated once and grows monotonically ([`StampedU32::ensure_len`]),
//! so a warm workspace performs zero O(n) allocation per query.
//!
//! Two variants:
//!
//! * [`StampedU32`] — 32-bit payload packed with its 32-bit stamp into
//!   one `AtomicU64`, so every read-modify-write (write-min, CAS,
//!   swap) is a single lock-free CAS. Used for distances (hop counts
//!   or f32 bits via the order-preserving bit trick), visited marks
//!   and pending flags.
//! * [`StampedU64`] — 64-bit payload (SCC reachability masks) with a
//!   separate stamp word and a per-slot first-touch handshake: the
//!   first writer of an epoch claims the slot by CASing the stamp to a
//!   transient BUSY value, installs its bits, then publishes the valid
//!   stamp. Readers treat non-current stamps as the default.
//!
//! Epoch wraparound: epochs are never reused without a hard reset.
//! When the epoch counter exhausts its range (once every ~4 billion
//! resets), `advance_epoch` falls back to one O(n) sweep that
//! invalidates every slot, then restarts from epoch 1 — correctness
//! never depends on a stale stamp "accidentally" matching.

use super::ops::{parallel_for, SendPtr};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Export cutover: below this many elements a serial copy beats the
/// fork-join round trip (exports used to be serial O(n) always —
/// visible at 100M vertices, see ROADMAP).
const PAR_EXPORT_MIN: usize = 1 << 14;

/// Leaf size of the parallel export loop.
const PAR_EXPORT_GRAIN: usize = 1 << 12;

/// Epoch-stamped array of `u32` slots (stamp and value packed in one
/// `AtomicU64`: high 32 bits = stamp, low 32 bits = value).
pub struct StampedU32 {
    slots: Vec<AtomicU64>,
    /// Current epoch; slot i is live iff its stamp equals this. Starts
    /// at 1 so the zeroed initial slots are stale.
    epoch: u32,
    /// Logical value of a stale slot.
    default: u32,
}

impl Default for StampedU32 {
    /// Empty array with default value 0 (re-target with
    /// [`StampedU32::reset`]).
    fn default() -> Self {
        StampedU32::new(0)
    }
}

impl StampedU32 {
    /// Empty array reading `default` everywhere.
    pub fn new(default: u32) -> StampedU32 {
        StampedU32 {
            slots: Vec::new(),
            epoch: 1,
            default,
        }
    }

    /// Array of `n` slots reading `default` everywhere.
    pub fn with_len(default: u32, n: usize) -> StampedU32 {
        let mut s = StampedU32::new(default);
        s.ensure_len(n);
        s
    }

    #[inline]
    fn pack(&self, v: u32) -> u64 {
        ((self.epoch as u64) << 32) | v as u64
    }

    #[inline]
    fn decode(&self, packed: u64) -> u32 {
        if (packed >> 32) as u32 == self.epoch {
            packed as u32
        } else {
            self.default
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no slots are allocated.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Default value returned by stale slots.
    pub fn default_value(&self) -> u32 {
        self.default
    }

    /// Grow to at least `n` slots (new slots read as default). Never
    /// shrinks, so a warm workspace never reallocates for a graph it
    /// has already seen.
    pub fn ensure_len(&mut self, n: usize) {
        if self.slots.len() < n {
            self.slots.resize_with(n, || AtomicU64::new(0));
        }
    }

    /// O(1) logical clear: every slot reads as default afterwards.
    pub fn advance_epoch(&mut self) {
        if self.epoch == u32::MAX {
            // Wraparound: one O(n) hard reset every 2^32-1 clears.
            for s in self.slots.iter_mut() {
                *s.get_mut() = 0; // stamp 0 is never a live epoch
            }
            self.epoch = 1;
        } else {
            self.epoch += 1;
        }
    }

    /// O(1) clear *and* change the default stale value (one array can
    /// serve algorithms wanting different sentinels).
    pub fn reset(&mut self, default: u32) {
        self.default = default;
        self.advance_epoch();
    }

    /// Test hook: jump the epoch counter (exercises wraparound).
    pub fn set_epoch_for_test(&mut self, epoch: u32) {
        self.epoch = epoch.max(1);
    }

    /// Logical value of slot `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        self.decode(self.slots[i].load(Ordering::Relaxed))
    }

    /// Unconditional store.
    #[inline]
    pub fn store(&self, i: usize, v: u32) {
        self.slots[i].store(self.pack(v), Ordering::Relaxed);
    }

    /// Atomic `slot = min(slot, v)`; true iff `v` strictly improved
    /// the logical value (mirrors
    /// [`crate::parallel::atomic::write_min_u32`]).
    #[inline]
    pub fn write_min(&self, i: usize, v: u32) -> bool {
        let slot = &self.slots[i];
        let mut p = slot.load(Ordering::Relaxed);
        loop {
            if v >= self.decode(p) {
                return false;
            }
            match slot.compare_exchange_weak(
                p,
                self.pack(v),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => p = seen,
            }
        }
    }

    /// Atomic swap; returns the previous logical value.
    #[inline]
    pub fn swap(&self, i: usize, v: u32) -> u32 {
        let slot = &self.slots[i];
        let mut p = slot.load(Ordering::Relaxed);
        loop {
            match slot.compare_exchange_weak(
                p,
                self.pack(v),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return self.decode(p),
                Err(seen) => p = seen,
            }
        }
    }

    /// Atomic compare-exchange on the logical value: true iff the slot
    /// logically held `expect` and now holds `new` (exactly one caller
    /// wins per value, like a CAS on a plain atomic).
    #[inline]
    pub fn compare_exchange(&self, i: usize, expect: u32, new: u32) -> bool {
        let slot = &self.slots[i];
        let mut p = slot.load(Ordering::Relaxed);
        loop {
            if self.decode(p) != expect {
                return false;
            }
            match slot.compare_exchange_weak(
                p,
                self.pack(new),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(seen) => p = seen,
            }
        }
    }

    /// Logical f32 value (slots store non-negative f32 bits).
    #[inline]
    pub fn get_f32(&self, i: usize) -> f32 {
        f32::from_bits(self.get(i))
    }

    /// Store an f32 by bits.
    #[inline]
    pub fn store_f32(&self, i: usize, v: f32) {
        self.store(i, v.to_bits());
    }

    /// Atomic f32 min via the order-preserving bit trick (non-negative
    /// floats only, like [`crate::parallel::atomic::write_min_f32`]).
    #[inline]
    pub fn write_min_f32(&self, i: usize, v: f32) -> bool {
        debug_assert!(v >= 0.0, "bit-trick min requires non-negative floats");
        self.write_min(i, v.to_bits())
    }

    /// Copy the first `n` logical values into `out` (reusing its
    /// storage). Parallel above [`PAR_EXPORT_MIN`] elements.
    pub fn export_into(&self, n: usize, out: &mut Vec<u32>) {
        self.export_strided_into(0, 1, n, out);
    }

    /// Copy `n` logical values at indices `start, start + stride, ...`
    /// into `out` — the demultiplex primitive for lane-striped
    /// multi-source layouts (`dist[v * lanes + lane]`): lane `l` of a
    /// width-`L` batch exports with `start = l, stride = L`. Parallel
    /// above [`PAR_EXPORT_MIN`] elements.
    pub fn export_strided_into(&self, start: usize, stride: usize, n: usize, out: &mut Vec<u32>) {
        let stride = stride.max(1);
        out.clear();
        if n == 0 {
            return;
        }
        assert!(
            start + (n - 1) * stride < self.slots.len(),
            "export past allocated length"
        );
        out.reserve(n);
        let op = SendPtr(out.as_mut_ptr());
        if n < PAR_EXPORT_MIN {
            for i in 0..n {
                unsafe { *op.add(i) = self.get(start + i * stride) };
            }
        } else {
            parallel_for(0, n, PAR_EXPORT_GRAIN, move |i| unsafe {
                *op.add(i) = self.get(start + i * stride);
            });
        }
        // Every index in 0..n was written exactly once above.
        unsafe { out.set_len(n) };
    }

    /// First `n` logical values as a fresh vector.
    pub fn export(&self, n: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.export_into(n, &mut out);
        out
    }

    /// First `n` logical values reinterpreted as f32 into `out`.
    pub fn export_f32_into(&self, n: usize, out: &mut Vec<f32>) {
        self.export_f32_strided_into(0, 1, n, out);
    }

    /// Strided f32 export (see [`StampedU32::export_strided_into`]).
    pub fn export_f32_strided_into(
        &self,
        start: usize,
        stride: usize,
        n: usize,
        out: &mut Vec<f32>,
    ) {
        let stride = stride.max(1);
        out.clear();
        if n == 0 {
            return;
        }
        assert!(
            start + (n - 1) * stride < self.slots.len(),
            "export past allocated length"
        );
        out.reserve(n);
        let op = SendPtr(out.as_mut_ptr());
        if n < PAR_EXPORT_MIN {
            for i in 0..n {
                unsafe { *op.add(i) = self.get_f32(start + i * stride) };
            }
        } else {
            parallel_for(0, n, PAR_EXPORT_GRAIN, move |i| unsafe {
                *op.add(i) = self.get_f32(start + i * stride);
            });
        }
        unsafe { out.set_len(n) };
    }

    /// First `n` logical f32 values as a fresh vector.
    pub fn export_f32(&self, n: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.export_f32_into(n, &mut out);
        out
    }
}

/// Epoch values for [`StampedU64`] stop here so the valid/busy stamp
/// pair `epoch << 1 | {0, 1}` always fits in a u32.
const MAX_EPOCH_U64: u32 = u32::MAX >> 1;

/// Epoch-stamped array of `u64` slots (separate stamp word; used for
/// the 64-bit reachability masks of multi-source SCC searches).
///
/// Mutation is `fetch_or` only — exactly what the reachability engines
/// need — which keeps the two-word protocol simple: the first writer
/// of an epoch claims the slot (stamp -> BUSY), installs its bits over
/// the stale value, then publishes stamp = valid. Concurrent writers
/// spin for the handful of cycles the handshake takes; readers treat
/// BUSY/stale stamps as "no bits yet".
pub struct StampedU64 {
    stamps: Vec<AtomicU32>,
    vals: Vec<AtomicU64>,
    epoch: u32,
    default: u64,
}

impl Default for StampedU64 {
    /// Empty array with default value 0.
    fn default() -> Self {
        StampedU64::new(0)
    }
}

impl StampedU64 {
    /// Empty array reading `default` everywhere.
    pub fn new(default: u64) -> StampedU64 {
        StampedU64 {
            stamps: Vec::new(),
            vals: Vec::new(),
            epoch: 1,
            default,
        }
    }

    /// Array of `n` slots reading `default` everywhere.
    pub fn with_len(default: u64, n: usize) -> StampedU64 {
        let mut s = StampedU64::new(default);
        s.ensure_len(n);
        s
    }

    #[inline]
    fn valid_stamp(&self) -> u32 {
        self.epoch << 1
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.stamps.len()
    }

    /// True when no slots are allocated.
    pub fn is_empty(&self) -> bool {
        self.stamps.is_empty()
    }

    /// Grow to at least `n` slots (new slots read as default).
    pub fn ensure_len(&mut self, n: usize) {
        if self.stamps.len() < n {
            self.stamps.resize_with(n, || AtomicU32::new(0));
            self.vals.resize_with(n, || AtomicU64::new(0));
        }
    }

    /// O(1) logical clear.
    pub fn advance_epoch(&mut self) {
        if self.epoch == MAX_EPOCH_U64 {
            for s in self.stamps.iter_mut() {
                *s.get_mut() = 0; // stamp 0 belongs to epoch 0: never live
            }
            self.epoch = 1;
        } else {
            self.epoch += 1;
        }
    }

    /// Test hook: jump the epoch counter (exercises wraparound).
    pub fn set_epoch_for_test(&mut self, epoch: u32) {
        self.epoch = epoch.clamp(1, MAX_EPOCH_U64);
    }

    /// Logical value of slot `i`. A slot mid-handshake (BUSY) reads as
    /// default: its first `fetch_or` has not linearized yet.
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        if self.stamps[i].load(Ordering::Acquire) == self.valid_stamp() {
            self.vals[i].load(Ordering::Relaxed)
        } else {
            self.default
        }
    }

    /// Atomic `slot |= bits` on the logical value; returns the
    /// previous logical value (so callers can test `old | bits != old`
    /// exactly as with a plain `AtomicU64::fetch_or`).
    #[inline]
    pub fn fetch_or(&self, i: usize, bits: u64) -> u64 {
        let valid = self.valid_stamp();
        let busy = valid | 1;
        let stamp = &self.stamps[i];
        loop {
            let s = stamp.load(Ordering::Acquire);
            if s == valid {
                return self.vals[i].fetch_or(bits, Ordering::AcqRel);
            }
            if s == busy {
                // Another thread is installing the epoch's first bits;
                // it finishes in two stores.
                std::hint::spin_loop();
                continue;
            }
            // Stale slot: race to become this epoch's first writer.
            if stamp
                .compare_exchange(s, busy, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.vals[i].store(self.default | bits, Ordering::Relaxed);
                stamp.store(valid, Ordering::Release);
                return self.default;
            }
        }
    }

    /// Unconditional store of the logical value. Not linearizable
    /// against a concurrent [`StampedU64::fetch_or`] on the same slot —
    /// callers must guarantee exclusive access to slot `i` (lane
    /// compaction permutes each vertex's word from exactly one task).
    #[inline]
    pub fn store(&self, i: usize, v: u64) {
        self.vals[i].store(v, Ordering::Relaxed);
        self.stamps[i].store(self.valid_stamp(), Ordering::Release);
    }

    /// Copy the first `n` logical values into `out`. Parallel above
    /// [`PAR_EXPORT_MIN`] elements.
    pub fn export_into(&self, n: usize, out: &mut Vec<u64>) {
        assert!(n <= self.stamps.len(), "export past allocated length");
        out.clear();
        if n == 0 {
            return;
        }
        out.reserve(n);
        let op = SendPtr(out.as_mut_ptr());
        if n < PAR_EXPORT_MIN {
            for i in 0..n {
                unsafe { *op.add(i) = self.get(i) };
            }
        } else {
            parallel_for(0, n, PAR_EXPORT_GRAIN, move |i| unsafe {
                *op.add(i) = self.get(i);
            });
        }
        unsafe { out.set_len(n) };
    }

    /// First `n` logical values as a fresh vector.
    pub fn export(&self, n: usize) -> Vec<u64> {
        let mut out = Vec::new();
        self.export_into(n, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::parallel_for;

    #[test]
    fn stale_slots_read_default() {
        let s = StampedU32::with_len(99, 8);
        for i in 0..8 {
            assert_eq!(s.get(i), 99);
        }
    }

    #[test]
    fn store_then_advance_clears() {
        let mut s = StampedU32::with_len(7, 4);
        s.store(2, 42);
        assert_eq!(s.get(2), 42);
        s.advance_epoch();
        assert_eq!(s.get(2), 7);
    }

    #[test]
    fn write_min_semantics_match_plain_atomic() {
        let s = StampedU32::with_len(u32::MAX, 2);
        assert!(s.write_min(0, 10));
        assert!(!s.write_min(0, 10));
        assert!(!s.write_min(0, 11));
        assert!(s.write_min(0, 3));
        assert_eq!(s.get(0), 3);
    }

    #[test]
    fn compare_exchange_wins_once() {
        let s = StampedU32::with_len(0, 1);
        assert!(s.compare_exchange(0, 0, 5));
        assert!(!s.compare_exchange(0, 0, 6));
        assert!(s.compare_exchange(0, 5, 6));
        assert_eq!(s.get(0), 6);
    }

    #[test]
    fn swap_returns_logical_old() {
        let mut s = StampedU32::with_len(0, 1);
        assert_eq!(s.swap(0, 1), 0);
        assert_eq!(s.swap(0, 2), 1);
        s.advance_epoch();
        assert_eq!(s.swap(0, 9), 0, "stale slot swaps from default");
    }

    #[test]
    fn reset_changes_default() {
        let mut s = StampedU32::with_len(0, 2);
        s.store(0, 123);
        s.reset(u32::MAX);
        assert_eq!(s.get(0), u32::MAX);
        assert_eq!(s.get(1), u32::MAX);
    }

    #[test]
    fn f32_min_via_bits() {
        let s = StampedU32::with_len(crate::INF.to_bits(), 1);
        assert!((s.get_f32(0) - crate::INF).abs() < 1.0);
        assert!(s.write_min_f32(0, 2.5));
        assert!(!s.write_min_f32(0, 3.0));
        assert_eq!(s.get_f32(0), 2.5);
    }

    #[test]
    fn wraparound_hard_resets() {
        let mut s = StampedU32::with_len(5, 3);
        s.set_epoch_for_test(u32::MAX - 1);
        s.store(1, 77);
        assert_eq!(s.get(1), 77);
        s.advance_epoch(); // now at MAX
        assert_eq!(s.get(1), 5);
        s.store(1, 88);
        s.advance_epoch(); // wraps: hard reset to epoch 1
        assert_eq!(s.get(1), 5, "values from the MAX epoch must not leak");
        s.store(2, 9);
        assert_eq!(s.get(2), 9);
    }

    #[test]
    fn u64_fetch_or_accumulates_and_clears() {
        let mut s = StampedU64::with_len(0, 4);
        assert_eq!(s.fetch_or(0, 0b01), 0);
        assert_eq!(s.fetch_or(0, 0b10), 0b01);
        assert_eq!(s.get(0), 0b11);
        assert_eq!(s.get(1), 0);
        s.advance_epoch();
        assert_eq!(s.get(0), 0);
        assert_eq!(s.fetch_or(0, 0b100), 0);
        assert_eq!(s.get(0), 0b100);
    }

    #[test]
    fn u64_wraparound_hard_resets() {
        let mut s = StampedU64::with_len(0, 2);
        s.set_epoch_for_test(MAX_EPOCH_U64 - 1);
        s.fetch_or(0, 7);
        s.advance_epoch();
        assert_eq!(s.get(0), 0);
        s.fetch_or(0, 3);
        s.advance_epoch(); // wraps
        assert_eq!(s.get(0), 0);
        s.fetch_or(1, 1);
        assert_eq!(s.get(1), 1);
    }

    #[test]
    fn concurrent_write_min_settles_at_min() {
        let s = StampedU32::with_len(u32::MAX, 1024);
        parallel_for(0, 64 * 1024, 64, |k| {
            let i = k % 1024;
            s.write_min(i, ((k * 2654435761) % 100_000) as u32 + 1);
        });
        // Every slot ended at some written value, never default.
        for i in 0..1024 {
            assert!(s.get(i) < u32::MAX);
        }
    }

    #[test]
    fn concurrent_fetch_or_loses_no_bits() {
        let mut s = StampedU64::with_len(0, 256);
        for round in 0..3 {
            s.advance_epoch();
            parallel_for(0, 64 * 256, 32, |k| {
                let i = k % 256;
                let bit = (k / 256) % 64;
                s.fetch_or(i, 1u64 << bit);
            });
            for i in 0..256 {
                assert_eq!(s.get(i), u64::MAX, "round {round} slot {i}");
            }
        }
    }

    #[test]
    fn export_roundtrips() {
        let s = StampedU32::with_len(1, 5);
        s.store(3, 9);
        assert_eq!(s.export(5), vec![1, 1, 1, 9, 1]);
        let mut u = StampedU64::with_len(0, 3);
        u.fetch_or(1, 6);
        assert_eq!(u.export(3), vec![0, 6, 0]);
        u.advance_epoch();
        assert_eq!(u.export(3), vec![0, 0, 0]);
    }

    #[test]
    fn parallel_export_matches_serial_gets() {
        // Big enough to take the parallel path in all three exports.
        let n = PAR_EXPORT_MIN + 123;
        let s = StampedU32::with_len(7, n);
        for i in (0..n).step_by(3) {
            s.store(i, (i % 1000) as u32);
        }
        let out = s.export(n);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, s.get(i), "index {i}");
        }
        let mut u = StampedU64::with_len(0, n);
        for i in (0..n).step_by(5) {
            u.fetch_or(i, (i as u64) | 1);
        }
        let big = u.export(n);
        for (i, &x) in big.iter().enumerate() {
            assert_eq!(x, u.get(i), "u64 index {i}");
        }
        u.advance_epoch();
        assert!(u.export(n).iter().all(|&x| x == 0));
    }

    #[test]
    fn strided_export_demuxes_lanes() {
        // 3-lane striped layout over 5 "vertices".
        let lanes = 3usize;
        let n = 5usize;
        let s = StampedU32::with_len(u32::MAX, n * lanes);
        for v in 0..n {
            for l in 0..lanes {
                s.store(v * lanes + l, (10 * v + l) as u32);
            }
        }
        let mut out = Vec::new();
        for l in 0..lanes {
            s.export_strided_into(l, lanes, n, &mut out);
            let want: Vec<u32> = (0..n).map(|v| (10 * v + l) as u32).collect();
            assert_eq!(out, want, "lane {l}");
        }
        // f32 flavour.
        let f = StampedU32::with_len(crate::INF.to_bits(), 2 * 2);
        f.store_f32(1, 2.5); // vertex 0, lane 1
        f.store_f32(3, 4.5); // vertex 1, lane 1
        let mut fout = Vec::new();
        f.export_f32_strided_into(1, 2, 2, &mut fout);
        assert_eq!(fout, vec![2.5, 4.5]);
        f.export_f32_strided_into(0, 2, 2, &mut fout);
        assert!(fout.iter().all(|&x| x >= crate::INF));
    }

    #[test]
    fn export_zero_len_is_empty() {
        let s = StampedU32::new(0);
        let mut out = vec![1, 2, 3];
        s.export_into(0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn ensure_len_grows_with_stale_slots() {
        let mut s = StampedU32::with_len(4, 2);
        s.store(0, 1);
        s.ensure_len(10);
        assert_eq!(s.len(), 10);
        assert_eq!(s.get(0), 1, "existing live slots survive growth");
        assert_eq!(s.get(9), 4);
    }
}
