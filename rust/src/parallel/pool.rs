//! Persistent work-stealing fork-join pool.
//!
//! One [`Deque`] per worker plus a global injector for external
//! submissions and overflow. [`join`] is the fork-join primitive all
//! data-parallel ops are built on: the forked half is pushed to the
//! local deque (work-first), and while waiting the owner *helps* —
//! popping its own deque or stealing — so no worker ever blocks on a
//! latch with runnable work in the system.
//!
//! The pool is deliberately simple where simplicity is honest (park
//! with timeout instead of a lost-wakeup-proof sleep protocol) and
//! careful where the paper's measurements live (push/pop/steal are
//! the calibrated `spawn` cost of the simulator's cost model).

use super::deque::{Deque, Steal};
use super::job::{HeapJob, JobRef, StackJob};
use super::latch::{CountLatch, Latch, LockLatch, SpinLatch};
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

thread_local! {
    /// (shared pool ptr, worker index) when running on a worker.
    static WORKER: Cell<(usize, usize)> = const { Cell::new((0, usize::MAX)) };
}

struct Shared {
    deques: Vec<Deque>,
    injector: Mutex<VecDeque<JobRef>>,
    injector_len: AtomicUsize,
    sleep_lock: Mutex<()>,
    wake: Condvar,
    sleepers: AtomicUsize,
    shutdown: AtomicBool,
    /// Monotone counters for the calibration benches.
    steals: AtomicUsize,
    executed: AtomicUsize,
}

/// A fork-join worker pool. Usually accessed through the process-wide
/// instance via [`with_pool`] / [`join`]; tests construct private ones.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

/// Thread count: `PASGAL_THREADS` env override, else
/// `available_parallelism`.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("PASGAL_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// The process-wide pool (created on first use with [`num_threads`]).
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| Pool::new(num_threads()))
}

/// Run `f` with a reference to the global pool.
pub fn with_pool<R>(f: impl FnOnce(&Pool) -> R) -> R {
    f(global())
}

/// Fork-join on the global pool: runs `a` and `b` in parallel, returns
/// both results. The primitive everything else is built from.
pub fn join<A, B, RA, RB>(a: A, b: B) -> (RA, RB)
where
    A: FnOnce() -> RA + Send,
    B: FnOnce() -> RB + Send,
    RA: Send,
    RB: Send,
{
    global().join(a, b)
}

impl Pool {
    /// Spin up `threads` workers.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            deques: (0..threads).map(|_| Deque::new()).collect(),
            injector: Mutex::new(VecDeque::new()),
            injector_len: AtomicUsize::new(0),
            sleep_lock: Mutex::new(()),
            wake: Condvar::new(),
            sleepers: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            steals: AtomicUsize::new(0),
            executed: AtomicUsize::new(0),
        });
        let handles = (0..threads)
            .map(|idx| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("pasgal-worker-{idx}"))
                    // Helping-while-waiting compounds stack frames of
                    // unrelated jobs on one stack; give workers room.
                    .stack_size(64 << 20)
                    .spawn(move || worker_loop(sh, idx))
                    .expect("spawning worker")
            })
            .collect();
        Pool {
            shared,
            handles,
            threads,
        }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total successful steals (calibration metric).
    pub fn steal_count(&self) -> usize {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Total jobs executed by workers (calibration metric).
    pub fn executed_count(&self) -> usize {
        self.shared.executed.load(Ordering::Relaxed)
    }

    fn shared_id(&self) -> usize {
        Arc::as_ptr(&self.shared) as usize
    }

    fn on_this_pool(&self) -> Option<usize> {
        let (pool, idx) = WORKER.with(|w| w.get());
        (pool == self.shared_id() && idx != usize::MAX).then_some(idx)
    }

    /// Run `f` on a worker of this pool, blocking until done. If the
    /// caller already is a worker of this pool, runs inline.
    pub fn run<F, R>(&self, f: F) -> R
    where
        F: FnOnce() -> R + Send,
        R: Send,
    {
        if self.on_this_pool().is_some() {
            return f();
        }
        let latch = LockLatch::new();
        let mut result: Option<std::thread::Result<R>> = None;
        {
            let result_ptr = super::ops::SendPtr(&mut result as *mut Option<std::thread::Result<R>>);
            let latch_ptr = super::ops::SendPtr(&latch as *const LockLatch as *mut LockLatch);
            // Safety: we block on `latch` before `result`/`latch` drop,
            // so the raw pointers outlive the job.
            let wrapper = move || {
                // Bind the wrappers whole: edition-2021 disjoint capture
                // would otherwise capture the raw-pointer fields (which
                // are not Send) instead of the Send wrapper structs.
                let (result_ptr, latch_ptr) = (result_ptr, latch_ptr);
                // Catch panics: they must not unwind through the worker
                // loop (that kills the worker and deadlocks waiters);
                // re-thrown on the calling thread below.
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                unsafe {
                    *result_ptr.0 = Some(r);
                    (*latch_ptr.0).set();
                }
            };
            let job = HeapJob::push(wrapper, std::ptr::null());
            self.inject(job);
        }
        latch.wait();
        match result.expect("pool job did not produce a result") {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }

    /// Fork-join inside this pool.
    pub fn join<A, B, RA, RB>(&self, a: A, b: B) -> (RA, RB)
    where
        A: FnOnce() -> RA + Send,
        B: FnOnce() -> RB + Send,
        RA: Send,
        RB: Send,
    {
        match self.on_this_pool() {
            Some(idx) => self.join_worker(idx, a, b),
            None => self.run(|| {
                let idx = self.on_this_pool().expect("run() puts us on a worker");
                self.join_worker(idx, a, b)
            }),
        }
    }

    fn join_worker<A, B, RA, RB>(&self, idx: usize, a: A, b: B) -> (RA, RB)
    where
        A: FnOnce() -> RA + Send,
        B: FnOnce() -> RB + Send,
        RA: Send,
        RB: Send,
    {
        let sh = &*self.shared;
        let mut job_b = StackJob::new(b);
        let b_ref = job_b.as_job_ref();
        sh.deques[idx].push(b_ref);
        sh.wake_one();

        let ra = a();

        // Drain our own deque until we find job_b or it's been stolen.
        while !job_b.latch.probe() {
            match sh.deques[idx].pop() {
                Some(j) if j == b_ref => {
                    // Not stolen: run inline (fast path).
                    unsafe { job_b.run_inline() };
                    break;
                }
                Some(j) => unsafe {
                    sh.executed.fetch_add(1, Ordering::Relaxed);
                    j.execute();
                },
                None => {
                    // Stolen: help others while the thief finishes.
                    self.wait_helping(idx, &job_b.latch);
                    break;
                }
            }
        }
        debug_assert!(job_b.latch.probe());
        let rb = job_b.take_result();
        (ra, rb)
    }

    /// Steal/execute work until `latch` is set.
    fn wait_helping(&self, idx: usize, latch: &SpinLatch) {
        let sh = &*self.shared;
        let mut spin = 0u32;
        while !latch.probe() {
            if let Some(job) = sh.find_work(idx) {
                sh.executed.fetch_add(1, Ordering::Relaxed);
                unsafe { job.execute() };
                spin = 0;
            } else {
                spin += 1;
                if spin < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Push an external job to the injector and wake a worker.
    fn inject(&self, job: JobRef) {
        let sh = &*self.shared;
        sh.injector.lock().unwrap().push_back(job);
        sh.injector_len.fetch_add(1, Ordering::Release);
        sh.wake_all();
    }

    /// Fire-and-forget spawn tracked by `done`.
    fn spawn_counted<F>(&self, f: F, done: &CountLatch)
    where
        F: FnOnce() + Send,
    {
        done.add(1);
        let job = HeapJob::push(f, done as *const CountLatch);
        match self.on_this_pool() {
            Some(idx) => {
                self.shared.deques[idx].push(job);
                self.shared.wake_one();
            }
            None => self.inject(job),
        }
    }

    /// Structured-concurrency scope: `body` may spawn any number of
    /// tasks through the [`Scope`] handle; `scope` returns only after
    /// every spawned task finished. Tasks must be `'static`-free via
    /// the scope lifetime (they may borrow data outliving the call).
    pub fn scope<'env, F, R>(&self, body: F) -> R
    where
        F: FnOnce(&Scope<'env, '_>) -> R,
    {
        let done = CountLatch::new(0);
        let scope = Scope {
            pool: self,
            done: &done,
            _env: std::marker::PhantomData,
        };
        let r = body(&scope);
        // Help until every spawned task completes.
        match self.on_this_pool() {
            Some(idx) => {
                let sh = &*self.shared;
                while !done.probe() {
                    if let Some(job) = sh.deques[idx].pop().or_else(|| sh.find_work(idx)) {
                        sh.executed.fetch_add(1, Ordering::Relaxed);
                        unsafe { job.execute() };
                    } else {
                        std::hint::spin_loop();
                    }
                }
            }
            None => {
                while !done.probe() {
                    std::thread::yield_now();
                }
            }
        }
        r
    }
}

/// Spawn handle passed to [`Pool::scope`] bodies.
pub struct Scope<'env, 'pool> {
    pool: &'pool Pool,
    done: &'pool CountLatch,
    _env: std::marker::PhantomData<&'env ()>,
}

impl<'env, 'pool> Scope<'env, 'pool> {
    /// Spawn a task that must finish before the scope returns.
    ///
    /// The closure may borrow from `'env` (data outliving the scope
    /// call); the scope's exit barrier makes that sound.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        // Safety: the scope blocks until `done` reaches zero, so the
        // erased closure cannot outlive its borrows.
        let f: Box<dyn FnOnce() + Send + 'env> = Box::new(f);
        let f: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(f) };
        self.pool.spawn_counted(move || f(), self.done);
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.wake_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Shared {
    /// Find runnable work: injector first (fairness for external
    /// callers), then steal sweep starting after `idx`.
    fn find_work(&self, idx: usize) -> Option<JobRef> {
        if self.injector_len.load(Ordering::Acquire) > 0 {
            if let Some(job) = self.injector.lock().unwrap().pop_front() {
                self.injector_len.fetch_sub(1, Ordering::Release);
                return Some(job);
            }
        }
        let n = self.deques.len();
        for probe in 0..n {
            let victim = (idx + 1 + probe) % n;
            if victim == idx {
                continue;
            }
            loop {
                match self.deques[victim].steal() {
                    Steal::Success(job) => {
                        self.steals.fetch_add(1, Ordering::Relaxed);
                        return Some(job);
                    }
                    Steal::Retry => continue,
                    Steal::Empty => break,
                }
            }
        }
        None
    }

    fn wake_one(&self) {
        if self.sleepers.load(Ordering::Acquire) > 0 {
            let _g = self.sleep_lock.lock().unwrap();
            self.wake.notify_one();
        }
    }

    fn wake_all(&self) {
        let _g = self.sleep_lock.lock().unwrap();
        self.wake.notify_all();
    }
}

fn worker_loop(sh: Arc<Shared>, idx: usize) {
    WORKER.with(|w| w.set((Arc::as_ptr(&sh) as usize, idx)));
    let mut spin = 0u32;
    loop {
        if sh.shutdown.load(Ordering::Acquire) {
            return;
        }
        let job = sh.deques[idx].pop().or_else(|| sh.find_work(idx));
        match job {
            Some(j) => {
                sh.executed.fetch_add(1, Ordering::Relaxed);
                unsafe { j.execute() };
                spin = 0;
            }
            None => {
                spin += 1;
                if spin < 16 {
                    std::hint::spin_loop();
                } else if spin < 32 {
                    std::thread::yield_now();
                } else {
                    // Park with timeout: immune to lost wakeups.
                    sh.sleepers.fetch_add(1, Ordering::AcqRel);
                    let g = sh.sleep_lock.lock().unwrap();
                    let _ = sh.wake.wait_timeout(g, Duration::from_millis(1)).unwrap();
                    sh.sleepers.fetch_sub(1, Ordering::AcqRel);
                    spin = 16;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_returns_both_results() {
        let pool = Pool::new(2);
        let (a, b) = pool.join(|| 1 + 1, || "two");
        assert_eq!(a, 2);
        assert_eq!(b, "two");
    }

    #[test]
    fn nested_joins_compute_fib() {
        fn fib(pool: &Pool, n: u64) -> u64 {
            if n < 2 {
                return n;
            }
            let (a, b) = pool.join(|| fib_inner(n - 1), || fib_inner(n - 2));
            a + b
        }
        fn fib_inner(n: u64) -> u64 {
            if n < 2 {
                return n;
            }
            let (a, b) = join(|| fib_inner(n - 1), || fib_inner(n - 2));
            a + b
        }
        let pool = Pool::new(4);
        assert_eq!(fib(&pool, 16), 987);
    }

    #[test]
    fn run_from_external_thread() {
        let pool = Pool::new(2);
        let v = pool.run(|| (0..100).sum::<i32>());
        assert_eq!(v, 4950);
    }

    #[test]
    fn join_borrows_stack_data() {
        let pool = Pool::new(2);
        let data = vec![1u64; 1000];
        let (s1, s2) = pool.join(
            || data[..500].iter().sum::<u64>(),
            || data[500..].iter().sum::<u64>(),
        );
        assert_eq!(s1 + s2, 1000);
    }

    #[test]
    fn many_concurrent_runs() {
        let pool = Arc::new(Pool::new(3));
        std::thread::scope(|s| {
            for t in 0..6 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    for i in 0..50 {
                        let v = pool.run(move || t * 1000 + i);
                        assert_eq!(v, t * 1000 + i);
                    }
                });
            }
        });
    }

    #[test]
    fn global_pool_join_works() {
        let (a, b) = join(|| 40, || 2);
        assert_eq!(a + b, 42);
    }

    #[test]
    fn scope_waits_for_all_spawns() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = Pool::new(2);
        let hits = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..100 {
                s.spawn(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn scope_spawns_borrow_stack_data() {
        let pool = Pool::new(2);
        let data = vec![1u64; 1000];
        let sum = std::sync::atomic::AtomicU64::new(0);
        pool.scope(|s| {
            for chunk in data.chunks(100) {
                let sum = &sum;
                s.spawn(move || {
                    sum.fetch_add(
                        chunk.iter().sum::<u64>(),
                        std::sync::atomic::Ordering::Relaxed,
                    );
                });
            }
        });
        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 1000);
    }

    #[test]
    fn nested_scopes() {
        let pool = Pool::new(2);
        let count = std::sync::atomic::AtomicUsize::new(0);
        pool.scope(|outer| {
            for _ in 0..4 {
                let count = &count;
                outer.spawn(move || {
                    count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                });
            }
        });
        pool.scope(|s| {
            let count = &count;
            s.spawn(move || {
                count.fetch_add(10, std::sync::atomic::Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::Relaxed), 14);
    }

    #[test]
    fn deep_recursion_balanced_tree() {
        // ~2^12 leaves; exercises deque growth + stealing.
        fn count(lo: usize, hi: usize) -> usize {
            if hi - lo <= 1 {
                return hi - lo;
            }
            let mid = (lo + hi) / 2;
            let (a, b) = join(|| count(lo, mid), || count(mid, hi));
            a + b
        }
        assert_eq!(count(0, 4096), 4096);
    }
}
