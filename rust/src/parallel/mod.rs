//! Fork-join parallel runtime (the ParlayLib-role substrate).
//!
//! PASGAL's subject is *scheduling overhead*: on large-diameter graphs
//! the per-round cost of distributing and synchronizing threads
//! dominates the tiny per-round work. To study that honestly we own
//! the scheduler end-to-end:
//!
//! * [`deque`] — Chase–Lev work-stealing deques (per worker).
//! * [`pool`] — persistent worker pool with a global injector,
//!   fork-join [`join`], and worker parking.
//! * [`ops`] — flat data-parallel primitives: [`parallel_for`],
//!   [`parallel_reduce`], [`scan_inplace`], [`pack`], built on `join`
//!   with (horizontal) granularity control.
//! * [`sort`] — parallel stable merge sort.
//! * [`vgc`] — **vertical granularity control**: the paper's core
//!   technique. A τ-budgeted local search that lets one scheduled task
//!   advance many hops, hiding scheduling overhead (§2.1 of the
//!   paper).
//! * [`atomic`] — lock-free min/CAS helpers used by the algorithms.
//! * [`workspace`] — epoch-stamped scratch arrays ([`StampedU32`] /
//!   [`StampedU64`]): O(1) logical reset so per-query state can be
//!   reused across queries with zero O(n) allocation after warm-up.
//!
//! Thread count comes from `PASGAL_THREADS` or
//! `std::thread::available_parallelism`.

pub mod atomic;
pub mod deque;
mod job;
mod latch;
pub mod ops;
pub mod pool;
pub mod sort;
pub mod vgc;
pub mod workspace;

pub use ops::{pack, pack_index, pack_index_into, pack_into, parallel_for, parallel_reduce, scan_inplace};
pub use pool::{join, num_threads, with_pool, Pool, Scope};
pub use sort::parallel_sort_by_key;
pub use vgc::LocalSearch;
pub use workspace::{StampedU32, StampedU64};

/// Default horizontal granularity (iterations per leaf task) for
/// `parallel_for` when the caller has no better estimate.
pub const DEFAULT_GRAIN: usize = 1024;

/// Default vertical granularity τ: minimum vertices visited per local
/// search (paper §2.1; tuned by `benches/ablation_tau.rs`).
pub const DEFAULT_TAU: usize = 512;
