//! Type-erased jobs for the work-stealing scheduler.
//!
//! A deque slot must be a single machine word (stealers CAS `top` and
//! read the slot non-atomically-paired), so jobs are erased to a raw
//! pointer to a header whose first field is the execute thunk —
//! rayon's `JobRef` scheme, simplified.

use super::latch::{CountLatch, Latch, SpinLatch};
use std::mem::ManuallyDrop;

/// First field of every concrete job type; the deque stores `*mut JobHeader`.
#[repr(C)]
pub struct JobHeader {
    /// Called exactly once; consumes the job's payload.
    pub exec: unsafe fn(*mut JobHeader),
}

/// Single-word erased reference to a pending job.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct JobRef(pub *mut JobHeader);

unsafe impl Send for JobRef {}

impl JobRef {
    /// Execute (and logically consume) the job.
    ///
    /// # Safety
    /// Must be called exactly once per job instance, and the job
    /// storage must still be alive (guaranteed by `StackJob`'s scoped
    /// usage and `HeapJob`'s boxed ownership).
    pub unsafe fn execute(self) {
        ((*self.0).exec)(self.0)
    }
}

/// A job whose closure and result live in the spawning stack frame
/// (used by `join`: frame outlives the job by construction).
///
/// Panics in the job are caught and stored, then re-thrown on the
/// joining thread by [`StackJob::take_result`] — a panic must not
/// unwind through the worker loop (it would kill the worker and
/// deadlock every waiter).
#[repr(C)]
pub struct StackJob<F, R> {
    header: JobHeader,
    func: ManuallyDrop<F>,
    pub result: Option<std::thread::Result<R>>,
    pub latch: SpinLatch,
}

impl<F, R> StackJob<F, R>
where
    F: FnOnce() -> R + Send,
    R: Send,
{
    pub fn new(func: F) -> Self {
        StackJob {
            header: JobHeader {
                exec: Self::exec_thunk,
            },
            func: ManuallyDrop::new(func),
            result: None,
            latch: SpinLatch::new(),
        }
    }

    pub fn as_job_ref(&mut self) -> JobRef {
        JobRef(&mut self.header as *mut JobHeader)
    }

    unsafe fn exec_thunk(header: *mut JobHeader) {
        let this = &mut *(header as *mut Self);
        let func = ManuallyDrop::take(&mut this.func);
        this.result = Some(std::panic::catch_unwind(std::panic::AssertUnwindSafe(func)));
        this.latch.set();
    }

    /// Run inline on the owning thread (un-stolen pop fast path).
    pub unsafe fn run_inline(&mut self) {
        let func = ManuallyDrop::take(&mut self.func);
        self.result = Some(std::panic::catch_unwind(std::panic::AssertUnwindSafe(func)));
        self.latch.set();
    }

    /// Unwrap the result, re-throwing a stored panic.
    pub fn take_result(&mut self) -> R {
        match self.result.take().expect("join: missing forked result") {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }
}

/// A heap-owned fire-and-forget job (used by the injector for external
/// submissions and scope spawns); decrements `done` when finished.
#[repr(C)]
pub struct HeapJob<F> {
    header: JobHeader,
    func: ManuallyDrop<F>,
    done: *const CountLatch,
}

impl<F> HeapJob<F>
where
    F: FnOnce() + Send,
{
    /// Box the job and return its erased ref. `done` must outlive the
    /// job's execution (the pool's `install`/`scope` guarantee it).
    pub fn push(func: F, done: *const CountLatch) -> JobRef {
        let boxed = Box::new(HeapJob {
            header: JobHeader {
                exec: Self::exec_thunk,
            },
            func: ManuallyDrop::new(func),
            done,
        });
        JobRef(Box::into_raw(boxed) as *mut JobHeader)
    }

    unsafe fn exec_thunk(header: *mut JobHeader) {
        let mut boxed = Box::from_raw(header as *mut Self);
        let func = ManuallyDrop::take(&mut boxed.func);
        let done = boxed.done;
        drop(boxed);
        func();
        if !done.is_null() {
            (*done).done();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn stack_job_roundtrip() {
        let mut job = StackJob::new(|| 21 * 2);
        let r = job.as_job_ref();
        unsafe { r.execute() };
        assert!(job.latch.probe());
        assert_eq!(job.take_result(), 42);
    }

    #[test]
    fn stack_job_captures_panic() {
        let mut job = StackJob::new(|| -> u32 { panic!("boom") });
        let r = job.as_job_ref();
        unsafe { r.execute() }; // must not unwind here
        assert!(job.latch.probe());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job.take_result()));
        assert!(caught.is_err(), "panic must re-throw at take_result");
    }

    #[test]
    fn heap_job_runs_and_counts_down() {
        let hit = AtomicUsize::new(0);
        let latch = CountLatch::new(1);
        let r = HeapJob::push(
            || {
                hit.fetch_add(1, Ordering::SeqCst);
            },
            &latch as *const CountLatch,
        );
        unsafe { r.execute() };
        assert_eq!(hit.load(Ordering::SeqCst), 1);
        assert!(latch.probe());
    }
}
