//! Completion latches for the scheduler.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Minimal latch interface: one-way false -> true.
pub trait Latch {
    fn set(&self);
    fn probe(&self) -> bool;
}

/// Set-once flag probed by a worker that steals while waiting.
#[derive(Default)]
pub struct SpinLatch {
    set: AtomicBool,
}

impl SpinLatch {
    pub fn new() -> Self {
        SpinLatch {
            set: AtomicBool::new(false),
        }
    }
}

impl Latch for SpinLatch {
    #[inline]
    fn set(&self) {
        self.set.store(true, Ordering::Release);
    }
    #[inline]
    fn probe(&self) -> bool {
        self.set.load(Ordering::Acquire)
    }
}

/// Blocking latch for external (non-worker) threads: `wait` parks on a
/// condvar until a worker calls `set`.
pub struct LockLatch {
    done: Mutex<bool>,
    cv: Condvar,
}

impl LockLatch {
    pub fn new() -> Self {
        LockLatch {
            done: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    pub fn wait(&self) {
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.cv.wait(done).unwrap();
        }
    }
}

impl Latch for LockLatch {
    fn set(&self) {
        *self.done.lock().unwrap() = true;
        self.cv.notify_all();
    }
    fn probe(&self) -> bool {
        *self.done.lock().unwrap()
    }
}

/// Counts down to zero; used by scopes / batched injections.
pub struct CountLatch {
    remaining: AtomicUsize,
}

impl CountLatch {
    pub fn new(count: usize) -> Self {
        CountLatch {
            remaining: AtomicUsize::new(count),
        }
    }

    pub fn add(&self, n: usize) {
        self.remaining.fetch_add(n, Ordering::Relaxed);
    }

    pub fn done(&self) {
        self.remaining.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn probe(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spin_latch_transitions_once() {
        let l = SpinLatch::new();
        assert!(!l.probe());
        l.set();
        assert!(l.probe());
    }

    #[test]
    fn count_latch_counts() {
        let l = CountLatch::new(2);
        assert!(!l.probe());
        l.done();
        assert!(!l.probe());
        l.done();
        assert!(l.probe());
    }

    #[test]
    fn lock_latch_wakes_waiter() {
        use std::sync::Arc;
        let l = Arc::new(LockLatch::new());
        let l2 = Arc::clone(&l);
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            l2.set();
        });
        l.wait();
        assert!(l.probe());
        h.join().unwrap();
    }
}
