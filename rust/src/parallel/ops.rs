//! Flat data-parallel primitives built on [`join`].
//!
//! These are the ParlayLib-style building blocks the graph algorithms
//! use between frontier rounds: `parallel_for` (with horizontal
//! granularity control), `parallel_reduce`, blocked exclusive
//! `scan_inplace`, and `pack`/`pack_index` (filter-by-flag, the
//! frontier-compaction primitive).

use super::pool::join;

/// Raw pointer wrapper so disjoint writes can cross the `join`
/// boundary. Safety contract: every call site must write disjoint
/// index ranges.
#[derive(Copy, Clone)]
pub(crate) struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    #[inline]
    pub(crate) unsafe fn add(self, i: usize) -> *mut T {
        self.0.add(i)
    }
}

/// Parallel `for i in lo..hi { f(i) }` with leaf size `grain`.
///
/// Recursive binary splitting over the index range; leaves run
/// sequentially. `grain` is the paper's *horizontal* granularity
/// control: the task size below which scheduling overhead would
/// exceed useful work.
pub fn parallel_for<F>(lo: usize, hi: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_ref(lo, hi, grain.max(1), &f);
}

fn parallel_for_ref<F>(lo: usize, hi: usize, grain: usize, f: &F)
where
    F: Fn(usize) + Sync,
{
    if hi <= lo {
        return;
    }
    if hi - lo <= grain {
        for i in lo..hi {
            f(i);
        }
        return;
    }
    let mid = lo + (hi - lo) / 2;
    join(
        || parallel_for_ref(lo, mid, grain, f),
        || parallel_for_ref(mid, hi, grain, f),
    );
}

/// Parallel loop over *chunks*: `f(chunk_index, lo..hi)` for
/// consecutive ranges of length `grain` (last one shorter). Used where
/// the body wants chunk-local state (e.g. a VGC local-search stack).
pub fn parallel_for_chunks<F>(lo: usize, hi: usize, grain: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let grain = grain.max(1);
    if hi <= lo {
        return;
    }
    let chunks = (hi - lo).div_ceil(grain);
    parallel_for(0, chunks, 1, |c| {
        let s = lo + c * grain;
        let e = (s + grain).min(hi);
        f(c, s..e);
    });
}

/// Parallel reduction of `map(i)` over `lo..hi` with an associative
/// `combine` and identity `id`.
pub fn parallel_reduce<R, M, C>(lo: usize, hi: usize, grain: usize, id: R, map: M, combine: C) -> R
where
    R: Send + Sync + Clone,
    M: Fn(usize) -> R + Sync,
    C: Fn(R, R) -> R + Sync,
{
    reduce_ref(lo, hi, grain.max(1), &id, &map, &combine)
}

fn reduce_ref<R, M, C>(lo: usize, hi: usize, grain: usize, id: &R, map: &M, combine: &C) -> R
where
    R: Send + Sync + Clone,
    M: Fn(usize) -> R + Sync,
    C: Fn(R, R) -> R + Sync,
{
    if hi <= lo {
        return id.clone();
    }
    if hi - lo <= grain {
        let mut acc = id.clone();
        for i in lo..hi {
            acc = combine(acc, map(i));
        }
        return acc;
    }
    let mid = lo + (hi - lo) / 2;
    let (a, b) = join(
        || reduce_ref(lo, mid, grain, id, map, combine),
        || reduce_ref(mid, hi, grain, id, map, combine),
    );
    combine(a, b)
}

/// Exclusive prefix sum in place; returns the total. Blocked two-pass
/// (block sums, sequential scan of block sums, parallel fix-up).
pub fn scan_inplace(v: &mut [usize]) -> usize {
    let n = v.len();
    if n == 0 {
        return 0;
    }
    let block = scan_block_size(n);
    let nblocks = n.div_ceil(block);
    if nblocks <= 1 {
        return seq_exclusive_scan(v);
    }
    // Pass 1: per-block totals.
    let mut sums = vec![0usize; nblocks];
    {
        let vp = SendPtr(v.as_mut_ptr());
        let sp = SendPtr(sums.as_mut_ptr());
        parallel_for(0, nblocks, 1, |b| unsafe {
            let s = b * block;
            let e = (s + block).min(n);
            let mut acc = 0usize;
            for i in s..e {
                acc += *vp.add(i);
            }
            *sp.add(b) = acc;
        });
    }
    // Sequential scan of block sums (nblocks is small).
    let total = seq_exclusive_scan(&mut sums);
    // Pass 2: per-block exclusive scan with block offset.
    {
        let vp = SendPtr(v.as_mut_ptr());
        let sums_ref = &sums;
        parallel_for(0, nblocks, 1, move |b| unsafe {
            let s = b * block;
            let e = (s + block).min(n);
            let mut acc = sums_ref[b];
            for i in s..e {
                let x = *vp.add(i);
                *vp.add(i) = acc;
                acc += x;
            }
        });
    }
    total
}

fn scan_block_size(n: usize) -> usize {
    let t = super::pool::num_threads();
    (n.div_ceil(4 * t)).clamp(1024, 1 << 16).min(n.max(1))
}

fn seq_exclusive_scan(v: &mut [usize]) -> usize {
    let mut acc = 0usize;
    for x in v.iter_mut() {
        let cur = *x;
        *x = acc;
        acc += cur;
    }
    acc
}

/// Keep `input[i]` where `keep(i)`; returns the packed vector in
/// order. The frontier-compaction primitive.
pub fn pack<T, F>(input: &[T], keep: F) -> Vec<T>
where
    T: Copy + Send + Sync,
    F: Fn(usize) -> bool + Sync,
{
    let mut out = Vec::new();
    pack_into(input, keep, &mut out);
    out
}

/// [`pack`] into a caller-owned buffer (cleared first), so hot loops
/// can reuse one allocation across rounds.
pub fn pack_into<T, F>(input: &[T], keep: F, out: &mut Vec<T>)
where
    T: Copy + Send + Sync,
    F: Fn(usize) -> bool + Sync,
{
    let n = input.len();
    let mut counts = count_blocks(n, &keep);
    let total = scan_inplace(&mut counts);
    out.clear();
    out.reserve(total);
    {
        let op = SendPtr(out.as_mut_ptr());
        let block = pack_block_size(n);
        let counts_ref = &counts;
        let keep_ref = &keep;
        parallel_for(0, counts.len(), 1, move |b| unsafe {
            let s = b * block;
            let e = (s + block).min(n);
            let mut w = counts_ref[b];
            for i in s..e {
                if keep_ref(i) {
                    *op.add(w) = input[i];
                    w += 1;
                }
            }
        });
    }
    unsafe { out.set_len(total) };
}

/// Indices `i in 0..n` with `keep(i)`, in order.
pub fn pack_index<F>(n: usize, keep: F) -> Vec<u32>
where
    F: Fn(usize) -> bool + Sync,
{
    let mut out = Vec::new();
    pack_index_into(n, keep, &mut out);
    out
}

/// [`pack_index`] into a caller-owned buffer (cleared first).
pub fn pack_index_into<F>(n: usize, keep: F, out: &mut Vec<u32>)
where
    F: Fn(usize) -> bool + Sync,
{
    let mut counts = count_blocks(n, &keep);
    let total = scan_inplace(&mut counts);
    out.clear();
    out.reserve(total);
    {
        let op = SendPtr(out.as_mut_ptr());
        let block = pack_block_size(n);
        let counts_ref = &counts;
        let keep_ref = &keep;
        parallel_for(0, counts.len(), 1, move |b| unsafe {
            let s = b * block;
            let e = (s + block).min(n);
            let mut w = counts_ref[b];
            for i in s..e {
                if keep_ref(i) {
                    *op.add(w) = i as u32;
                    w += 1;
                }
            }
        });
    }
    unsafe { out.set_len(total) };
}

fn pack_block_size(n: usize) -> usize {
    scan_block_size(n)
}

fn count_blocks<F>(n: usize, keep: &F) -> Vec<usize>
where
    F: Fn(usize) -> bool + Sync,
{
    if n == 0 {
        return vec![0];
    }
    let block = pack_block_size(n);
    let nblocks = n.div_ceil(block);
    let mut counts = vec![0usize; nblocks];
    {
        let cp = SendPtr(counts.as_mut_ptr());
        parallel_for(0, nblocks, 1, move |b| unsafe {
            let s = b * block;
            let e = (s + block).min(n);
            let mut c = 0usize;
            for i in s..e {
                if keep(i) {
                    c += 1;
                }
            }
            *cp.add(b) = c;
        });
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_every_index_once() {
        let n = 100_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(0, n, 128, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_empty_and_single() {
        parallel_for(5, 5, 10, |_| panic!("must not run"));
        let hit = AtomicUsize::new(0);
        parallel_for(7, 8, 10, |i| {
            assert_eq!(i, 7);
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunks_partition_range() {
        let total = AtomicU64::new(0);
        let chunks = AtomicUsize::new(0);
        parallel_for_chunks(3, 1003, 97, |_, r| {
            chunks.fetch_add(1, Ordering::Relaxed);
            total.fetch_add(r.map(|x| x as u64).sum::<u64>(), Ordering::Relaxed);
        });
        let want: u64 = (3..1003u64).sum();
        assert_eq!(total.load(Ordering::Relaxed), want);
        assert_eq!(chunks.load(Ordering::Relaxed), 1000usize.div_ceil(97));
    }

    #[test]
    fn reduce_sums() {
        let s = parallel_reduce(0, 1_000_001, 1000, 0u64, |i| i as u64, |a, b| a + b);
        assert_eq!(s, 500_000_500_000);
    }

    #[test]
    fn reduce_empty_is_identity() {
        let s = parallel_reduce(10, 10, 4, 7u64, |_| 0, |a, b| a + b);
        assert_eq!(s, 7);
    }

    #[test]
    fn scan_matches_sequential() {
        for n in [0usize, 1, 2, 1023, 1024, 1025, 100_000] {
            let mut v: Vec<usize> = (0..n).map(|i| (i * 7 + 3) % 11).collect();
            let mut expect = v.clone();
            let mut acc = 0;
            for x in expect.iter_mut() {
                let c = *x;
                *x = acc;
                acc += c;
            }
            let total = scan_inplace(&mut v);
            assert_eq!(total, acc, "n={n}");
            assert_eq!(v, expect, "n={n}");
        }
    }

    #[test]
    fn pack_keeps_order() {
        let input: Vec<u32> = (0..50_000).collect();
        let out = pack(&input, |i| i % 3 == 0);
        let expect: Vec<u32> = (0..50_000).filter(|x| x % 3 == 0).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn pack_index_matches_filter() {
        let out = pack_index(10_000, |i| i % 7 == 2);
        let expect: Vec<u32> = (0..10_000u32).filter(|x| x % 7 == 2).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn pack_all_and_none() {
        let input = [5u32; 100];
        assert_eq!(pack(&input, |_| true).len(), 100);
        assert!(pack(&input, |_| false).is_empty());
        assert!(pack_index(0, |_| true).is_empty());
    }
}
