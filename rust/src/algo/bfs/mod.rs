//! Breadth-first search: all four implementations compared in Table 5.
//!
//! * [`seq::seq_bfs`] — the standard queue-based sequential algorithm
//!   (the paper's baseline, "Queue-based*").
//! * [`frontier::frontier_bfs`] — GBBS-like round-synchronous sparse
//!   edge-map: O(D) rounds, one barrier each.
//! * [`diropt::diropt_bfs`] — GAPBS-like direction-optimizing BFS
//!   (Beamer et al. [4]): switches between sparse top-down and dense
//!   bottom-up rounds.
//! * [`vgc::vgc_bfs`] — PASGAL's BFS: τ-budget VGC local searches,
//!   multiple 2^i-distance frontiers backed by hash bags (§2.2).
//!
//! All return hop distances (`UNREACHED` = not reachable) and agree
//! with `seq_bfs` on every graph — enforced by the cross-validation
//! tests at the bottom, which also pin the batched multi-source
//! engines ([`crate::algo::multi`]) to these single-source results:
//! a width-k batch must be bit-identical to k solo runs.

pub mod diropt;
pub mod frontier;
pub mod seq;
pub mod vgc;

pub use diropt::{diropt_bfs, diropt_bfs_ws};
pub use frontier::frontier_bfs;
pub use seq::seq_bfs;
pub use vgc::{vgc_bfs, vgc_bfs_ws};

#[cfg(test)]
mod cross_tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::Graph;
    use crate::prop::{forall, Rng};
    use crate::V;

    fn check_all(g: &Graph, src: V) {
        let want = seq_bfs(g, src);
        let f = frontier_bfs(g, src, None);
        assert_eq!(f, want, "frontier_bfs mismatch");
        let d = diropt_bfs(g, None, src, None);
        assert_eq!(d, want, "diropt_bfs mismatch");
        let v = vgc_bfs(g, src, 64, None);
        assert_eq!(v, want, "vgc_bfs mismatch");
        // τ=1 degenerates to plain frontier processing; still correct.
        let v1 = vgc_bfs(g, src, 1, None);
        assert_eq!(v1, want, "vgc_bfs tau=1 mismatch");
        // Batched engines at width 1 must match the solo runs exactly.
        let mv = crate::algo::multi::multi_bfs_vgc(g, &[src], 64, None);
        assert_eq!(mv[0], want, "multi_bfs_vgc width-1 mismatch");
        let md = crate::algo::multi::multi_bfs_diropt(g, None, &[src], None);
        assert_eq!(md[0], want, "multi_bfs_diropt width-1 mismatch");
    }

    #[test]
    fn all_agree_on_named_shapes() {
        check_all(&gen::path(200), 0);
        check_all(&gen::path(200), 199);
        check_all(&gen::cycle(100), 5);
        check_all(&gen::star(50).symmetrize(), 3);
        check_all(&gen::grid(17, 23), 0);
        check_all(&gen::complete(20), 7);
        check_all(&gen::bubbles(12, 5, 3), 0);
    }

    #[test]
    fn all_agree_on_suite_categories() {
        check_all(&gen::social(10, 8, 1), 0);
        check_all(&gen::road(15, 25, 2), 7);
        check_all(&gen::knn_chain(3000, 4, 9, 3), 1500);
        check_all(&gen::traces(60, 6, 4), 0);
    }

    #[test]
    fn prop_all_agree_on_random_graphs() {
        forall(0xBF5, |rng: &mut Rng| {
            let n = rng.range(1, 250);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            let g = Graph::from_edges(n, &edges, true);
            let src = rng.below(n as u64) as V;
            check_all(&g, src);
        });
    }

    #[test]
    fn batched_widths_match_repeated_solo_queries() {
        // The batching contract on this module's engines: a width-k
        // batch is bit-identical to k solo queries.
        let g = gen::bubbles(10, 6, 2);
        let seeds: Vec<V> = (0..17).map(|i| (i * 5) % g.n() as u32).collect();
        let gt = g.transpose();
        let vgc = crate::algo::multi::multi_bfs_vgc(&g, &seeds, 32, None);
        let dir = crate::algo::multi::multi_bfs_diropt(&g, Some(&gt), &seeds, None);
        for (lane, &s) in seeds.iter().enumerate() {
            let want = seq_bfs(&g, s);
            assert_eq!(vgc[lane], want, "vgc lane {lane}");
            assert_eq!(dir[lane], want, "diropt lane {lane}");
        }
    }

    #[test]
    fn prop_symmetric_graphs_with_transpose_diropt() {
        forall(0xBF6, |rng: &mut Rng| {
            let n = rng.range(2, 200);
            let m = rng.range(1, 3 * n);
            let edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            let g = Graph::from_edges(n, &edges, true).symmetrize();
            let src = rng.below(n as u64) as V;
            let want = seq_bfs(&g, src);
            // With an explicit transpose (== g for symmetric graphs),
            // the dense path is exercised.
            let got = diropt_bfs(&g, Some(&g), src, None);
            assert_eq!(got, want);
        });
    }
}
