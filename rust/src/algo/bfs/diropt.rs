//! GAPBS-like direction-optimizing BFS (Beamer, Asanović, Patterson
//! [4]).
//!
//! Top-down rounds process the frontier sparsely (like
//! `frontier_bfs`); when the frontier's out-edge count grows past
//! m/ALPHA the round flips to bottom-up: every unvisited vertex scans
//! its *in*-neighbors for a frontier member and claims itself. On
//! low-diameter graphs this skips the huge mid-BFS frontiers — the
//! optimization that makes parallel BFS superlinear on social
//! networks. On large-diameter graphs frontiers never get dense, the
//! heuristic never fires, and the O(D)-round cost remains — exactly
//! the contrast the paper draws.
//!
//! Per-query state (distances, level-stamped frontier flags, frontier
//! and edge-map buffers) lives in a reusable [`BfsWorkspace`]:
//! [`diropt_bfs_ws`] resets it in O(1) via epoch stamps;
//! [`diropt_bfs`] is the allocate-per-call wrapper.
//!
//! The batched variant [`crate::algo::multi::multi_bfs_diropt_ws`]
//! runs the same level-synchronous switch for up to 64 sources at
//! once; its bottom-up step tests a whole 64-lane frontier mask word
//! per in-neighbor instead of one flag.

use crate::algo::workspace::BfsWorkspace;
use crate::algo::UNREACHED;
use crate::graph::Graph;
use crate::parallel::{pack_index_into, pack_into, parallel_for};
use crate::sim::trace::{Recorder, RoundSlots, TaskCost};
use crate::V;
use std::sync::atomic::{AtomicU64, Ordering};

/// GAPBS defaults.
const ALPHA: usize = 15;
const BETA: usize = 18;

/// Hop distances from `src` (allocate-per-call wrapper around
/// [`diropt_bfs_ws`]).
pub fn diropt_bfs(g: &Graph, gt: Option<&Graph>, src: V, rec: Recorder) -> Vec<u32> {
    let mut ws = BfsWorkspace::new();
    diropt_bfs_ws(g, gt, src, rec, &mut ws);
    ws.dist.export(g.n())
}

/// Hop distances from `src`, computed in a reusable workspace and left
/// in `ws.dist`. `gt` supplies in-neighbors for directed graphs (pass
/// `Some(&g)` for symmetric ones); without it the algorithm stays
/// top-down (still correct).
pub fn diropt_bfs_ws(
    g: &Graph,
    gt: Option<&Graph>,
    src: V,
    mut rec: Recorder,
    ws: &mut BfsWorkspace,
) {
    let n = g.n();
    let m = g.m();
    ws.dist.ensure_len(n);
    ws.dist.reset(UNREACHED);
    ws.aux.ensure_len(n);
    ws.aux.reset(0);
    if n == 0 {
        return;
    }
    let dist = &ws.dist;
    // Frontier as sparse list + dense flag array (flags always kept in
    // sync so either representation can be used next round). Flags are
    // level-stamped — flag[v] = level+2 when v entered the frontier at
    // `level` — so they never need clearing within a query, and the
    // epoch stamp clears them across queries.
    let flags = &ws.aux;
    dist.store(src as usize, 0);
    flags.store(src as usize, 1);
    let gt = gt.or(if g.symmetric { Some(g) } else { None });

    let mut frontier = std::mem::take(&mut ws.frontier);
    frontier.clear();
    frontier.push(src);
    let mut next = std::mem::take(&mut ws.next);
    let mut offs = std::mem::take(&mut ws.offs);
    let mut out = std::mem::take(&mut ws.edge_buf);
    let mut level: u32 = 0;

    while !frontier.is_empty() {
        let frontier_edges: usize = frontier.iter().map(|&v| g.degree(v)).sum();
        let dense = gt.is_some() && frontier_edges > m / ALPHA && frontier.len() > n / (BETA * 4);

        if dense {
            let gt = gt.unwrap();
            // Bottom-up: every unvisited vertex looks back.
            let nchunks = n.div_ceil(1024);
            let slots = RoundSlots::new(nchunks);
            let edges_scanned = AtomicU64::new(0);
            crate::parallel::ops::parallel_for_chunks(0, n, 1024, |ci, range| {
                let mut scanned = 0u64;
                let mut visited = 0u64;
                for v in range {
                    if dist.get(v) != UNREACHED {
                        continue;
                    }
                    visited += 1;
                    for &u in gt.neighbors(v as V) {
                        scanned += 1;
                        if flags.get(u as usize) == level + 1 {
                            dist.store(v, level + 1);
                            flags.store(v, level + 2);
                            break;
                        }
                    }
                }
                slots.set(
                    ci,
                    TaskCost {
                        vertices: visited,
                        edges: scanned,
                    },
                );
                edges_scanned.fetch_add(scanned, Ordering::Relaxed);
            });
            if let Some(trace) = rec.as_deref_mut() {
                trace.push_round(slots.into_round());
            }
            pack_index_into(n, |v| flags.get(v) == level + 2, &mut next);
            std::mem::swap(&mut frontier, &mut next);
        } else {
            // Top-down sparse round.
            offs.clear();
            offs.extend(frontier.iter().map(|&v| g.degree(v)));
            let total = crate::parallel::scan_inplace(&mut offs);
            out.clear();
            out.resize(total, UNREACHED);
            {
                let op = crate::parallel::ops::SendPtr(out.as_mut_ptr());
                let frontier_ref = &frontier;
                let offs_ref = &offs;
                parallel_for(0, frontier_ref.len(), 64, move |i| {
                    let v = frontier_ref[i];
                    let base = offs_ref[i];
                    for (j, &w) in g.neighbors(v).iter().enumerate() {
                        if dist.compare_exchange(w as usize, UNREACHED, level + 1) {
                            flags.store(w as usize, level + 2);
                            unsafe { *op.add(base + j) = w };
                        }
                    }
                });
            }
            if let Some(trace) = rec.as_deref_mut() {
                trace.push_round(
                    frontier
                        .iter()
                        .map(|&v| TaskCost {
                            vertices: 1,
                            edges: g.degree(v) as u64,
                        })
                        .collect(),
                );
            }
            pack_into(&out, |i| out[i] != UNREACHED, &mut next);
            std::mem::swap(&mut frontier, &mut next);
        }
        level += 1;
    }

    ws.frontier = frontier;
    ws.next = next;
    ws.offs = offs;
    ws.edge_buf = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bfs::seq_bfs;
    use crate::graph::gen;

    #[test]
    fn matches_seq_on_dense_social() {
        // Dense enough to trigger bottom-up rounds.
        let g = gen::social(11, 30, 7).symmetrize();
        let got = diropt_bfs(&g, Some(&g), 0, None);
        assert_eq!(got, seq_bfs(&g, 0));
    }

    #[test]
    fn directed_graph_with_transpose() {
        let g = gen::web(10, 20, 3);
        let gt = g.transpose();
        let got = diropt_bfs(&g, Some(&gt), 1, None);
        assert_eq!(got, seq_bfs(&g, 1));
    }

    #[test]
    fn no_transpose_falls_back_to_topdown() {
        let g = gen::web(9, 12, 5);
        assert_eq!(diropt_bfs(&g, None, 2, None), seq_bfs(&g, 2));
    }

    #[test]
    fn road_like_graph_stays_sparse_and_correct() {
        let g = gen::road(12, 40, 11);
        let got = diropt_bfs(&g, Some(&g), 0, None);
        assert_eq!(got, seq_bfs(&g, 0));
    }

    #[test]
    fn trace_rounds_match_levels_on_path() {
        let g = gen::path(40).symmetrize();
        let mut t = crate::sim::AlgoTrace::new();
        let _ = diropt_bfs(&g, Some(&g), 0, Some(&mut t));
        assert_eq!(t.num_rounds(), 40);
    }

    #[test]
    fn warm_workspace_reuse_matches_fresh_calls() {
        let g = gen::social(10, 12, 9).symmetrize();
        let mut ws = BfsWorkspace::new();
        for src in [0u32, 5, 9, 0] {
            diropt_bfs_ws(&g, Some(&g), src, None, &mut ws);
            assert_eq!(ws.dist.export(g.n()), seq_bfs(&g, src), "src={src}");
        }
        // Same workspace also serves VGC BFS afterwards.
        super::super::vgc::vgc_bfs_ws(&g, 2, 64, None, &mut ws);
        assert_eq!(ws.dist.export(g.n()), seq_bfs(&g, 2));
    }
}
