//! GAPBS-like direction-optimizing BFS (Beamer, Asanović, Patterson
//! [4]).
//!
//! Top-down rounds process the frontier sparsely (like
//! `frontier_bfs`); when the frontier's out-edge count grows past
//! m/ALPHA the round flips to bottom-up: every unvisited vertex scans
//! its *in*-neighbors for a frontier member and claims itself. On
//! low-diameter graphs this skips the huge mid-BFS frontiers — the
//! optimization that makes parallel BFS superlinear on social
//! networks. On large-diameter graphs frontiers never get dense, the
//! heuristic never fires, and the O(D)-round cost remains — exactly
//! the contrast the paper draws.

use crate::algo::UNREACHED;
use crate::graph::Graph;
use crate::parallel::atomic::claim;
use crate::parallel::{pack_index, parallel_for};
use crate::sim::trace::{Recorder, RoundSlots, TaskCost};
use crate::V;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// GAPBS defaults.
const ALPHA: usize = 15;
const BETA: usize = 18;

/// Hop distances from `src`. `gt` supplies in-neighbors for directed
/// graphs (pass `Some(&g)` for symmetric ones); without it the
/// algorithm stays top-down (still correct).
pub fn diropt_bfs(g: &Graph, gt: Option<&Graph>, src: V, mut rec: Recorder) -> Vec<u32> {
    let n = g.n();
    let m = g.m();
    let mut dist = vec![UNREACHED; n];
    if n == 0 {
        return dist;
    }
    dist[src as usize] = 0;
    let dist_at: &[AtomicU32] = crate::parallel::atomic::as_atomic_u32(&mut dist);
    let gt = gt.or(if g.symmetric { Some(g) } else { None });

    // Frontier as sparse list + dense flag array (flags always kept in
    // sync so either representation can be used next round).
    let flags: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    flags[src as usize].store(1, Ordering::Relaxed);
    let mut frontier: Vec<V> = vec![src];
    let mut level: u32 = 0;

    while !frontier.is_empty() {
        let frontier_edges: usize = frontier.iter().map(|&v| g.degree(v)).sum();
        let dense = gt.is_some() && frontier_edges > m / ALPHA && frontier.len() > n / (BETA * 4);

        // Clear current flags lazily after each round: we instead use
        // level-stamps — flag[v] = level+1 when v entered frontier at
        // `level`. Membership test: flag[v] == level (+1 offset).
        if dense {
            let gt = gt.unwrap();
            // Bottom-up: every unvisited vertex looks back.
            let nchunks = n.div_ceil(1024);
            let slots = RoundSlots::new(nchunks);
            let edges_scanned = AtomicU64::new(0);
            crate::parallel::ops::parallel_for_chunks(0, n, 1024, |ci, range| {
                let mut scanned = 0u64;
                let mut visited = 0u64;
                for v in range {
                    if dist_at[v].load(Ordering::Relaxed) != UNREACHED {
                        continue;
                    }
                    visited += 1;
                    for &u in gt.neighbors(v as V) {
                        scanned += 1;
                        if flags[u as usize].load(Ordering::Relaxed) == level + 1 {
                            dist_at[v].store(level + 1, Ordering::Relaxed);
                            flags[v].store(level + 2, Ordering::Relaxed);
                            break;
                        }
                    }
                }
                slots.set(
                    ci,
                    TaskCost {
                        vertices: visited,
                        edges: scanned,
                    },
                );
                edges_scanned.fetch_add(scanned, Ordering::Relaxed);
            });
            if let Some(trace) = rec.as_deref_mut() {
                trace.push_round(slots.into_round());
            }
            frontier = pack_index(n, |v| flags[v].load(Ordering::Relaxed) == level + 2)
                .into_iter()
                .collect();
        } else {
            // Top-down sparse round.
            let mut offs: Vec<usize> = frontier.iter().map(|&v| g.degree(v)).collect();
            let total = crate::parallel::scan_inplace(&mut offs);
            let mut out: Vec<u32> = vec![UNREACHED; total];
            {
                let op = crate::parallel::ops::SendPtr(out.as_mut_ptr());
                let frontier_ref = &frontier;
                let offs_ref = &offs;
                let flags_ref = &flags;
                parallel_for(0, frontier_ref.len(), 64, move |i| {
                    let v = frontier_ref[i];
                    let base = offs_ref[i];
                    for (j, &w) in g.neighbors(v).iter().enumerate() {
                        if claim(&dist_at[w as usize], UNREACHED, level + 1) {
                            flags_ref[w as usize].store(level + 2, Ordering::Relaxed);
                            unsafe { *op.add(base + j) = w };
                        }
                    }
                });
            }
            if let Some(trace) = rec.as_deref_mut() {
                trace.push_round(
                    frontier
                        .iter()
                        .map(|&v| TaskCost {
                            vertices: 1,
                            edges: g.degree(v) as u64,
                        })
                        .collect(),
                );
            }
            frontier = crate::parallel::pack(&out, |i| out[i] != UNREACHED);
        }
        level += 1;
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bfs::seq_bfs;
    use crate::graph::gen;

    #[test]
    fn matches_seq_on_dense_social() {
        // Dense enough to trigger bottom-up rounds.
        let g = gen::social(11, 30, 7).symmetrize();
        let got = diropt_bfs(&g, Some(&g), 0, None);
        assert_eq!(got, seq_bfs(&g, 0));
    }

    #[test]
    fn directed_graph_with_transpose() {
        let g = gen::web(10, 20, 3);
        let gt = g.transpose();
        let got = diropt_bfs(&g, Some(&gt), 1, None);
        assert_eq!(got, seq_bfs(&g, 1));
    }

    #[test]
    fn no_transpose_falls_back_to_topdown() {
        let g = gen::web(9, 12, 5);
        assert_eq!(diropt_bfs(&g, None, 2, None), seq_bfs(&g, 2));
    }

    #[test]
    fn road_like_graph_stays_sparse_and_correct() {
        let g = gen::road(12, 40, 11);
        let got = diropt_bfs(&g, Some(&g), 0, None);
        assert_eq!(got, seq_bfs(&g, 0));
    }

    #[test]
    fn trace_rounds_match_levels_on_path() {
        let g = gen::path(40).symmetrize();
        let mut t = crate::sim::AlgoTrace::new();
        let _ = diropt_bfs(&g, Some(&g), 0, Some(&mut t));
        assert_eq!(t.num_rounds(), 40);
    }
}
