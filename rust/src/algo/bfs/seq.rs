//! The standard sequential queue-based BFS (Table 5's baseline).

use crate::algo::UNREACHED;
use crate::graph::Graph;
use crate::V;
use std::collections::VecDeque;

/// Hop distances from `src`; `UNREACHED` where not reachable.
pub fn seq_bfs(g: &Graph, src: V) -> Vec<u32> {
    let n = g.n();
    let mut dist = vec![UNREACHED; n];
    if n == 0 {
        return dist;
    }
    let mut q = VecDeque::new();
    dist[src as usize] = 0;
    q.push_back(src);
    while let Some(u) = q.pop_front() {
        let du = dist[u as usize];
        for &v in g.neighbors(u) {
            if dist[v as usize] == UNREACHED {
                dist[v as usize] = du + 1;
                q.push_back(v);
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn path_distances_are_indices() {
        let g = gen::path(10);
        let d = seq_bfs(&g, 0);
        for (i, &x) in d.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn unreachable_marked() {
        let g = gen::path(10);
        let d = seq_bfs(&g, 5);
        assert_eq!(d[4], UNREACHED);
        assert_eq!(d[5], 0);
        assert_eq!(d[9], 4);
    }

    #[test]
    fn grid_distance_is_manhattan() {
        let g = gen::grid(5, 7);
        let d = seq_bfs(&g, 0);
        for r in 0..5 {
            for c in 0..7 {
                assert_eq!(d[r * 7 + c], (r + c) as u32);
            }
        }
    }
}
