//! GBBS-like round-synchronous frontier BFS (sparse edge-map).
//!
//! The classic theoretically-efficient parallel BFS: each round
//! processes the current frontier in parallel, claiming unvisited
//! neighbors with a CAS and packing them into the next frontier.
//! Exactly O(D) rounds with a global barrier each — the behaviour
//! whose large-diameter cost PASGAL attacks.
//!
//! Round scratch is ping-ponged, not reallocated: two frontier buffers
//! swap each round and the edge-map offset/output buffers are reused,
//! so the baseline's per-round cost in benches is its O(D) barriers —
//! the thing under study — not allocator noise.

use crate::algo::UNREACHED;
use crate::graph::Graph;
use crate::parallel::atomic::claim;
use crate::parallel::{pack_into, parallel_for};
use crate::sim::trace::{Recorder, TaskCost};
use crate::V;
use std::sync::atomic::AtomicU32;

/// Hop distances from `src` (parallel, round-synchronous).
pub fn frontier_bfs(g: &Graph, src: V, mut rec: Recorder) -> Vec<u32> {
    let n = g.n();
    let mut dist = vec![UNREACHED; n];
    if n == 0 {
        return dist;
    }
    dist[src as usize] = 0;
    let dist_at: &[AtomicU32] = crate::parallel::atomic::as_atomic_u32(&mut dist);
    // Ping-pong frontier buffers + reusable edge-map scratch (see
    // module docs): nothing below allocates per round once warm.
    let mut frontier = vec![src];
    let mut next: Vec<V> = Vec::new();
    let mut offs: Vec<usize> = Vec::new();
    let mut out: Vec<u32> = Vec::new();
    let mut level: u32 = 0;

    while !frontier.is_empty() {
        // Sparse edge map: exclusive scan of frontier degrees gives
        // each vertex a disjoint slice of the output buffer.
        offs.clear();
        offs.extend(frontier.iter().map(|&v| g.degree(v)));
        let total = crate::parallel::scan_inplace(&mut offs);
        out.clear();
        out.resize(total, UNREACHED);
        {
            let op = crate::parallel::ops::SendPtr(out.as_mut_ptr());
            let frontier_ref = &frontier;
            let offs_ref = &offs;
            parallel_for(0, frontier_ref.len(), 64, move |i| {
                let v = frontier_ref[i];
                let base = offs_ref[i];
                for (j, &w) in g.neighbors(v).iter().enumerate() {
                    if claim(&dist_at[w as usize], UNREACHED, level + 1) {
                        unsafe { *op.add(base + j) = w };
                    }
                }
            });
        }
        if let Some(trace) = rec.as_deref_mut() {
            // One task per frontier vertex: the natural unit the
            // scheduler chunks (see sim::sched grouping).
            trace.push_round(
                frontier
                    .iter()
                    .map(|&v| TaskCost {
                        vertices: 1,
                        edges: g.degree(v) as u64,
                    })
                    .collect(),
            );
        }
        pack_into(&out, |i| out[i] != UNREACHED, &mut next);
        std::mem::swap(&mut frontier, &mut next);
        level += 1;
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bfs::seq_bfs;
    use crate::graph::gen;

    #[test]
    fn matches_seq_on_grid() {
        let g = gen::grid(12, 30);
        assert_eq!(frontier_bfs(&g, 0, None), seq_bfs(&g, 0));
    }

    #[test]
    fn records_one_round_per_level() {
        let g = gen::path(64);
        let mut trace = crate::sim::AlgoTrace::new();
        let d = frontier_bfs(&g, 0, Some(&mut trace));
        assert_eq!(d[63], 63);
        // 64 levels processed (last one expands no one but is a round).
        assert_eq!(trace.num_rounds(), 64);
        assert_eq!(trace.total().vertices, 64);
        assert_eq!(trace.total().edges, 63);
    }

    #[test]
    fn empty_graph_and_isolated_source() {
        let g = gen::star(5); // directed star, leaves have out-degree 0
        let d = frontier_bfs(&g, 3, None);
        assert_eq!(d[3], 0);
        assert_eq!(d[0], UNREACHED);
    }

    #[test]
    fn handles_duplicate_discoveries() {
        // Diamond: two paths to the same vertex in one round.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)], false);
        let d = frontier_bfs(&g, 0, None);
        assert_eq!(d, vec![0, 1, 1, 2]);
    }
}
