//! PASGAL's BFS: vertical granularity control + multiple 2^i-distance
//! frontiers backed by hash bags (paper §2.2).
//!
//! Each scheduled task seeds a τ-budget local search ([`local_search`])
//! from a few frontier vertices and walks the graph in *relaxed*
//! (non-BFS) order, claiming vertices with `write_min` on the hop
//! distance. Because the walk may overshoot (a vertex's first claimed
//! distance need not be its final one), a vertex can be visited more
//! than once; the multi-frontier structure bounds that extra work:
//! a claim `delta = d - cur` hops ahead of the current level lands in
//! frontier bucket ⌊log2 delta⌋, so far-ahead (likely-stale) vertices
//! are not expanded until the wavefront approaches them.
//!
//! One round = process current frontier with local searches + one
//! bucket extraction — so the number of synchronized rounds drops from
//! O(D) to roughly O(D/τ) on path-like graphs, the paper's headline
//! mechanism.

use crate::algo::UNREACHED;
use crate::graph::Graph;
use crate::hashbag::HashBag;
use crate::parallel::atomic::write_min_u32;
use crate::sim::trace::{Recorder, RoundSlots};
use crate::V;
use std::sync::atomic::{AtomicU32, Ordering};

/// Number of exponential frontier buckets (covers deltas < 2^K).
const K: usize = 8;

/// Seeds per local-search task.
const SEEDS: usize = 4;

/// Hop window: a local search keeps walking while the tentative
/// distance is within `cur + WINDOW`; farther discoveries go to the
/// exponential buckets instead of being expanded now ("avoid visiting
/// too many unready vertices", paper §2.2). Must stay below 2^K.
const WINDOW: u32 = 64;

#[inline]
fn bucket(delta: u32) -> usize {
    debug_assert!(delta >= 1);
    (31 - delta.leading_zeros()).min(K as u32 - 1) as usize
}

/// Hop distances from `src` with VGC budget `tau`.
pub fn vgc_bfs(g: &Graph, src: V, tau: usize, mut rec: Recorder) -> Vec<u32> {
    let n = g.n();
    let mut dist = vec![UNREACHED; n];
    if n == 0 {
        return dist;
    }
    dist[src as usize] = 0;
    let dist_at: &[AtomicU32] = crate::parallel::atomic::as_atomic_u32(&mut dist);
    // expanded[v] = distance value v was last expanded with; a vertex
    // qualifies for (re-)expansion whenever dist[v] < expanded[v].
    let expanded: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNREACHED)).collect();
    // A vertex may be claimed (and inserted) several times per round
    // while its distance improves, so size by n + m, not n; chunks
    // are allocated lazily so unused capacity costs nothing.
    let bags: Vec<HashBag> = (0..K).map(|_| HashBag::new(n + g.m())).collect();

    let mut cur: u32 = 0;
    let mut frontier: Vec<V> = vec![src];
    let tau = tau.max(1);
    // Buckets 0..=B cover deltas within the hop window; higher buckets
    // hold "unready" far-ahead discoveries.
    let near = bucket(WINDOW);

    loop {
        if frontier.is_empty() {
            // Gather the within-window buckets (one frontier round may
            // advance up to WINDOW levels).
            let mut candidates: Vec<V> = Vec::new();
            for b in &bags[..=near] {
                if !b.is_empty() {
                    candidates.extend(b.extract_and_clear());
                }
            }
            if candidates.is_empty() {
                // Cascade: pull the first non-empty far bucket.
                let Some(j) = bags.iter().position(|b| !b.is_empty()) else {
                    break;
                };
                candidates = bags[j].extract_and_clear();
            }
            // Re-align `cur` to the smallest still-pending distance
            // (it may even move backward: local searches overshoot and
            // later corrections re-queue vertices below `cur`).
            let mut min_d = UNREACHED;
            for &v in &candidates {
                let d = dist_at[v as usize].load(Ordering::Relaxed);
                if d < expanded[v as usize].load(Ordering::Relaxed) && d < min_d {
                    min_d = d;
                }
            }
            if min_d == UNREACHED {
                continue; // all stale; keep draining
            }
            cur = min_d;
            for &v in &candidates {
                let d = dist_at[v as usize].load(Ordering::Relaxed);
                if d >= expanded[v as usize].load(Ordering::Relaxed) {
                    continue; // stale entry: a newer claim handled it
                }
                let delta = d.saturating_sub(cur);
                if delta <= WINDOW {
                    frontier.push(v);
                } else {
                    bags[bucket(delta)].insert(v);
                }
            }
            continue;
        }

        // Process the frontier with τ-budget local searches.
        let ntasks = frontier.len().div_ceil(SEEDS);
        let slots = RoundSlots::new(if rec.is_some() { ntasks } else { 0 });
        let record = rec.is_some();
        {
            let frontier_ref = &frontier;
            let bags_ref = &bags;
            let expanded_ref = &expanded;
            let slots_ref = &slots;
            crate::parallel::ops::parallel_for_chunks(
                0,
                frontier_ref.len(),
                SEEDS,
                move |ti, range| {
                    // FIFO local search: processing the task-local
                    // queue in discovery order keeps the walk close to
                    // BFS order *within* the region, which bounds the
                    // distance overestimates (and thus re-visits) that
                    // a LIFO walk would cause on meshes.
                    let mut queue: Vec<u32> = Vec::with_capacity(64);
                    for i in range {
                        queue.push(frontier_ref[i]);
                    }
                    let mut head = 0usize;
                    let mut stats = crate::parallel::vgc::SearchStats::default();
                    while head < queue.len() && (stats.vertices as usize) < tau {
                        let v = queue[head];
                        head += 1;
                        stats.vertices += 1;
                        let vd = dist_at[v as usize].load(Ordering::Relaxed);
                        // Qualify: only expand if this distance hasn't
                        // been expanded yet (one winner per value).
                        let exp = expanded_ref[v as usize].load(Ordering::Relaxed);
                        if vd >= exp
                            || expanded_ref[v as usize]
                                .compare_exchange(exp, vd, Ordering::AcqRel, Ordering::Relaxed)
                                .is_err()
                        {
                            continue;
                        }
                        let nd = vd + 1;
                        for &w in g.neighbors(v) {
                            stats.edges += 1;
                            if write_min_u32(&dist_at[w as usize], nd) {
                                // `cur` may sit above nd after a
                                // backward cascade: saturate.
                                let delta = nd.saturating_sub(cur);
                                if delta <= WINDOW {
                                    queue.push(w);
                                } else {
                                    bags_ref[bucket(delta)].insert(w);
                                }
                            }
                        }
                    }
                    // Budget exhausted: spill leftovers into buckets.
                    for &w in &queue[head..] {
                        let d = dist_at[w as usize].load(Ordering::Relaxed);
                        if d < expanded_ref[w as usize].load(Ordering::Relaxed) {
                            let delta = d.saturating_sub(cur).max(1);
                            bags_ref[bucket(delta)].insert(w);
                        }
                    }
                    if record {
                        slots_ref.set(ti, stats.into());
                    }
                },
            );
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(slots.into_round());
        }

        // Next frontier: gathered from the buckets at the top of the
        // loop (which also re-aligns `cur`).
        frontier = Vec::new();
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bfs::seq_bfs;
    use crate::graph::gen;
    use crate::prop::{forall, Rng};

    #[test]
    fn bucket_is_log2() {
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 1);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(255), 7);
        assert_eq!(bucket(1 << 20), K - 1);
    }

    #[test]
    fn chain_uses_few_rounds_with_big_tau() {
        let g = gen::path(4096);
        let mut trace = crate::sim::AlgoTrace::new();
        let d = vgc_bfs(&g, 0, 512, Some(&mut trace));
        assert_eq!(d, seq_bfs(&g, 0));
        // The whole point of VGC: rounds << D.
        assert!(
            trace.num_rounds() < 200,
            "VGC should collapse 4096 levels into few rounds, got {}",
            trace.num_rounds()
        );
    }

    #[test]
    fn tau_one_matches_frontier_behaviour() {
        let g = gen::grid(9, 13);
        assert_eq!(vgc_bfs(&g, 0, 1, None), seq_bfs(&g, 0));
    }

    #[test]
    fn revisits_fix_overestimates_on_mesh() {
        // Grids force overshooting local searches to be corrected.
        let g = gen::grid(31, 17);
        for tau in [4usize, 32, 1024] {
            assert_eq!(vgc_bfs(&g, 0, tau, None), seq_bfs(&g, 0), "tau={tau}");
        }
    }

    #[test]
    fn prop_matches_seq_on_random_graphs_various_tau() {
        forall(0x76C, |rng: &mut Rng| {
            let n = rng.range(1, 300);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(crate::V, crate::V)> = (0..m)
                .map(|_| (rng.below(n as u64) as crate::V, rng.below(n as u64) as crate::V))
                .collect();
            let g = crate::graph::Graph::from_edges(n, &edges, true);
            let src = rng.below(n as u64) as crate::V;
            let tau = *rng.pick(&[1usize, 2, 7, 64, 100_000]);
            assert_eq!(vgc_bfs(&g, src, tau, None), seq_bfs(&g, src));
        });
    }

    #[test]
    fn disconnected_unreached_stays_max() {
        let g = gen::path(10); // directed: 5 can't reach 0..4
        let d = vgc_bfs(&g, 5, 16, None);
        assert_eq!(d[0], UNREACHED);
        assert_eq!(d[9], 4);
    }
}
