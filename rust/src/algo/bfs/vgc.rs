//! PASGAL's BFS: vertical granularity control + multiple 2^i-distance
//! frontiers backed by hash bags (paper §2.2).
//!
//! Each scheduled task seeds a τ-budget local search ([`local_search`])
//! from a few frontier vertices and walks the graph in *relaxed*
//! (non-BFS) order, claiming vertices with `write_min` on the hop
//! distance. Because the walk may overshoot (a vertex's first claimed
//! distance need not be its final one), a vertex can be visited more
//! than once; the multi-frontier structure bounds that extra work:
//! a claim `delta = d - cur` hops ahead of the current level lands in
//! frontier bucket ⌊log2 delta⌋, so far-ahead (likely-stale) vertices
//! are not expanded until the wavefront approaches them.
//!
//! One round = process current frontier with local searches + one
//! bucket extraction — so the number of synchronized rounds drops from
//! O(D) to roughly O(D/τ) on path-like graphs, the paper's headline
//! mechanism.
//!
//! Per-query state (distances, expanded marks, the K frontier bags)
//! lives in a reusable [`BfsWorkspace`]: [`vgc_bfs_ws`] resets it in
//! O(1) via epoch stamps and performs zero O(n)/O(m) allocation once
//! the workspace is warm; [`vgc_bfs`] is the allocate-per-call wrapper.
//!
//! Serving many sources over one graph? The batched variant
//! [`crate::algo::multi::multi_bfs_vgc_ws`] runs this τ-budget loop
//! over lane-striped distances, answering up to 64 sources per walk
//! with per-lane results bit-identical to this engine's.
//!
//! [`local_search`]: crate::parallel::vgc::local_search

use crate::algo::workspace::BfsWorkspace;
use crate::algo::UNREACHED;
use crate::graph::Graph;
use crate::sim::trace::{Recorder, RoundSlots};
use crate::V;

/// Number of exponential frontier buckets (covers deltas < 2^K).
const K: usize = 8;

/// Seeds per local-search task.
const SEEDS: usize = 4;

/// Hop window: a local search keeps walking while the tentative
/// distance is within `cur + WINDOW`; farther discoveries go to the
/// exponential buckets instead of being expanded now ("avoid visiting
/// too many unready vertices", paper §2.2). Must stay below 2^K.
const WINDOW: u32 = 64;

#[inline]
fn bucket(delta: u32) -> usize {
    debug_assert!(delta >= 1);
    (31 - delta.leading_zeros()).min(K as u32 - 1) as usize
}

/// Hop distances from `src` with VGC budget `tau` (allocate-per-call
/// wrapper around [`vgc_bfs_ws`]).
pub fn vgc_bfs(g: &Graph, src: V, tau: usize, rec: Recorder) -> Vec<u32> {
    let mut ws = BfsWorkspace::new();
    vgc_bfs_ws(g, src, tau, rec, &mut ws);
    ws.dist.export(g.n())
}

/// Hop distances from `src` with VGC budget `tau`, computed in a
/// reusable workspace. Results are left in `ws.dist` (read with
/// [`crate::parallel::StampedU32::get`] or export them); a warm
/// workspace performs no O(n)/O(m) allocation.
pub fn vgc_bfs_ws(g: &Graph, src: V, tau: usize, mut rec: Recorder, ws: &mut BfsWorkspace) {
    let n = g.n();
    ws.dist.ensure_len(n);
    ws.dist.reset(UNREACHED);
    ws.aux.ensure_len(n);
    ws.aux.reset(UNREACHED);
    if n == 0 {
        return;
    }
    // A vertex may be claimed (and inserted) several times per round
    // while its distance improves, so size by n + m, not n; chunk slot
    // arrays are allocated lazily (and kept across queries), so unused
    // capacity costs nothing.
    ws.prepare_bags(K, n + g.m());

    let dist = &ws.dist;
    // expanded[v] = distance value v was last expanded with; a vertex
    // qualifies for (re-)expansion whenever dist[v] < expanded[v].
    let expanded = &ws.aux;
    let bags = &ws.bags[..K];
    dist.store(src as usize, 0);

    let mut frontier = std::mem::take(&mut ws.frontier);
    frontier.clear();
    frontier.push(src);
    let mut candidates = std::mem::take(&mut ws.next);
    candidates.clear();
    let mut gather = std::mem::take(&mut ws.gather);

    let mut cur: u32 = 0;
    let tau = tau.max(1);
    // Buckets 0..=B cover deltas within the hop window; higher buckets
    // hold "unready" far-ahead discoveries.
    let near = bucket(WINDOW);

    loop {
        if frontier.is_empty() {
            // Gather the within-window buckets (one frontier round may
            // advance up to WINDOW levels).
            candidates.clear();
            for b in &bags[..=near] {
                if !b.is_empty() {
                    b.extract_into(&mut gather);
                    candidates.append(&mut gather);
                }
            }
            if candidates.is_empty() {
                // Cascade: pull the first non-empty far bucket.
                let Some(j) = bags.iter().position(|b| !b.is_empty()) else {
                    break;
                };
                bags[j].extract_into(&mut candidates);
            }
            // Re-align `cur` to the smallest still-pending distance
            // (it may even move backward: local searches overshoot and
            // later corrections re-queue vertices below `cur`).
            let mut min_d = UNREACHED;
            for &v in &candidates {
                let d = dist.get(v as usize);
                if d < expanded.get(v as usize) && d < min_d {
                    min_d = d;
                }
            }
            if min_d == UNREACHED {
                continue; // all stale; keep draining
            }
            cur = min_d;
            for &v in &candidates {
                let d = dist.get(v as usize);
                if d >= expanded.get(v as usize) {
                    continue; // stale entry: a newer claim handled it
                }
                let delta = d.saturating_sub(cur);
                if delta <= WINDOW {
                    frontier.push(v);
                } else {
                    bags[bucket(delta)].insert(v);
                }
            }
            continue;
        }

        // Process the frontier with τ-budget local searches.
        let ntasks = frontier.len().div_ceil(SEEDS);
        let slots = RoundSlots::new(if rec.is_some() { ntasks } else { 0 });
        let record = rec.is_some();
        {
            let frontier_ref = &frontier;
            let slots_ref = &slots;
            crate::parallel::ops::parallel_for_chunks(
                0,
                frontier_ref.len(),
                SEEDS,
                move |ti, range| {
                    // FIFO local search: processing the task-local
                    // queue in discovery order keeps the walk close to
                    // BFS order *within* the region, which bounds the
                    // distance overestimates (and thus re-visits) that
                    // a LIFO walk would cause on meshes.
                    let mut queue: Vec<u32> = Vec::with_capacity(64);
                    for i in range {
                        queue.push(frontier_ref[i]);
                    }
                    let mut head = 0usize;
                    let mut stats = crate::parallel::vgc::SearchStats::default();
                    while head < queue.len() && (stats.vertices as usize) < tau {
                        let v = queue[head];
                        head += 1;
                        stats.vertices += 1;
                        let vd = dist.get(v as usize);
                        // Qualify: only expand if this distance hasn't
                        // been expanded yet (one winner per value).
                        let exp = expanded.get(v as usize);
                        if vd >= exp || !expanded.compare_exchange(v as usize, exp, vd) {
                            continue;
                        }
                        let nd = vd + 1;
                        for &w in g.neighbors(v) {
                            stats.edges += 1;
                            if dist.write_min(w as usize, nd) {
                                // `cur` may sit above nd after a
                                // backward cascade: saturate.
                                let delta = nd.saturating_sub(cur);
                                if delta <= WINDOW {
                                    queue.push(w);
                                } else {
                                    bags[bucket(delta)].insert(w);
                                }
                            }
                        }
                    }
                    // Budget exhausted: spill leftovers into buckets.
                    for &w in &queue[head..] {
                        let d = dist.get(w as usize);
                        if d < expanded.get(w as usize) {
                            let delta = d.saturating_sub(cur).max(1);
                            bags[bucket(delta)].insert(w);
                        }
                    }
                    if record {
                        slots_ref.set(ti, stats.into());
                    }
                },
            );
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(slots.into_round());
        }

        // Next frontier: gathered from the buckets at the top of the
        // loop (which also re-aligns `cur`).
        frontier.clear();
    }

    ws.frontier = frontier;
    ws.next = candidates;
    ws.gather = gather;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bfs::seq_bfs;
    use crate::graph::gen;
    use crate::prop::{forall, Rng};

    #[test]
    fn bucket_is_log2() {
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 1);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(255), 7);
        assert_eq!(bucket(1 << 20), K - 1);
    }

    #[test]
    fn chain_uses_few_rounds_with_big_tau() {
        let g = gen::path(4096);
        let mut trace = crate::sim::AlgoTrace::new();
        let d = vgc_bfs(&g, 0, 512, Some(&mut trace));
        assert_eq!(d, seq_bfs(&g, 0));
        // The whole point of VGC: rounds << D.
        assert!(
            trace.num_rounds() < 200,
            "VGC should collapse 4096 levels into few rounds, got {}",
            trace.num_rounds()
        );
    }

    #[test]
    fn tau_one_matches_frontier_behaviour() {
        let g = gen::grid(9, 13);
        assert_eq!(vgc_bfs(&g, 0, 1, None), seq_bfs(&g, 0));
    }

    #[test]
    fn revisits_fix_overestimates_on_mesh() {
        // Grids force overshooting local searches to be corrected.
        let g = gen::grid(31, 17);
        for tau in [4usize, 32, 1024] {
            assert_eq!(vgc_bfs(&g, 0, tau, None), seq_bfs(&g, 0), "tau={tau}");
        }
    }

    #[test]
    fn prop_matches_seq_on_random_graphs_various_tau() {
        forall(0x76C, |rng: &mut Rng| {
            let n = rng.range(1, 300);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(crate::V, crate::V)> = (0..m)
                .map(|_| (rng.below(n as u64) as crate::V, rng.below(n as u64) as crate::V))
                .collect();
            let g = crate::graph::Graph::from_edges(n, &edges, true);
            let src = rng.below(n as u64) as crate::V;
            let tau = *rng.pick(&[1usize, 2, 7, 64, 100_000]);
            assert_eq!(vgc_bfs(&g, src, tau, None), seq_bfs(&g, src));
        });
    }

    #[test]
    fn disconnected_unreached_stays_max() {
        let g = gen::path(10); // directed: 5 can't reach 0..4
        let d = vgc_bfs(&g, 5, 16, None);
        assert_eq!(d[0], UNREACHED);
        assert_eq!(d[9], 4);
    }

    #[test]
    fn warm_workspace_reuse_matches_fresh_calls() {
        let g = gen::grid(13, 29);
        let mut ws = BfsWorkspace::new();
        for src in [0u32, 7, 100, 3, 0] {
            vgc_bfs_ws(&g, src, 32, None, &mut ws);
            assert_eq!(ws.dist.export(g.n()), seq_bfs(&g, src), "src={src}");
        }
    }

    #[test]
    fn workspace_survives_graph_switch() {
        // Smaller graph after a bigger one: stale slots beyond n must
        // not matter, and values from graph A must not leak into B.
        let big = gen::grid(20, 40);
        let small = gen::path(50);
        let mut ws = BfsWorkspace::new();
        vgc_bfs_ws(&big, 0, 64, None, &mut ws);
        vgc_bfs_ws(&small, 3, 64, None, &mut ws);
        assert_eq!(ws.dist.export(small.n()), seq_bfs(&small, 3));
    }
}
