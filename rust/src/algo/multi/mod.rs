//! `algo::multi` — the batched multi-source traversal engine: answer
//! up to 64 BFS/SSSP/reachability sources with **one** frontier walk.
//!
//! PASGAL's subject is per-round scheduling overhead; a serving
//! workload pays that overhead *per query* when it issues many
//! single-source traversals over the same graph. The SCC engine
//! already amortizes it with 64-bit reachability masks
//! (`vgc_multi_reach`); this module makes the technique a first-class
//! query path:
//!
//! * [`mask`] — the shared mask-frontier worklist engine (one 64-bit
//!   lane mask + one pending flag per vertex + a deferred bag), the
//!   loop that reachability, BFS and SSSP all drive.
//! * [`reach`] — multi-source reachability, the SCC inner engine
//!   (moved here from `algo::scc::reach`, which re-exports it).
//! * [`bfs`] — batched BFS: lane-striped hop distances, in VGC
//!   τ-budget and direction-optimizing (mask-word bottom-up) flavours.
//! * [`sssp`] — batched ρ-stepping: lane-striped `f32` distances with
//!   per-lane `write_min`, one θ-threshold bucket structure shared by
//!   the whole batch.
//!
//! The lane count always equals the actual batch width, so a 4-source
//! batch pays 4 lanes of storage, relaxation and export — not 64. The
//! serving layer ([`crate::coordinator::Coordinator::run_batch`])
//! fuses same-graph, same-algorithm requests into these engines and
//! demultiplexes per-lane results back into per-request responses: k
//! traversals for one walk's scheduling cost.

pub mod bfs;
pub mod mask;
pub mod reach;
pub mod sssp;

pub use bfs::{
    multi_bfs_diropt, multi_bfs_diropt_ws, multi_bfs_diropt_ws_cancel, multi_bfs_vgc,
    multi_bfs_vgc_ws, multi_bfs_vgc_ws_cancel,
};
pub use mask::{
    compact_lanes, compaction_due, for_each_lane, full_mask, lane_fifo_search, reset_mask_state,
    LanePerm, MaskFrontier, MAX_LANES,
};
pub use reach::{
    bfs_multi_reach, bfs_multi_reach_ws, vgc_multi_reach, vgc_multi_reach_ws, ReachCtx, UNSET,
};
pub use sssp::{multi_rho, multi_rho_ws, multi_rho_ws_cancel};
