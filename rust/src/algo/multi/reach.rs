//! Multi-source reachability — the inner engine of parallel SCC, now
//! hosted by the shared batched-traversal module.
//!
//! Up to 64 sources per call, one bit each: `masks[v]` accumulates the
//! set of sources that reach `v` (within v's subproblem). Two engines
//! share the same monotone worklist semantics, both driven through the
//! [`MaskFrontier`] protocol of [`super::mask`]:
//!
//! * [`bfs_multi_reach`] — round-synchronous frontier propagation
//!   (what GBBS/Multistep do): O(D) barriers per call.
//! * [`vgc_multi_reach`] — PASGAL's engine [24]: τ-budget local
//!   searches over hash bags. Reachability needs no BFS order, so the
//!   relaxed visit order is free — this is the paper's core insight.
//!
//! Re-scheduling uses the classic pending-flag worklist pattern
//! ([`MaskFrontier::begin`] / [`MaskFrontier::spread`]): a propagation
//! that adds bits to `masks[w]` enqueues `w` iff `w` is not already
//! pending; a task clears the flag *before* reading the mask, so late
//! arrivals always re-enqueue.
//!
//! Both engines come in `_ws` form taking epoch-stamped mask/flag
//! arrays plus a reusable bag: one SCC decomposition issues two
//! reachability calls per pivot batch, and with a warm
//! [`crate::algo::SccWorkspace`] none of them allocates O(n) state.
//! The same mask technique, generalized to per-source distances,
//! powers the batched BFS/SSSP engines in [`super::bfs`] and
//! [`super::sssp`].
//!
//! Unlike those distance engines, reachability does **not** perform
//! mid-walk lane compaction ([`super::mask::compact_lanes`]): its only
//! per-lane state is the mask word itself, every lane scan is a
//! whole-word `fetch_or`/popcount (no lane-striped arrays to stride
//! over), and the SCC caller reads `masks[v]` by the *original* seed
//! bit positions — so a lane permutation would buy nothing and break
//! the caller's bit contract. What compaction relies on, though —
//! lanes being fully independent under permutation — holds here too,
//! and is pinned by a test below.

use super::mask::{reset_mask_state, MaskFrontier, MAX_LANES};
use crate::algo::cancel::{cancelled, Cancel};
use crate::graph::Graph;
use crate::hashbag::HashBag;
use crate::parallel::vgc::local_search;
use crate::parallel::workspace::{StampedU32, StampedU64};
use crate::sim::trace::{Recorder, RoundSlots, TaskCost};
use crate::V;
use std::sync::atomic::{AtomicU32, Ordering};

/// Sentinel: vertex not yet assigned to an SCC (still active).
pub const UNSET: u32 = u32::MAX;

/// Shared context: assignment state + subproblem labels. Propagation
/// only crosses edge (u, v) when both are active and in the same
/// subproblem.
pub struct ReachCtx<'a> {
    pub scc: &'a [AtomicU32],
    pub sub: &'a [u64],
}

impl ReachCtx<'_> {
    #[inline]
    fn active(&self, v: u32) -> bool {
        self.scc[v as usize].load(Ordering::Relaxed) == UNSET
    }

    #[inline]
    fn same_sub(&self, u: u32, v: u32) -> bool {
        self.sub[u as usize] == self.sub[v as usize]
    }
}

/// Rebind the workspace pieces for a new search and seed the frontier.
fn seed_masks_ws(
    n: usize,
    seeds: &[V],
    ctx: &ReachCtx,
    masks: &mut StampedU64,
    pending: &mut StampedU32,
    bag: &mut HashBag,
    frontier: &mut Vec<V>,
) {
    assert!(seeds.len() <= MAX_LANES, "at most 64 sources per call");
    reset_mask_state(n, masks, pending, bag);
    frontier.clear();
    let mf = MaskFrontier {
        masks,
        pending,
        bag,
    };
    for (i, &s) in seeds.iter().enumerate() {
        if ctx.active(s) && mf.spread(s, 1 << i) {
            frontier.push(s);
        }
    }
}

/// Round-synchronous multi-source reachability (allocate-per-call
/// wrapper around [`bfs_multi_reach_ws`]).
pub fn bfs_multi_reach(g: &Graph, seeds: &[V], ctx: &ReachCtx, rec: Recorder) -> Vec<u64> {
    let mut masks = StampedU64::new(0);
    let mut pending = StampedU32::new(0);
    let mut bag = HashBag::default();
    let mut frontier = Vec::new();
    bfs_multi_reach_ws(
        g,
        seeds,
        ctx,
        rec,
        &mut masks,
        &mut pending,
        &mut bag,
        &mut frontier,
        None,
    );
    masks.export(g.n())
}

/// Round-synchronous multi-source reachability into a reusable
/// workspace: results are left in `masks` (read via
/// [`StampedU64::get`]); a warm workspace allocates no O(n) state.
///
/// `cancel` is polled once per frontier round (never per edge): an
/// expired or condemned query abandons the search within one round,
/// leaving partial masks the caller must not summarize.
#[allow(clippy::too_many_arguments)]
pub fn bfs_multi_reach_ws(
    g: &Graph,
    seeds: &[V],
    ctx: &ReachCtx,
    mut rec: Recorder,
    masks: &mut StampedU64,
    pending: &mut StampedU32,
    bag: &mut HashBag,
    frontier: &mut Vec<V>,
    cancel: Cancel<'_>,
) {
    let n = g.n();
    seed_masks_ws(n, seeds, ctx, masks, pending, bag, frontier);
    let mf = MaskFrontier {
        masks,
        pending,
        bag,
    };
    while !frontier.is_empty() {
        if cancelled(cancel) {
            break;
        }
        let ntasks = frontier.len();
        let slots = RoundSlots::new(if rec.is_some() { ntasks } else { 0 });
        let record = rec.is_some();
        {
            let frontier_ref = &*frontier;
            let slots_ref = &slots;
            crate::parallel::parallel_for(0, ntasks, 16, move |i| {
                let v = frontier_ref[i];
                let mv = mf.begin(v);
                let mut edges = 0u64;
                for &w in g.neighbors(v) {
                    edges += 1;
                    if !ctx.active(w) || !ctx.same_sub(v, w) {
                        continue;
                    }
                    if mf.spread(w, mv) {
                        mf.defer(w);
                    }
                }
                if record {
                    slots_ref.set(i, TaskCost { vertices: 1, edges });
                }
            });
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(slots.into_round());
        }
        mf.drain_into(frontier);
    }
}

/// Seeds-per-task for the VGC engine.
const SEEDS_PER_TASK: usize = 4;

/// VGC multi-source reachability (allocate-per-call wrapper around
/// [`vgc_multi_reach_ws`]).
pub fn vgc_multi_reach(
    g: &Graph,
    seeds: &[V],
    ctx: &ReachCtx,
    tau: usize,
    rec: Recorder,
) -> Vec<u64> {
    let mut masks = StampedU64::new(0);
    let mut pending = StampedU32::new(0);
    let mut bag = HashBag::default();
    let mut frontier = Vec::new();
    vgc_multi_reach_ws(
        g,
        seeds,
        ctx,
        tau,
        rec,
        &mut masks,
        &mut pending,
        &mut bag,
        &mut frontier,
        None,
    );
    masks.export(g.n())
}

/// VGC multi-source reachability into a reusable workspace: the PASGAL
/// engine, allocation-free when warm.
///
/// `cancel` is polled once per bag-drain round (never per edge or per
/// τ-budget task): an expired or condemned query abandons the search
/// within one round, leaving partial masks the caller must not
/// summarize.
#[allow(clippy::too_many_arguments)]
pub fn vgc_multi_reach_ws(
    g: &Graph,
    seeds: &[V],
    ctx: &ReachCtx,
    tau: usize,
    mut rec: Recorder,
    masks: &mut StampedU64,
    pending: &mut StampedU32,
    bag: &mut HashBag,
    frontier: &mut Vec<V>,
    cancel: Cancel<'_>,
) {
    let n = g.n();
    let tau = tau.max(1);
    seed_masks_ws(n, seeds, ctx, masks, pending, bag, frontier);
    let mf = MaskFrontier {
        masks,
        pending,
        bag,
    };
    while !frontier.is_empty() {
        if cancelled(cancel) {
            break;
        }
        let ntasks = frontier.len().div_ceil(SEEDS_PER_TASK);
        let slots = RoundSlots::new(if rec.is_some() { ntasks } else { 0 });
        let record = rec.is_some();
        {
            let frontier_ref = &*frontier;
            let slots_ref = &slots;
            crate::parallel::ops::parallel_for_chunks(
                0,
                frontier_ref.len(),
                SEEDS_PER_TASK,
                move |ti, range| {
                    let mut stack: Vec<u32> = Vec::with_capacity(64);
                    stack.extend(range.map(|i| frontier_ref[i]));
                    let stats = local_search(&mut stack, tau, |v, stack| {
                        let mv = mf.begin(v);
                        let mut edges = 0usize;
                        for &w in g.neighbors(v) {
                            edges += 1;
                            if !ctx.active(w) || !ctx.same_sub(v, w) {
                                continue;
                            }
                            if mf.spread(w, mv) {
                                // Claimed: expand within this search
                                // (any order is fine for reachability).
                                stack.push(w);
                            }
                        }
                        edges
                    });
                    // Budget exhausted: the leftovers become frontier.
                    for &w in &stack {
                        mf.defer(w);
                    }
                    if record {
                        slots_ref.set(ti, stats.into());
                    }
                },
            );
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(slots.into_round());
        }
        mf.drain_into(frontier);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn fresh_ctx(n: usize) -> (Vec<AtomicU32>, Vec<u64>) {
        ((0..n).map(|_| AtomicU32::new(UNSET)).collect(), vec![0; n])
    }

    /// Sequential reference: single-source reachability.
    fn seq_reach(g: &Graph, s: V) -> Vec<bool> {
        let mut seen = vec![false; g.n()];
        let mut stack = vec![s];
        seen[s as usize] = true;
        while let Some(u) = stack.pop() {
            for &w in g.neighbors(u) {
                if !seen[w as usize] {
                    seen[w as usize] = true;
                    stack.push(w);
                }
            }
        }
        seen
    }

    fn check_engines(g: &Graph, seeds: &[V]) {
        let (scc, sub) = fresh_ctx(g.n());
        let ctx = ReachCtx {
            scc: &scc,
            sub: &sub,
        };
        let bfs = bfs_multi_reach(g, seeds, &ctx, None);
        for tau in [1usize, 8, 1 << 20] {
            let vgc = vgc_multi_reach(g, seeds, &ctx, tau, None);
            assert_eq!(bfs, vgc, "engines disagree at tau={tau}");
        }
        // Against the sequential oracle, bit by bit.
        for (i, &s) in seeds.iter().enumerate() {
            let want = seq_reach(g, s);
            for v in 0..g.n() {
                assert_eq!(
                    bfs[v] & (1 << i) != 0,
                    want[v],
                    "seed {s} vertex {v} mismatch"
                );
            }
        }
    }

    #[test]
    fn single_source_on_shapes() {
        check_engines(&gen::path(100), &[0]);
        check_engines(&gen::path(100), &[99]);
        check_engines(&gen::cycle(64), &[5]);
        check_engines(&gen::grid(8, 9), &[0]);
    }

    #[test]
    fn multi_source_bits_are_independent() {
        let g = gen::web(9, 6, 1);
        let seeds: Vec<V> = (0..32).map(|i| (i * 13) % g.n() as u32).collect();
        check_engines(&g, &seeds);
    }

    #[test]
    fn subproblem_labels_block_propagation() {
        // Path 0->1->2->3 with a sub boundary between 1 and 2.
        let g = gen::path(4);
        let (scc, mut sub) = fresh_ctx(4);
        sub[2] = 7;
        sub[3] = 7;
        let ctx = ReachCtx {
            scc: &scc,
            sub: &sub,
        };
        let m = bfs_multi_reach(&g, &[0], &ctx, None);
        assert_eq!(m, vec![1, 1, 0, 0]);
        let v = vgc_multi_reach(&g, &[0], &ctx, 4, None);
        assert_eq!(v, vec![1, 1, 0, 0]);
    }

    #[test]
    fn assigned_vertices_block_propagation() {
        let g = gen::path(4);
        let (scc, sub) = fresh_ctx(4);
        scc[2].store(9, Ordering::Relaxed); // vertex 2 already assigned
        let ctx = ReachCtx {
            scc: &scc,
            sub: &sub,
        };
        let m = bfs_multi_reach(&g, &[0], &ctx, None);
        assert_eq!(m, vec![1, 1, 0, 0]);
    }

    #[test]
    fn vgc_uses_fewer_rounds_on_chain() {
        let g = gen::path(2048);
        let (scc, sub) = fresh_ctx(g.n());
        let ctx = ReachCtx {
            scc: &scc,
            sub: &sub,
        };
        let mut t_bfs = crate::sim::AlgoTrace::new();
        let _ = bfs_multi_reach(&g, &[0], &ctx, Some(&mut t_bfs));
        let mut t_vgc = crate::sim::AlgoTrace::new();
        let _ = vgc_multi_reach(&g, &[0], &ctx, 256, Some(&mut t_vgc));
        assert!(t_bfs.num_rounds() >= 2047, "BFS rounds = D");
        assert!(
            t_vgc.num_rounds() * 16 < t_bfs.num_rounds(),
            "VGC must collapse rounds: {} vs {}",
            t_vgc.num_rounds(),
            t_bfs.num_rounds()
        );
    }

    #[test]
    fn lanes_are_invariant_under_seed_permutation() {
        // The independence property lane compaction builds on (see the
        // module docs): permuting the seed order only permutes which
        // *bit* carries each source's answer, never the answer itself.
        let g = gen::web(9, 6, 2);
        let (scc, sub) = fresh_ctx(g.n());
        let ctx = ReachCtx {
            scc: &scc,
            sub: &sub,
        };
        let seeds: Vec<V> = (0..24).map(|i| (i * 11) % g.n() as u32).collect();
        let base = vgc_multi_reach(&g, &seeds, &ctx, 16, None);
        let mut shuffled = seeds.clone();
        shuffled.reverse();
        shuffled.swap(0, 12);
        let perm = vgc_multi_reach(&g, &shuffled, &ctx, 16, None);
        for v in 0..g.n() {
            for (lane, &s) in seeds.iter().enumerate() {
                let shuffled_lane = shuffled.iter().position(|&x| x == s).unwrap();
                assert_eq!(
                    base[v] >> lane & 1,
                    perm[v] >> shuffled_lane & 1,
                    "vertex {v} seed {s}: reachability depends on lane position"
                );
            }
        }
    }

    #[test]
    fn warm_workspace_reuse_across_calls_is_exact() {
        let g = gen::web(8, 5, 3);
        let (scc, sub) = fresh_ctx(g.n());
        let ctx = ReachCtx {
            scc: &scc,
            sub: &sub,
        };
        let mut masks = StampedU64::new(0);
        let mut pending = StampedU32::new(0);
        let mut bag = HashBag::default();
        let mut frontier = Vec::new();
        for round in 0..5u32 {
            let seeds: Vec<V> = (0..8).map(|i| (i * 7 + round) % g.n() as u32).collect();
            vgc_multi_reach_ws(
                &g,
                &seeds,
                &ctx,
                16,
                None,
                &mut masks,
                &mut pending,
                &mut bag,
                &mut frontier,
                None,
            );
            let fresh = vgc_multi_reach(&g, &seeds, &ctx, 16, None);
            assert_eq!(masks.export(g.n()), fresh, "round {round}");
        }
    }
}
