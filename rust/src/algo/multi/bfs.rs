//! Batched multi-source BFS: up to 64 sources answered by one frontier
//! walk.
//!
//! The `vgc_multi_reach` bit-mask technique generalized to per-source
//! distances. State lives in a [`MultiBfsWorkspace`]:
//!
//! * `dist[v * lanes + lane]` — lane-striped hop distances. The lane
//!   count is the *actual* batch width, so a 4-source batch pays 4
//!   lanes of storage and export, not 64.
//! * `masks[v]` — one [`StampedU64`] word of "active sources" per
//!   vertex: the lanes whose distance at `v` ever improved. The word
//!   is monotone (`fetch_or` only); per-lane *expanded-at* marks
//!   qualify re-expansion exactly (one winner per improved value), so
//!   stale mask bits cost one load, never an edge scan.
//!
//! Two engines, mirroring the single-source pair:
//!
//! * [`multi_bfs_vgc_ws`] — the VGC τ-budget worklist loop: each
//!   scheduled task runs a FIFO local search that relaxes *all* of a
//!   vertex's expanding lanes against each scanned edge, so one
//!   neighbor-list traversal pays for up to 64 logical BFS steps.
//!   Discoveries more than a hop-window ahead of the round's level are
//!   deferred (the same "don't visit unready vertices" rule as
//!   `vgc_bfs`, collapsed to one window instead of 2^i buckets — the
//!   per-lane qualification already bounds re-visits exactly).
//! * [`multi_bfs_diropt_ws`] — level-synchronous direction-optimizing
//!   walk: top-down rounds claim `(vertex, lane)` pairs with a CAS;
//!   bottom-up rounds test the whole frontier mask *word* of each
//!   in-neighbor against the vertex's unvisited lanes — not one bit —
//!   so a dense round completes up to 64 BFS levels per vertex scan.
//!   Level synchrony makes every first discovery final: no
//!   corrections, bit-identical to per-source `diropt_bfs`.
//!
//! Both leave results in the workspace; demultiplex per lane with
//! [`MultiBfsWorkspace::export_lane_into`] (a parallel strided copy).
//!
//! [`StampedU64`]: crate::parallel::StampedU64

use super::mask::{
    compact_lanes, compaction_due, for_each_lane, full_mask, lane_fifo_search, reset_mask_state,
    LanePerm, MaskFrontier, MAX_LANES,
};
use crate::algo::cancel::{cancelled, Cancel};
use crate::algo::workspace::MultiBfsWorkspace;
use crate::algo::UNREACHED;
use crate::graph::Graph;
use crate::parallel::vgc::SearchStats;
use crate::parallel::{pack_index_into, pack_into, parallel_for};
use crate::sim::trace::{Recorder, RoundSlots, TaskCost};
use crate::V;

/// Seeds per local-search task (VGC engine).
const SEEDS: usize = 4;

/// Hop window of the VGC engine: discoveries within `cur + WINDOW`
/// keep expanding inside the task; farther ones are deferred until the
/// wavefront approaches (cf. `vgc_bfs`).
const WINDOW: u32 = 64;

/// GAPBS direction-switch thresholds (diropt engine).
const ALPHA: usize = 15;
const BETA: usize = 18;

/// Validate a batch and return its lane count.
fn check_batch(g: &Graph, seeds: &[V]) -> usize {
    let lanes = seeds.len();
    assert!(
        (1..=MAX_LANES).contains(&lanes),
        "batch width must be 1..=64, got {lanes}"
    );
    for &s in seeds {
        assert!((s as usize) < g.n(), "source {s} out of range (n={})", g.n());
    }
    lanes
}

/// Hop distances from every seed (allocate-per-call wrapper around
/// [`multi_bfs_vgc_ws`]): `result[lane][v]` = distance from
/// `seeds[lane]` to `v`.
pub fn multi_bfs_vgc(g: &Graph, seeds: &[V], tau: usize, rec: Recorder) -> Vec<Vec<u32>> {
    let mut ws = MultiBfsWorkspace::new();
    multi_bfs_vgc_ws(g, seeds, tau, rec, &mut ws);
    ws.export_all(g.n())
}

/// Batched VGC BFS into a reusable workspace: one τ-budget frontier
/// walk answers all `seeds` (≤ 64). Per-lane results are left
/// lane-striped in `ws.dist`; a warm workspace performs no O(n·lanes)
/// allocation.
pub fn multi_bfs_vgc_ws(
    g: &Graph,
    seeds: &[V],
    tau: usize,
    rec: Recorder,
    ws: &mut MultiBfsWorkspace,
) {
    multi_bfs_vgc_ws_cancel(g, seeds, tau, rec, ws, None);
}

/// [`multi_bfs_vgc_ws`] with a cooperative-cancellation token: the
/// round loop polls `cancel` once per frontier round (never per edge)
/// and exits early — leaving partial lane-striped state the serving
/// layer must not summarize — when it fires.
pub fn multi_bfs_vgc_ws_cancel(
    g: &Graph,
    seeds: &[V],
    tau: usize,
    mut rec: Recorder,
    ws: &mut MultiBfsWorkspace,
    cancel: Cancel<'_>,
) {
    let lanes = check_batch(g, seeds);
    let n = g.n();
    let tau = tau.max(1);
    ws.lanes = lanes;
    ws.dist.ensure_len(n * lanes);
    ws.dist.reset(UNREACHED);
    ws.expanded.ensure_len(n * lanes);
    ws.expanded.reset(UNREACHED);
    reset_mask_state(n, &mut ws.masks, &mut ws.pending, &mut ws.bag);
    // Submission lane -> physical lane; identity (empty) until a
    // mid-walk compaction permutes the stripes.
    let mut lane_map = std::mem::take(&mut ws.lane_map);
    lane_map.clear();

    let dist = &ws.dist;
    let expanded = &ws.expanded;
    let mf = MaskFrontier {
        masks: &ws.masks,
        pending: &ws.pending,
        bag: &ws.bag,
    };

    let mut frontier = std::mem::take(&mut ws.frontier);
    frontier.clear();
    for (i, &s) in seeds.iter().enumerate() {
        dist.store(s as usize * lanes + i, 0);
        if mf.mark_pending(s, 1u64 << i) {
            frontier.push(s);
        }
    }

    let mut work = std::mem::take(&mut ws.next);
    // Reused per-round cache of each frontier vertex's pending
    // distance (the lane scan is paid once, not twice).
    let mut dmins = std::mem::take(&mut ws.offs);

    // Mid-walk lane compaction state: `width` is the physical lane
    // count still walking, `live` the live set seen by the previous
    // round's wavefront scan (a converged lane can never produce work
    // again — its improvements are all expanded and expansion is the
    // only source of new ones — so liveness is monotone).
    let mut width = lanes;
    let mut live = full_mask(lanes);
    let mut compactions = 0u64;

    while !frontier.is_empty() {
        // Cancellation point: break (never return) so the workspace
        // restores below still run and the pooled buffers stay warm.
        if cancelled(cancel) {
            break;
        }
        // Re-pack live lanes into a dense prefix once >= 3/4 of the
        // batch has converged: later mask scans stop visiting dead
        // lanes entirely, while their final distances stay exportable
        // at the parked positions via `lane_map`.
        if compaction_due(live, width) {
            let perm = LanePerm::build(live, width);
            compact_lanes(n, lanes, width, &perm, &[dist, expanded], mf.masks);
            if lane_map.is_empty() {
                lane_map.extend(0..lanes as u32);
            }
            for m in lane_map.iter_mut() {
                if (*m as usize) < width {
                    *m = perm.target(*m as usize) as u32;
                }
            }
            width = perm.live;
            live = full_mask(width);
            compactions += 1;
        }
        // Re-align the hop window to the smallest unexpanded distance
        // still pending (lanes run at different phases; the minimum is
        // the wavefront). The same scan observes which lanes still
        // have unexpanded work — the compaction live set.
        dmins.clear();
        let mut cur = UNREACHED;
        let mut round_live = 0u64;
        for &v in &frontier {
            let mut dmin = UNREACHED;
            for_each_lane(mf.mask(v), |lane| {
                let idx = v as usize * lanes + lane;
                let d = dist.get(idx);
                if d < expanded.get(idx) {
                    round_live |= 1u64 << lane;
                    if d < dmin {
                        dmin = d;
                    }
                }
            });
            dmins.push(dmin as usize);
            if dmin < cur {
                cur = dmin;
            }
        }
        live = round_live;
        // Admit the within-window slice; defer unready (far-ahead)
        // vertices so overshooting claims are corrected before they
        // are expanded — vgc_bfs's bucket rule, one window at a time.
        // Stale entries are admitted: processing them is how their
        // pending flag clears.
        work.clear();
        for (&v, &dmin) in frontier.iter().zip(&dmins) {
            let d = dmin as u32;
            if d == UNREACHED || d.saturating_sub(cur) <= WINDOW {
                work.push(v);
            } else {
                mf.defer(v);
            }
        }
        let ntasks = work.len().div_ceil(SEEDS);
        let slots = RoundSlots::new(if rec.is_some() { ntasks } else { 0 });
        let record = rec.is_some();
        // Qualify each touched lane: expand only on a strict
        // improvement since its last expansion (one winner per value).
        let qualify = |v: V, mv: u64, exp: &mut Vec<(usize, u32)>| {
            for_each_lane(mv, |lane| {
                let idx = v as usize * lanes + lane;
                let d = dist.get(idx);
                let e = expanded.get(idx);
                if d < e && expanded.compare_exchange(idx, e, d) {
                    exp.push((lane, d + 1));
                }
            });
        };
        // One neighbor-list traversal relaxes every expanding lane:
        // the batched-walk payoff.
        let scan = |v: V,
                    exp: &[(usize, u32)],
                    stats: &mut SearchStats,
                    enqueue: &mut dyn FnMut(V, bool)| {
            for &w in g.neighbors(v) {
                stats.edges += 1;
                let mut bits = 0u64;
                let mut best = UNREACHED;
                for &(lane, nd) in exp {
                    if dist.write_min(w as usize * lanes + lane, nd) {
                        bits |= 1u64 << lane;
                        if nd < best {
                            best = nd;
                        }
                    }
                }
                if bits != 0 && mf.mark_pending(w, bits) {
                    enqueue(w, best.saturating_sub(cur) <= WINDOW);
                }
            }
        };
        lane_fifo_search(&work, tau, SEEDS, mf, &slots, record, &qualify, &scan);
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(slots.into_round());
        }
        mf.drain_into(&mut frontier);
    }

    ws.frontier = frontier;
    ws.next = work;
    ws.offs = dmins;
    ws.lane_map = lane_map;
    ws.compactions = compactions;
}

/// Hop distances from every seed (allocate-per-call wrapper around
/// [`multi_bfs_diropt_ws`]).
pub fn multi_bfs_diropt(
    g: &Graph,
    gt: Option<&Graph>,
    seeds: &[V],
    rec: Recorder,
) -> Vec<Vec<u32>> {
    let mut ws = MultiBfsWorkspace::new();
    multi_bfs_diropt_ws(g, gt, seeds, rec, &mut ws);
    ws.export_all(g.n())
}

/// Batched direction-optimizing BFS into a reusable workspace:
/// level-synchronous, so every claim is final. `gt` supplies
/// in-neighbors for the bottom-up rounds (pass `Some(&g)` for
/// symmetric graphs); without it the walk stays top-down (still
/// correct). The bottom-up step tests each in-neighbor's whole
/// frontier mask word against the vertex's unvisited lanes.
pub fn multi_bfs_diropt_ws(
    g: &Graph,
    gt: Option<&Graph>,
    seeds: &[V],
    rec: Recorder,
    ws: &mut MultiBfsWorkspace,
) {
    multi_bfs_diropt_ws_cancel(g, gt, seeds, rec, ws, None);
}

/// [`multi_bfs_diropt_ws`] with a cooperative-cancellation token,
/// polled once per level (see [`multi_bfs_vgc_ws_cancel`]).
pub fn multi_bfs_diropt_ws_cancel(
    g: &Graph,
    gt: Option<&Graph>,
    seeds: &[V],
    mut rec: Recorder,
    ws: &mut MultiBfsWorkspace,
    cancel: Cancel<'_>,
) {
    let lanes = check_batch(g, seeds);
    let n = g.n();
    let m = g.m();
    ws.lanes = lanes;
    // Level synchrony never compacts: lanes stay at their submission
    // positions (a stale map from a previous VGC walk must not leak).
    ws.lane_map.clear();
    ws.compactions = 0;
    ws.dist.ensure_len(n * lanes);
    ws.dist.reset(UNREACHED);
    ws.masks.ensure_len(n);
    ws.masks.advance_epoch();
    let mut cur_mask = std::mem::take(&mut ws.cur_mask);
    cur_mask.ensure_len(n);
    cur_mask.advance_epoch();
    let mut next_mask = std::mem::take(&mut ws.next_mask);
    next_mask.ensure_len(n);
    // (next_mask's epoch advances at the top of every round.)
    let gt = gt.or(if g.symmetric { Some(g) } else { None });
    let full = full_mask(lanes);
    let dist = &ws.dist;
    // Accumulated visited lanes per vertex; the bottom-up filter.
    let visited = &ws.masks;

    let mut frontier = std::mem::take(&mut ws.frontier);
    let mut next = std::mem::take(&mut ws.next);
    let mut offs = std::mem::take(&mut ws.offs);
    let mut out = std::mem::take(&mut ws.edge_buf);
    frontier.clear();
    for (i, &s) in seeds.iter().enumerate() {
        dist.store(s as usize * lanes + i, 0);
        if visited.fetch_or(s as usize, 1u64 << i) == 0 {
            frontier.push(s);
        }
        cur_mask.fetch_or(s as usize, 1u64 << i);
    }

    let mut level: u32 = 0;
    while !frontier.is_empty() {
        // Cancellation point: break, not return — the restores below
        // must run (see `crate::algo::cancel`).
        if cancelled(cancel) {
            break;
        }
        let frontier_edges: usize = frontier.iter().map(|&v| g.degree(v)).sum();
        let dense = gt.is_some() && frontier_edges > m / ALPHA && frontier.len() > n / (BETA * 4);
        next_mask.advance_epoch();

        if dense {
            let gt = gt.unwrap();
            // Bottom-up: every vertex with unvisited lanes looks back,
            // absorbing whole frontier mask words.
            let nchunks = n.div_ceil(1024);
            let slots = RoundSlots::new(nchunks);
            {
                let cur = &cur_mask;
                let nxt = &next_mask;
                crate::parallel::ops::parallel_for_chunks(0, n, 1024, |ci, range| {
                    let mut scanned = 0u64;
                    let mut seen = 0u64;
                    for v in range {
                        let mut rem = full & !visited.get(v);
                        if rem == 0 {
                            continue;
                        }
                        seen += 1;
                        for &u in gt.neighbors(v as V) {
                            scanned += 1;
                            let add = cur.get(u as usize) & rem;
                            if add != 0 {
                                for_each_lane(add, |lane| {
                                    dist.store(v * lanes + lane, level + 1);
                                });
                                visited.fetch_or(v, add);
                                nxt.fetch_or(v, add);
                                rem &= !add;
                                if rem == 0 {
                                    break;
                                }
                            }
                        }
                    }
                    slots.set(
                        ci,
                        TaskCost {
                            vertices: seen,
                            edges: scanned,
                        },
                    );
                });
            }
            if let Some(trace) = rec.as_deref_mut() {
                trace.push_round(slots.into_round());
            }
            pack_index_into(n, |v| next_mask.get(v) != 0, &mut next);
            std::mem::swap(&mut frontier, &mut next);
        } else {
            // Top-down sparse round: claim (vertex, lane) pairs by CAS.
            offs.clear();
            offs.extend(frontier.iter().map(|&v| g.degree(v)));
            let total = crate::parallel::scan_inplace(&mut offs);
            out.clear();
            out.resize(total, UNREACHED);
            {
                let op = crate::parallel::ops::SendPtr(out.as_mut_ptr());
                let frontier_ref = &frontier;
                let offs_ref = &offs;
                let cur = &cur_mask;
                let nxt = &next_mask;
                parallel_for(0, frontier_ref.len(), 64, move |i| {
                    let v = frontier_ref[i];
                    let mv = cur.get(v as usize);
                    let base = offs_ref[i];
                    for (j, &w) in g.neighbors(v).iter().enumerate() {
                        let mut bits = 0u64;
                        for_each_lane(mv, |lane| {
                            if dist.compare_exchange(
                                w as usize * lanes + lane,
                                UNREACHED,
                                level + 1,
                            ) {
                                bits |= 1u64 << lane;
                            }
                        });
                        if bits != 0 {
                            visited.fetch_or(w as usize, bits);
                            // Exactly one edge sees the word go 0 -> x
                            // this level: it owns w's frontier slot.
                            if nxt.fetch_or(w as usize, bits) == 0 {
                                unsafe { *op.add(base + j) = w };
                            }
                        }
                    }
                });
            }
            if let Some(trace) = rec.as_deref_mut() {
                trace.push_round(
                    frontier
                        .iter()
                        .map(|&v| TaskCost {
                            vertices: 1,
                            edges: g.degree(v) as u64,
                        })
                        .collect(),
                );
            }
            pack_into(&out, |i| out[i] != UNREACHED, &mut next);
            std::mem::swap(&mut frontier, &mut next);
        }
        std::mem::swap(&mut cur_mask, &mut next_mask);
        level += 1;
    }

    ws.cur_mask = cur_mask;
    ws.next_mask = next_mask;
    ws.frontier = frontier;
    ws.next = next;
    ws.offs = offs;
    ws.edge_buf = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bfs::seq_bfs;
    use crate::graph::gen;

    fn check_lanes(g: &Graph, seeds: &[V], got: &[Vec<u32>], tag: &str) {
        assert_eq!(got.len(), seeds.len(), "{tag}: lane count");
        for (lane, &s) in seeds.iter().enumerate() {
            assert_eq!(got[lane], seq_bfs(g, s), "{tag}: lane {lane} seed {s}");
        }
    }

    #[test]
    fn vgc_engine_matches_seq_per_lane() {
        let g = gen::grid(11, 13);
        let seeds: Vec<V> = vec![0, 7, 100, 42];
        for tau in [1usize, 16, 1 << 20] {
            let got = multi_bfs_vgc(&g, &seeds, tau, None);
            check_lanes(&g, &seeds, &got, &format!("tau={tau}"));
        }
    }

    #[test]
    fn vgc_engine_full_width_64() {
        let g = gen::web(8, 5, 2);
        let seeds: Vec<V> = (0..64).map(|i| (i * 11) % g.n() as u32).collect();
        let got = multi_bfs_vgc(&g, &seeds, 64, None);
        check_lanes(&g, &seeds, &got, "width 64");
    }

    #[test]
    fn vgc_engine_duplicate_and_unreachable_seeds() {
        let g = gen::path(50); // directed: nothing reaches backwards
        let seeds: Vec<V> = vec![49, 0, 49];
        let got = multi_bfs_vgc(&g, &seeds, 8, None);
        check_lanes(&g, &seeds, &got, "dup seeds");
        assert_eq!(got[0][0], UNREACHED);
        assert_eq!(got[1][49], 49);
    }

    #[test]
    fn vgc_batched_chain_still_collapses_rounds() {
        let g = gen::path(2048);
        let seeds: Vec<V> = vec![0, 1, 512];
        let mut t = crate::sim::AlgoTrace::new();
        let got = multi_bfs_vgc(&g, &seeds, 512, Some(&mut t));
        check_lanes(&g, &seeds, &got, "chain");
        assert!(
            t.num_rounds() < 200,
            "batched VGC must keep rounds << D, got {}",
            t.num_rounds()
        );
    }

    #[test]
    fn diropt_engine_matches_seq_per_lane() {
        // Dense enough to trigger bottom-up mask-word rounds.
        let g = gen::social(10, 24, 5).symmetrize();
        let seeds: Vec<V> = (0..32).map(|i| (i * 17) % g.n() as u32).collect();
        let got = multi_bfs_diropt(&g, Some(&g), &seeds, None);
        check_lanes(&g, &seeds, &got, "social");
    }

    #[test]
    fn diropt_directed_with_transpose_and_without() {
        let g = gen::web(9, 8, 4);
        let gt = g.transpose();
        let seeds: Vec<V> = vec![1, 3, 5, 7, 11];
        let got = multi_bfs_diropt(&g, Some(&gt), &seeds, None);
        check_lanes(&g, &seeds, &got, "with transpose");
        let got = multi_bfs_diropt(&g, None, &seeds, None);
        check_lanes(&g, &seeds, &got, "top-down only");
    }

    #[test]
    fn vgc_lane_compaction_is_bit_identical() {
        // Directed path: seeds near the tail converge within a few
        // hops, the seed at the head walks the whole chain — the skew
        // that triggers mid-walk compaction.
        let g = gen::path(2048);
        let n = g.n() as u32;
        for &w in &[5usize, 17, 64] {
            let mut seeds: Vec<V> = (0..w as u32 - 1).map(|i| n - 1 - i).collect();
            seeds.push(0);
            let mut ws = MultiBfsWorkspace::new();
            multi_bfs_vgc_ws(&g, &seeds, 32, None, &mut ws);
            assert!(
                ws.compactions > 0,
                "width {w}: skewed batch should compact, got 0"
            );
            check_lanes(&g, &seeds, &ws.export_all(g.n()), &format!("compacted w={w}"));
        }
    }

    #[test]
    fn vgc_repeated_compaction_composes_the_lane_map() {
        // Three convergence tiers: 48 tail seeds die first (live drops
        // to 16 of 64 -> first re-pack), 15 mid-chain seeds die next
        // (live drops to 1 of 16 -> second re-pack), the head seed
        // walks alone to the end. Exports must survive the composed
        // permutation.
        let g = gen::path(4096);
        let n = g.n() as u32;
        let mut seeds: Vec<V> = (0..48).map(|i| n - 1 - i).collect();
        seeds.extend((0..15u32).map(|i| n / 2 - i * 7));
        seeds.push(0);
        let mut ws = MultiBfsWorkspace::new();
        multi_bfs_vgc_ws(&g, &seeds, 64, None, &mut ws);
        assert!(
            ws.compactions >= 2,
            "tiered convergence should compact at least twice, got {}",
            ws.compactions
        );
        check_lanes(&g, &seeds, &ws.export_all(g.n()), "two-tier 64");
    }

    #[test]
    fn warm_workspace_reuse_across_widths() {
        let g = gen::grid(9, 17);
        let mut ws = MultiBfsWorkspace::new();
        // Shrinking then growing widths: stale lanes must never leak.
        for &w in &[5usize, 1, 3, 5] {
            let seeds: Vec<V> = (0..w as u32).map(|i| i * 29 % g.n() as u32).collect();
            multi_bfs_vgc_ws(&g, &seeds, 32, None, &mut ws);
            check_lanes(&g, &seeds, &ws.export_all(g.n()), &format!("vgc w={w}"));
            multi_bfs_diropt_ws(&g, Some(&g), &seeds, None, &mut ws);
            check_lanes(&g, &seeds, &ws.export_all(g.n()), &format!("diropt w={w}"));
        }
    }
}
