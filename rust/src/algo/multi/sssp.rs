//! Batched multi-source ρ-stepping SSSP: up to 64 sources relaxed by
//! one bucketed frontier walk.
//!
//! Lane-striped `f32` tentative distances (`dist[v * lanes + lane]`,
//! stored as order-preserving bits in a [`StampedU32`]) with per-lane
//! `write_min`; one pending bag, one pending flag array and one
//! threshold/sample structure shared across every lane, so the
//! frontier walk, the θ sampling and the edge scan are paid once per
//! batch instead of once per source.
//!
//! The round structure is `rho_stepping`'s: sample the pending
//! distances (a vertex's pending distance is the minimum over its
//! *unsettled* lanes), pick a threshold θ admitting ~ρ vertices capped
//! by a mean-weight window, expand the admitted slice with τ-budget
//! VGC local searches, defer the rest. Per-lane settled marks qualify
//! re-expansion (strict improvement since the last expansion — one
//! winner per value), exactly as in the single-source engine, so the
//! batch converges to the same least fixpoint as 64 solo runs:
//! per-lane results are **bit-identical** to `rho_stepping_ws`.
//!
//! [`StampedU32`]: crate::parallel::StampedU32

use super::mask::{
    compact_lanes, compaction_due, for_each_lane, full_mask, lane_fifo_search, reset_mask_state,
    LanePerm, MaskFrontier, MAX_LANES,
};
use crate::algo::cancel::{cancelled, Cancel};
use crate::algo::workspace::MultiSsspWorkspace;
use crate::graph::Graph;
use crate::parallel::vgc::SearchStats;
use crate::sim::trace::{Recorder, RoundSlots};
use crate::{INF, V};

/// Vertices admitted per round (the ρ parameter).
const RHO: usize = 1 << 10;

/// Seeds per local-search task.
const SEEDS: usize = 4;

/// Shortest distances from every seed (allocate-per-call wrapper
/// around [`multi_rho_ws`]): `result[lane][v]` = distance from
/// `seeds[lane]` to `v`.
pub fn multi_rho(g: &Graph, seeds: &[V], tau: usize, rec: Recorder) -> Vec<Vec<f32>> {
    let mut ws = MultiSsspWorkspace::new();
    multi_rho_ws(g, seeds, tau, rec, &mut ws);
    ws.export_all(g.n())
}

/// Batched ρ-stepping into a reusable workspace: one θ-thresholded
/// frontier walk answers all `seeds` (≤ 64). Per-lane results are left
/// lane-striped in `ws.dist` as f32 bits; a warm workspace performs no
/// O(n·lanes) allocation.
pub fn multi_rho_ws(
    g: &Graph,
    seeds: &[V],
    tau: usize,
    rec: Recorder,
    ws: &mut MultiSsspWorkspace,
) {
    multi_rho_ws_cancel(g, seeds, tau, rec, ws, None);
}

/// [`multi_rho_ws`] with a cooperative-cancellation token, polled once
/// per θ-threshold round (never per edge): an expired or condemned
/// query abandons the walk within one round, leaving partial
/// lane-striped state the serving layer must not summarize.
pub fn multi_rho_ws_cancel(
    g: &Graph,
    seeds: &[V],
    tau: usize,
    mut rec: Recorder,
    ws: &mut MultiSsspWorkspace,
    cancel: Cancel<'_>,
) {
    let lanes = seeds.len();
    assert!(
        (1..=MAX_LANES).contains(&lanes),
        "batch width must be 1..=64, got {lanes}"
    );
    let n = g.n();
    for &s in seeds {
        assert!((s as usize) < n, "source {s} out of range (n={n})");
    }
    let tau = tau.max(1);
    ws.lanes = lanes;
    ws.dist.ensure_len(n * lanes);
    ws.dist.reset(INF.to_bits());
    ws.settled.ensure_len(n * lanes);
    ws.settled.reset(INF.to_bits());
    reset_mask_state(n, &mut ws.masks, &mut ws.flags, &mut ws.bag);
    // Submission lane -> physical lane; identity (empty) until a
    // mid-walk compaction permutes the stripes.
    let mut lane_map = std::mem::take(&mut ws.lane_map);
    lane_map.clear();

    let dist = &ws.dist;
    // settled[v*L+lane] = bits of the distance that lane was last
    // *expanded* with; a lane re-expands only after a strict
    // improvement (same qualify step as rho_stepping — without it,
    // in-round corrections re-relax whole neighborhoods).
    let settled = &ws.settled;
    let mf = MaskFrontier {
        masks: &ws.masks,
        pending: &ws.flags,
        bag: &ws.bag,
    };

    // Admission window in units of the memoized mean edge weight (one
    // parallel reduction per graph, shared by every query and lane).
    let mean_w = g.weight_stats().mean.max(1e-6);
    let width = 16.0 * mean_w;

    let mut pending = std::mem::take(&mut ws.pending);
    pending.clear();
    for (i, &s) in seeds.iter().enumerate() {
        dist.store_f32(s as usize * lanes + i, 0.0);
        if mf.mark_pending(s, 1u64 << i) {
            pending.push(s);
        }
    }
    let mut work = std::mem::take(&mut ws.work);
    let mut sample = std::mem::take(&mut ws.sample);

    // Pending distance of a vertex: min over its unsettled lanes.
    let pending_min = |v: V| {
        let mut best = INF;
        for_each_lane(mf.mask(v), |lane| {
            let idx = v as usize * lanes + lane;
            let db = dist.get(idx);
            if db < settled.get(idx) {
                let d = f32::from_bits(db);
                if d < best {
                    best = d;
                }
            }
        });
        best
    };

    // Mid-walk lane compaction state: `active` is the physical lane
    // count still walking, `live` the live set seen by the previous
    // round's partition scan (liveness is monotone: a settled lane's
    // improvements are all expanded, and expansion is the only source
    // of new ones).
    let mut active = lanes;
    let mut live = full_mask(lanes);
    let mut compactions = 0u64;

    while !pending.is_empty() {
        // Cancellation point: break (never return) so the workspace
        // restores below still run and the pooled buffers stay warm.
        if cancelled(cancel) {
            break;
        }
        // Re-pack live lanes into a dense prefix once >= 3/4 of the
        // batch has settled: later mask scans stop visiting dead lanes
        // entirely, while their final distances stay exportable at the
        // parked positions via `lane_map`.
        if compaction_due(live, active) {
            let perm = LanePerm::build(live, active);
            compact_lanes(n, lanes, active, &perm, &[dist, settled], mf.masks);
            if lane_map.is_empty() {
                lane_map.extend(0..lanes as u32);
            }
            for m in lane_map.iter_mut() {
                if (*m as usize) < active {
                    *m = perm.target(*m as usize) as u32;
                }
            }
            active = perm.live;
            live = full_mask(active);
            compactions += 1;
        }
        // Threshold: the smaller of (a) the ~RHO-th smallest pending
        // distance and (b) min pending distance + the width cap —
        // one sample pass shared by all lanes.
        let stride = (pending.len() / 1024).max(1);
        sample.clear();
        sample.extend(pending.iter().step_by(stride).map(|&v| pending_min(v)));
        sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let by_count = if pending.len() <= RHO {
            INF
        } else {
            let idx = (RHO * sample.len() / pending.len()).min(sample.len() - 1);
            sample[idx]
        };
        let theta = by_count.min(sample[0] + width);

        // Partition: admitted now, deferred back to the bag. The same
        // lane scan observes which lanes still carry unsettled work —
        // the compaction live set.
        work.clear();
        let mut round_live = 0u64;
        for &v in &pending {
            let mut best = INF;
            for_each_lane(mf.mask(v), |lane| {
                let idx = v as usize * lanes + lane;
                let db = dist.get(idx);
                if db < settled.get(idx) {
                    round_live |= 1u64 << lane;
                    let d = f32::from_bits(db);
                    if d < best {
                        best = d;
                    }
                }
            });
            if best <= theta {
                work.push(v);
            } else {
                mf.defer(v); // still pending (flag stays 1)
            }
        }
        live = round_live;
        if work.is_empty() {
            // θ below every pending distance can't happen (θ is a
            // pending distance or INF), but guard against fp quirks.
            work.extend_from_slice(&pending);
        }

        // VGC local searches over the admitted set; one edge scan
        // relaxes every expanding lane. The FIFO qualify/mark-pending/
        // defer protocol is the shared lane_fifo_search engine.
        let ntasks = work.len().div_ceil(SEEDS);
        let slots = RoundSlots::new(if rec.is_some() { ntasks } else { 0 });
        let record = rec.is_some();
        // Qualify each touched lane: expand only on a strict
        // improvement since its last expansion.
        let qualify = |v: V, mv: u64, exp: &mut Vec<(usize, f32)>| {
            for_each_lane(mv, |lane| {
                let idx = v as usize * lanes + lane;
                let db = dist.get(idx);
                let set = settled.get(idx);
                if db < set && settled.compare_exchange(idx, set, db) {
                    exp.push((lane, f32::from_bits(db)));
                }
            });
        };
        let scan = |v: V,
                    exp: &[(usize, f32)],
                    stats: &mut SearchStats,
                    enqueue: &mut dyn FnMut(V, bool)| {
            let ws_edge = g.weights().map(|_| g.weights_of(v));
            for (j, &u) in g.neighbors(v).iter().enumerate() {
                stats.edges += 1;
                let w = ws_edge.map_or(1.0, |we| we[j]);
                let mut bits = 0u64;
                let mut best = INF;
                for &(lane, dv) in exp {
                    let nd = dv + w;
                    if dist.write_min_f32(u as usize * lanes + lane, nd) {
                        bits |= 1u64 << lane;
                        if nd < best {
                            best = nd;
                        }
                    }
                }
                if bits != 0 && mf.mark_pending(u, bits) {
                    // Near the threshold: keep walking in this task.
                    enqueue(u, best <= theta);
                }
            }
        };
        lane_fifo_search(&work, tau, SEEDS, mf, &slots, record, &qualify, &scan);
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(slots.into_round());
        }
        mf.drain_into(&mut pending);
        // Dedupe: flag==0 entries were already processed this round.
        pending.retain(|&v| mf.is_pending(v));
    }

    ws.pending = pending;
    ws.work = work;
    ws.sample = sample;
    ws.lane_map = lane_map;
    ws.compactions = compactions;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::sssp::{dijkstra, rho_stepping};
    use crate::graph::gen;

    fn close(got: &[f32], want: &[f32], tag: &str) {
        for (v, (a, b)) in got.iter().zip(want).enumerate() {
            let ok = if *b >= INF {
                *a >= INF
            } else {
                (a - b).abs() <= 1e-3 * b.max(1.0)
            };
            assert!(ok, "{tag}: vertex {v}: got {a} want {b}");
        }
    }

    #[test]
    fn lanes_match_dijkstra_on_knn() {
        let g = gen::knn_points(300, 5, 9);
        let seeds: Vec<V> = vec![0, 7, 150];
        let got = multi_rho(&g, &seeds, 64, None);
        for (lane, &s) in seeds.iter().enumerate() {
            close(&got[lane], &dijkstra(&g, s), &format!("lane {lane}"));
        }
    }

    #[test]
    fn lanes_bit_identical_to_solo_rho() {
        let g = gen::road(8, 11, 5);
        for width in [1usize, 3, 16] {
            let seeds: Vec<V> = (0..width as u32).map(|i| i * 13 % g.n() as u32).collect();
            let got = multi_rho(&g, &seeds, 64, None);
            for (lane, &s) in seeds.iter().enumerate() {
                assert_eq!(
                    got[lane],
                    rho_stepping(&g, s, 64, None),
                    "width {width} lane {lane}: batched must hit the same fixpoint"
                );
            }
        }
    }

    #[test]
    fn unweighted_graph_defaults_to_unit_weights() {
        let g = gen::grid(7, 9);
        let seeds: Vec<V> = vec![0, 31];
        let got = multi_rho(&g, &seeds, 16, None);
        for (lane, &s) in seeds.iter().enumerate() {
            let bfs = crate::algo::bfs::seq_bfs(&g, s);
            for v in 0..g.n() {
                if bfs[v] == u32::MAX {
                    assert!(got[lane][v] >= INF);
                } else {
                    assert_eq!(got[lane][v], bfs[v] as f32, "lane {lane} vertex {v}");
                }
            }
        }
    }

    #[test]
    fn various_tau_all_correct_at_width_64() {
        let g = gen::road(7, 9, 2);
        let seeds: Vec<V> = (0..64).map(|i| i % g.n() as u32).collect();
        for tau in [1usize, 8, 1 << 20] {
            let got = multi_rho(&g, &seeds, tau, None);
            for (lane, &s) in seeds.iter().enumerate() {
                close(&got[lane], &dijkstra(&g, s), &format!("tau {tau} lane {lane}"));
            }
        }
    }

    #[test]
    fn lane_compaction_stays_bit_identical_to_solo_rho() {
        // Directed path with unit weights: tail seeds settle within a
        // few rounds, the head seed walks the whole chain — the skew
        // that triggers mid-walk compaction.
        let g = gen::path(2048);
        let n = g.n() as u32;
        for &w in &[5usize, 17, 64] {
            let mut seeds: Vec<V> = (0..w as u32 - 1).map(|i| n - 1 - i).collect();
            seeds.push(0);
            let mut ws = MultiSsspWorkspace::new();
            multi_rho_ws(&g, &seeds, 32, None, &mut ws);
            assert!(
                ws.compactions > 0,
                "width {w}: skewed batch should compact, got 0"
            );
            let got = ws.export_all(g.n());
            for (lane, &s) in seeds.iter().enumerate() {
                assert_eq!(
                    got[lane],
                    rho_stepping(&g, s, 32, None),
                    "width {w} lane {lane}: compaction must be invisible"
                );
            }
        }
    }

    #[test]
    fn warm_workspace_reuse_across_widths() {
        let g = gen::road(9, 8, 4);
        let mut ws = MultiSsspWorkspace::new();
        for &width in &[8usize, 1, 3] {
            let seeds: Vec<V> = (0..width as u32).map(|i| i * 7 % g.n() as u32).collect();
            multi_rho_ws(&g, &seeds, 32, None, &mut ws);
            let got = ws.export_all(g.n());
            for (lane, &s) in seeds.iter().enumerate() {
                assert_eq!(got[lane], rho_stepping(&g, s, 32, None), "w={width} lane={lane}");
            }
        }
    }
}
