//! The shared mask-frontier engine: per-vertex 64-lane worklist state.
//!
//! Every batched traversal in PASGAL — multi-source reachability (the
//! SCC inner engine), batched multi-source BFS and batched ρ-stepping
//! SSSP — keeps the same three pieces of per-vertex state:
//!
//! * a 64-bit **lane mask** per vertex ([`StampedU64`]) recording which
//!   of the batch's sources (lanes) have touched it,
//! * a **pending flag** per vertex ([`StampedU32`]) deduplicating the
//!   worklist (a vertex is enqueued at most once until processed), and
//! * a deferred-work [`HashBag`] drained into the frontier between
//!   rounds.
//!
//! [`MaskFrontier`] bundles the three behind the classic worklist
//! protocol: a task *begins* a vertex by clearing its pending flag
//! **before** reading the mask — so bits arriving after the read
//! re-enqueue the vertex — and writers add bits and enqueue the target
//! iff its flag flips 0 → 1. This loop previously lived, twice, in
//! `algo::scc::reach`; reachability, BFS and SSSP now all drive it.
//!
//! Two propagation flavours, because the two families define
//! "progress" differently:
//!
//! * [`MaskFrontier::spread`] — reachability style: the mask *is* the
//!   whole state, so only a bit that was absent counts as progress.
//! * [`MaskFrontier::mark_pending`] — distance style: progress was
//!   already established by a `write_min` on a lane-striped distance
//!   array; the mask is just a filter of ever-touched lanes (it only
//!   grows; the per-lane "expanded at" qualification makes re-visits
//!   of settled lanes cheap no-ops).

use crate::hashbag::HashBag;
use crate::parallel::ops::parallel_for_chunks;
use crate::parallel::vgc::SearchStats;
use crate::parallel::workspace::{StampedU32, StampedU64};
use crate::sim::trace::RoundSlots;
use crate::V;

/// Most lanes a batch can carry (one bit per source in the mask word).
pub const MAX_LANES: usize = 64;

/// All-ones mask over the first `lanes` lanes.
#[inline]
pub fn full_mask(lanes: usize) -> u64 {
    if lanes >= MAX_LANES {
        u64::MAX
    } else {
        (1u64 << lanes) - 1
    }
}

/// Call `f(lane)` for each set bit of `m`, lowest first.
#[inline]
pub fn for_each_lane(mut m: u64, mut f: impl FnMut(usize)) {
    while m != 0 {
        let lane = m.trailing_zeros() as usize;
        m &= m - 1;
        f(lane);
    }
}

/// Rebind the three mask-frontier arrays for a new query over `n`
/// vertices: O(1) epoch bumps plus a bag rebind — zero O(n) allocation
/// once warm.
pub fn reset_mask_state(
    n: usize,
    masks: &mut StampedU64,
    pending: &mut StampedU32,
    bag: &mut HashBag,
) {
    masks.ensure_len(n);
    masks.advance_epoch();
    pending.ensure_len(n);
    pending.reset(0);
    bag.reset(n);
}

/// Borrowed view over the three mask-frontier arrays (see module
/// docs). `Copy`, so parallel tasks capture it by value.
#[derive(Clone, Copy)]
pub struct MaskFrontier<'a> {
    /// Per-vertex lane bits (monotone within a query: `fetch_or` only).
    pub masks: &'a StampedU64,
    /// Per-vertex pending flag (worklist dedup).
    pub pending: &'a StampedU32,
    /// Deferred vertices, drained into the frontier between rounds.
    pub bag: &'a HashBag,
}

impl MaskFrontier<'_> {
    /// Claim `v` for processing: clear its pending flag — *before*
    /// reading the mask, so bits landing after the read re-enqueue `v`
    /// — and return its lane bits.
    #[inline]
    pub fn begin(&self, v: V) -> u64 {
        self.pending.store(v as usize, 0);
        self.masks.get(v as usize)
    }

    /// Current lane bits of `v` (no pending-flag handshake).
    #[inline]
    pub fn mask(&self, v: V) -> u64 {
        self.masks.get(v as usize)
    }

    /// True while `v` sits in the worklist (frontier, a task-local
    /// queue, or the bag).
    #[inline]
    pub fn is_pending(&self, v: V) -> bool {
        self.pending.get(v as usize) == 1
    }

    /// Reachability-style propagation: `masks[w] |= bits`; true iff
    /// the bits changed the mask *and* `w` newly became pending (the
    /// caller decides task-local queue vs deferred bag).
    #[inline]
    pub fn spread(&self, w: V, bits: u64) -> bool {
        let old = self.masks.fetch_or(w as usize, bits);
        old | bits != old && self.pending.swap(w as usize, 1) == 0
    }

    /// Distance-style propagation: the caller already established
    /// progress (a `write_min` improved some lane); record the touched
    /// lanes and return true iff `w` newly became pending.
    #[inline]
    pub fn mark_pending(&self, w: V, bits: u64) -> bool {
        self.masks.fetch_or(w as usize, bits);
        self.pending.swap(w as usize, 1) == 0
    }

    /// Defer `w` to the between-rounds bag (its pending flag stays up).
    #[inline]
    pub fn defer(&self, w: V) {
        self.bag.insert(w);
    }

    /// Drain the deferred bag into `frontier` for the next round.
    pub fn drain_into(&self, frontier: &mut Vec<V>) {
        self.bag.extract_into(frontier);
    }
}

/// One round of τ-budget, lane-qualified FIFO local searches — the
/// worklist protocol shared by batched VGC BFS
/// ([`crate::algo::multi::multi_bfs_vgc_ws`]) and batched ρ-stepping
/// ([`crate::algo::multi::multi_rho_ws`]), parameterized over the lane
/// payload `P` (the value a qualified lane propagates: `u32` hop
/// distances for BFS, `f32` tentative distances for SSSP).
///
/// `work` is split into chunks of `seeds_per_task` admitted vertices;
/// each parallel task runs one FIFO local search (discovery order, to
/// bound overshoot) with a τ vertex budget:
///
/// 1. *Claim* the next vertex `v` ([`MaskFrontier::begin`]: clear its
///    pending flag before reading its mask, so late-arriving bits
///    re-enqueue it).
/// 2. *Qualify* each touched lane via `qualify(v, mask, &mut exp)` —
///    the caller CASes its per-lane expanded/settled mark and pushes
///    `(lane, payload)` for lanes with a strict improvement to
///    propagate (one winner per improved value).
/// 3. *Scan* `v`'s neighbor list **once** for all expanding lanes via
///    `scan(v, &exp, stats, enqueue)` — the caller relaxes every lane
///    against each edge and calls `enqueue(w, near)` for each
///    newly-pending discovery; `near` decides task-local FIFO
///    (keep walking) vs deferred bag (next round).
/// 4. On budget exhaustion, leftover queued vertices are deferred (the
///    round ends; they stay pending).
///
/// Task costs land in `slots` (when `record`) for the virtual-multicore
/// simulator.
#[allow(clippy::too_many_arguments)]
pub fn lane_fifo_search<P: Copy>(
    work: &[V],
    tau: usize,
    seeds_per_task: usize,
    mf: MaskFrontier<'_>,
    slots: &RoundSlots,
    record: bool,
    qualify: &(impl Fn(V, u64, &mut Vec<(usize, P)>) + Sync),
    scan: &(impl Fn(V, &[(usize, P)], &mut SearchStats, &mut dyn FnMut(V, bool)) + Sync),
) {
    parallel_for_chunks(0, work.len(), seeds_per_task.max(1), |ti, range| {
        // FIFO local search (discovery order) to bound overshoot, as
        // in vgc_bfs / rho_stepping.
        let mut queue: Vec<V> = Vec::with_capacity(64);
        queue.extend(range.map(|i| work[i]));
        let mut head = 0usize;
        let mut exp: Vec<(usize, P)> = Vec::with_capacity(MAX_LANES);
        let mut stats = SearchStats::default();
        while head < queue.len() && (stats.vertices as usize) < tau {
            let v = queue[head];
            head += 1;
            stats.vertices += 1;
            let mv = mf.begin(v);
            exp.clear();
            qualify(v, mv, &mut exp);
            if exp.is_empty() {
                continue;
            }
            scan(v, &exp, &mut stats, &mut |w, near| {
                if near {
                    // Near the wavefront: keep walking in this task.
                    queue.push(w);
                } else {
                    mf.defer(w);
                }
            });
        }
        // Budget exhausted: leftovers stay pending.
        for &w in &queue[head..] {
            mf.defer(w);
        }
        if record {
            slots.set(ti, stats.into());
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mask_widths() {
        assert_eq!(full_mask(1), 1);
        assert_eq!(full_mask(3), 0b111);
        assert_eq!(full_mask(64), u64::MAX);
    }

    #[test]
    fn for_each_lane_visits_set_bits_in_order() {
        let mut seen = Vec::new();
        for_each_lane(0b1010_0001, |l| seen.push(l));
        assert_eq!(seen, vec![0, 5, 7]);
        for_each_lane(0, |_| panic!("no bits"));
        let mut hi = Vec::new();
        for_each_lane(1u64 << 63, |l| hi.push(l));
        assert_eq!(hi, vec![63]);
    }

    #[test]
    fn spread_requires_new_bits_mark_pending_does_not() {
        let mut masks = StampedU64::new(0);
        let mut pending = StampedU32::new(0);
        let mut bag = HashBag::default();
        reset_mask_state(8, &mut masks, &mut pending, &mut bag);
        let mf = MaskFrontier {
            masks: &masks,
            pending: &pending,
            bag: &bag,
        };
        assert!(mf.spread(3, 0b01), "first bit enqueues");
        assert!(!mf.spread(3, 0b01), "same bit is not progress");
        assert!(!mf.spread(3, 0b10), "new bit but already pending");
        assert_eq!(mf.begin(3), 0b11);
        assert!(!mf.is_pending(3));
        // Distance-style: re-marking an existing lane still enqueues
        // (the caller saw a write_min succeed).
        assert!(mf.mark_pending(3, 0b01));
        assert!(!mf.mark_pending(3, 0b01), "already pending again");
        assert!(mf.is_pending(3));
    }

    #[test]
    fn defer_and_drain_roundtrip() {
        let mut masks = StampedU64::new(0);
        let mut pending = StampedU32::new(0);
        let mut bag = HashBag::default();
        reset_mask_state(16, &mut masks, &mut pending, &mut bag);
        let mf = MaskFrontier {
            masks: &masks,
            pending: &pending,
            bag: &bag,
        };
        for v in [1u32, 5, 9] {
            assert!(mf.spread(v, 1));
            mf.defer(v);
        }
        let mut frontier = Vec::new();
        mf.drain_into(&mut frontier);
        frontier.sort();
        assert_eq!(frontier, vec![1, 5, 9]);
    }
}
