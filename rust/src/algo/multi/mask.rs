//! The shared mask-frontier engine: per-vertex 64-lane worklist state.
//!
//! Every batched traversal in PASGAL — multi-source reachability (the
//! SCC inner engine), batched multi-source BFS and batched ρ-stepping
//! SSSP — keeps the same three pieces of per-vertex state:
//!
//! * a 64-bit **lane mask** per vertex ([`StampedU64`]) recording which
//!   of the batch's sources (lanes) have touched it,
//! * a **pending flag** per vertex ([`StampedU32`]) deduplicating the
//!   worklist (a vertex is enqueued at most once until processed), and
//! * a deferred-work [`HashBag`] drained into the frontier between
//!   rounds.
//!
//! [`MaskFrontier`] bundles the three behind the classic worklist
//! protocol: a task *begins* a vertex by clearing its pending flag
//! **before** reading the mask — so bits arriving after the read
//! re-enqueue the vertex — and writers add bits and enqueue the target
//! iff its flag flips 0 → 1. This loop previously lived, twice, in
//! `algo::scc::reach`; reachability, BFS and SSSP now all drive it.
//!
//! Two propagation flavours, because the two families define
//! "progress" differently:
//!
//! * [`MaskFrontier::spread`] — reachability style: the mask *is* the
//!   whole state, so only a bit that was absent counts as progress.
//! * [`MaskFrontier::mark_pending`] — distance style: progress was
//!   already established by a `write_min` on a lane-striped distance
//!   array; the mask is just a filter of ever-touched lanes (it only
//!   grows; the per-lane "expanded at" qualification makes re-visits
//!   of settled lanes cheap no-ops).
//!
//! Distance-style walks additionally support **mid-walk lane
//! compaction**: once ≥3/4 of a fused walk's lanes have converged
//! ([`compaction_due`]), [`compact_lanes`] re-packs the live lanes
//! into a dense low-lane prefix — permuting the lane-striped state and
//! dropping converged lanes' mask bits — so a skewed batch mixing tiny
//! and huge searches stops paying wide mask scans for a handful of
//! live lanes. The permutation is invisible to results: converged
//! lanes keep their final values at parked positions and the engines
//! record the submission-lane → physical-lane map for export.

use crate::hashbag::HashBag;
use crate::parallel::ops::parallel_for_chunks;
use crate::parallel::vgc::SearchStats;
use crate::parallel::workspace::{StampedU32, StampedU64};
use crate::sim::trace::RoundSlots;
use crate::V;

/// Most lanes a batch can carry (one bit per source in the mask word).
pub const MAX_LANES: usize = 64;

/// All-ones mask over the first `lanes` lanes.
#[inline]
pub fn full_mask(lanes: usize) -> u64 {
    if lanes >= MAX_LANES {
        u64::MAX
    } else {
        (1u64 << lanes) - 1
    }
}

/// Call `f(lane)` for each set bit of `m`, lowest first.
#[inline]
pub fn for_each_lane(mut m: u64, mut f: impl FnMut(usize)) {
    while m != 0 {
        let lane = m.trailing_zeros() as usize;
        m &= m - 1;
        f(lane);
    }
}

/// Rebind the three mask-frontier arrays for a new query over `n`
/// vertices: O(1) epoch bumps plus a bag rebind — zero O(n) allocation
/// once warm.
pub fn reset_mask_state(
    n: usize,
    masks: &mut StampedU64,
    pending: &mut StampedU32,
    bag: &mut HashBag,
) {
    masks.ensure_len(n);
    masks.advance_epoch();
    pending.ensure_len(n);
    pending.reset(0);
    bag.reset(n);
}

/// Borrowed view over the three mask-frontier arrays (see module
/// docs). `Copy`, so parallel tasks capture it by value.
#[derive(Clone, Copy)]
pub struct MaskFrontier<'a> {
    /// Per-vertex lane bits (monotone within a query: `fetch_or` only).
    pub masks: &'a StampedU64,
    /// Per-vertex pending flag (worklist dedup).
    pub pending: &'a StampedU32,
    /// Deferred vertices, drained into the frontier between rounds.
    pub bag: &'a HashBag,
}

impl MaskFrontier<'_> {
    /// Claim `v` for processing: clear its pending flag — *before*
    /// reading the mask, so bits landing after the read re-enqueue `v`
    /// — and return its lane bits.
    #[inline]
    pub fn begin(&self, v: V) -> u64 {
        self.pending.store(v as usize, 0);
        self.masks.get(v as usize)
    }

    /// Current lane bits of `v` (no pending-flag handshake).
    #[inline]
    pub fn mask(&self, v: V) -> u64 {
        self.masks.get(v as usize)
    }

    /// True while `v` sits in the worklist (frontier, a task-local
    /// queue, or the bag).
    #[inline]
    pub fn is_pending(&self, v: V) -> bool {
        self.pending.get(v as usize) == 1
    }

    /// Reachability-style propagation: `masks[w] |= bits`; true iff
    /// the bits changed the mask *and* `w` newly became pending (the
    /// caller decides task-local queue vs deferred bag).
    #[inline]
    pub fn spread(&self, w: V, bits: u64) -> bool {
        let old = self.masks.fetch_or(w as usize, bits);
        old | bits != old && self.pending.swap(w as usize, 1) == 0
    }

    /// Distance-style propagation: the caller already established
    /// progress (a `write_min` improved some lane); record the touched
    /// lanes and return true iff `w` newly became pending.
    #[inline]
    pub fn mark_pending(&self, w: V, bits: u64) -> bool {
        self.masks.fetch_or(w as usize, bits);
        self.pending.swap(w as usize, 1) == 0
    }

    /// Defer `w` to the between-rounds bag (its pending flag stays up).
    #[inline]
    pub fn defer(&self, w: V) {
        self.bag.insert(w);
    }

    /// Drain the deferred bag into `frontier` for the next round.
    pub fn drain_into(&self, frontier: &mut Vec<V>) {
        self.bag.extract_into(frontier);
    }
}

/// One round of τ-budget, lane-qualified FIFO local searches — the
/// worklist protocol shared by batched VGC BFS
/// ([`crate::algo::multi::multi_bfs_vgc_ws`]) and batched ρ-stepping
/// ([`crate::algo::multi::multi_rho_ws`]), parameterized over the lane
/// payload `P` (the value a qualified lane propagates: `u32` hop
/// distances for BFS, `f32` tentative distances for SSSP).
///
/// `work` is split into chunks of `seeds_per_task` admitted vertices;
/// each parallel task runs one FIFO local search (discovery order, to
/// bound overshoot) with a τ vertex budget:
///
/// 1. *Claim* the next vertex `v` ([`MaskFrontier::begin`]: clear its
///    pending flag before reading its mask, so late-arriving bits
///    re-enqueue it).
/// 2. *Qualify* each touched lane via `qualify(v, mask, &mut exp)` —
///    the caller CASes its per-lane expanded/settled mark and pushes
///    `(lane, payload)` for lanes with a strict improvement to
///    propagate (one winner per improved value).
/// 3. *Scan* `v`'s neighbor list **once** for all expanding lanes via
///    `scan(v, &exp, stats, enqueue)` — the caller relaxes every lane
///    against each edge and calls `enqueue(w, near)` for each
///    newly-pending discovery; `near` decides task-local FIFO
///    (keep walking) vs deferred bag (next round).
/// 4. On budget exhaustion, leftover queued vertices are deferred (the
///    round ends; they stay pending).
///
/// Task costs land in `slots` (when `record`) for the virtual-multicore
/// simulator.
#[allow(clippy::too_many_arguments)]
pub fn lane_fifo_search<P: Copy>(
    work: &[V],
    tau: usize,
    seeds_per_task: usize,
    mf: MaskFrontier<'_>,
    slots: &RoundSlots,
    record: bool,
    qualify: &(impl Fn(V, u64, &mut Vec<(usize, P)>) + Sync),
    scan: &(impl Fn(V, &[(usize, P)], &mut SearchStats, &mut dyn FnMut(V, bool)) + Sync),
) {
    parallel_for_chunks(0, work.len(), seeds_per_task.max(1), |ti, range| {
        // FIFO local search (discovery order) to bound overshoot, as
        // in vgc_bfs / rho_stepping.
        let mut queue: Vec<V> = Vec::with_capacity(64);
        queue.extend(range.map(|i| work[i]));
        let mut head = 0usize;
        let mut exp: Vec<(usize, P)> = Vec::with_capacity(MAX_LANES);
        let mut stats = SearchStats::default();
        while head < queue.len() && (stats.vertices as usize) < tau {
            let v = queue[head];
            head += 1;
            stats.vertices += 1;
            let mv = mf.begin(v);
            exp.clear();
            qualify(v, mv, &mut exp);
            if exp.is_empty() {
                continue;
            }
            scan(v, &exp, &mut stats, &mut |w, near| {
                if near {
                    // Near the wavefront: keep walking in this task.
                    queue.push(w);
                } else {
                    mf.defer(w);
                }
            });
        }
        // Budget exhausted: leftovers stay pending.
        for &w in &queue[head..] {
            mf.defer(w);
        }
        if record {
            slots.set(ti, stats.into());
        }
    });
}

/// True when a fused walk should re-pack its lanes: at least 3/4 of
/// the current `width` lanes have converged (no pending improvement
/// anywhere) while at least one lane is still walking. The 4× ratio
/// keeps compaction rare — at most `log4(MAX_LANES) = 3` re-packs per
/// walk — so the O(n·width) permutation pass amortizes against the
/// per-round mask scans it eliminates.
#[inline]
pub fn compaction_due(live_mask: u64, width: usize) -> bool {
    let live = live_mask.count_ones() as usize;
    live > 0 && live < width && live * 4 <= width
}

/// A lane permutation packing live lanes into the dense prefix
/// `[0, live)` and parking converged lanes behind them (see
/// [`compact_lanes`]). Converged lanes keep their (final) lane-striped
/// values at their parked positions, so every lane stays exportable;
/// only the *mask bits* of converged lanes are dropped — a converged
/// lane can never improve again, so its bits would only cost
/// [`for_each_lane`] scan work in every later round.
pub struct LanePerm {
    /// Old physical lane → new physical lane (bijective over the old
    /// width).
    to: [u8; MAX_LANES],
    /// Bits (old positions) of the lanes still live.
    live_mask: u64,
    /// Number of live lanes — the compacted width.
    pub live: usize,
}

impl LanePerm {
    /// Build the packing permutation for the given live set over the
    /// current `width` physical lanes.
    pub fn build(live_mask: u64, width: usize) -> LanePerm {
        debug_assert!(width <= MAX_LANES);
        debug_assert_eq!(live_mask & !full_mask(width), 0, "live bits past width");
        let mut to = [0u8; MAX_LANES];
        let mut next_live = 0u8;
        let mut next_dead = live_mask.count_ones() as u8;
        for (lane, slot) in to.iter_mut().enumerate().take(width) {
            if live_mask & (1u64 << lane) != 0 {
                *slot = next_live;
                next_live += 1;
            } else {
                *slot = next_dead;
                next_dead += 1;
            }
        }
        LanePerm {
            to,
            live_mask,
            live: next_live as usize,
        }
    }

    /// New physical position of old physical lane `lane`.
    #[inline]
    pub fn target(&self, lane: usize) -> usize {
        self.to[lane] as usize
    }

    /// Re-map a per-vertex mask word: live bits move to their packed
    /// positions, converged bits are dropped.
    #[inline]
    pub fn remap_word(&self, word: u64) -> u64 {
        let mut out = 0u64;
        for_each_lane(word & self.live_mask, |lane| out |= 1u64 << self.to[lane]);
        out
    }
}

/// Apply `perm` to every vertex's lane-striped state in one parallel
/// pass: each array in `striped` (stride-`stride` per vertex, e.g.
/// dist + expanded for BFS, dist + settled for SSSP) has its first
/// `width` lanes permuted in place, and each vertex's mask word is
/// re-packed via [`LanePerm::remap_word`]. Runs between rounds, when
/// no search tasks are in flight — the unconditional stores are not
/// linearizable against concurrent `fetch_or`/`write_min` traffic.
pub fn compact_lanes(
    n: usize,
    stride: usize,
    width: usize,
    perm: &LanePerm,
    striped: &[&StampedU32],
    masks: &StampedU64,
) {
    debug_assert!(width <= stride && width <= MAX_LANES);
    parallel_for_chunks(0, n, 512, |_, range| {
        let mut tmp = [0u32; MAX_LANES];
        for v in range {
            let base = v * stride;
            for arr in striped {
                for (lane, t) in tmp.iter_mut().enumerate().take(width) {
                    *t = arr.get(base + lane);
                }
                for (lane, t) in tmp.iter().enumerate().take(width) {
                    arr.store(base + perm.to[lane] as usize, *t);
                }
            }
            let word = masks.get(v);
            let packed = perm.remap_word(word);
            if packed != word {
                masks.store(v, packed);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mask_widths() {
        assert_eq!(full_mask(1), 1);
        assert_eq!(full_mask(3), 0b111);
        assert_eq!(full_mask(64), u64::MAX);
    }

    #[test]
    fn for_each_lane_visits_set_bits_in_order() {
        let mut seen = Vec::new();
        for_each_lane(0b1010_0001, |l| seen.push(l));
        assert_eq!(seen, vec![0, 5, 7]);
        for_each_lane(0, |_| panic!("no bits"));
        let mut hi = Vec::new();
        for_each_lane(1u64 << 63, |l| hi.push(l));
        assert_eq!(hi, vec![63]);
    }

    #[test]
    fn spread_requires_new_bits_mark_pending_does_not() {
        let mut masks = StampedU64::new(0);
        let mut pending = StampedU32::new(0);
        let mut bag = HashBag::default();
        reset_mask_state(8, &mut masks, &mut pending, &mut bag);
        let mf = MaskFrontier {
            masks: &masks,
            pending: &pending,
            bag: &bag,
        };
        assert!(mf.spread(3, 0b01), "first bit enqueues");
        assert!(!mf.spread(3, 0b01), "same bit is not progress");
        assert!(!mf.spread(3, 0b10), "new bit but already pending");
        assert_eq!(mf.begin(3), 0b11);
        assert!(!mf.is_pending(3));
        // Distance-style: re-marking an existing lane still enqueues
        // (the caller saw a write_min succeed).
        assert!(mf.mark_pending(3, 0b01));
        assert!(!mf.mark_pending(3, 0b01), "already pending again");
        assert!(mf.is_pending(3));
    }

    #[test]
    fn compaction_due_needs_three_quarters_converged() {
        // width 5: due once a single lane remains.
        assert!(compaction_due(0b00100, 5));
        assert!(!compaction_due(0b00101, 5), "2 live of 5 is below 3/4");
        // width 17: due at <= 4 live lanes.
        assert!(compaction_due(0b1111, 17));
        assert!(!compaction_due(0b11111, 17));
        // width 64: due at <= 16 live lanes.
        assert!(compaction_due((1u64 << 16) - 1, 64));
        assert!(!compaction_due((1u64 << 17) - 1, 64));
        // Degenerate cases never trigger.
        assert!(!compaction_due(0, 64), "no live lanes: walk is over");
        assert!(!compaction_due(1, 1), "nothing to pack at width 1");
        assert!(!compaction_due(full_mask(8), 8), "all live");
    }

    #[test]
    fn lane_perm_is_a_bijection_packing_live_lanes_first() {
        let live = 0b1000_0100_0001u64; // lanes 0, 6, 11 live of 12
        let perm = LanePerm::build(live, 12);
        assert_eq!(perm.live, 3);
        assert_eq!(perm.target(0), 0);
        assert_eq!(perm.target(6), 1);
        assert_eq!(perm.target(11), 2);
        // Bijective over the old width: every target hit exactly once.
        let mut seen = vec![false; 12];
        for lane in 0..12 {
            let t = perm.target(lane);
            assert!(!seen[t], "duplicate target {t}");
            seen[t] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // Dead lanes keep ascending order behind the live prefix.
        assert!(perm.target(1) < perm.target(2));
        // Mask re-pack keeps live bits only, at packed positions.
        assert_eq!(perm.remap_word(live), 0b111);
        assert_eq!(perm.remap_word(0b0100_0010), 0b010, "dead bit 1 dropped");
        assert_eq!(perm.remap_word(0), 0);
    }

    #[test]
    fn compact_lanes_permutes_striped_state_and_repacks_masks() {
        let n = 7usize;
        let width = 8usize;
        let stride = 8usize;
        let mut dist = StampedU32::new(u32::MAX);
        dist.ensure_len(n * stride);
        dist.reset(u32::MAX);
        let mut masks = StampedU64::new(0);
        let mut pending = StampedU32::new(0);
        let mut bag = HashBag::default();
        reset_mask_state(n, &mut masks, &mut pending, &mut bag);
        // Stamp a recognizable value into every (vertex, lane) slot and
        // give each vertex a mask word mixing live and dead lanes.
        for v in 0..n {
            for lane in 0..width {
                dist.store(v * stride + lane, (v * 100 + lane) as u32);
            }
            masks.fetch_or(v, full_mask(width));
        }
        let live = 0b0010_0010u64; // lanes 1 and 5 still walking
        let perm = LanePerm::build(live, width);
        compact_lanes(n, stride, width, &perm, &[&dist], &masks);
        for v in 0..n {
            // Live lanes packed to the prefix, dead values preserved at
            // their parked positions (still exportable).
            assert_eq!(dist.get(v * stride), (v * 100 + 1) as u32);
            assert_eq!(dist.get(v * stride + 1), (v * 100 + 5) as u32);
            let mut vals: Vec<u32> = (0..width).map(|l| dist.get(v * stride + l)).collect();
            vals.sort_unstable();
            let mut want: Vec<u32> = (0..width).map(|l| (v * 100 + l) as u32).collect();
            want.sort_unstable();
            assert_eq!(vals, want, "permutation lost a lane value");
            assert_eq!(masks.get(v), 0b11, "masks keep live bits only");
        }
    }

    #[test]
    fn defer_and_drain_roundtrip() {
        let mut masks = StampedU64::new(0);
        let mut pending = StampedU32::new(0);
        let mut bag = HashBag::default();
        reset_mask_state(16, &mut masks, &mut pending, &mut bag);
        let mf = MaskFrontier {
            masks: &masks,
            pending: &pending,
            bag: &bag,
        };
        for v in [1u32, 5, 9] {
            assert!(mf.spread(v, 1));
            mf.defer(v);
        }
        let mut frontier = Vec::new();
        mf.drain_into(&mut frontier);
        frontier.sort();
        assert_eq!(frontier, vec![1, 5, 9]);
    }
}
