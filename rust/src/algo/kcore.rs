//! k-core decomposition — the paper's §4 future-work extension
//! ("we believe the techniques in current PASGAL can be extended to
//! more problems, including k-core and other peeling algorithms").
//!
//! Coreness of v = largest k such that v belongs to a subgraph of
//! minimum degree k. The classic parallel algorithm peels degree-<k
//! vertices level by level — another round-synchronous frontier
//! computation whose round count ("peeling complexity") can be huge
//! on degenerate graphs, so the same hash-bag frontier machinery
//! applies. We provide the sequential bucket algorithm
//! (Matula–Beck / Batagelj–Zaveršnik) as the oracle and a parallel
//! peeler over hash bags.

use crate::algo::workspace::KcoreWorkspace;
use crate::graph::Graph;
use crate::parallel::workspace::StampedU32;
use crate::parallel::{pack_index_into, parallel_for};
use crate::sim::trace::{Recorder, TaskCost};
use crate::V;

/// Sequential O(n + m) bucket peeling (the oracle). Input must be
/// symmetric; self-loops are ignored.
pub fn seq_kcore(g: &Graph) -> Vec<u32> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let mut deg: Vec<u32> = (0..n as V)
        .map(|v| {
            g.neighbors(v).iter().filter(|&&w| w != v).count() as u32
        })
        .collect();
    let maxd = deg.iter().copied().max().unwrap_or(0) as usize;
    // Bucket sort vertices by degree.
    let mut bucket_start = vec![0usize; maxd + 2];
    for &d in &deg {
        bucket_start[d as usize + 1] += 1;
    }
    for i in 1..bucket_start.len() {
        bucket_start[i] += bucket_start[i - 1];
    }
    let mut pos = vec![0usize; n];
    let mut order = vec![0 as V; n];
    {
        let mut cursor = bucket_start.clone();
        for v in 0..n {
            let d = deg[v] as usize;
            pos[v] = cursor[d];
            order[cursor[d]] = v as V;
            cursor[d] += 1;
        }
    }
    let mut core = vec![0u32; n];
    let mut bucket_cursor = bucket_start.clone();
    for i in 0..n {
        let v = order[i];
        core[v as usize] = deg[v as usize];
        for &w in g.neighbors(v) {
            let w = w as usize;
            if deg[w] > deg[v as usize] {
                // Move w one bucket down (swap with the first vertex
                // of its current bucket).
                let dw = deg[w] as usize;
                let pw = pos[w];
                let first = bucket_cursor[dw].max(i + 1);
                let u = order[first];
                order.swap(pw, first);
                pos[w] = first;
                pos[u as usize] = pw;
                bucket_cursor[dw] = first + 1;
                deg[w] -= 1;
            }
        }
        // Advance cursor past processed vertex.
        let dv = core[v as usize] as usize;
        bucket_cursor[dv] = bucket_cursor[dv].max(i + 1);
    }
    core
}

/// Parallel peeling with hash-bag frontiers: peel all vertices of
/// degree <= k simultaneously, round by round, incrementing k when the
/// k-frontier drains. Records one trace round per peel wave.
///
/// Allocate-per-call wrapper over [`par_kcore_ws`].
pub fn par_kcore(g: &Graph, rec: Recorder) -> Vec<u32> {
    let mut ws = KcoreWorkspace::new();
    par_kcore_ws(g, rec, &mut ws);
    std::mem::take(&mut ws.out)
}

/// Atomic `deg[i] -= 1` on the stamped array, returning the previous
/// logical value (a CAS loop on the logical value — equivalent to
/// `fetch_sub` on a plain atomic). Never called on a slot holding 0:
/// total decrements of a vertex are bounded by its seeded degree (one
/// per incident peeled neighbor), but guard anyway so a stray call
/// cannot underflow or spin.
#[inline]
fn deg_sub_one(deg: &StampedU32, i: usize) -> u32 {
    loop {
        let d = deg.get(i);
        if d == 0 || deg.compare_exchange(i, d, d - 1) {
            return d;
        }
    }
}

/// [`par_kcore`] out of a reusable workspace: coreness is left in
/// `ws.out` (also returned as a slice). The stamped degree/core
/// arrays clear in O(1); a warm workspace performs zero O(n)
/// allocation — the per-query O(n) work is one parallel degree-seeding
/// pass, matching the other `_ws` entry points.
pub fn par_kcore_ws<'a>(g: &Graph, mut rec: Recorder, ws: &'a mut KcoreWorkspace) -> &'a [u32] {
    let n = g.n();
    if n == 0 {
        ws.out.clear();
        return &ws.out;
    }
    // Rebind the stamped arrays (O(1) logical clear), then seed live
    // degrees in one parallel pass. `core` reads u32::MAX (unpeeled)
    // everywhere until a claim CAS installs a coreness.
    ws.deg.reset(0);
    ws.deg.ensure_len(n);
    ws.core.reset(u32::MAX);
    ws.core.ensure_len(n);
    ws.bag.reset(n);
    let deg = &ws.deg;
    let core = &ws.core;
    parallel_for(0, n, 256, |v| {
        let v32 = v as V;
        deg.store(v, g.neighbors(v32).iter().filter(|&&w| w != v32).count() as u32);
    });
    let mut remaining = n;
    let mut k = 0u32;
    while remaining > 0 {
        // Frontier: unpeeled vertices with degree <= k.
        pack_index_into(
            n,
            |v| core.get(v) == u32::MAX && deg.get(v) <= k,
            &mut ws.frontier,
        );
        // Claim them (avoids double peeling across waves).
        ws.frontier
            .retain(|&v| core.compare_exchange(v as usize, u32::MAX, k));
        if ws.frontier.is_empty() {
            k += 1;
            continue;
        }
        while !ws.frontier.is_empty() {
            remaining -= ws.frontier.len();
            {
                let frontier_ref = &ws.frontier;
                let bag_ref = &ws.bag;
                parallel_for(0, frontier_ref.len(), 64, move |i| {
                    let v = frontier_ref[i];
                    for &w in g.neighbors(v) {
                        if w == v || core.get(w as usize) != u32::MAX {
                            continue;
                        }
                        // Decrement; if w sinks to <= k, peel it now.
                        let old = deg_sub_one(deg, w as usize);
                        if old.saturating_sub(1) <= k
                            && core.compare_exchange(w as usize, u32::MAX, k)
                        {
                            bag_ref.insert(w);
                        }
                    }
                });
            }
            if let Some(trace) = rec.as_deref_mut() {
                trace.push_round(
                    ws.frontier
                        .iter()
                        .map(|&v| TaskCost {
                            vertices: 1,
                            edges: g.degree(v) as u64,
                        })
                        .collect(),
                );
            }
            ws.bag.extract_into(&mut ws.frontier);
        }
        k += 1;
    }
    ws.core.export_into(n, &mut ws.out);
    &ws.out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::prop::{forall, Rng};

    #[test]
    fn path_is_1_core_endpoints_too() {
        let g = gen::path(6).symmetrize();
        let c = seq_kcore(&g);
        assert_eq!(c, vec![1, 1, 1, 1, 1, 1]);
    }

    #[test]
    fn clique_is_k_minus_1_core() {
        let g = gen::complete(6).symmetrize();
        let c = seq_kcore(&g);
        assert!(c.iter().all(|&x| x == 5));
    }

    #[test]
    fn star_center_core_1() {
        let g = gen::star(10).symmetrize();
        let c = seq_kcore(&g);
        assert!(c.iter().all(|&x| x == 1));
    }

    #[test]
    fn clique_with_tail() {
        // K4 on {0,1,2,3} plus tail 3-4-5: tail coreness 1, clique 3.
        let mut edges = vec![(3u32, 4u32), (4, 5)];
        for u in 0..4u32 {
            for v in (u + 1)..4 {
                edges.push((u, v));
            }
        }
        let g = crate::graph::Graph::from_edges(6, &edges, true).symmetrize();
        let c = seq_kcore(&g);
        assert_eq!(c, vec![3, 3, 3, 3, 1, 1]);
    }

    #[test]
    fn par_matches_seq_on_shapes() {
        for g in [
            gen::bubbles(10, 6, 1),
            gen::social(10, 8, 2).symmetrize(),
            gen::road(10, 14, 3).symmetrize(),
            gen::grid(6, 9).symmetrize(),
        ] {
            assert_eq!(par_kcore(&g, None), seq_kcore(&g), "mismatch");
        }
    }

    #[test]
    fn warm_workspace_reuse_matches_seq_across_graphs() {
        // One workspace across shrinking and growing graphs: stale
        // degrees/coreness from a previous query must never leak —
        // the stamped arrays clear logically, the seeding pass only
        // writes live vertices.
        let mut ws = KcoreWorkspace::new();
        for g in [
            gen::grid(9, 11).symmetrize(),
            gen::bubbles(6, 5, 2),
            gen::grid(2, 3).symmetrize(),
            gen::social(9, 8, 4).symmetrize(),
        ] {
            assert_eq!(par_kcore_ws(&g, None, &mut ws), &seq_kcore(&g)[..]);
        }
        // Same graph twice in a row: warm run bit-identical to cold.
        let g = gen::road(9, 9, 7).symmetrize();
        let cold = par_kcore_ws(&g, None, &mut ws).to_vec();
        let warm = par_kcore_ws(&g, None, &mut ws).to_vec();
        assert_eq!(cold, warm);
        assert_eq!(warm, seq_kcore(&g));
    }

    /// Definition-level oracle: core[v] >= k iff v survives
    /// iterated removal of degree-<k vertices.
    fn brute_kcore(g: &crate::graph::Graph) -> Vec<u32> {
        let n = g.n();
        let mut core = vec![0u32; n];
        let maxd = (0..n as V).map(|v| g.degree(v)).max().unwrap_or(0) as u32;
        for k in 1..=maxd {
            let mut alive = vec![true; n];
            loop {
                let mut changed = false;
                for v in 0..n as V {
                    if !alive[v as usize] {
                        continue;
                    }
                    let d = g
                        .neighbors(v)
                        .iter()
                        .filter(|&&w| w != v && alive[w as usize])
                        .count() as u32;
                    if d < k {
                        alive[v as usize] = false;
                        changed = true;
                    }
                }
                if !changed {
                    break;
                }
            }
            for v in 0..n {
                if alive[v] {
                    core[v] = k;
                }
            }
        }
        core
    }

    #[test]
    fn prop_par_and_seq_match_definition() {
        forall(0xC04E, |rng: &mut Rng| {
            let n = rng.range(1, 80);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            let g = crate::graph::Graph::from_edges(n, &edges, true).symmetrize();
            let want = brute_kcore(&g);
            assert_eq!(seq_kcore(&g), want, "seq vs definition");
            assert_eq!(par_kcore(&g, None), want, "par vs definition");
        });
    }

    #[test]
    fn coreness_is_monotone_under_edge_addition() {
        forall(0xC04F, |rng: &mut Rng| {
            let n = rng.range(3, 80);
            let m = rng.range(1, 2 * n);
            let mut edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            let g1 = crate::graph::Graph::from_edges(n, &edges, true).symmetrize();
            let c1 = seq_kcore(&g1);
            edges.push((rng.below(n as u64) as V, rng.below(n as u64) as V));
            let g2 = crate::graph::Graph::from_edges(n, &edges, true).symmetrize();
            let c2 = seq_kcore(&g2);
            for v in 0..n {
                assert!(c2[v] >= c1[v], "coreness dropped after adding an edge");
            }
        });
    }
}
