//! The graph algorithms: PASGAL's contributions plus every published
//! baseline the paper compares against, on the same substrate.
//!
//! | Problem | Sequential baseline | Parallel baselines | PASGAL |
//! |---------|--------------------|--------------------|--------|
//! | BFS  | queue BFS | GBBS-like frontier edge-map; GAPBS-like direction-optimizing | VGC BFS (τ local search, 2^i multi-frontiers, hash bags) |
//! | SCC  | Tarjan | BGSS-style multi-pivot (BFS reachability); Multistep (trim + FW-BW + coloring) | VGC SCC (local-search reachability, hash bags) |
//! | BCC  | Hopcroft–Tarjan | Tarjan–Vishkin (explicit aux graph, O(m) space); GBBS-like (BFS tree) | FAST-BCC (CC tree, implicit skeleton, O(n) space) |
//! | SSSP | Dijkstra | Δ-stepping | ρ-stepping with VGC |
//! | CC   | — | hook/compress union-find (+ spanning forest) | (substrate) |
//!
//! Every parallel implementation optionally records an execution
//! trace ([`crate::sim::AlgoTrace`]) for the virtual-multicore
//! scalability studies (Fig. 1 / Fig. 2).
//!
//! On top of the single-source algorithms, [`multi`] hosts the batched
//! multi-source traversal engine: up to 64 BFS/SSSP/reachability
//! sources answered by one frontier walk (lane-striped distances, one
//! 64-bit source mask per vertex), which the coordinator uses to fuse
//! same-graph, same-algorithm requests.
//!
//! [`api`] is the open Query API over all of the above: one static
//! [`api::AlgoSpec`] registry entry per algorithm (label, aliases,
//! parameter parsing, solo/batch/traced engines), so every front end
//! — coordinator, sharded server, CLI, benches — dispatches through
//! one table instead of per-algorithm match arms.
//!
//! [`cancel`] is the cooperative-cancellation substrate: engines with
//! `_ws_cancel` entry points poll a shared [`cancel::CancelToken`]
//! once per frontier round / bucket epoch, so expired or condemned
//! queries release their worker within one round.

pub mod api;
pub mod bcc;
pub mod bfs;
pub mod cancel;
pub mod cc;
pub mod kcore;
pub mod multi;
pub mod scc;
pub mod sssp;
pub mod workspace;

pub use api::{AlgoSpec, Params, ParseArgs, Query, QueryOutput};
pub use cancel::{Cancel, CancelToken};
pub use workspace::{
    BfsWorkspace, CcWorkspace, KcoreWorkspace, MultiBfsWorkspace, MultiSsspWorkspace,
    QueryWorkspace, SccWorkspace, SsspWorkspace, WorkspacePool,
};

/// Distance sentinel for unreached vertices in hop-distance outputs.
pub const UNREACHED: u32 = u32::MAX;
