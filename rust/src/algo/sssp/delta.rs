//! Δ-stepping SSSP (Meyer & Sanders) — the parallel baseline.
//!
//! Distances are partitioned into width-Δ buckets processed in order;
//! within a bucket, relaxations iterate to a fixpoint (the classic
//! simplification that folds the light/heavy split into repeated
//! rounds). Each inner iteration is a synchronized round — on
//! large-diameter weighted graphs the bucket chain is long and the
//! round count grows accordingly.

use crate::graph::Graph;
use crate::hashbag::HashBag;
use crate::parallel::atomic::{load_f32, write_min_f32};
use crate::parallel::parallel_for;
use crate::sim::trace::{Recorder, TaskCost};
use crate::{INF, V};
use std::sync::atomic::AtomicU32;

/// Shortest distances from `src`. `delta` defaults to the mean edge
/// weight (a standard heuristic).
pub fn delta_stepping(g: &Graph, src: V, delta: Option<f32>, mut rec: Recorder) -> Vec<f32> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let delta = delta.unwrap_or_else(|| {
        match &g.weights {
            Some(ws) if !ws.is_empty() => {
                (ws.iter().sum::<f32>() / ws.len() as f32).max(1e-6)
            }
            _ => 1.0,
        }
    });
    let mut dist_bits = vec![INF.to_bits(); n];
    let dist: &[AtomicU32] = crate::parallel::atomic::as_atomic_u32(unsafe {
        // Reinterpret u32 bits storage (same layout as the helper used
        // elsewhere; write_min_f32 operates on bits).
        std::mem::transmute::<&mut [u32], &mut [u32]>(&mut dist_bits)
    });
    write_min_f32(&dist[src as usize], 0.0);

    let bucket_of = |d: f32| -> usize { (d / delta) as usize };
    let mut buckets: Vec<HashBag> = Vec::new();
    let ensure = |buckets: &mut Vec<HashBag>, i: usize, n: usize| {
        while buckets.len() <= i {
            buckets.push(HashBag::new(n));
        }
    };
    ensure(&mut buckets, 0, n);
    buckets[0].insert(src);

    let mut i = 0usize;
    while i < buckets.len() {
        loop {
            let frontier: Vec<V> = buckets[i].extract_and_clear();
            if frontier.is_empty() {
                break;
            }
            // Split: current-bucket vertices vs deferred.
            let mut work: Vec<V> = Vec::with_capacity(frontier.len());
            for &v in &frontier {
                let d = load_f32(&dist[v as usize]);
                let b = bucket_of(d);
                if b < i {
                    continue; // settled in an earlier bucket: stale
                } else if b == i {
                    work.push(v);
                } else {
                    ensure(&mut buckets, b, n);
                    buckets[b].insert(v);
                }
            }
            if work.is_empty() {
                break;
            }
            // One synchronized relaxation round over `work`.
            let max_new_bucket =
                std::sync::atomic::AtomicUsize::new(i);
            {
                // Collect insertions first (buckets can't grow during
                // the parallel phase), staged through one overflow bag.
                let staged = HashBag::new(n);
                let work_ref = &work;
                let staged_ref = &staged;
                let max_ref = &max_new_bucket;
                parallel_for(0, work_ref.len(), 32, move |k| {
                    let v = work_ref[k];
                    let dv = load_f32(&dist[v as usize]);
                    let ws = g.weights.as_ref().map(|_| g.weights_of(v));
                    for (j, &u) in g.neighbors(v).iter().enumerate() {
                        let w = ws.map_or(1.0, |ws| ws[j]);
                        let nd = dv + w;
                        if write_min_f32(&dist[u as usize], nd) {
                            let b = bucket_of(nd);
                            max_ref.fetch_max(b, std::sync::atomic::Ordering::Relaxed);
                            staged_ref.insert(u);
                        }
                    }
                });
                if let Some(trace) = rec.as_deref_mut() {
                    trace.push_round(
                        work.iter()
                            .map(|&v| TaskCost {
                                vertices: 1,
                                edges: g.degree(v) as u64,
                            })
                            .collect(),
                    );
                }
                // Distribute staged updates into their buckets.
                let hi = max_new_bucket.load(std::sync::atomic::Ordering::Relaxed);
                ensure(&mut buckets, hi, n);
                for u in staged.extract_and_clear() {
                    let b = bucket_of(load_f32(&dist[u as usize]));
                    buckets[b.max(i)].insert(u);
                }
            }
        }
        i += 1;
    }
    dist_bits.into_iter().map(f32::from_bits).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::sssp::dijkstra;
    use crate::graph::gen;

    #[test]
    fn matches_dijkstra_on_road() {
        let g = gen::road(9, 13, 7);
        let want = dijkstra(&g, 0);
        let got = delta_stepping(&g, 0, None, None);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.max(1.0) || (*a >= INF && *b >= INF));
        }
    }

    #[test]
    fn tiny_delta_degenerates_to_dijkstra_like() {
        let g = gen::road(6, 8, 1);
        let want = dijkstra(&g, 5);
        let got = delta_stepping(&g, 5, Some(0.5), None);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.max(1.0) || (*a >= INF && *b >= INF));
        }
    }

    #[test]
    fn huge_delta_degenerates_to_bellman_ford() {
        let g = gen::road(6, 8, 2);
        let want = dijkstra(&g, 0);
        let got = delta_stepping(&g, 0, Some(1e9), None);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.max(1.0) || (*a >= INF && *b >= INF));
        }
    }
}
