//! Δ-stepping SSSP (Meyer & Sanders) — the parallel baseline.
//!
//! Distances are partitioned into width-Δ buckets processed in order;
//! within a bucket, relaxations iterate to a fixpoint (the classic
//! simplification that folds the light/heavy split into repeated
//! rounds). Each inner iteration is a synchronized round — on
//! large-diameter weighted graphs the bucket chain is long and the
//! round count grows accordingly.
//!
//! Per-query state (distances, the bucket bags, the staging bag) lives
//! in a reusable [`SsspWorkspace`]: [`delta_stepping_ws`] resets it in
//! O(1) via epoch stamps and bag rebinding; [`delta_stepping`] is the
//! allocate-per-call wrapper. The default Δ (mean edge weight) comes
//! from the graph's memoized [`crate::graph::WeightStats`].

use crate::algo::cancel::{cancelled, Cancel};
use crate::algo::workspace::SsspWorkspace;
use crate::graph::Graph;
use crate::hashbag::HashBag;
use crate::parallel::parallel_for;
use crate::sim::trace::{Recorder, TaskCost};
use crate::{INF, V};

/// Shortest distances from `src`. `delta` defaults to the mean edge
/// weight (a standard heuristic). Allocate-per-call wrapper around
/// [`delta_stepping_ws`].
pub fn delta_stepping(g: &Graph, src: V, delta: Option<f32>, rec: Recorder) -> Vec<f32> {
    let mut ws = SsspWorkspace::new();
    delta_stepping_ws(g, src, delta, rec, &mut ws);
    ws.dist.export_f32(g.n())
}

/// Shortest distances from `src`, computed in a reusable workspace and
/// left in `ws.dist` as f32 bits.
pub fn delta_stepping_ws(
    g: &Graph,
    src: V,
    delta: Option<f32>,
    rec: Recorder,
    ws: &mut SsspWorkspace,
) {
    delta_stepping_ws_cancel(g, src, delta, rec, ws, None);
}

/// [`delta_stepping_ws`] with a cooperative-cancellation token, polled
/// once per bucket relaxation round (never per edge): an expired or
/// condemned query abandons the bucket chain within one round, leaving
/// partial distances the serving layer must not summarize.
pub fn delta_stepping_ws_cancel(
    g: &Graph,
    src: V,
    delta: Option<f32>,
    mut rec: Recorder,
    ws: &mut SsspWorkspace,
    cancel: Cancel<'_>,
) {
    let n = g.n();
    ws.dist.ensure_len(n);
    ws.dist.reset(INF.to_bits());
    if n == 0 {
        return;
    }
    ws.bag.reset(n);
    for bucket in ws.buckets.iter_mut() {
        bucket.reset(n);
    }
    let delta = delta.unwrap_or_else(|| g.weight_stats().mean.max(1e-6));
    let dist = &ws.dist;
    let staged = &ws.bag;
    dist.store_f32(src as usize, 0.0);

    let bucket_of = |d: f32| -> usize { (d / delta) as usize };
    let mut buckets = std::mem::take(&mut ws.buckets);
    let ensure = |buckets: &mut Vec<HashBag>, i: usize, n: usize| {
        while buckets.len() <= i {
            buckets.push(HashBag::new(n));
        }
    };
    ensure(&mut buckets, 0, n);
    buckets[0].insert(src);

    let mut frontier = std::mem::take(&mut ws.pending);
    let mut work = std::mem::take(&mut ws.work);
    let mut staged_buf = std::mem::take(&mut ws.staged_buf);

    let mut i = 0usize;
    'buckets: while i < buckets.len() {
        loop {
            // Cancellation point, once per inner relaxation round: a
            // labeled break (never a return) so the workspace restores
            // below still run and the pooled buffers stay warm.
            if cancelled(cancel) {
                break 'buckets;
            }
            buckets[i].extract_into(&mut frontier);
            if frontier.is_empty() {
                break;
            }
            // Split: current-bucket vertices vs deferred.
            work.clear();
            for &v in &frontier {
                let d = dist.get_f32(v as usize);
                let b = bucket_of(d);
                if b < i {
                    continue; // settled in an earlier bucket: stale
                } else if b == i {
                    work.push(v);
                } else {
                    ensure(&mut buckets, b, n);
                    buckets[b].insert(v);
                }
            }
            if work.is_empty() {
                break;
            }
            // One synchronized relaxation round over `work`.
            let max_new_bucket = std::sync::atomic::AtomicUsize::new(i);
            {
                // Collect insertions first (buckets can't grow during
                // the parallel phase), staged through one reused
                // overflow bag.
                let work_ref = &work;
                let max_ref = &max_new_bucket;
                parallel_for(0, work_ref.len(), 32, move |k| {
                    let v = work_ref[k];
                    let dv = dist.get_f32(v as usize);
                    let ws_edge = g.weights().map(|_| g.weights_of(v));
                    for (j, &u) in g.neighbors(v).iter().enumerate() {
                        let w = ws_edge.map_or(1.0, |ws_edge| ws_edge[j]);
                        let nd = dv + w;
                        if dist.write_min_f32(u as usize, nd) {
                            let b = bucket_of(nd);
                            max_ref.fetch_max(b, std::sync::atomic::Ordering::Relaxed);
                            staged.insert(u);
                        }
                    }
                });
                if let Some(trace) = rec.as_deref_mut() {
                    trace.push_round(
                        work.iter()
                            .map(|&v| TaskCost {
                                vertices: 1,
                                edges: g.degree(v) as u64,
                            })
                            .collect(),
                    );
                }
                // Distribute staged updates into their buckets.
                let hi = max_new_bucket.load(std::sync::atomic::Ordering::Relaxed);
                ensure(&mut buckets, hi, n);
                staged.extract_into(&mut staged_buf);
                for &u in &staged_buf {
                    let b = bucket_of(dist.get_f32(u as usize));
                    buckets[b.max(i)].insert(u);
                }
            }
        }
        i += 1;
    }

    ws.buckets = buckets;
    ws.pending = frontier;
    ws.work = work;
    ws.staged_buf = staged_buf;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::sssp::dijkstra;
    use crate::graph::gen;

    #[test]
    fn matches_dijkstra_on_road() {
        let g = gen::road(9, 13, 7);
        let want = dijkstra(&g, 0);
        let got = delta_stepping(&g, 0, None, None);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.max(1.0) || (*a >= INF && *b >= INF));
        }
    }

    #[test]
    fn tiny_delta_degenerates_to_dijkstra_like() {
        let g = gen::road(6, 8, 1);
        let want = dijkstra(&g, 5);
        let got = delta_stepping(&g, 5, Some(0.5), None);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.max(1.0) || (*a >= INF && *b >= INF));
        }
    }

    #[test]
    fn huge_delta_degenerates_to_bellman_ford() {
        let g = gen::road(6, 8, 2);
        let want = dijkstra(&g, 0);
        let got = delta_stepping(&g, 0, Some(1e9), None);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.max(1.0) || (*a >= INF && *b >= INF));
        }
    }

    #[test]
    fn warm_workspace_reuse_matches_fresh_calls() {
        let g = gen::road(8, 10, 4);
        let mut ws = SsspWorkspace::new();
        for src in [0u32, 11, 40, 0] {
            delta_stepping_ws(&g, src, None, None, &mut ws);
            let got = ws.dist.export_f32(g.n());
            let want = dijkstra(&g, src);
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() <= 1e-3 * b.max(1.0) || (*a >= INF && *b >= INF),
                    "src={src}"
                );
            }
        }
    }
}
