//! Single-source shortest paths (paper §2.2: stepping framework [11]).
//!
//! * [`dijkstra::dijkstra`] — sequential binary-heap Dijkstra (the
//!   baseline).
//! * [`delta::delta_stepping`] — Δ-stepping (Meyer & Sanders), the
//!   classic parallel baseline: distance-bucketed rounds.
//! * [`rho::rho_stepping`] — ρ-stepping from the stepping-algorithm
//!   framework [11] with VGC local searches + hash bags, PASGAL's
//!   SSSP.
//!
//! Distances are `f32` with [`crate::INF`] for unreachable; weights
//! must be non-negative (checked in debug).
//!
//! For serving workloads issuing many sources on one graph, the
//! batched engine [`crate::algo::multi::multi_rho_ws`] answers up to
//! 64 sources per walk with per-lane results bit-identical to
//! [`rho_stepping_ws`] (pinned by the cross-validation tests below).

pub mod delta;
pub mod dijkstra;
pub mod rho;

pub use delta::{delta_stepping, delta_stepping_ws, delta_stepping_ws_cancel};
pub use dijkstra::dijkstra;
pub use rho::{rho_stepping, rho_stepping_ws, rho_stepping_ws_cancel};

#[cfg(test)]
mod cross_tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::Graph;
    use crate::prop::{forall, Rng};
    use crate::{INF, V, W};

    fn assert_dists_eq(got: &[f32], want: &[f32], tag: &str) {
        assert_eq!(got.len(), want.len());
        for (v, (g, w)) in got.iter().zip(want).enumerate() {
            let ok = if *w >= INF {
                *g >= INF
            } else {
                (g - w).abs() <= 1e-3 * w.max(1.0)
            };
            assert!(ok, "{tag}: vertex {v}: got {g}, want {w}");
        }
    }

    fn check_all(g: &Graph, src: V) {
        let want = dijkstra(g, src);
        let d = delta_stepping(g, src, None, None);
        assert_dists_eq(&d, &want, "delta");
        let r = rho_stepping(g, src, 64, None);
        assert_dists_eq(&r, &want, "rho");
        let r1 = rho_stepping(g, src, 1, None);
        assert_dists_eq(&r1, &want, "rho tau=1");
        // The batched engine at width 1 converges to the same least
        // fixpoint as solo rho-stepping: bit-identical, not just close.
        let mr = crate::algo::multi::multi_rho(g, &[src], 64, None);
        assert_eq!(mr[0], r, "multi_rho width-1 must match rho bit-exactly");
    }

    #[test]
    fn all_agree_on_weighted_shapes() {
        check_all(&gen::road(10, 14, 3), 0);
        check_all(&gen::road(10, 14, 3), 77);
        check_all(&gen::knn_points(400, 4, 5), 7);
        let g = gen::with_random_weights(&gen::grid(9, 11), 13);
        check_all(&g, 0);
        let g = gen::with_random_weights(&gen::social(9, 8, 17), 19);
        check_all(&g, 3);
    }

    #[test]
    fn prop_all_agree_on_random_weighted_graphs() {
        forall(0x555, |rng: &mut Rng| {
            let n = rng.range(1, 200);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(V, V, W)> = (0..m)
                .map(|_| {
                    (
                        rng.below(n as u64) as V,
                        rng.below(n as u64) as V,
                        1.0 + rng.below(50) as W,
                    )
                })
                .collect();
            let g = Graph::from_weighted_edges(n, &edges, true);
            check_all(&g, rng.below(n as u64) as V);
        });
    }

    #[test]
    fn unit_weights_reduce_to_bfs() {
        let g = gen::grid(8, 9).with_unit_weights();
        let bfs = crate::algo::bfs::seq_bfs(&g, 0);
        let sssp = rho_stepping(&g, 0, 32, None);
        for v in 0..g.n() {
            if bfs[v] == u32::MAX {
                assert!(sssp[v] >= INF);
            } else {
                assert_eq!(sssp[v], bfs[v] as f32);
            }
        }
    }
}
