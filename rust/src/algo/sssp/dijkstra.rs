//! Sequential binary-heap Dijkstra — the SSSP correctness oracle and
//! sequential baseline.

use crate::graph::Graph;
use crate::{INF, V};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Total-ordered f32 wrapper for the heap (distances are never NaN).
#[derive(PartialEq, PartialOrd)]
struct D(f32);
impl Eq for D {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for D {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

/// Shortest distances from `src` over non-negative weights.
pub fn dijkstra(g: &Graph, src: V) -> Vec<f32> {
    let n = g.n();
    let mut dist = vec![INF; n];
    if n == 0 {
        return dist;
    }
    let mut heap: BinaryHeap<Reverse<(D, V)>> = BinaryHeap::new();
    dist[src as usize] = 0.0;
    heap.push(Reverse((D(0.0), src)));
    while let Some(Reverse((D(d), v))) = heap.pop() {
        if d > dist[v as usize] {
            continue; // stale entry
        }
        let ws = if g.weights().is_some() {
            Some(g.weights_of(v))
        } else {
            None
        };
        for (i, &u) in g.neighbors(v).iter().enumerate() {
            let w = ws.map_or(1.0, |ws| ws[i]);
            debug_assert!(w >= 0.0, "negative weight");
            let nd = d + w;
            if nd < dist[u as usize] {
                dist[u as usize] = nd;
                heap.push(Reverse((D(nd), u)));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::Graph;

    #[test]
    fn weighted_diamond_prefers_cheap_path() {
        // 0->1 (1), 0->2 (10), 1->2 (1): dist(2) = 2 not 10.
        let g = Graph::from_weighted_edges(3, &[(0, 1, 1.0), (0, 2, 10.0), (1, 2, 1.0)], false);
        let d = dijkstra(&g, 0);
        assert_eq!(d, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn unreachable_is_inf() {
        let g = Graph::from_weighted_edges(3, &[(0, 1, 1.0)], false);
        let d = dijkstra(&g, 0);
        assert!(d[2] >= INF);
    }

    #[test]
    fn unweighted_graph_counts_hops() {
        let g = gen::path(6);
        let d = dijkstra(&g, 0);
        assert_eq!(d, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn road_distances_respect_triangle_inequality() {
        let g = gen::road(8, 12, 3);
        let d = dijkstra(&g, 0);
        for u in 0..g.n() as V {
            if d[u as usize] >= INF {
                continue;
            }
            for (i, &v) in g.neighbors(u).iter().enumerate() {
                let w = g.weights_of(u)[i];
                assert!(
                    d[v as usize] <= d[u as usize] + w + 1e-3,
                    "triangle violated at {u}->{v}"
                );
            }
        }
    }
}
