//! ρ-stepping SSSP with VGC (Dong, Gu, Sun, Zhang — SPAA'21 [11]):
//! PASGAL's shortest-path algorithm (§2.2).
//!
//! One pending bag holds every vertex whose distance improved. Each
//! round samples the pending distances to pick a threshold θ that
//! admits roughly ρ vertices, processes the admitted set with
//! τ-budget VGC local searches (relaxations need no strict priority
//! order — write_min fixes any overshoot), and defers the rest. Far
//! fewer synchronized rounds than Δ-stepping's bucket chain.
//!
//! Per-query state (distances, pending flags, settled marks, the
//! pending bag) lives in a reusable [`SsspWorkspace`]:
//! [`rho_stepping_ws`] resets it in O(1) via epoch stamps;
//! [`rho_stepping`] is the allocate-per-call wrapper. The mean edge
//! weight that sizes the admission window comes from the graph's
//! memoized [`crate::graph::WeightStats`] (one parallel reduction per
//! graph) instead of a serial O(m) scan per query.
//!
//! The batched variant [`crate::algo::multi::multi_rho_ws`] shares one
//! θ-threshold/bucket structure across up to 64 sources (lane-striped
//! distances, one walk per batch) and converges to the same least
//! fixpoint: per-lane results are bit-identical to this engine's.

use crate::algo::cancel::{cancelled, Cancel};
use crate::algo::workspace::SsspWorkspace;
use crate::graph::Graph;
use crate::sim::trace::{Recorder, RoundSlots};
use crate::{INF, V};

/// Vertices admitted per round (the ρ parameter of [11]).
const RHO: usize = 1 << 10;

/// Seeds per local-search task.
const SEEDS: usize = 4;

/// Shortest distances from `src` with VGC budget `tau`
/// (allocate-per-call wrapper around [`rho_stepping_ws`]).
pub fn rho_stepping(g: &Graph, src: V, tau: usize, rec: Recorder) -> Vec<f32> {
    let mut ws = SsspWorkspace::new();
    rho_stepping_ws(g, src, tau, rec, &mut ws);
    ws.dist.export_f32(g.n())
}

/// Shortest distances from `src` with VGC budget `tau`, computed in a
/// reusable workspace. Results are left in `ws.dist` as f32 bits (read
/// with [`crate::parallel::StampedU32::get_f32`] or export them); a
/// warm workspace performs no O(n)/O(m) allocation.
pub fn rho_stepping_ws(g: &Graph, src: V, tau: usize, rec: Recorder, ws: &mut SsspWorkspace) {
    rho_stepping_ws_cancel(g, src, tau, rec, ws, None);
}

/// [`rho_stepping_ws`] with a cooperative-cancellation token, polled
/// once per θ-threshold round (never per edge): an expired or
/// condemned query abandons the walk within one round, leaving partial
/// distances the serving layer must not summarize.
pub fn rho_stepping_ws_cancel(
    g: &Graph,
    src: V,
    tau: usize,
    mut rec: Recorder,
    ws: &mut SsspWorkspace,
    cancel: Cancel<'_>,
) {
    let n = g.n();
    ws.dist.ensure_len(n);
    ws.dist.reset(INF.to_bits());
    ws.flags.ensure_len(n);
    ws.flags.reset(0);
    ws.settled.ensure_len(n);
    ws.settled.reset(INF.to_bits());
    if n == 0 {
        return;
    }
    ws.bag.reset(n);
    let tau = tau.max(1);
    let dist = &ws.dist;
    let flag = &ws.flags;
    // settled[v] = distance (as bits) v was last *expanded* with; a
    // vertex re-expands only after a strict improvement. Without this
    // qualify step, in-round corrections re-relax whole neighborhoods
    // quadratically (measured 100x work amplification on road meshes
    // — see EXPERIMENTS.md §Perf).
    let settled = &ws.settled;
    let bag = &ws.bag;
    dist.store_f32(src as usize, 0.0);
    flag.store(src as usize, 1);

    // Mean edge weight: the admission window is measured in units of
    // it. Memoized on the graph — computed once by a parallel
    // reduction, not per query (the old serial O(m) scan dominated
    // repeated small traversals).
    let mean_w = g.weight_stats().mean.max(1e-6);
    // Distance width of one round's admitted slice. Admitting an
    // unbounded slice makes the relaxation Bellman-Ford-like: distances
    // get corrected O(width/min_w) times each (measured 100x work
    // amplification with theta = INF — EXPERIMENTS.md §Perf). 16 mean
    // hops per round keeps the re-relaxation factor ~2.5x while still
    // collapsing Δ-stepping's one-hop bucket chain ~25x (width sweep
    // in EXPERIMENTS.md §Perf).
    let width = 16.0 * mean_w;

    let mut pending = std::mem::take(&mut ws.pending);
    pending.clear();
    pending.push(src);
    let mut work = std::mem::take(&mut ws.work);
    let mut sample = std::mem::take(&mut ws.sample);

    while !pending.is_empty() {
        // Cancellation point: break (never return) so the workspace
        // restores below still run and the pooled buffers stay warm.
        if cancelled(cancel) {
            break;
        }
        // Threshold: the smaller of (a) the ~RHO-th smallest pending
        // distance and (b) min pending distance + the width cap.
        let stride = (pending.len() / 1024).max(1);
        sample.clear();
        sample.extend(
            pending
                .iter()
                .step_by(stride)
                .map(|&v| dist.get_f32(v as usize)),
        );
        sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Count bound only binds above RHO pending; the width bound
        // always applies (and always leaves room to chain forward).
        let by_count = if pending.len() <= RHO {
            INF
        } else {
            let idx = (RHO * sample.len() / pending.len()).min(sample.len() - 1);
            sample[idx]
        };
        let theta = by_count.min(sample[0] + width);

        // Partition: admitted now, deferred back to the bag.
        work.clear();
        for &v in &pending {
            if dist.get_f32(v as usize) <= theta {
                work.push(v);
            } else {
                bag.insert(v); // still pending (flag stays 1)
            }
        }
        if work.is_empty() {
            // θ below every pending distance can't happen (θ is a
            // pending distance or INF), but guard against fp quirks.
            work.extend_from_slice(&pending);
        }

        // VGC local searches over the admitted set.
        let ntasks = work.len().div_ceil(SEEDS);
        let slots = RoundSlots::new(if rec.is_some() { ntasks } else { 0 });
        let record = rec.is_some();
        {
            let work_ref = &work;
            crate::parallel::ops::parallel_for_chunks(0, work_ref.len(), SEEDS, |ti, range| {
                // FIFO local search (discovery order): keeps the walk
                // close to distance order within the admitted slice,
                // which bounds overshoot corrections (a LIFO walk
                // churns on path-like graphs).
                let mut queue: Vec<u32> = Vec::with_capacity(64);
                queue.extend(range.map(|i| work_ref[i]));
                let mut head = 0usize;
                let mut stats = crate::parallel::vgc::SearchStats::default();
                while head < queue.len() && (stats.vertices as usize) < tau {
                    let v = queue[head];
                    head += 1;
                    stats.vertices += 1;
                    flag.store(v as usize, 0);
                    let dv = dist.get_f32(v as usize);
                    // Qualify: expand only on strict improvement since
                    // the last expansion (one winner per value).
                    let set = settled.get(v as usize);
                    if dv.to_bits() >= set
                        || !settled.compare_exchange(v as usize, set, dv.to_bits())
                    {
                        continue;
                    }
                    let ws_edge = g.weights().map(|_| g.weights_of(v));
                    for (j, &u) in g.neighbors(v).iter().enumerate() {
                        stats.edges += 1;
                        let w = ws_edge.map_or(1.0, |ws_edge| ws_edge[j]);
                        let nd = dv + w;
                        if dist.write_min_f32(u as usize, nd)
                            && flag.swap(u as usize, 1) == 0
                        {
                            if nd <= theta {
                                // Near: keep walking inside this task.
                                queue.push(u);
                            } else {
                                bag.insert(u);
                            }
                        }
                    }
                }
                // Budget exhausted: leftovers stay pending.
                for &u in &queue[head..] {
                    bag.insert(u);
                }
                if record {
                    slots.set(ti, stats.into());
                }
            });
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(slots.into_round());
        }
        bag.extract_into(&mut pending);
        // Dedupe: flag==0 entries were already processed this round.
        pending.retain(|&v| flag.get(v as usize) == 1);
    }

    ws.pending = pending;
    ws.work = work;
    ws.sample = sample;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::sssp::dijkstra;
    use crate::graph::gen;

    fn close(got: &[f32], want: &[f32]) {
        for (v, (a, b)) in got.iter().zip(want).enumerate() {
            let ok = if *b >= INF {
                *a >= INF
            } else {
                (a - b).abs() <= 1e-3 * b.max(1.0)
            };
            assert!(ok, "vertex {v}: got {a} want {b}");
        }
    }

    #[test]
    fn matches_dijkstra_on_knn() {
        let g = gen::knn_points(300, 5, 9);
        close(&rho_stepping(&g, 0, 64, None), &dijkstra(&g, 0));
    }

    #[test]
    fn various_tau_all_correct() {
        let g = gen::road(7, 11, 5);
        let want = dijkstra(&g, 3);
        for tau in [1usize, 8, 512, 1 << 20] {
            close(&rho_stepping(&g, 3, tau, None), &want);
        }
    }

    #[test]
    fn fewer_rounds_than_delta_on_long_road() {
        let g = gen::road(3, 700, 1);
        let mut t_rho = crate::sim::AlgoTrace::new();
        let _ = rho_stepping(&g, 0, 512, Some(&mut t_rho));
        let mut t_delta = crate::sim::AlgoTrace::new();
        let _ = super::super::delta_stepping(&g, 0, None, Some(&mut t_delta));
        assert!(
            t_rho.num_rounds() * 4 < t_delta.num_rounds(),
            "rho rounds {} vs delta rounds {}",
            t_rho.num_rounds(),
            t_delta.num_rounds()
        );
    }

    #[test]
    fn warm_workspace_reuse_matches_fresh_calls() {
        let g = gen::road(9, 12, 3);
        let mut ws = SsspWorkspace::new();
        for src in [0u32, 17, 50, 0] {
            rho_stepping_ws(&g, src, 64, None, &mut ws);
            close(&ws.dist.export_f32(g.n()), &dijkstra(&g, src));
        }
    }
}
