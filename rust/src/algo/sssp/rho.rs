//! ρ-stepping SSSP with VGC (Dong, Gu, Sun, Zhang — SPAA'21 [11]):
//! PASGAL's shortest-path algorithm (§2.2).
//!
//! One pending bag holds every vertex whose distance improved. Each
//! round samples the pending distances to pick a threshold θ that
//! admits roughly ρ vertices, processes the admitted set with
//! τ-budget VGC local searches (relaxations need no strict priority
//! order — write_min fixes any overshoot), and defers the rest. Far
//! fewer synchronized rounds than Δ-stepping's bucket chain.

use crate::graph::Graph;
use crate::hashbag::HashBag;
use crate::parallel::atomic::{load_f32, write_min_f32};
use crate::sim::trace::{Recorder, RoundSlots};
use crate::{INF, V};
use std::sync::atomic::{AtomicU32, Ordering};

/// Vertices admitted per round (the ρ parameter of [11]).
const RHO: usize = 1 << 10;

/// Seeds per local-search task.
const SEEDS: usize = 4;

/// Shortest distances from `src` with VGC budget `tau`.
pub fn rho_stepping(g: &Graph, src: V, tau: usize, mut rec: Recorder) -> Vec<f32> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let tau = tau.max(1);
    let mut dist_bits = vec![INF.to_bits(); n];
    let dist: &[AtomicU32] = crate::parallel::atomic::as_atomic_u32(&mut dist_bits);
    write_min_f32(&dist[src as usize], 0.0);
    let pending_flag: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    pending_flag[src as usize].store(1, Ordering::Relaxed);
    // settled[v] = distance (as bits) v was last *expanded* with; a
    // vertex re-expands only after a strict improvement. Without this
    // qualify step, in-round corrections re-relax whole neighborhoods
    // quadratically (measured 100x work amplification on road meshes
    // — see EXPERIMENTS.md §Perf).
    let settled: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(INF.to_bits())).collect();

    let mut pending: Vec<V> = vec![src];
    let bag = HashBag::new(n);
    // Mean edge weight: the admission window is measured in units of
    // it (see below).
    let mean_w = match &g.weights {
        Some(ws) if !ws.is_empty() => {
            (ws.iter().sum::<f32>() / ws.len() as f32).max(1e-6)
        }
        _ => 1.0,
    };
    // Distance width of one round's admitted slice. Admitting an
    // unbounded slice makes the relaxation Bellman-Ford-like: distances
    // get corrected O(width/min_w) times each (measured 100x work
    // amplification with theta = INF — EXPERIMENTS.md §Perf). 16 mean
    // hops per round keeps the re-relaxation factor ~2.5x while still
    // collapsing Δ-stepping's one-hop bucket chain ~25x (width sweep
    // in EXPERIMENTS.md §Perf).
    let width = 16.0 * mean_w;

    while !pending.is_empty() {
        // Threshold: the smaller of (a) the ~RHO-th smallest pending
        // distance and (b) min pending distance + the width cap.
        let stride = (pending.len() / 1024).max(1);
        let mut sample: Vec<f32> = pending
            .iter()
            .step_by(stride)
            .map(|&v| load_f32(&dist[v as usize]))
            .collect();
        sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Count bound only binds above RHO pending; the width bound
        // always applies (and always leaves room to chain forward).
        let by_count = if pending.len() <= RHO {
            INF
        } else {
            let idx = (RHO * sample.len() / pending.len()).min(sample.len() - 1);
            sample[idx]
        };
        let theta = by_count.min(sample[0] + width);

        // Partition: admitted now, deferred back to the bag.
        let mut work: Vec<V> = Vec::new();
        for &v in &pending {
            if load_f32(&dist[v as usize]) <= theta {
                work.push(v);
            } else {
                bag.insert(v); // still pending (flag stays 1)
            }
        }
        if work.is_empty() {
            // θ below every pending distance can't happen (θ is a
            // pending distance or INF), but guard against fp quirks.
            work = pending.clone();
        }

        // VGC local searches over the admitted set.
        let ntasks = work.len().div_ceil(SEEDS);
        let slots = RoundSlots::new(if rec.is_some() { ntasks } else { 0 });
        let record = rec.is_some();
        {
            let work_ref = &work;
            let bag_ref = &bag;
            let flag_ref = &pending_flag;
            let settled_ref = &settled;
            crate::parallel::ops::parallel_for_chunks(0, work_ref.len(), SEEDS, |ti, range| {
                // FIFO local search (discovery order): keeps the walk
                // close to distance order within the admitted slice,
                // which bounds overshoot corrections (a LIFO walk
                // churns on path-like graphs).
                let mut queue: Vec<u32> = Vec::with_capacity(64);
                queue.extend(range.map(|i| work_ref[i]));
                let mut head = 0usize;
                let mut stats = crate::parallel::vgc::SearchStats::default();
                while head < queue.len() && (stats.vertices as usize) < tau {
                    let v = queue[head];
                    head += 1;
                    stats.vertices += 1;
                    flag_ref[v as usize].store(0, Ordering::Relaxed);
                    let dv = load_f32(&dist[v as usize]);
                    // Qualify: expand only on strict improvement since
                    // the last expansion (one winner per value).
                    let set = settled_ref[v as usize].load(Ordering::Relaxed);
                    if dv.to_bits() >= set
                        || settled_ref[v as usize]
                            .compare_exchange(
                                set,
                                dv.to_bits(),
                                Ordering::AcqRel,
                                Ordering::Relaxed,
                            )
                            .is_err()
                    {
                        continue;
                    }
                    let ws = g.weights.as_ref().map(|_| g.weights_of(v));
                    for (j, &u) in g.neighbors(v).iter().enumerate() {
                        stats.edges += 1;
                        let w = ws.map_or(1.0, |ws| ws[j]);
                        let nd = dv + w;
                        if write_min_f32(&dist[u as usize], nd)
                            && flag_ref[u as usize].swap(1, Ordering::Relaxed) == 0
                        {
                            if nd <= theta {
                                // Near: keep walking inside this task.
                                queue.push(u);
                            } else {
                                bag_ref.insert(u);
                            }
                        }
                    }
                }
                // Budget exhausted: leftovers stay pending.
                for &u in &queue[head..] {
                    bag_ref.insert(u);
                }
                if record {
                    slots.set(ti, stats.into());
                }
            });
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(slots.into_round());
        }
        pending = bag.extract_and_clear();
        // Dedupe: flag==0 entries were already processed this round.
        pending.retain(|&v| pending_flag[v as usize].load(Ordering::Relaxed) == 1);
    }
    dist_bits.into_iter().map(f32::from_bits).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::sssp::dijkstra;
    use crate::graph::gen;

    fn close(got: &[f32], want: &[f32]) {
        for (v, (a, b)) in got.iter().zip(want).enumerate() {
            let ok = if *b >= INF {
                *a >= INF
            } else {
                (a - b).abs() <= 1e-3 * b.max(1.0)
            };
            assert!(ok, "vertex {v}: got {a} want {b}");
        }
    }

    #[test]
    fn matches_dijkstra_on_knn() {
        let g = gen::knn_points(300, 5, 9);
        close(&rho_stepping(&g, 0, 64, None), &dijkstra(&g, 0));
    }

    #[test]
    fn various_tau_all_correct() {
        let g = gen::road(7, 11, 5);
        let want = dijkstra(&g, 3);
        for tau in [1usize, 8, 512, 1 << 20] {
            close(&rho_stepping(&g, 3, tau, None), &want);
        }
    }

    #[test]
    fn fewer_rounds_than_delta_on_long_road() {
        let g = gen::road(3, 700, 1);
        let mut t_rho = crate::sim::AlgoTrace::new();
        let _ = rho_stepping(&g, 0, 512, Some(&mut t_rho));
        let mut t_delta = crate::sim::AlgoTrace::new();
        let _ = super::super::delta_stepping(&g, 0, None, Some(&mut t_delta));
        assert!(
            t_rho.num_rounds() * 4 < t_delta.num_rounds(),
            "rho rounds {} vs delta rounds {}",
            t_rho.num_rounds(),
            t_delta.num_rounds()
        );
    }
}
