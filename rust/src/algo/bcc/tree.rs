//! Rooted-forest machinery for parallel BCC: Euler tours, parallel
//! list ranking (Wyllie), first/last interval labels, and
//! segment-tree range-min/max for subtree aggregates.
//!
//! This is the substrate shared by all three parallel BCC variants:
//! given a spanning forest (from parallel CC or from BFS), it roots
//! every tree *without* a sequential DFS — the Euler circuit is built
//! arc-locally and positions come from pointer-jumping list ranking,
//! so the span stays polylogarithmic regardless of tree depth (a
//! chain-shaped tree would kill any DFS/BFS-based numbering).

use crate::parallel::parallel_for;
use crate::sim::trace::{Recorder, TaskCost};
use crate::V;

const NIL: u32 = u32::MAX;

/// A rooted spanning forest with Euler-interval labels.
pub struct RootedForest {
    /// parent\[v\] (== v for roots and isolated vertices).
    pub parent: Vec<V>,
    /// Entry time: unique within a component; subtree(v) = vertices u
    /// with first\[v\] <= first\[u\] <= last\[v\]. Comparisons are only
    /// meaningful within one component.
    pub first: Vec<u64>,
    /// Exit time (see `first`).
    pub last: Vec<u64>,
}

impl RootedForest {
    #[inline]
    pub fn is_root(&self, v: V) -> bool {
        self.parent[v as usize] == v
    }

    /// Is `u` an ancestor of `v` (or equal), same component assumed.
    #[inline]
    pub fn is_ancestor(&self, u: V, v: V) -> bool {
        self.first[u as usize] <= self.first[v as usize]
            && self.first[v as usize] <= self.last[u as usize]
    }
}

/// Build a rooted forest from an edge list (each edge once, any
/// orientation). Roots are the minimum vertex id of each tree
/// (matching `UnionFind`'s hook-by-min labels). `rec` receives the
/// pointer-jumping rounds.
pub fn build_rooted_forest(
    n: usize,
    forest_edges: &[(V, V)],
    mut rec: Recorder,
) -> RootedForest {
    let t = forest_edges.len();
    let n_arcs = 2 * t;
    if t == 0 {
        return RootedForest {
            parent: (0..n as V).collect(),
            first: (0..n as u64).collect(),
            last: (0..n as u64).collect(),
        };
    }

    // Arcs: 2k = (u -> v), 2k+1 = (v -> u); twin(a) = a ^ 1.
    let src = |a: u32| -> V {
        let (u, v) = forest_edges[(a >> 1) as usize];
        if a & 1 == 0 {
            u
        } else {
            v
        }
    };
    let dst = |a: u32| -> V {
        let (u, v) = forest_edges[(a >> 1) as usize];
        if a & 1 == 0 {
            v
        } else {
            u
        }
    };

    // Bucket arc ids by source (counting sort: O(n + L), no
    // comparisons — this sat on the BCC hot path, EXPERIMENTS.md
    // §Perf).
    let mut degree = vec![0usize; n];
    for a in 0..n_arcs as u32 {
        degree[src(a) as usize] += 1;
    }
    let mut starts = vec![0usize; n];
    {
        let mut acc = 0usize;
        for v in 0..n {
            starts[v] = acc;
            acc += degree[v];
        }
    }
    let mut order: Vec<u32> = vec![0; n_arcs];
    {
        let mut cursor = starts.clone();
        for a in 0..n_arcs as u32 {
            let v = src(a) as usize;
            order[cursor[v]] = a;
            cursor[v] += 1;
        }
    }
    let order = order;
    let starts = starts;
    let degree = degree;
    // Position of each arc within its source's slice.
    let mut pos_of = vec![0u32; n_arcs];
    {
        let pp = crate::parallel::ops::SendPtr(pos_of.as_mut_ptr());
        let order_ref = &order;
        let starts_ref = &starts;
        parallel_for(0, n_arcs, 4096, move |i| unsafe {
            let a = order_ref[i];
            *pp.add(a as usize) = (i - starts_ref[src(a) as usize]) as u32;
        });
    }

    // Euler circuit successor: succ[a] = arc after twin(a) in
    // dst(a)'s list (cyclic).
    let mut succ = vec![NIL; n_arcs];
    {
        let sp = crate::parallel::ops::SendPtr(succ.as_mut_ptr());
        let order_ref = &order;
        let starts_ref = &starts;
        let degree_ref = &degree;
        let pos_ref = &pos_of;
        parallel_for(0, n_arcs, 4096, move |ai| unsafe {
            let a = ai as u32;
            let tw = a ^ 1;
            let v = dst(a) as usize; // == src(tw)
            let d = degree_ref[v];
            let next_pos = (pos_ref[tw as usize] as usize + 1) % d;
            *sp.add(ai) = order_ref[starts_ref[v] + next_pos];
        });
    }

    // Roots: min vertex per component. Find components by replaying
    // the forest through union-find (cheap: t edges).
    let uf = crate::algo::cc::UnionFind::new(n);
    for &(u, v) in forest_edges {
        uf.unite(u, v);
    }
    let comp = uf.labels(); // label = min vertex of component
    // Component heads in increasing root order.
    let mut roots: Vec<V> = (0..n as V)
        .filter(|&v| comp[v as usize] == v && degree[v as usize] > 0)
        .collect();
    roots.sort_unstable();
    // Break each circuit before its head arc and chain the lists.
    let mut heads = Vec::with_capacity(roots.len());
    for &r in &roots {
        let head = order[starts[r as usize]];
        // Arc x with succ[x] == head: twin of the last arc in r's list.
        let last_arc = order[starts[r as usize] + degree[r as usize] - 1];
        let x = last_arc ^ 1;
        debug_assert_eq!(succ[x as usize], head);
        succ[x as usize] = NIL; // temporarily: re-chain below
        heads.push((head, x));
    }
    for i in 0..heads.len().saturating_sub(1) {
        let (_, tail) = heads[i];
        let (next_head, _) = heads[i + 1];
        succ[tail as usize] = next_head;
    }

    // List ranking: pos[a] = index of arc a in the chained Euler
    // order. Two engines with identical output and identical *modeled*
    // round structure (the simulator always sees the O(log L)
    // pointer-jumping rounds a real multicore run would execute):
    //   - sequential walk (O(L)) when only one worker exists — the
    //     classic granularity-control fallback;
    //   - Wyllie pointer jumping (O(L log L) work, O(log L) rounds)
    //     otherwise.
    let total = n_arcs as u64;
    let pos: Vec<u64> = if crate::parallel::num_threads() == 1 || n_arcs < (1 << 14) {
        let mut pos = vec![0u64; n_arcs];
        let mut p = 0u64;
        let (head0, _) = heads[0];
        let mut a = head0;
        while a != NIL {
            pos[a as usize] = p;
            p += 1;
            a = succ[a as usize];
        }
        debug_assert_eq!(p, total);
        pos
    } else {
        // rank[a] = #arcs strictly after a.
        let mut rank: Vec<u64> = succ
            .iter()
            .map(|&s| if s == NIL { 0 } else { 1 })
            .collect();
        let mut next = succ.clone();
        let mut rank2 = rank.clone();
        let mut next2 = next.clone();
        loop {
            let done = std::sync::atomic::AtomicBool::new(true);
            {
                let r2 = crate::parallel::ops::SendPtr(rank2.as_mut_ptr());
                let n2 = crate::parallel::ops::SendPtr(next2.as_mut_ptr());
                let rank_ref = &rank;
                let next_ref = &next;
                let done_ref = &done;
                parallel_for(0, n_arcs, 2048, move |a| unsafe {
                    let nx = next_ref[a];
                    if nx == NIL {
                        *r2.add(a) = rank_ref[a];
                        *n2.add(a) = NIL;
                    } else {
                        done_ref.store(false, std::sync::atomic::Ordering::Relaxed);
                        *r2.add(a) = rank_ref[a] + rank_ref[nx as usize];
                        *n2.add(a) = next_ref[nx as usize];
                    }
                });
            }
            std::mem::swap(&mut rank, &mut rank2);
            std::mem::swap(&mut next, &mut next2);
            if done.load(std::sync::atomic::Ordering::Relaxed) {
                break;
            }
        }
        rank.iter().map(|&r| total - 1 - r).collect()
    };
    // Model the pointer-jumping rounds regardless of engine.
    if let Some(trace) = rec.as_deref_mut() {
        let rounds = (n_arcs.max(2) as f64).log2().ceil() as usize;
        for _ in 0..rounds {
            trace.push_round(vec![TaskCost {
                vertices: (n_arcs / rounds.max(1)) as u64,
                edges: n_arcs as u64,
            }]);
        }
    }

    // parent / first / last.
    let mut parent: Vec<V> = (0..n as V).collect();
    let mut first = vec![0u64; n];
    let mut last = vec![0u64; n];
    {
        let pp = crate::parallel::ops::SendPtr(parent.as_mut_ptr());
        let fp = crate::parallel::ops::SendPtr(first.as_mut_ptr());
        let lp = crate::parallel::ops::SendPtr(last.as_mut_ptr());
        let starts_ref = &starts;
        let degree_ref = &degree;
        let order_ref = &order;
        let pos_ref = &pos;
        let comp_ref = &comp;
        parallel_for(0, n, 1024, move |v| unsafe {
            let d = degree_ref[v];
            if d == 0 {
                // Isolated: unique interval beyond all arc positions.
                *fp.add(v) = total + v as u64;
                *lp.add(v) = total + v as u64;
                return;
            }
            if comp_ref[v] == v as u32 {
                // Root: spans its whole component; use its head arc's
                // position for first and "infinity" for last (interval
                // tests are intra-component only).
                let head = order_ref[starts_ref[v]];
                *fp.add(v) = pos_ref[head as usize];
                *lp.add(v) = u64::MAX / 2;
                return;
            }
            // parent arc = incoming arc (u -> v) with minimal position.
            let mut best_arc = NIL;
            let mut best_pos = u64::MAX;
            for i in 0..d {
                let out = order_ref[starts_ref[v] + i];
                let incoming = out ^ 1;
                if pos_ref[incoming as usize] < best_pos {
                    best_pos = pos_ref[incoming as usize];
                    best_arc = incoming;
                }
            }
            *pp.add(v) = src(best_arc);
            *fp.add(v) = best_pos + 1;
            *lp.add(v) = pos_ref[(best_arc ^ 1) as usize] + 1;
        });
    }
    RootedForest {
        parent,
        first,
        last,
    }
}

// ---------------------------------------------------------------------------
// Segment trees for subtree range-min / range-max queries
// ---------------------------------------------------------------------------

/// Static segment tree over u64 values (min or max by `MIN` flag).
pub struct SegTree<const MIN: bool> {
    size: usize,
    tree: Vec<u64>,
}

impl<const MIN: bool> SegTree<MIN> {
    const ID: u64 = if MIN { u64::MAX } else { 0 };

    #[inline]
    fn op(a: u64, b: u64) -> u64 {
        if MIN {
            a.min(b)
        } else {
            a.max(b)
        }
    }

    /// Build over `values` (parallel bottom-up level by level).
    pub fn build(values: &[u64]) -> Self {
        let size = values.len().next_power_of_two().max(1);
        let mut tree = vec![Self::ID; 2 * size];
        tree[size..size + values.len()].copy_from_slice(values);
        // levels bottom-up
        let mut lo = size / 2;
        while lo >= 1 {
            let hi = lo * 2;
            {
                let tp = crate::parallel::ops::SendPtr(tree.as_mut_ptr());
                parallel_for(lo, hi, 4096, |i| unsafe {
                    let l = *tp.add(2 * i);
                    let r = *tp.add(2 * i + 1);
                    *tp.add(i) = Self::op(l, r);
                });
            }
            lo /= 2;
            if lo == 0 {
                break;
            }
        }
        SegTree { size, tree }
    }

    /// Aggregate over the inclusive index range [l, r].
    pub fn query(&self, l: u64, r: u64) -> u64 {
        let (mut l, mut r) = (
            (l as usize).min(self.size - 1) + self.size,
            (r as usize).min(self.size - 1) + self.size + 1,
        );
        let mut acc = Self::ID;
        while l < r {
            if l & 1 == 1 {
                acc = Self::op(acc, self.tree[l]);
                l += 1;
            }
            if r & 1 == 1 {
                r -= 1;
                acc = Self::op(acc, self.tree[r]);
            }
            l /= 2;
            r /= 2;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn forest_for_path(n: usize) -> RootedForest {
        let edges: Vec<(V, V)> = (0..n - 1).map(|i| (i as V, (i + 1) as V)).collect();
        build_rooted_forest(n, &edges, None)
    }

    #[test]
    fn path_parents_point_down_from_root_zero() {
        let f = forest_for_path(6);
        assert!(f.is_root(0));
        for v in 1..6u32 {
            assert_eq!(f.parent[v as usize], v - 1);
        }
    }

    #[test]
    fn path_intervals_nest() {
        let f = forest_for_path(8);
        for v in 0..8u32 {
            for u in 0..8u32 {
                let anc = f.is_ancestor(v, u);
                assert_eq!(anc, v <= u, "ancestor({v},{u})");
            }
        }
    }

    #[test]
    fn star_all_children_of_center() {
        let edges: Vec<(V, V)> = (1..7).map(|i| (0, i as V)).collect();
        let f = build_rooted_forest(7, &edges, None);
        assert!(f.is_root(0));
        for v in 1..7u32 {
            assert_eq!(f.parent[v as usize], 0);
            assert!(f.is_ancestor(0, v));
            assert!(!f.is_ancestor(v, 0));
            for u in 1..7u32 {
                if u != v {
                    assert!(!f.is_ancestor(v, u), "{v} anc of {u}?");
                }
            }
        }
    }

    #[test]
    fn multi_component_forest() {
        // Two trees: {0-1-2} and {5-6}, isolated 3, 4.
        let edges = vec![(0, 1), (1, 2), (5, 6)];
        let f = build_rooted_forest(7, &edges, None);
        assert!(f.is_root(0));
        assert!(f.is_root(5));
        assert!(f.is_root(3) && f.is_root(4));
        assert_eq!(f.parent[6], 5);
        assert!(f.is_ancestor(0, 2));
        assert!(f.is_ancestor(5, 6));
    }

    #[test]
    fn binary_tree_subtree_intervals() {
        //        0
        //      1   2
        //     3 4 5 6
        let edges = vec![(0, 1), (0, 2), (1, 3), (1, 4), (2, 5), (2, 6)];
        let f = build_rooted_forest(7, &edges, None);
        assert!(f.is_ancestor(1, 3) && f.is_ancestor(1, 4));
        assert!(!f.is_ancestor(1, 5) && !f.is_ancestor(1, 2));
        assert!(f.is_ancestor(2, 6));
        assert!(f.is_ancestor(0, 6));
    }

    #[test]
    fn random_tree_parent_edges_are_forest_edges() {
        use crate::prop::{forall, Rng};
        forall(0x7EE, |rng: &mut Rng| {
            let n = rng.range(2, 200);
            // random spanning tree: attach v to a random earlier vertex
            let edges: Vec<(V, V)> = (1..n)
                .map(|v| (rng.range(0, v) as V, v as V))
                .collect();
            let f = build_rooted_forest(n, &edges, None);
            let set: std::collections::HashSet<(V, V)> = edges
                .iter()
                .flat_map(|&(a, b)| [(a, b), (b, a)])
                .collect();
            assert!(f.is_root(0));
            for v in 1..n as u32 {
                assert!(
                    set.contains(&(f.parent[v as usize], v)),
                    "parent edge missing"
                );
                assert!(f.is_ancestor(0, v));
            }
            // interval containment is a partial order consistent with
            // parent pointers
            for v in 1..n as u32 {
                assert!(f.is_ancestor(f.parent[v as usize], v));
            }
        });
    }

    #[test]
    fn segtree_min_max_match_naive() {
        use crate::prop::{forall, Rng};
        forall(0x5E6, |rng: &mut Rng| {
            let n = rng.range(1, 300);
            let vals: Vec<u64> = (0..n).map(|_| rng.below(1000)).collect();
            let mn = SegTree::<true>::build(&vals);
            let mx = SegTree::<false>::build(&vals);
            for _ in 0..20 {
                let l = rng.range(0, n);
                let r = rng.range(l, n);
                let want_min = vals[l..=r.min(n - 1)].iter().copied().min().unwrap();
                let want_max = vals[l..=r.min(n - 1)].iter().copied().max().unwrap();
                assert_eq!(mn.query(l as u64, r as u64), want_min);
                assert_eq!(mx.query(l as u64, r as u64), want_max);
            }
        });
    }
}
