//! Tarjan–Vishkin BCC [22] (implementation role: the O(m)-space
//! baseline of Table 3).
//!
//! Spanning forest from parallel connectivity, Euler-tour rooting,
//! then the auxiliary graph is **materialized** as an explicit edge
//! list before running connectivity on it — asymptotically fine
//! (O(n+m) work, polylog span) but the O(m) auxiliary space is what
//! makes it blow up on the paper's billion-edge graphs ("o.o.m." in
//! Table 3). `BccResult::aux_bytes` reports that footprint.

use super::skeleton::{run, BccResult, Mode};
use super::tree::build_rooted_forest;
use crate::algo::cc::spanning_forest;
use crate::graph::Graph;
use crate::sim::trace::Recorder;

/// Parallel Tarjan–Vishkin BCC over a symmetric, deduplicated graph.
pub fn tarjan_vishkin(g: &Graph, mut rec: Recorder) -> BccResult {
    let (_labels, forest) = spanning_forest(g);
    let rf = build_rooted_forest(g.n(), &forest, rec.as_deref_mut());
    run(g, &rf, Mode::Explicit, rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn triangle_one_block() {
        let g = crate::graph::Graph::from_edges(3, &[(0, 1), (1, 2), (2, 0)], true).symmetrize();
        let r = tarjan_vishkin(&g, None);
        assert_eq!(r.n_bcc, 1);
        assert!(r.arc_label.iter().all(|&l| l == r.arc_label[0]));
    }

    #[test]
    fn aux_bytes_scale_with_m() {
        let small = gen::bubbles(10, 5, 1);
        let big = gen::bubbles(100, 5, 1);
        let rs = tarjan_vishkin(&small, None);
        let rb = tarjan_vishkin(&big, None);
        assert!(
            rb.aux_bytes > 3 * rs.aux_bytes,
            "explicit aux edges must grow with the graph: {} vs {}",
            rs.aux_bytes,
            rb.aux_bytes
        );
    }
}
