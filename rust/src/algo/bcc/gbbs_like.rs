//! GBBS-like BCC: Tarjan–Vishkin low/high over a **BFS spanning
//! tree** (what GBBS [9] does). Correct and space-frugal, but the
//! tree construction takes O(D) synchronized rounds — this is the
//! Table 3 baseline that degrades on road/kNN/synthetic graphs.

use super::skeleton::{run, BccResult, Mode};
use super::tree::build_rooted_forest;
use crate::graph::Graph;
use crate::parallel::atomic::claim;
use crate::parallel::parallel_for;
use crate::sim::trace::{Recorder, TaskCost};
use crate::V;
use std::sync::atomic::{AtomicU32, Ordering};

const UNSET: u32 = u32::MAX;

/// BFS spanning forest: one multi-source BFS seeded at every
/// component root simultaneously (roots from a connectivity pass, as
/// GBBS does), so the round count is the *maximum* component diameter
/// — still the O(D) weakness, but not a sum over components.
fn bfs_forest(g: &Graph, rec: &mut Recorder) -> Vec<(V, V)> {
    let n = g.n();
    let labels = crate::algo::cc::connected_components(g);
    let parent: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNSET)).collect();
    let mut frontier: Vec<V> =
        crate::parallel::pack_index(n, |v| labels[v] == v as u32);
    for &r in &frontier {
        parent[r as usize].store(r, Ordering::Relaxed);
    }
    let mut forest: Vec<(V, V)> = Vec::with_capacity(n.saturating_sub(frontier.len()));
    while !frontier.is_empty() {
        let bag = crate::hashbag::HashBag::new(n);
        {
            let frontier_ref = &frontier;
            let parent_ref = &parent;
            let bag_ref = &bag;
            parallel_for(0, frontier_ref.len(), 64, move |i| {
                let v = frontier_ref[i];
                for &w in g.neighbors(v) {
                    if claim(&parent_ref[w as usize], UNSET, v) {
                        bag_ref.insert(w);
                    }
                }
            });
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(
                frontier
                    .iter()
                    .map(|&v| TaskCost {
                        vertices: 1,
                        edges: g.degree(v) as u64,
                    })
                    .collect(),
            );
        }
        let next = bag.extract_and_clear();
        forest.extend(
            next.iter()
                .map(|&w| (parent[w as usize].load(Ordering::Relaxed), w)),
        );
        frontier = next;
    }
    forest
}

/// GBBS-like BCC over a symmetric, deduplicated graph.
pub fn gbbs_bcc(g: &Graph, mut rec: Recorder) -> BccResult {
    let forest = bfs_forest(g, &mut rec);
    let rf = build_rooted_forest(g.n(), &forest, rec.as_deref_mut());
    run(g, &rf, Mode::Implicit, rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn two_triangles_share_articulation() {
        let g = crate::graph::Graph::from_edges(
            5,
            &[(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)],
            true,
        )
        .symmetrize();
        let r = gbbs_bcc(&g, None);
        assert_eq!(r.n_bcc, 2);
        assert!(r.articulation[2]);
    }

    #[test]
    fn rounds_scale_with_diameter_unlike_fast_bcc() {
        let long = gen::cycle(4096).symmetrize();
        let mut t_gbbs = crate::sim::AlgoTrace::new();
        let _ = gbbs_bcc(&long, Some(&mut t_gbbs));
        let mut t_fast = crate::sim::AlgoTrace::new();
        let _ = super::super::fast_bcc(&long, Some(&mut t_fast));
        assert!(
            t_gbbs.num_rounds() > 20 * t_fast.num_rounds(),
            "BFS-tree rounds {} should dwarf FAST-BCC rounds {}",
            t_gbbs.num_rounds(),
            t_fast.num_rounds()
        );
    }
}
