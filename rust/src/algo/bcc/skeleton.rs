//! Shared BCC skeleton: Tarjan–Vishkin auxiliary-graph connectivity
//! over a rooted spanning forest.
//!
//! Nodes of the auxiliary graph are the non-root vertices (vertex v
//! stands for its parent tree edge). Two rules generate aux edges:
//!
//! * **Rule A** (cross edges): a non-tree edge {u, v} with neither
//!   endpoint an ancestor of the other puts e_u and e_v on a common
//!   cycle (through their LCA): union(u, v).
//! * **Rule B** (chaining): tree edge (p, v) joins e_v with e_p iff
//!   some edge from subtree(v) *escapes* subtree(p) — computed from
//!   subtree min/max of neighbor entry times via segment-tree range
//!   queries (the low/high of Tarjan–Vishkin, cross-edge-safe).
//!
//! Back edges (ancestor-related non-tree edges) need no rule: the
//! chain of Rule B unions along the tree path covers their cycle, and
//! the fence at the top child stops exactly below the ancestor — this
//! is what keeps two blocks that share an articulation vertex apart.
//!
//! The connected components of the aux graph are the biconnected
//! components. [`Mode::Explicit`] materializes the aux edge list
//! (Tarjan–Vishkin's O(m) space — the paper's o.o.m. column);
//! [`Mode::Implicit`] unions on the fly in O(n) extra space
//! (FAST-BCC's space discipline).

use super::tree::{RootedForest, SegTree};
use crate::algo::cc::UnionFind;
use crate::graph::Graph;
use crate::parallel::parallel_for;
use crate::sim::trace::{Recorder, TaskCost};
use crate::V;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

/// No-label sentinel (self-loops).
pub const NO_BCC: u32 = u32::MAX;

/// Aux-graph materialization strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Materialize the aux edge list (O(m) space).
    Explicit,
    /// Union on the fly (O(n) space).
    Implicit,
}

/// BCC output shared by all implementations.
pub struct BccResult {
    /// Per-CSR-arc BCC label (`NO_BCC` for self-loops). Arcs (u,v)
    /// and (v,u) always agree.
    pub arc_label: Vec<u32>,
    /// Number of biconnected components.
    pub n_bcc: usize,
    /// Per-vertex articulation flags.
    pub articulation: Vec<bool>,
    /// Peak auxiliary bytes beyond the input graph (the Table 3
    /// space story: O(m) for Tarjan–Vishkin vs O(n) for FAST-BCC).
    pub aux_bytes: usize,
}

/// Run the skeleton over `g` (symmetric, deduplicated) and its rooted
/// spanning forest.
pub fn run(g: &Graph, rf: &RootedForest, mode: Mode, mut rec: Recorder) -> BccResult {
    let n = g.n();
    let m = g.m();

    // --- per-vertex neighbor-entry-time extremes (self excluded) ---
    // nf[v] = min(first(v), min first(w) over non-tree neighbors w)
    // xf[v] = max(...). Tree edges to parent/children are excluded:
    // they never witness an escape (parent edge handled by Rule B
    // itself; child edges stay inside the subtree).
    let is_tree_arc = |u: V, w: V| rf.parent[w as usize] == u || rf.parent[u as usize] == w;
    let mut nf = vec![u64::MAX; n];
    let mut xf = vec![0u64; n];
    {
        let nfp = crate::parallel::ops::SendPtr(nf.as_mut_ptr());
        let xfp = crate::parallel::ops::SendPtr(xf.as_mut_ptr());
        parallel_for(0, n, 512, move |v| unsafe {
            let vf = rf.first[v];
            let mut lo = vf;
            let mut hi = vf;
            for &w in g.neighbors(v as V) {
                if w as usize == v || is_tree_arc(v as V, w) {
                    continue;
                }
                let wf = rf.first[w as usize];
                lo = lo.min(wf);
                hi = hi.max(wf);
            }
            *nfp.add(v) = lo;
            *xfp.add(v) = hi;
        });
    }
    if let Some(trace) = rec.as_deref_mut() {
        trace.push_round(vec![TaskCost {
            vertices: n as u64,
            edges: m as u64,
        }]);
    }

    // --- position-indexed arrays + segment trees ---
    let pos_span = (0..n).map(|v| rf.first[v]).max().unwrap_or(0) as usize + 2;
    let mut wmin = vec![u64::MAX; pos_span];
    let mut wmax = vec![0u64; pos_span];
    for v in 0..n {
        let p = rf.first[v] as usize;
        wmin[p] = nf[v];
        wmax[p] = xf[v];
    }
    let seg_min = SegTree::<true>::build(&wmin);
    let seg_max = SegTree::<false>::build(&wmax);

    // Escape test: subtree(v) has an edge leaving subtree(parent(v)).
    // Roots' last is huge so escapes never fire for root children.
    let escape = |v: usize| -> bool {
        let p = rf.parent[v] as usize;
        if p == v {
            return false;
        }
        // Clamp the query into the position array (root last is inf).
        let hi = rf.last[v].min(pos_span as u64 - 1);
        let w1 = seg_min.query(rf.first[v], hi);
        let w2 = seg_max.query(rf.first[v], hi);
        w1 < rf.first[p] || w2 > rf.last[p]
    };

    // --- auxiliary connectivity ---
    let uf = UnionFind::new(n);
    let mut aux_bytes = 0usize;
    match mode {
        Mode::Implicit => {
            // Rule B.
            parallel_for(0, n, 512, |v| {
                if !rf.is_root(v as V) && escape(v) {
                    uf.unite(v as u32, rf.parent[v]);
                }
            });
            // Rule A.
            parallel_for(0, n, 256, |u| {
                for &w in g.neighbors(u as V) {
                    let (u, w) = (u as V, w);
                    if u >= w || w as usize == u as usize {
                        continue; // each undirected edge once
                    }
                    if is_tree_arc(u, w) {
                        continue;
                    }
                    if !rf.is_ancestor(u, w) && !rf.is_ancestor(w, u) {
                        uf.unite(u, w);
                    }
                }
            });
            aux_bytes += n * 4; // the union-find parents
        }
        Mode::Explicit => {
            // Materialize the aux edge list first (the O(m) cost).
            let buckets: Vec<std::sync::Mutex<Vec<(V, V)>>> =
                (0..n.div_ceil(256)).map(|_| std::sync::Mutex::new(Vec::new())).collect();
            crate::parallel::ops::parallel_for_chunks(0, n, 256, |ci, range| {
                let mut local = Vec::new();
                for v in range.clone() {
                    if !rf.is_root(v as V) && escape(v) {
                        local.push((v as V, rf.parent[v]));
                    }
                }
                for u in range {
                    for &w in g.neighbors(u as V) {
                        let u = u as V;
                        if u >= w {
                            continue;
                        }
                        if is_tree_arc(u, w) {
                            continue;
                        }
                        if !rf.is_ancestor(u, w) && !rf.is_ancestor(w, u) {
                            local.push((u, w));
                        }
                    }
                }
                *buckets[ci].lock().unwrap() = local;
            });
            let mut aux_edges: Vec<(V, V)> = Vec::new();
            for b in buckets {
                aux_edges.extend(b.into_inner().unwrap());
            }
            aux_bytes += aux_edges.capacity() * std::mem::size_of::<(V, V)>() + n * 4;
            parallel_for(0, aux_edges.len(), 1024, |i| {
                let (u, v) = aux_edges[i];
                uf.unite(u, v);
            });
        }
    }
    if let Some(trace) = rec.as_deref_mut() {
        trace.push_round(vec![TaskCost {
            vertices: n as u64,
            edges: m as u64,
        }]);
    }

    // --- labels per arc ---
    let comp = uf.labels();
    let mut arc_label = vec![NO_BCC; m];
    {
        let lp = crate::parallel::ops::SendPtr(arc_label.as_mut_ptr());
        let comp = &comp;
        parallel_for(0, n, 256, move |u| {
            let base = g.offsets()[u] as usize;
            for (i, &w) in g.neighbors(u as V).iter().enumerate() {
                let u = u as V;
                if w == u {
                    continue; // self-loop: no block
                }
                let label = if rf.parent[w as usize] == u {
                    comp[w as usize]
                } else if rf.parent[u as usize] == w {
                    comp[u as usize]
                } else if rf.is_ancestor(u, w) {
                    comp[w as usize]
                } else if rf.is_ancestor(w, u) {
                    comp[u as usize]
                } else {
                    comp[u as usize]
                };
                unsafe { *lp.add(base + i) = label };
            }
        });
    }

    // --- articulation points ---
    // A vertex articulates iff it belongs to >= 2 blocks. Non-root p
    // belongs to comp(p) (its parent edge) plus comp(c) of every
    // child c, so: exists child with comp(c) != comp(p). A root has
    // no parent edge: >= 2 distinct comps among its children.
    let art: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let root_first_comp: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(NO_BCC)).collect();
    parallel_for(0, n, 512, |v| {
        let p = rf.parent[v] as usize;
        if p == v {
            return;
        }
        let c = comp[v];
        if rf.is_root(p as V) {
            let prev = root_first_comp[p]
                .compare_exchange(NO_BCC, c, Ordering::AcqRel, Ordering::Relaxed);
            if let Err(existing) = prev {
                if existing != c {
                    art[p].store(true, Ordering::Relaxed);
                }
            }
        } else if c != comp[p] {
            art[p].store(true, Ordering::Relaxed);
        }
    });

    // --- count blocks ---
    let mut distinct = std::collections::HashSet::new();
    for v in 0..n {
        if !rf.is_root(v as V) {
            distinct.insert(comp[v]);
        }
    }

    BccResult {
        arc_label,
        n_bcc: distinct.len(),
        articulation: art.into_iter().map(|a| a.into_inner()).collect(),
        aux_bytes,
    }
}
