//! FAST-BCC (Dong, Wang, Gu, Sun — PPoPP'23 [12]): PASGAL's BCC.
//!
//! The two properties the paper leans on, both reproduced here:
//!
//! 1. **No BFS anywhere**: the spanning forest comes from parallel
//!    connectivity (hook/compress — O(1)-ish rounds) and the rooting
//!    from Euler tour + pointer-jumping list ranking (O(log n)
//!    rounds), so unlike GBBS's BFS-tree BCC the round count is
//!    *independent of the diameter*.
//! 2. **O(n) auxiliary space**: the Tarjan–Vishkin skeleton is
//!    evaluated implicitly — aux edges are unioned on the fly, never
//!    materialized (contrast `tarjan_vishkin`, o.o.m. in Table 3).

use super::skeleton::{run, BccResult, Mode};
use super::tree::build_rooted_forest;
use crate::algo::cc::spanning_forest;
use crate::graph::Graph;
use crate::sim::trace::Recorder;

/// FAST-BCC over a symmetric, deduplicated graph.
pub fn fast_bcc(g: &Graph, mut rec: Recorder) -> BccResult {
    let (_labels, forest) = spanning_forest(g);
    let rf = build_rooted_forest(g.n(), &forest, rec.as_deref_mut());
    run(g, &rf, Mode::Implicit, rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn bubbles_block_per_bubble() {
        let nb = 9;
        let g = gen::bubbles(nb, 6, 2);
        let r = fast_bcc(&g, None);
        assert_eq!(r.n_bcc, nb);
    }

    #[test]
    fn aux_space_linear_in_n_not_m() {
        // Dense graph: m >> n; implicit mode must stay near O(n).
        let g = gen::complete(64).symmetrize();
        let r = fast_bcc(&g, None);
        assert!(
            r.aux_bytes <= 64 * 4 * 8,
            "implicit skeleton must not materialize O(m): {}",
            r.aux_bytes
        );
        assert_eq!(r.n_bcc, 1);
    }

    #[test]
    fn rounds_do_not_scale_with_diameter() {
        // Long cycle (diameter n/2) vs short cycle: round counts stay
        // within a log factor — the whole point of FAST-BCC.
        let short = gen::cycle(64).symmetrize();
        let long = gen::cycle(8192).symmetrize();
        let mut ts = crate::sim::AlgoTrace::new();
        let _ = fast_bcc(&short, Some(&mut ts));
        let mut tl = crate::sim::AlgoTrace::new();
        let _ = fast_bcc(&long, Some(&mut tl));
        assert!(
            tl.num_rounds() <= ts.num_rounds() + 16,
            "rounds must not grow with D: {} vs {}",
            tl.num_rounds(),
            ts.num_rounds()
        );
    }
}
