//! Biconnected components: the Table 3 contenders.
//!
//! * [`hopcroft_tarjan::hopcroft_tarjan`] — sequential baseline [14].
//! * [`tarjan_vishkin::tarjan_vishkin`] — parallel, explicit aux graph
//!   (O(m) space: the "o.o.m." baseline) [22].
//! * [`gbbs_like::gbbs_bcc`] — BFS-spanning-tree variant (O(D)
//!   rounds: the round-bound baseline) [9].
//! * [`fast_bcc::fast_bcc`] — PASGAL's FAST-BCC [12]: CC spanning
//!   tree + implicit skeleton: no BFS, O(n) aux space, polylog span.
//!
//! All four produce per-arc block labels, articulation flags and a
//! block count; cross-tests verify the *edge partitions* match the
//! sequential oracle exactly.

pub mod fast_bcc;
pub mod gbbs_like;
pub mod hopcroft_tarjan;
pub mod skeleton;
pub mod tarjan_vishkin;
pub mod tree;

pub use fast_bcc::fast_bcc;
pub use gbbs_like::gbbs_bcc;
pub use hopcroft_tarjan::hopcroft_tarjan;
pub use skeleton::{BccResult, NO_BCC};
pub use tarjan_vishkin::tarjan_vishkin;

/// Canonicalize an arc labeling: each label class renamed to the
/// smallest arc index it contains. Two labelings describe the same
/// edge partition iff their canonical forms are equal.
pub fn canonicalize_arcs(labels: &[u32]) -> Vec<u32> {
    let mut min_of = std::collections::HashMap::<u32, u32>::new();
    for (i, &l) in labels.iter().enumerate() {
        if l == NO_BCC {
            continue;
        }
        let e = min_of.entry(l).or_insert(i as u32);
        if (i as u32) < *e {
            *e = i as u32;
        }
    }
    labels
        .iter()
        .map(|&l| if l == NO_BCC { NO_BCC } else { min_of[&l] })
        .collect()
}

#[cfg(test)]
mod cross_tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::Graph;
    use crate::prop::{forall, Rng};
    use crate::V;

    fn check_all(g: &Graph) {
        assert!(g.symmetric, "BCC inputs are symmetrized");
        let want = hopcroft_tarjan(g);
        let want_arcs = canonicalize_arcs(&want.arc_label);
        for (name, got) in [
            ("tarjan_vishkin", tarjan_vishkin(g, None)),
            ("gbbs_bcc", gbbs_bcc(g, None)),
            ("fast_bcc", fast_bcc(g, None)),
        ] {
            assert_eq!(got.n_bcc, want.n_bcc, "{name}: block count");
            assert_eq!(
                canonicalize_arcs(&got.arc_label),
                want_arcs,
                "{name}: edge partition"
            );
            assert_eq!(got.articulation, want.articulation, "{name}: articulation");
        }
    }

    #[test]
    fn all_agree_on_named_shapes() {
        check_all(&gen::path(30).symmetrize());
        check_all(&gen::cycle(30).symmetrize());
        check_all(&gen::star(20).symmetrize());
        check_all(&gen::complete(10).symmetrize());
        check_all(&gen::bubbles(8, 5, 3));
        check_all(&gen::grid(5, 7).symmetrize());
    }

    #[test]
    fn all_agree_on_suite_categories() {
        check_all(&gen::social(9, 6, 3).symmetrize());
        check_all(&gen::road(7, 11, 4).symmetrize());
        check_all(&gen::traces(40, 5, 5));
        check_all(&gen::knn_chain(400, 3, 6, 6).symmetrize());
    }

    #[test]
    fn prop_all_agree_on_random_graphs() {
        forall(0xBCC, |rng: &mut Rng| {
            let n = rng.range(2, 120);
            let m = rng.range(0, 3 * n);
            let edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            let g = Graph::from_edges(n, &edges, true).symmetrize();
            check_all(&g);
        });
    }

    #[test]
    fn prop_sparse_tree_like_graphs() {
        // Trees + a few extra edges: lots of bridges + articulation.
        forall(0xBCD, |rng: &mut Rng| {
            let n = rng.range(2, 150);
            let mut edges: Vec<(V, V)> = (1..n)
                .map(|v| (rng.range(0, v) as V, v as V))
                .collect();
            for _ in 0..rng.range(0, 5) {
                edges.push((rng.below(n as u64) as V, rng.below(n as u64) as V));
            }
            let g = Graph::from_edges(n, &edges, true).symmetrize();
            check_all(&g);
        });
    }
}
