//! Sequential Hopcroft–Tarjan biconnected components [14] — the
//! paper's sequential baseline and our correctness oracle.
//!
//! Iterative DFS with an explicit edge stack; pops a block whenever a
//! child's lowpoint does not pass its parent. Inputs must be
//! symmetric and deduplicated (what [`crate::graph::Graph::symmetrize`]
//! produces); self-loops are ignored.

use super::skeleton::{BccResult, NO_BCC};
use crate::graph::Graph;
use crate::V;

const UNSET: u32 = u32::MAX;

/// Arc index of (w -> u) given that (u -> w) exists — unique because
/// the graph is deduplicated; neighbors are sorted by construction.
fn twin(g: &Graph, u: V, w: V) -> usize {
    let base = g.offsets()[w as usize] as usize;
    let nbrs = g.neighbors(w);
    let i = nbrs.partition_point(|&x| x < u);
    debug_assert!(nbrs[i] == u, "twin arc missing: graph not symmetric?");
    base + i
}

/// Sequential BCC.
pub fn hopcroft_tarjan(g: &Graph) -> BccResult {
    let n = g.n();
    let m = g.m();
    let mut disc = vec![UNSET; n];
    let mut low = vec![0u32; n];
    let mut arc_label = vec![NO_BCC; m];
    let mut articulation = vec![false; n];
    let mut edge_stack: Vec<u32> = Vec::new(); // arc ids, canonical dir
    let mut n_bcc = 0u32;
    let mut timer = 0u32;

    // Call frames: (vertex, parent, arc-to-parent twin, next edge i,
    // #tree children).
    struct Frame {
        v: V,
        parent: V,
        skip_arc: u32, // the arc (v -> parent), skipped once
        ei: usize,
        children: u32,
    }

    for s in 0..n as V {
        if disc[s as usize] != UNSET {
            continue;
        }
        disc[s as usize] = timer;
        low[s as usize] = timer;
        timer += 1;
        let mut stack = vec![Frame {
            v: s,
            parent: s,
            skip_arc: u32::MAX,
            ei: 0,
            children: 0,
        }];
        while let Some(top) = stack.last_mut() {
            let v = top.v;
            let base = g.offsets()[v as usize] as usize;
            let nbrs = g.neighbors(v);
            if top.ei < nbrs.len() {
                let i = top.ei;
                top.ei += 1;
                let w = nbrs[i];
                let arc = (base + i) as u32;
                if w == v || arc == top.skip_arc {
                    continue; // self-loop or the parent edge
                }
                if disc[w as usize] == UNSET {
                    // Tree edge: push and descend.
                    top.children += 1;
                    edge_stack.push(arc);
                    disc[w as usize] = timer;
                    low[w as usize] = timer;
                    timer += 1;
                    let skip = twin(g, v, w) as u32;
                    stack.push(Frame {
                        v: w,
                        parent: v,
                        skip_arc: skip,
                        ei: 0,
                        children: 0,
                    });
                } else if disc[w as usize] < disc[v as usize] {
                    // Back edge (to an ancestor): stack it.
                    edge_stack.push(arc);
                    low[v as usize] = low[v as usize].min(disc[w as usize]);
                }
                // disc[w] > disc[v]: the edge was stacked from w's side.
            } else {
                // Retreat from v into parent u.
                let frame = stack.pop().unwrap();
                let (v, u) = (frame.v, frame.parent);
                if v == u {
                    // Component root done; leftover (shouldn't happen:
                    // every pushed edge pops with some block).
                    debug_assert!(edge_stack.is_empty());
                    // Root articulation: >= 2 tree children.
                    if frame.children >= 2 {
                        articulation[v as usize] = true;
                    }
                    continue;
                }
                low[u as usize] = low[u as usize].min(low[v as usize]);
                if low[v as usize] >= disc[u as usize] {
                    // Pop one block: all edges until (u, v) inclusive.
                    let stop_arc = {
                        // the tree arc (u -> v) pushed at descent
                        let ub = g.offsets()[u as usize] as usize;
                        let i = g.neighbors(u).partition_point(|&x| x < v);
                        (ub + i) as u32
                    };
                    let comp = n_bcc;
                    n_bcc += 1;
                    loop {
                        let arc = edge_stack.pop().expect("edge stack underflow");
                        let a = arc as usize;
                        arc_label[a] = comp;
                        // label the twin too
                        let (au, aw) = arc_endpoints(g, a);
                        arc_label[twin(g, au, aw)] = comp;
                        if arc == stop_arc {
                            break;
                        }
                    }
                    // u separates this block (unless u is the root:
                    // handled via child count on retreat).
                    let u_frame = stack.last().unwrap();
                    if u_frame.parent != u_frame.v {
                        articulation[u as usize] = true;
                    }
                }
            }
        }
    }

    BccResult {
        arc_label,
        n_bcc: n_bcc as usize,
        articulation,
        aux_bytes: 0,
    }
}

/// (source, target) of a CSR arc index.
fn arc_endpoints(g: &Graph, arc: usize) -> (V, V) {
    // binary search the offsets for the source vertex
    let u = match g.offsets().binary_search(&(arc as u64)) {
        Ok(mut i) => {
            // offsets may repeat for degree-0 vertices: take the last
            // vertex whose slice starts here
            while i + 1 < g.offsets().len() && g.offsets()[i + 1] == arc as u64 {
                i += 1;
            }
            i
        }
        Err(i) => i - 1,
    };
    (u as V, g.targets()[arc])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn blocks(g: &Graph) -> BccResult {
        hopcroft_tarjan(g)
    }

    #[test]
    fn triangle_is_one_block_no_articulation() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (2, 0)], true).symmetrize();
        let r = blocks(&g);
        assert_eq!(r.n_bcc, 1);
        assert!(r.articulation.iter().all(|&a| !a));
        assert!(r.arc_label.iter().all(|&l| l == 0));
    }

    #[test]
    fn path_every_edge_own_block_inner_vertices_articulate() {
        let g = gen::path(5).symmetrize();
        let r = blocks(&g);
        assert_eq!(r.n_bcc, 4);
        assert_eq!(r.articulation, vec![false, true, true, true, false]);
    }

    #[test]
    fn two_triangles_sharing_a_vertex() {
        // 0-1-2-0 and 2-3-4-2; vertex 2 articulates.
        let g = Graph::from_edges(
            5,
            &[(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)],
            true,
        )
        .symmetrize();
        let r = blocks(&g);
        assert_eq!(r.n_bcc, 2);
        assert_eq!(r.articulation, vec![false, false, true, false, false]);
    }

    #[test]
    fn bubbles_one_block_per_bubble() {
        let nb = 7;
        let g = gen::bubbles(nb, 5, 1);
        let r = blocks(&g);
        // each bubble is a cycle (+ maybe a chord): one block each
        assert_eq!(r.n_bcc, nb);
    }

    #[test]
    fn star_center_articulates() {
        let g = gen::star(6).symmetrize();
        let r = blocks(&g);
        assert_eq!(r.n_bcc, 5);
        assert!(r.articulation[0]);
        assert!(!r.articulation[1]);
    }

    #[test]
    fn twin_arcs_share_labels() {
        let g = gen::road(6, 9, 2).symmetrize();
        let r = blocks(&g);
        for u in 0..g.n() as V {
            let base = g.offsets()[u as usize] as usize;
            for (i, &w) in g.neighbors(u).iter().enumerate() {
                if w == u {
                    continue;
                }
                let tw = twin(&g, u, w);
                assert_eq!(r.arc_label[base + i], r.arc_label[tw]);
            }
        }
    }

    use crate::graph::Graph;
}
