//! PASGAL's SCC [24]: the same multi-pivot decomposition as
//! [`super::bgss`], but every reachability search runs the VGC engine
//! (τ-budget local searches over hash bags). Reachability does not
//! need BFS order, so the relaxed visit order costs nothing and buys
//! back all the round-synchronization overhead — the paper's §2.1.

use super::decomp::{decompose, decompose_ws, decompose_ws_cancel, Engine};
use crate::algo::cancel::Cancel;
use crate::algo::workspace::SccWorkspace;
use crate::graph::Graph;
use crate::sim::trace::Recorder;

/// Per-vertex SCC labels with VGC budget `tau`.
pub fn vgc_scc(g: &Graph, gt: Option<&Graph>, tau: usize, seed: u64, rec: Recorder) -> Vec<u32> {
    decompose(g, gt, Engine::Vgc(tau), seed, rec)
}

/// [`vgc_scc`] out of a reusable workspace: labels are left in
/// `ws.labels`, and a warm workspace performs zero O(n) allocation —
/// including across the many reachability sub-queries one
/// decomposition issues.
pub fn vgc_scc_ws(
    g: &Graph,
    gt: Option<&Graph>,
    tau: usize,
    seed: u64,
    rec: Recorder,
    ws: &mut SccWorkspace,
) {
    decompose_ws(g, gt, Engine::Vgc(tau), seed, rec, ws)
}

/// [`vgc_scc_ws`] with a cooperative-cancellation token threaded into
/// the trim peel, pivot loop and reachability sub-queries: an expired
/// or condemned query abandons the decomposition within one round,
/// leaving partial labels the serving layer must not summarize.
pub fn vgc_scc_ws_cancel(
    g: &Graph,
    gt: Option<&Graph>,
    tau: usize,
    seed: u64,
    rec: Recorder,
    ws: &mut SccWorkspace,
    cancel: Cancel<'_>,
) {
    decompose_ws_cancel(g, gt, Engine::Vgc(tau), seed, rec, ws, cancel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::scc::{canonicalize, tarjan_scc};
    use crate::graph::gen;

    #[test]
    fn matches_tarjan_across_tau() {
        let g = gen::web(9, 7, 13);
        let want = canonicalize(&tarjan_scc(&g));
        for tau in [1usize, 16, 512, 1 << 20] {
            let got = canonicalize(&vgc_scc(&g, None, tau, 5, None));
            assert_eq!(got, want, "tau={tau}");
        }
    }

    #[test]
    fn fewer_rounds_than_bgss_on_large_diameter() {
        // Two long cycles bridged one-way: large-diameter SCC work.
        let n = 4000u32;
        let mut edges: Vec<(u32, u32)> = (0..n / 2).map(|i| (i, (i + 1) % (n / 2))).collect();
        edges.extend((n / 2..n).map(|i| (i, n / 2 + (i + 1 - n / 2) % (n / 2))));
        edges.push((0, n / 2));
        let g = crate::graph::Graph::from_edges(n as usize, &edges, true);

        let mut t_vgc = crate::sim::AlgoTrace::new();
        let _ = vgc_scc(&g, None, 256, 3, Some(&mut t_vgc));
        let mut t_bgss = crate::sim::AlgoTrace::new();
        let _ = super::super::bgss_scc(&g, None, 3, Some(&mut t_bgss));
        assert!(
            t_vgc.num_rounds() * 8 < t_bgss.num_rounds(),
            "VGC rounds {} should be far fewer than BGSS rounds {}",
            t_vgc.num_rounds(),
            t_bgss.num_rounds()
        );
    }
}
