//! Shared SCC decomposition skeleton (trim + batched multi-pivot
//! forward/backward reachability), parameterized by the reachability
//! engine. `bgss_scc` plugs in the round-synchronous engine,
//! `vgc_scc` the VGC engine — so the measured difference between them
//! is exactly the paper's contribution.

use super::reach::{bfs_multi_reach, vgc_multi_reach, ReachCtx, UNSET};
use crate::graph::Graph;
use crate::parallel::parallel_for;
use crate::prop::Rng;
use crate::sim::trace::Recorder;
use crate::V;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

/// Which reachability engine drives the decomposition.
#[derive(Debug, Clone, Copy)]
pub enum Engine {
    /// Round-synchronous BFS-order frontier (GBBS-style).
    Rounds,
    /// VGC local searches with budget τ (PASGAL).
    Vgc(usize),
}

/// Largest pivot batch (bits in the reachability mask).
const MAX_BATCH: usize = 64;

/// splitmix-style label mixer for subproblem refinement.
#[inline]
fn mix(a: u64, b: u64, c: u64) -> u64 {
    let mut z = a ^ b.rotate_left(23) ^ c.rotate_left(47);
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// How far trimming goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrimMode {
    /// One peel round (what PASGAL [24] and GBBS-style SCC do).
    Once,
    /// Worklist to fixpoint (Multistep's signature phase — the
    /// iterated peel is itself O(D) rounds on chain-shaped fringes).
    Fixpoint,
}

/// Peel trivial SCCs: vertices with zero active in- or out-degree
/// cannot be in a nontrivial SCC, so they are their own (singleton)
/// components. Returns #peeled.
pub fn trim(
    g: &Graph,
    gt: &Graph,
    scc: &[AtomicU32],
    mode: TrimMode,
    mut rec: Recorder,
) -> usize {
    let n = g.n();
    let peeled = AtomicUsize::new(0);
    // Active out/in degrees.
    let out_deg: Vec<AtomicU32> = (0..n as u32).map(|v| AtomicU32::new(g.degree(v) as u32)).collect();
    let in_deg: Vec<AtomicU32> = (0..n as u32)
        .map(|v| AtomicU32::new(gt.degree(v) as u32))
        .collect();
    // Self-loops keep a vertex alive as its own cycle only if the
    // loop exists; standard trim treats self-loop as non-trivial.
    // We count self-loops out of the degrees.
    parallel_for(0, n, 1024, |v| {
        let selfs = g.neighbors(v as V).iter().filter(|&&w| w == v as V).count() as u32;
        if selfs > 0 {
            out_deg[v].fetch_sub(selfs, Ordering::Relaxed);
            in_deg[v].fetch_sub(selfs, Ordering::Relaxed);
        }
    });

    let mut frontier: Vec<V> = crate::parallel::pack_index(n, |v| {
        out_deg[v].load(Ordering::Relaxed) == 0 || in_deg[v].load(Ordering::Relaxed) == 0
    });
    // Claim initial frontier.
    frontier.retain(|&v| {
        scc[v as usize]
            .compare_exchange(UNSET, v, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    });
    while !frontier.is_empty() {
        peeled.fetch_add(frontier.len(), Ordering::Relaxed);
        let bag = crate::hashbag::HashBag::new(n);
        {
            let frontier_ref = &frontier;
            let bag_ref = &bag;
            let out_ref = &out_deg;
            let in_ref = &in_deg;
            parallel_for(0, frontier_ref.len(), 64, move |i| {
                let v = frontier_ref[i];
                // v leaves: decrement in-degree of out-neighbors and
                // out-degree of in-neighbors; newly-zero ones peel.
                for &w in g.neighbors(v) {
                    if w == v || scc[w as usize].load(Ordering::Relaxed) != UNSET {
                        continue;
                    }
                    if in_ref[w as usize].fetch_sub(1, Ordering::Relaxed) == 1
                        && scc[w as usize]
                            .compare_exchange(UNSET, w, Ordering::AcqRel, Ordering::Relaxed)
                            .is_ok()
                    {
                        bag_ref.insert(w);
                    }
                }
                for &w in gt.neighbors(v) {
                    if w == v || scc[w as usize].load(Ordering::Relaxed) != UNSET {
                        continue;
                    }
                    if out_ref[w as usize].fetch_sub(1, Ordering::Relaxed) == 1
                        && scc[w as usize]
                            .compare_exchange(UNSET, w, Ordering::AcqRel, Ordering::Relaxed)
                            .is_ok()
                    {
                        bag_ref.insert(w);
                    }
                }
            });
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(
                frontier
                    .iter()
                    .map(|&v| crate::sim::trace::TaskCost {
                        vertices: 1,
                        edges: (g.degree(v) + gt.degree(v)) as u64,
                    })
                    .collect(),
            );
        }
        frontier = match mode {
            TrimMode::Once => Vec::new(),
            TrimMode::Fixpoint => bag.extract_and_clear(),
        };
    }
    peeled.load(Ordering::Relaxed)
}

/// Full decomposition. Returns per-vertex SCC labels (member vertex).
pub fn decompose(
    g: &Graph,
    gt: Option<&Graph>,
    engine: Engine,
    seed: u64,
    mut rec: Recorder,
) -> Vec<u32> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let gt_owned;
    let gt = match gt {
        Some(t) => t,
        None => {
            gt_owned = g.transpose();
            &gt_owned
        }
    };
    let scc: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNSET)).collect();
    let mut sub: Vec<u64> = vec![0; n];

    trim(g, gt, &scc, TrimMode::Once, rec.as_deref_mut());

    // Random pivot order.
    let mut perm: Vec<V> = (0..n as V).collect();
    Rng::new(seed).shuffle(&mut perm);
    let mut cursor = 0usize;
    let mut batch = 1usize;

    while cursor < n {
        // Next `batch` active pivots in permutation order.
        let mut pivots: Vec<V> = Vec::with_capacity(batch);
        while cursor < n && pivots.len() < batch {
            let v = perm[cursor];
            cursor += 1;
            if scc[v as usize].load(Ordering::Relaxed) == UNSET {
                pivots.push(v);
            }
        }
        if pivots.is_empty() {
            break;
        }
        let ctx = ReachCtx {
            scc: &scc,
            sub: &sub,
        };
        let (fwd, bwd) = match engine {
            Engine::Rounds => (
                bfs_multi_reach(g, &pivots, &ctx, rec.as_deref_mut()),
                bfs_multi_reach(gt, &pivots, &ctx, rec.as_deref_mut()),
            ),
            Engine::Vgc(tau) => (
                vgc_multi_reach(g, &pivots, &ctx, tau, rec.as_deref_mut()),
                vgc_multi_reach(gt, &pivots, &ctx, tau, rec.as_deref_mut()),
            ),
        };
        // Assign SCCs / refine subproblems.
        {
            let sub_at = crate::parallel::atomic::as_atomic_u64(&mut sub);
            let pivots_ref = &pivots;
            let scc_ref = &scc;
            let fwd_ref = &fwd;
            let bwd_ref = &bwd;
            parallel_for(0, n, 2048, move |v| {
                if scc_ref[v].load(Ordering::Relaxed) != UNSET {
                    return;
                }
                let (f, b) = (fwd_ref[v], bwd_ref[v]);
                let common = f & b;
                if common != 0 {
                    let pivot = pivots_ref[common.trailing_zeros() as usize];
                    scc_ref[v].store(pivot, Ordering::Relaxed);
                } else if f != 0 || b != 0 {
                    let old = sub_at[v].load(Ordering::Relaxed);
                    sub_at[v].store(mix(old, f, b), Ordering::Relaxed);
                }
            });
        }
        // Partition-refinement shortcut: an active vertex alone in its
        // subproblem can share an SCC with no other active vertex, so
        // it is a singleton SCC. This keeps the 64-bit-mask batching
        // efficient on DAG-like regions (unique (f,b) signatures
        // separate fast), playing the role of BGSS's unbounded prefix
        // doubling.
        {
            let mut sub_count: std::collections::HashMap<u64, u32> =
                std::collections::HashMap::new();
            for v in 0..n {
                if scc[v].load(Ordering::Relaxed) == UNSET {
                    *sub_count.entry(sub[v]).or_insert(0) += 1;
                }
            }
            let sub_ref = &sub;
            let sub_count_ref = &sub_count;
            let scc_ref = &scc;
            parallel_for(0, n, 2048, move |v| {
                if scc_ref[v].load(Ordering::Relaxed) == UNSET
                    && sub_count_ref[&sub_ref[v]] == 1
                {
                    scc_ref[v].store(v as u32, Ordering::Relaxed);
                }
            });
        }
        batch = (batch * 4).min(MAX_BATCH);
    }
    // Safety net: any vertex still unassigned (shouldn't happen since
    // every vertex appears in the permutation) becomes a singleton.
    scc.into_iter()
        .enumerate()
        .map(|(v, a)| {
            let x = a.into_inner();
            if x == UNSET {
                v as u32
            } else {
                x
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn trim_peels_dag_completely() {
        let g = gen::grid(5, 6);
        let gt = g.transpose();
        let scc: Vec<AtomicU32> = (0..g.n()).map(|_| AtomicU32::new(UNSET)).collect();
        let peeled = trim(&g, &gt, &scc, TrimMode::Fixpoint, None);
        assert_eq!(peeled, g.n(), "a DAG trims away entirely");
        for (v, s) in scc.iter().enumerate() {
            assert_eq!(s.load(Ordering::Relaxed), v as u32);
        }
    }

    #[test]
    fn trim_leaves_cycle_alone() {
        let g = gen::cycle(10);
        let gt = g.transpose();
        let scc: Vec<AtomicU32> = (0..10).map(|_| AtomicU32::new(UNSET)).collect();
        let peeled = trim(&g, &gt, &scc, TrimMode::Fixpoint, None);
        assert_eq!(peeled, 0);
    }

    #[test]
    fn trim_peels_tail_into_cycle() {
        // cycle 0..5 plus tail 5->6->7
        let mut edges: Vec<(V, V)> = (0..5).map(|i| (i, (i + 1) % 5)).collect();
        edges.push((0, 5));
        edges.push((5, 6));
        edges.push((6, 7));
        let g = Graph::from_edges(8, &edges, true);
        let gt = g.transpose();
        let scc: Vec<AtomicU32> = (0..8).map(|_| AtomicU32::new(UNSET)).collect();
        let peeled = trim(&g, &gt, &scc, TrimMode::Fixpoint, None);
        assert_eq!(peeled, 3, "tail 5,6,7 peels; cycle stays");
    }

    use crate::graph::Graph;

    #[test]
    fn decompose_cycle_single_scc() {
        let g = gen::cycle(64);
        let labels = decompose(&g, None, Engine::Rounds, 1, None);
        assert!(labels.iter().all(|&l| l == labels[0]));
        let labels = decompose(&g, None, Engine::Vgc(8), 2, None);
        assert!(labels.iter().all(|&l| l == labels[0]));
    }
}
