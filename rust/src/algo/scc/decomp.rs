//! Shared SCC decomposition skeleton (trim + batched multi-pivot
//! forward/backward reachability), parameterized by the reachability
//! engine. `bgss_scc` plugs in the round-synchronous engine,
//! `vgc_scc` the VGC engine — so the measured difference between them
//! is exactly the paper's contribution.
//!
//! [`decompose_ws`] runs the whole decomposition out of a reusable
//! [`SccWorkspace`]: labels, subproblem ids, trim degrees, the pivot
//! permutation and — the hot part — the per-batch reachability masks
//! are all reused, so repeated SCC queries on a warm workspace perform
//! zero O(n) allocation, and the many reachability sub-queries *within*
//! one decomposition stopped reallocating masks entirely.

use super::reach::{bfs_multi_reach_ws, vgc_multi_reach_ws, ReachCtx, UNSET};
use crate::algo::cancel::{cancelled, Cancel};
use crate::algo::workspace::SccWorkspace;
use crate::graph::Graph;
use crate::hashbag::HashBag;
use crate::parallel::atomic::as_atomic_u32;
use crate::parallel::{pack_index_into, parallel_for};
use crate::prop::Rng;
use crate::sim::trace::Recorder;
use crate::V;
use std::sync::atomic::{AtomicU32, Ordering};

/// Which reachability engine drives the decomposition.
#[derive(Debug, Clone, Copy)]
pub enum Engine {
    /// Round-synchronous BFS-order frontier (GBBS-style).
    Rounds,
    /// VGC local searches with budget τ (PASGAL).
    Vgc(usize),
}

/// Largest pivot batch (bits in the reachability mask).
const MAX_BATCH: usize = 64;

/// splitmix-style label mixer for subproblem refinement.
#[inline]
fn mix(a: u64, b: u64, c: u64) -> u64 {
    let mut z = a ^ b.rotate_left(23) ^ c.rotate_left(47);
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// How far trimming goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrimMode {
    /// One peel round (what PASGAL [24] and GBBS-style SCC do).
    Once,
    /// Worklist to fixpoint (Multistep's signature phase — the
    /// iterated peel is itself O(D) rounds on chain-shaped fringes).
    Fixpoint,
}

/// Peel trivial SCCs (allocate-per-call wrapper around [`trim_ws`]).
pub fn trim(g: &Graph, gt: &Graph, scc: &[AtomicU32], mode: TrimMode, rec: Recorder) -> usize {
    let mut deg_out = Vec::new();
    let mut deg_in = Vec::new();
    let mut bag = HashBag::default();
    let mut frontier = Vec::new();
    trim_ws(
        g,
        gt,
        scc,
        mode,
        rec,
        &mut deg_out,
        &mut deg_in,
        &mut bag,
        &mut frontier,
        None,
    )
}

/// Peel trivial SCCs using caller-owned scratch: vertices with zero
/// active in- or out-degree cannot be in a nontrivial SCC, so they are
/// their own (singleton) components. Returns #peeled.
///
/// `cancel` is polled once per peel round (never per edge): an expired
/// or condemned query abandons the peel within one round, leaving a
/// partial assignment the caller must not summarize.
#[allow(clippy::too_many_arguments)]
pub fn trim_ws(
    g: &Graph,
    gt: &Graph,
    scc: &[AtomicU32],
    mode: TrimMode,
    mut rec: Recorder,
    deg_out: &mut Vec<u32>,
    deg_in: &mut Vec<u32>,
    bag: &mut HashBag,
    frontier: &mut Vec<V>,
    cancel: Cancel<'_>,
) -> usize {
    let n = g.n();
    let mut peeled = 0usize;
    bag.reset(n);
    // Active out/in degrees (O(n) writes into reused storage).
    deg_out.clear();
    deg_out.resize(n, 0);
    deg_in.clear();
    deg_in.resize(n, 0);
    let out_deg = as_atomic_u32(deg_out);
    let in_deg = as_atomic_u32(deg_in);
    // Self-loops keep a vertex alive as its own cycle only if the
    // loop exists; standard trim treats self-loop as non-trivial.
    // We count self-loops out of the degrees.
    parallel_for(0, n, 1024, |v| {
        let selfs = g.neighbors(v as V).iter().filter(|&&w| w == v as V).count() as u32;
        out_deg[v].store(g.degree(v as V) as u32 - selfs, Ordering::Relaxed);
        in_deg[v].store(gt.degree(v as V) as u32 - selfs, Ordering::Relaxed);
    });

    pack_index_into(
        n,
        |v| out_deg[v].load(Ordering::Relaxed) == 0 || in_deg[v].load(Ordering::Relaxed) == 0,
        frontier,
    );
    // Claim initial frontier.
    frontier.retain(|&v| {
        scc[v as usize]
            .compare_exchange(UNSET, v, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    });
    while !frontier.is_empty() {
        if cancelled(cancel) {
            break;
        }
        peeled += frontier.len();
        {
            let frontier_ref = &*frontier;
            let bag_ref = &*bag;
            parallel_for(0, frontier_ref.len(), 64, move |i| {
                let v = frontier_ref[i];
                // v leaves: decrement in-degree of out-neighbors and
                // out-degree of in-neighbors; newly-zero ones peel.
                for &w in g.neighbors(v) {
                    if w == v || scc[w as usize].load(Ordering::Relaxed) != UNSET {
                        continue;
                    }
                    if in_deg[w as usize].fetch_sub(1, Ordering::Relaxed) == 1
                        && scc[w as usize]
                            .compare_exchange(UNSET, w, Ordering::AcqRel, Ordering::Relaxed)
                            .is_ok()
                    {
                        bag_ref.insert(w);
                    }
                }
                for &w in gt.neighbors(v) {
                    if w == v || scc[w as usize].load(Ordering::Relaxed) != UNSET {
                        continue;
                    }
                    if out_deg[w as usize].fetch_sub(1, Ordering::Relaxed) == 1
                        && scc[w as usize]
                            .compare_exchange(UNSET, w, Ordering::AcqRel, Ordering::Relaxed)
                            .is_ok()
                    {
                        bag_ref.insert(w);
                    }
                }
            });
        }
        if let Some(trace) = rec.as_deref_mut() {
            trace.push_round(
                frontier
                    .iter()
                    .map(|&v| crate::sim::trace::TaskCost {
                        vertices: 1,
                        edges: (g.degree(v) + gt.degree(v)) as u64,
                    })
                    .collect(),
            );
        }
        match mode {
            TrimMode::Once => frontier.clear(),
            TrimMode::Fixpoint => bag.extract_into(frontier),
        }
    }
    peeled
}

/// Full decomposition (allocate-per-call wrapper around
/// [`decompose_ws`]). Returns per-vertex SCC labels (member vertex).
pub fn decompose(
    g: &Graph,
    gt: Option<&Graph>,
    engine: Engine,
    seed: u64,
    rec: Recorder,
) -> Vec<u32> {
    let mut ws = SccWorkspace::new();
    decompose_ws(g, gt, engine, seed, rec, &mut ws);
    std::mem::take(&mut ws.labels)
}

/// Full decomposition out of a reusable workspace. Per-vertex SCC
/// labels (member vertex) are left in `ws.labels`; a warm workspace
/// performs zero O(n) allocation, including across the many
/// reachability sub-queries.
pub fn decompose_ws(
    g: &Graph,
    gt: Option<&Graph>,
    engine: Engine,
    seed: u64,
    rec: Recorder,
    ws: &mut SccWorkspace,
) {
    decompose_ws_cancel(g, gt, engine, seed, rec, ws, None);
}

/// [`decompose_ws`] with a cooperative-cancellation token, threaded
/// into the trim peel, the pivot loop and every reachability
/// sub-query: an expired or condemned query abandons the decomposition
/// within one round, leaving partial labels the serving layer must not
/// summarize. Cancellation always breaks (never returns) so the
/// workspace restores at the end still run.
pub fn decompose_ws_cancel(
    g: &Graph,
    gt: Option<&Graph>,
    engine: Engine,
    seed: u64,
    mut rec: Recorder,
    ws: &mut SccWorkspace,
    cancel: Cancel<'_>,
) {
    let n = g.n();
    let mut labels = std::mem::take(&mut ws.labels);
    labels.clear();
    labels.resize(n, UNSET);
    let mut sub = std::mem::take(&mut ws.sub);
    sub.clear();
    sub.resize(n, 0);
    if n == 0 {
        ws.labels = labels;
        ws.sub = sub;
        return;
    }
    let gt_owned;
    let gt = match gt {
        Some(t) => t,
        None => {
            gt_owned = g.transpose();
            &gt_owned
        }
    };
    {
        let scc: &[AtomicU32] = as_atomic_u32(&mut labels);

        trim_ws(
            g,
            gt,
            scc,
            TrimMode::Once,
            rec.as_deref_mut(),
            &mut ws.deg_out,
            &mut ws.deg_in,
            &mut ws.bag,
            &mut ws.frontier,
            cancel,
        );

        // Random pivot order.
        let mut perm = std::mem::take(&mut ws.perm);
        perm.clear();
        perm.extend(0..n as V);
        Rng::new(seed).shuffle(&mut perm);
        let mut cursor = 0usize;
        let mut batch = 1usize;

        while cursor < n {
            // Cancellation point, once per pivot batch: break (never
            // return) so the perm/label restores below still run.
            if cancelled(cancel) {
                break;
            }
            // Next `batch` active pivots in permutation order.
            let mut pivots: Vec<V> = Vec::with_capacity(batch);
            while cursor < n && pivots.len() < batch {
                let v = perm[cursor];
                cursor += 1;
                if scc[v as usize].load(Ordering::Relaxed) == UNSET {
                    pivots.push(v);
                }
            }
            if pivots.is_empty() {
                break;
            }
            let ctx = ReachCtx {
                scc,
                sub: &sub,
            };
            match engine {
                Engine::Rounds => {
                    bfs_multi_reach_ws(
                        g,
                        &pivots,
                        &ctx,
                        rec.as_deref_mut(),
                        &mut ws.fwd,
                        &mut ws.pending,
                        &mut ws.bag,
                        &mut ws.frontier,
                        cancel,
                    );
                    bfs_multi_reach_ws(
                        gt,
                        &pivots,
                        &ctx,
                        rec.as_deref_mut(),
                        &mut ws.bwd,
                        &mut ws.pending,
                        &mut ws.bag,
                        &mut ws.frontier,
                        cancel,
                    );
                }
                Engine::Vgc(tau) => {
                    vgc_multi_reach_ws(
                        g,
                        &pivots,
                        &ctx,
                        tau,
                        rec.as_deref_mut(),
                        &mut ws.fwd,
                        &mut ws.pending,
                        &mut ws.bag,
                        &mut ws.frontier,
                        cancel,
                    );
                    vgc_multi_reach_ws(
                        gt,
                        &pivots,
                        &ctx,
                        tau,
                        rec.as_deref_mut(),
                        &mut ws.bwd,
                        &mut ws.pending,
                        &mut ws.bag,
                        &mut ws.frontier,
                        cancel,
                    );
                }
            }
            // Assign SCCs / refine subproblems.
            {
                let sub_at = crate::parallel::atomic::as_atomic_u64(&mut sub);
                let pivots_ref = &pivots;
                let fwd_ref = &ws.fwd;
                let bwd_ref = &ws.bwd;
                parallel_for(0, n, 2048, move |v| {
                    if scc[v].load(Ordering::Relaxed) != UNSET {
                        return;
                    }
                    let (f, b) = (fwd_ref.get(v), bwd_ref.get(v));
                    let common = f & b;
                    if common != 0 {
                        let pivot = pivots_ref[common.trailing_zeros() as usize];
                        scc[v].store(pivot, Ordering::Relaxed);
                    } else if f != 0 || b != 0 {
                        let old = sub_at[v].load(Ordering::Relaxed);
                        sub_at[v].store(mix(old, f, b), Ordering::Relaxed);
                    }
                });
            }
            // Partition-refinement shortcut: an active vertex alone in
            // its subproblem can share an SCC with no other active
            // vertex, so it is a singleton SCC. This keeps the
            // 64-bit-mask batching efficient on DAG-like regions
            // (unique (f,b) signatures separate fast), playing the role
            // of BGSS's unbounded prefix doubling.
            {
                let sub_count = &mut ws.sub_count;
                sub_count.clear();
                for v in 0..n {
                    if scc[v].load(Ordering::Relaxed) == UNSET {
                        *sub_count.entry(sub[v]).or_insert(0) += 1;
                    }
                }
                let sub_ref = &sub;
                let sub_count_ref = &*sub_count;
                parallel_for(0, n, 2048, move |v| {
                    if scc[v].load(Ordering::Relaxed) == UNSET && sub_count_ref[&sub_ref[v]] == 1
                    {
                        scc[v].store(v as u32, Ordering::Relaxed);
                    }
                });
            }
            batch = (batch * 4).min(MAX_BATCH);
        }
        ws.perm = perm;
    }
    // Safety net: any vertex still unassigned (shouldn't happen since
    // every vertex appears in the permutation) becomes a singleton.
    for (v, l) in labels.iter_mut().enumerate() {
        if *l == UNSET {
            *l = v as u32;
        }
    }
    ws.labels = labels;
    ws.sub = sub;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn trim_peels_dag_completely() {
        let g = gen::grid(5, 6);
        let gt = g.transpose();
        let scc: Vec<AtomicU32> = (0..g.n()).map(|_| AtomicU32::new(UNSET)).collect();
        let peeled = trim(&g, &gt, &scc, TrimMode::Fixpoint, None);
        assert_eq!(peeled, g.n(), "a DAG trims away entirely");
        for (v, s) in scc.iter().enumerate() {
            assert_eq!(s.load(Ordering::Relaxed), v as u32);
        }
    }

    #[test]
    fn trim_leaves_cycle_alone() {
        let g = gen::cycle(10);
        let gt = g.transpose();
        let scc: Vec<AtomicU32> = (0..10).map(|_| AtomicU32::new(UNSET)).collect();
        let peeled = trim(&g, &gt, &scc, TrimMode::Fixpoint, None);
        assert_eq!(peeled, 0);
    }

    #[test]
    fn trim_peels_tail_into_cycle() {
        // cycle 0..5 plus tail 5->6->7
        let mut edges: Vec<(V, V)> = (0..5).map(|i| (i, (i + 1) % 5)).collect();
        edges.push((0, 5));
        edges.push((5, 6));
        edges.push((6, 7));
        let g = Graph::from_edges(8, &edges, true);
        let gt = g.transpose();
        let scc: Vec<AtomicU32> = (0..8).map(|_| AtomicU32::new(UNSET)).collect();
        let peeled = trim(&g, &gt, &scc, TrimMode::Fixpoint, None);
        assert_eq!(peeled, 3, "tail 5,6,7 peels; cycle stays");
    }

    use crate::graph::Graph;

    #[test]
    fn decompose_cycle_single_scc() {
        let g = gen::cycle(64);
        let labels = decompose(&g, None, Engine::Rounds, 1, None);
        assert!(labels.iter().all(|&l| l == labels[0]));
        let labels = decompose(&g, None, Engine::Vgc(8), 2, None);
        assert!(labels.iter().all(|&l| l == labels[0]));
    }

    #[test]
    fn warm_workspace_decompose_matches_fresh() {
        let g = gen::web(9, 7, 4);
        let mut ws = SccWorkspace::new();
        for seed in [1u64, 2, 3] {
            decompose_ws(&g, None, Engine::Vgc(32), seed, None, &mut ws);
            let fresh = decompose(&g, None, Engine::Vgc(32), seed, None);
            assert_eq!(ws.labels(), &fresh[..], "seed {seed}");
        }
    }
}
