//! Multi-source reachability — the inner engine of parallel SCC.
//!
//! The engines moved to [`crate::algo::multi::reach`] when batching
//! became a first-class query path: the mask-frontier worklist loop
//! they pioneered (64-bit source masks, pending-flag dedup, deferred
//! bag) now also drives batched multi-source BFS and SSSP, so it lives
//! in [`crate::algo::multi`] as shared machinery
//! ([`crate::algo::multi::mask::MaskFrontier`]). This module
//! re-exports everything so SCC-side call sites and downstream users
//! keep their paths.

pub use crate::algo::multi::reach::{
    bfs_multi_reach, bfs_multi_reach_ws, vgc_multi_reach, vgc_multi_reach_ws, ReachCtx, UNSET,
};
