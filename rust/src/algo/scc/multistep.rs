//! Multistep SCC (Slota, Rajamanickam, Madduri — IPDPS'14 [20]).
//!
//! Phases: (1) trim trivial SCCs; (2) one forward/backward BFS from a
//! high-degree pivot extracts the giant SCC; (3) the remainder is
//! decomposed by *coloring*: propagate the maximum vertex id forward
//! to a fixpoint, then a backward search from each color root within
//! its color class yields one SCC per root. All phases are
//! round-synchronous — the large-diameter weakness Fig. 1 shows.

use super::decomp::{trim, TrimMode};
use super::reach::{bfs_multi_reach, ReachCtx, UNSET};
use crate::graph::Graph;
use crate::hashbag::HashBag;
use crate::parallel::{pack_index, parallel_for};
use crate::sim::trace::{Recorder, TaskCost};
use crate::V;
use std::sync::atomic::{AtomicU32, Ordering};

/// Per-vertex SCC labels.
pub fn multistep_scc(g: &Graph, gt: Option<&Graph>, mut rec: Recorder) -> Vec<u32> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let gt_owned;
    let gt = match gt {
        Some(t) => t,
        None => {
            gt_owned = g.transpose();
            &gt_owned
        }
    };
    let scc: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNSET)).collect();
    let sub: Vec<u64> = vec![0; n];

    // Phase 1: trim.
    trim(g, gt, &scc, TrimMode::Fixpoint, rec.as_deref_mut());

    // Phase 2: FW-BW from the max-degree-product active pivot.
    let pivot = (0..n as V)
        .filter(|&v| scc[v as usize].load(Ordering::Relaxed) == UNSET)
        .max_by_key(|&v| (g.degree(v) as u64 + 1) * (gt.degree(v) as u64 + 1));
    if let Some(p) = pivot {
        let ctx = ReachCtx {
            scc: &scc,
            sub: &sub,
        };
        let fwd = bfs_multi_reach(g, &[p], &ctx, rec.as_deref_mut());
        let bwd = bfs_multi_reach(gt, &[p], &ctx, rec.as_deref_mut());
        parallel_for(0, n, 2048, |v| {
            if fwd[v] & bwd[v] != 0 {
                scc[v as usize].store(p, Ordering::Relaxed);
            }
        });
    }

    // Phase 3: coloring rounds on the remainder.
    // color[v] starts as v; forward edges propagate the max; roots
    // (color[v] == v) then collect their SCC by backward search
    // restricted to their color class.
    let color: Vec<AtomicU32> = (0..n as u32).map(AtomicU32::new).collect();
    loop {
        let active: Vec<V> = pack_index(n, |v| scc[v].load(Ordering::Relaxed) == UNSET);
        if active.is_empty() {
            break;
        }
        // Reset colors of active vertices.
        parallel_for(0, active.len(), 2048, |i| {
            let v = active[i];
            color[v as usize].store(v, Ordering::Relaxed);
        });
        // Propagate max color forward to fixpoint (worklist rounds).
        // We propagate *negated-min* via write_min on !color so one
        // atomic primitive serves: max(color) == min(!color).
        let mut frontier = active.clone();
        let pending: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        while !frontier.is_empty() {
            let bag = HashBag::new(n);
            {
                let frontier_ref = &frontier;
                let color_ref = &color;
                let pending_ref = &pending;
                let bag_ref = &bag;
                let scc_ref = &scc;
                parallel_for(0, frontier_ref.len(), 64, move |i| {
                    let v = frontier_ref[i];
                    pending_ref[v as usize].store(0, Ordering::Relaxed);
                    let cv = color_ref[v as usize].load(Ordering::Relaxed);
                    for &w in g.neighbors(v) {
                        if scc_ref[w as usize].load(Ordering::Relaxed) != UNSET {
                            continue;
                        }
                        // color[w] = max(color[w], cv) (write-max CAS).
                        let slot = &color_ref[w as usize];
                        let mut cur = slot.load(Ordering::Relaxed);
                        let mut improved = false;
                        while cv > cur {
                            match slot.compare_exchange_weak(
                                cur,
                                cv,
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                            ) {
                                Ok(_) => {
                                    improved = true;
                                    break;
                                }
                                Err(seen) => cur = seen,
                            }
                        }
                        if improved
                            && pending_ref[w as usize].swap(1, Ordering::Relaxed) == 0
                        {
                            bag_ref.insert(w);
                        }
                    }
                });
            }
            if let Some(trace) = rec.as_deref_mut() {
                trace.push_round(
                    frontier
                        .iter()
                        .map(|&v| TaskCost {
                            vertices: 1,
                            edges: g.degree(v) as u64,
                        })
                        .collect(),
                );
            }
            frontier = bag.extract_and_clear();
        }
        // Roots, in batches of 64: backward reach within color class.
        let roots: Vec<V> = active
            .iter()
            .copied()
            .filter(|&v| color[v as usize].load(Ordering::Relaxed) == v)
            .collect();
        debug_assert!(!roots.is_empty());
        for chunk in roots.chunks(64) {
            // Color classes act as subproblem labels for this search.
            let class: Vec<u64> = (0..n)
                .map(|v| color[v].load(Ordering::Relaxed) as u64)
                .collect();
            let ctx = ReachCtx {
                scc: &scc,
                sub: &class,
            };
            let bwd = bfs_multi_reach(gt, chunk, &ctx, rec.as_deref_mut());
            let chunk_ref = chunk;
            parallel_for(0, n, 2048, |v| {
                if scc[v].load(Ordering::Relaxed) == UNSET && bwd[v] != 0 {
                    let root = chunk_ref[bwd[v].trailing_zeros() as usize];
                    // v is in root's class and reaches root => same SCC.
                    scc[v].store(root, Ordering::Relaxed);
                }
            });
        }
    }
    scc.into_iter().map(|a| a.into_inner()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::scc::{canonicalize, tarjan_scc};
    use crate::graph::gen;

    #[test]
    fn cycle_single_scc() {
        let g = gen::cycle(30);
        let got = multistep_scc(&g, None, None);
        assert!(got.iter().all(|&l| l == got[0]));
    }

    #[test]
    fn matches_tarjan_on_web() {
        let g = gen::web(10, 8, 21);
        assert_eq!(
            canonicalize(&multistep_scc(&g, None, None)),
            canonicalize(&tarjan_scc(&g))
        );
    }

    #[test]
    fn matches_tarjan_on_two_cycles_and_bridge() {
        let mut edges: Vec<(V, V)> = (0..8).map(|i| (i, (i + 1) % 8)).collect();
        edges.extend((8..16).map(|i| (i, 8 + (i + 1 - 8) % 8)));
        edges.push((2, 9));
        let g = crate::graph::Graph::from_edges(16, &edges, true);
        assert_eq!(
            canonicalize(&multistep_scc(&g, None, None)),
            canonicalize(&tarjan_scc(&g))
        );
    }
}
