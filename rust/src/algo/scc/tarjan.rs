//! Sequential Tarjan SCC (the paper's sequential baseline, [21]).
//!
//! Iterative formulation with explicit stacks — recursion would blow
//! the thread stack on the large-diameter graphs this library targets
//! (a 10^5-vertex chain is a normal input here).

use crate::graph::Graph;

const UNSET: u32 = u32::MAX;

/// Per-vertex SCC labels; label = the vertex of the class that Tarjan
/// pops as the root (canonicalize before comparing partitions).
pub fn tarjan_scc(g: &Graph) -> Vec<u32> {
    let n = g.n();
    let mut index = vec![UNSET; n]; // discovery order
    let mut low = vec![0u32; n];
    let mut on_stack = vec![false; n];
    let mut scc = vec![UNSET; n];
    let mut stack: Vec<u32> = Vec::new(); // Tarjan's vertex stack
    let mut next_index = 0u32;

    // Explicit DFS call stack: (vertex, next-edge-offset).
    let mut call: Vec<(u32, usize)> = Vec::new();
    for start in 0..n as u32 {
        if index[start as usize] != UNSET {
            continue;
        }
        call.push((start, 0));
        index[start as usize] = next_index;
        low[start as usize] = next_index;
        next_index += 1;
        stack.push(start);
        on_stack[start as usize] = true;

        while let Some(&mut (v, ref mut ei)) = call.last_mut() {
            let nbrs = g.neighbors(v);
            if *ei < nbrs.len() {
                let w = nbrs[*ei];
                *ei += 1;
                if index[w as usize] == UNSET {
                    // Tree edge: descend.
                    index[w as usize] = next_index;
                    low[w as usize] = next_index;
                    next_index += 1;
                    stack.push(w);
                    on_stack[w as usize] = true;
                    call.push((w, 0));
                } else if on_stack[w as usize] {
                    low[v as usize] = low[v as usize].min(index[w as usize]);
                }
            } else {
                // Retreat.
                call.pop();
                if low[v as usize] == index[v as usize] {
                    // v is a root: pop its SCC.
                    loop {
                        let w = stack.pop().expect("tarjan stack underflow");
                        on_stack[w as usize] = false;
                        scc[w as usize] = v;
                        if w == v {
                            break;
                        }
                    }
                }
                if let Some(&(parent, _)) = call.last() {
                    low[parent as usize] = low[parent as usize].min(low[v as usize]);
                }
            }
        }
    }
    scc
}

/// Number of SCCs in a labeling.
pub fn scc_count(labels: &[u32]) -> usize {
    let mut seen = std::collections::HashSet::new();
    for &l in labels {
        seen.insert(l);
    }
    seen.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn cycle_is_one_scc() {
        let g = gen::cycle(100);
        let scc = tarjan_scc(&g);
        assert!(scc.iter().all(|&x| x == scc[0]));
    }

    #[test]
    fn dag_is_all_singletons() {
        let g = gen::grid(6, 8);
        let scc = tarjan_scc(&g);
        assert_eq!(scc_count(&scc), g.n());
    }

    #[test]
    fn textbook_example() {
        // 0→1→2→0 (SCC); 3→4, 4→3 (SCC); 2→3; 5 isolated
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (2, 0), (3, 4), (4, 3), (2, 3)], false);
        let scc = tarjan_scc(&g);
        assert_eq!(scc[0], scc[1]);
        assert_eq!(scc[1], scc[2]);
        assert_eq!(scc[3], scc[4]);
        assert_ne!(scc[0], scc[3]);
        assert_ne!(scc[5], scc[0]);
        assert_eq!(scc_count(&scc), 3);
    }

    use crate::graph::Graph;

    #[test]
    fn deep_chain_no_stack_overflow() {
        // 200k-vertex cycle: recursion would smash the stack.
        let g = gen::cycle(200_000);
        let scc = tarjan_scc(&g);
        assert!(scc.iter().all(|&x| x == scc[0]));
    }

    #[test]
    fn self_loop_is_singleton_scc() {
        let g = Graph::from_edges(3, &[(0, 0), (0, 1), (1, 2)], false);
        let scc = tarjan_scc(&g);
        assert_eq!(scc_count(&scc), 3);
    }
}
