//! Strongly connected components: the Table 4 / Fig. 1 contenders.
//!
//! * [`tarjan::tarjan_scc`] — sequential Tarjan (the baseline, always
//!   speedup 1 in Fig. 1).
//! * [`bgss::bgss_scc`] — GBBS-style randomized multi-pivot SCC
//!   (Blelloch–Gu–Shun–Sun framework): batched forward/backward
//!   *BFS-order* reachability — O(D) synchronized rounds per batch,
//!   the large-diameter weakness.
//! * [`multistep::multistep_scc`] — Slota–Rajamanickam–Madduri
//!   Multistep: trim, one FW-BW for the giant SCC, then coloring.
//! * [`vgc_scc::vgc_scc`] — PASGAL's SCC [24]: identical decomposition
//!   to BGSS but every reachability search uses VGC local searches
//!   over hash bags, collapsing the round count.
//!
//! All outputs are per-vertex SCC labels (label = some canonical
//! member vertex); cross-tests verify the induced *partitions* match
//! Tarjan exactly.

mod decomp;
pub mod bgss;
pub mod multistep;
pub mod reach;
pub mod tarjan;
pub mod vgc_scc;

pub use bgss::{bgss_scc, bgss_scc_ws};
pub use multistep::multistep_scc;
pub use tarjan::tarjan_scc;
pub use vgc_scc::{vgc_scc, vgc_scc_ws, vgc_scc_ws_cancel};

/// Normalize an SCC labeling to the partition's canonical form: every
/// vertex labeled with the *smallest* vertex id in its class. Two
/// labelings are equivalent iff their canonical forms are equal.
pub fn canonicalize(labels: &[u32]) -> Vec<u32> {
    let n = labels.len();
    let mut min_of = std::collections::HashMap::<u32, u32>::new();
    for (v, &l) in labels.iter().enumerate() {
        let e = min_of.entry(l).or_insert(v as u32);
        if (v as u32) < *e {
            *e = v as u32;
        }
    }
    (0..n).map(|v| min_of[&labels[v]]).collect()
}

#[cfg(test)]
mod cross_tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::Graph;
    use crate::prop::{forall, Rng};
    use crate::V;

    fn check_all(g: &Graph) {
        let want = canonicalize(&tarjan_scc(g));
        let gt = g.transpose();
        let b = canonicalize(&bgss_scc(g, Some(&gt), 42, None));
        assert_eq!(b, want, "bgss_scc mismatch");
        let m = canonicalize(&multistep_scc(g, Some(&gt), None));
        assert_eq!(m, want, "multistep_scc mismatch");
        let v = canonicalize(&vgc_scc(g, Some(&gt), 64, 42, None));
        assert_eq!(v, want, "vgc_scc mismatch");
        let v1 = canonicalize(&vgc_scc(g, Some(&gt), 1, 7, None));
        assert_eq!(v1, want, "vgc_scc tau=1 mismatch");
    }

    #[test]
    fn all_agree_on_named_shapes() {
        check_all(&gen::cycle(50)); // one big SCC
        check_all(&gen::path(50)); // all singletons
        check_all(&gen::complete(12));
        check_all(&gen::grid(7, 9)); // DAG: singletons
        // two cycles joined by a one-way bridge
        let mut edges: Vec<(V, V)> = (0..10).map(|i| (i, (i + 1) % 10)).collect();
        edges.extend((10..20).map(|i| (i, 10 + (i + 1 - 10) % 10)));
        edges.push((3, 15));
        check_all(&Graph::from_edges(20, &edges, true));
    }

    #[test]
    fn all_agree_on_suite_categories() {
        check_all(&gen::social(9, 10, 3));
        check_all(&gen::web(9, 8, 4));
        check_all(&gen::road(8, 14, 5));
        check_all(&gen::knn_chain(500, 3, 7, 6));
        check_all(&gen::grid(4, 50));
    }

    #[test]
    fn prop_all_agree_on_random_graphs() {
        forall(0x5CC, |rng: &mut Rng| {
            let n = rng.range(1, 160);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            check_all(&Graph::from_edges(n, &edges, true));
        });
    }

    #[test]
    fn prop_sccs_shrink_under_edge_removal_sanity() {
        // Adding all reverse edges makes every weakly-connected
        // component one SCC — a structural sanity check.
        forall(0x5CD, |rng: &mut Rng| {
            let n = rng.range(2, 120);
            let m = rng.range(1, 3 * n);
            let edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            let g = Graph::from_edges(n, &edges, true).symmetrize();
            let scc = canonicalize(&vgc_scc(&g, Some(&g), 16, 1, None));
            let cc = crate::algo::cc::connected_components(&g);
            let cc_canon = canonicalize(&cc);
            assert_eq!(scc, cc_canon, "SCC of symmetric graph == CC");
        });
    }
}
