//! GBBS-style SCC: the shared multi-pivot decomposition driven by
//! round-synchronous BFS-order reachability. This is the "theoretically
//! efficient but round-bound" baseline of Fig. 1 / Table 4.

use super::decomp::{decompose, decompose_ws, Engine};
use crate::algo::workspace::SccWorkspace;
use crate::graph::Graph;
use crate::sim::trace::Recorder;

/// Per-vertex SCC labels via batched FW-BW with BFS reachability.
/// `gt` is the transpose (computed if absent); `seed` fixes the pivot
/// permutation.
pub fn bgss_scc(g: &Graph, gt: Option<&Graph>, seed: u64, rec: Recorder) -> Vec<u32> {
    decompose(g, gt, Engine::Rounds, seed, rec)
}

/// [`bgss_scc`] out of a reusable workspace (labels in `ws.labels`).
pub fn bgss_scc_ws(
    g: &Graph,
    gt: Option<&Graph>,
    seed: u64,
    rec: Recorder,
    ws: &mut SccWorkspace,
) {
    decompose_ws(g, gt, Engine::Rounds, seed, rec, ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::scc::{canonicalize, tarjan_scc};
    use crate::graph::gen;

    #[test]
    fn matches_tarjan_on_web_graph() {
        let g = gen::web(10, 8, 11);
        let got = canonicalize(&bgss_scc(&g, None, 3, None));
        assert_eq!(got, canonicalize(&tarjan_scc(&g)));
    }

    #[test]
    fn seed_invariance() {
        let g = gen::web(9, 6, 2);
        let a = canonicalize(&bgss_scc(&g, None, 1, None));
        let b = canonicalize(&bgss_scc(&g, None, 999, None));
        assert_eq!(a, b, "different pivot orders, same partition");
    }

    #[test]
    fn records_rounds_proportional_to_diameter_on_grid() {
        // Grid is a DAG: everything trims; rounds stay small.
        let g = gen::grid(4, 100);
        let mut t = crate::sim::AlgoTrace::new();
        let _ = bgss_scc(&g, None, 5, Some(&mut t));
        assert!(t.num_rounds() > 50, "trim peels layer by layer");
    }
}
