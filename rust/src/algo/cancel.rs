//! Cooperative cancellation: one shared `AtomicU64` per worker.
//!
//! A [`CancelToken`] is a deadline-or-flag the serving layer threads
//! from a request into the engine inner loops. Engines poll it **once
//! per frontier round / bucket epoch** — never per edge — so the check
//! costs one atomic load plus (when a deadline is armed) one
//! monotonic-clock read per round, and an expired or abandoned query
//! releases its shard within one round instead of running to fixpoint.
//!
//! Encoding of the single `AtomicU64`:
//!
//! * `0` — inert: never expires (the default, and what `_ws` wrappers
//!   without a token observe).
//! * `1` — hard-cancelled: the owner (a shard-worker watchdog, or an
//!   explicit [`CancelToken::cancel`]) condemned the work. Sticky: a
//!   [`CancelToken::rearm`] never overwrites it, so a condemned worker
//!   cannot accidentally resurrect its token for the next request.
//! * anything else — an absolute deadline, in nanoseconds since a
//!   process-wide anchor instant (clamped to ≥ 2 so it can never
//!   collide with the two flag values).
//!
//! Engines observe cancellation via [`cancelled`] and must exit their
//! round loop with `break`, **not** an early `return`: the `_ws` entry
//! points restore taken workspace buffers after the loop, and skipping
//! the restores would leak the buffers and leave a pooled
//! [`crate::algo::QueryWorkspace`] cold (correctness is unaffected —
//! epoch stamps rebind every array per query — but the zero-allocation
//! warm path would silently regress). A cancelled engine leaves
//! partial per-lane state behind; the serving layer never summarizes
//! it (the post-run token check in `ExecCore::run_spec` turns the
//! partial result into a typed failure).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Stable message prefix for deadline expiry — `coordinator::faults`
/// aliases it so `FailKind::classify` recovers the kind from the
/// message alone.
pub const MSG_DEADLINE: &str = "deadline exceeded";

/// Stable message prefix for watchdog-condemned (hard-cancelled) work.
pub const MSG_STALLED: &str = "engine stalled";

const INERT: u64 = 0;
const CONDEMNED: u64 = 1;

/// Process-wide clock anchor: deadlines are encoded as nanoseconds
/// since this instant, so one `AtomicU64` holds them.
fn anchor() -> Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// Nanoseconds since the process anchor (monotone). Also what the
/// shard watchdog stamps worker heartbeats with.
pub fn now_nanos() -> u64 {
    anchor().elapsed().as_nanos() as u64
}

fn encode_deadline(deadline: Instant) -> u64 {
    // Saturates to the anchor for deadlines in the past: encodes as a
    // tiny (already-expired) value, which is exactly right.
    (deadline.saturating_duration_since(anchor()).as_nanos() as u64).max(2)
}

/// Shared deadline-or-flag checked cooperatively by engine loops (see
/// module docs for the encoding).
#[derive(Debug, Default)]
pub struct CancelToken {
    state: AtomicU64,
}

impl CancelToken {
    /// An inert token: never expires until armed or cancelled.
    pub const fn new() -> Self {
        CancelToken {
            state: AtomicU64::new(INERT),
        }
    }

    /// A token that expires at `deadline`.
    pub fn with_deadline(deadline: Instant) -> Self {
        let t = CancelToken::new();
        t.rearm(Some(deadline));
        t
    }

    /// Hard-cancel: every subsequent [`CancelToken::is_cancelled`] is
    /// true and no [`CancelToken::rearm`] can undo it. The shard
    /// watchdog calls this on a condemned worker's token.
    pub fn cancel(&self) {
        self.state.store(CONDEMNED, Ordering::Release);
    }

    /// Re-arm for the next piece of work: set the deadline (`None`
    /// disarms back to inert). Returns `false` — leaving the token
    /// untouched — if the token is hard-cancelled, so a condemned
    /// worker discovers its state on the next dispatch instead of
    /// resurrecting the token.
    pub fn rearm(&self, deadline: Option<Instant>) -> bool {
        let new = deadline.map_or(INERT, encode_deadline);
        let mut cur = self.state.load(Ordering::Acquire);
        loop {
            if cur == CONDEMNED {
                return false;
            }
            match self
                .state
                .compare_exchange(cur, new, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// True once the deadline passed or the token was hard-cancelled.
    /// One atomic load; the clock is read only when a deadline is
    /// armed.
    pub fn is_cancelled(&self) -> bool {
        match self.state.load(Ordering::Acquire) {
            INERT => false,
            CONDEMNED => true,
            d => now_nanos() >= d,
        }
    }

    /// True only for a hard cancel ([`CancelToken::cancel`]), never
    /// for mere deadline expiry — what distinguishes
    /// `Failed { EngineStalled }` from `Failed { DeadlineExceeded }`.
    pub fn is_hard_cancelled(&self) -> bool {
        self.state.load(Ordering::Acquire) == CONDEMNED
    }
}

/// The optional borrow engines thread through their loops.
pub type Cancel<'a> = Option<&'a CancelToken>;

/// `true` iff a token is present and cancelled — the once-per-round
/// check engine loops make. `None` (no token) never cancels, so the
/// classic `_ws` wrappers cost one branch per round.
#[inline]
pub fn cancelled(c: Cancel<'_>) -> bool {
    match c {
        Some(t) => t.is_cancelled(),
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn inert_token_never_cancels() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert!(!t.is_hard_cancelled());
        assert!(!cancelled(Some(&t)));
        assert!(!cancelled(None));
    }

    #[test]
    fn deadlines_expire_in_order() {
        let t = CancelToken::with_deadline(Instant::now() + Duration::from_secs(3600));
        assert!(!t.is_cancelled(), "distant deadline still live");
        assert!(t.rearm(Some(Instant::now())), "re-arming a live token works");
        assert!(t.is_cancelled(), "past deadline is expired");
        assert!(!t.is_hard_cancelled(), "expiry is not a hard cancel");
        assert!(t.rearm(None), "disarm works");
        assert!(!t.is_cancelled(), "disarmed token is inert again");
    }

    #[test]
    fn hard_cancel_is_sticky() {
        let t = CancelToken::new();
        t.cancel();
        assert!(t.is_cancelled());
        assert!(t.is_hard_cancelled());
        assert!(
            !t.rearm(Some(Instant::now() + Duration::from_secs(60))),
            "rearm must refuse to resurrect a condemned token"
        );
        assert!(!t.rearm(None));
        assert!(t.is_hard_cancelled(), "still condemned after rearm attempts");
    }

    #[test]
    fn already_past_deadlines_encode_as_expired() {
        // A deadline before the anchor (or simply in the past) must
        // read as expired, not wrap into the flag values.
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_secs(5));
        assert!(t.is_cancelled());
        assert!(!t.is_hard_cancelled());
    }

    #[test]
    fn now_nanos_is_monotone() {
        let a = now_nanos();
        let b = now_nanos();
        assert!(b >= a);
    }
}
