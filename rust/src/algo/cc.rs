//! Parallel connectivity: concurrent union-find (Rem's algorithm with
//! splicing) plus spanning-forest extraction.
//!
//! This is the substrate FAST-BCC builds its (non-BFS) spanning tree
//! on — the key to avoiding O(D) rounds — and a useful algorithm in
//! its own right. Lock-free: `unite` uses CAS on parent slots;
//! `find` uses path halving.

use crate::graph::Graph;
use crate::parallel::parallel_for;
use crate::V;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

/// Concurrent union-find over `0..n`.
#[derive(Default)]
pub struct UnionFind {
    parent: Vec<AtomicU32>,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind {
            parent: (0..n as u32).map(AtomicU32::new).collect(),
        }
    }

    /// Rebind for a universe of size `n`, reusing the parent storage
    /// (O(n) writes, zero allocation once warm).
    pub fn reset(&mut self, n: usize) {
        self.parent.clear();
        self.parent.extend((0..n as u32).map(AtomicU32::new));
    }

    /// Universe size.
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// True when the universe is empty.
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Current root of `x` with path halving.
    pub fn find(&self, mut x: u32) -> u32 {
        loop {
            let p = self.parent[x as usize].load(Ordering::Relaxed);
            if p == x {
                return x;
            }
            let gp = self.parent[p as usize].load(Ordering::Relaxed);
            if p == gp {
                return p;
            }
            // Path halving (benign race).
            let _ = self.parent[x as usize].compare_exchange_weak(
                p,
                gp,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            x = gp;
        }
    }

    /// Union by id (smaller id wins as root). Returns true iff this
    /// call merged two previously-distinct sets — i.e. the caller's
    /// edge is a spanning-forest edge.
    pub fn unite(&self, u: u32, v: u32) -> bool {
        let (mut x, mut y) = (u, v);
        loop {
            x = self.find(x);
            y = self.find(y);
            if x == y {
                return false;
            }
            // Hook larger root under smaller (deterministic tie-break).
            let (lo, hi) = if x < y { (x, y) } else { (y, x) };
            match self.parent[hi as usize].compare_exchange(
                hi,
                lo,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(_) => continue, // hi gained a parent meanwhile; retry
            }
        }
    }

    /// Fully-compressed labels (parallel).
    pub fn labels(&self) -> Vec<u32> {
        let mut out = Vec::new();
        self.labels_into(&mut out);
        out
    }

    /// [`Self::labels`] into a caller-owned buffer (reused storage).
    pub fn labels_into(&self, out: &mut Vec<u32>) {
        let n = self.parent.len();
        out.clear();
        out.resize(n, 0);
        {
            let op = crate::parallel::ops::SendPtr(out.as_mut_ptr());
            parallel_for(0, n, 2048, |i| unsafe {
                *op.add(i) = self.find(i as u32);
            });
        }
    }
}

/// Connected-component labels of a (symmetric or not — edges treated
/// both ways) graph. Label = smallest vertex id in the component.
pub fn connected_components(g: &Graph) -> Vec<u32> {
    let mut ws = crate::algo::workspace::CcWorkspace::new();
    connected_components_ws(g, &mut ws);
    std::mem::take(&mut ws.labels)
}

/// [`connected_components`] out of a reusable workspace: labels are
/// left in `ws.labels` (also returned as a slice); a warm workspace
/// performs zero O(n) allocation.
pub fn connected_components_ws<'a>(
    g: &Graph,
    ws: &'a mut crate::algo::workspace::CcWorkspace,
) -> &'a [u32] {
    ws.uf.reset(g.n());
    let uf = &ws.uf;
    parallel_for(0, g.n(), 256, |u| {
        for &v in g.neighbors(u as V) {
            uf.unite(u as u32, v);
        }
    });
    uf.labels_into(&mut ws.labels);
    &ws.labels
}

/// Spanning forest: edges whose `unite` succeeded. Returns (labels,
/// forest edges). The forest has `n - #components` edges.
pub fn spanning_forest(g: &Graph) -> (Vec<u32>, Vec<(V, V)>) {
    let n = g.n();
    let uf = UnionFind::new(n);
    // Collect winning edges into per-chunk buffers, then flatten.
    let nchunks = n.div_ceil(256);
    let buffers: Vec<std::sync::Mutex<Vec<(V, V)>>> =
        (0..nchunks).map(|_| std::sync::Mutex::new(Vec::new())).collect();
    let found = AtomicUsize::new(0);
    crate::parallel::ops::parallel_for_chunks(0, n, 256, |ci, range| {
        let mut local = Vec::new();
        for u in range {
            for &v in g.neighbors(u as V) {
                if uf.unite(u as u32, v) {
                    local.push((u as V, v));
                }
            }
        }
        found.fetch_add(local.len(), Ordering::Relaxed);
        *buffers[ci].lock().unwrap() = local;
    });
    let mut forest = Vec::with_capacity(found.load(Ordering::Relaxed));
    for b in buffers {
        forest.extend(b.into_inner().unwrap());
    }
    (uf.labels(), forest)
}

/// Number of distinct components given labels.
pub fn component_count(labels: &[u32]) -> usize {
    labels
        .iter()
        .enumerate()
        .filter(|&(i, &l)| l == i as u32)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::prop::{forall, Rng};

    /// Sequential reference CC by BFS flood fill.
    fn seq_cc(g: &Graph) -> Vec<u32> {
        let n = g.n();
        let mut label = vec![u32::MAX; n];
        for s in 0..n {
            if label[s] != u32::MAX {
                continue;
            }
            let mut q = std::collections::VecDeque::new();
            label[s] = s as u32;
            q.push_back(s as u32);
            while let Some(u) = q.pop_front() {
                for &v in g.neighbors(u) {
                    if label[v as usize] == u32::MAX {
                        label[v as usize] = s as u32;
                        q.push_back(v);
                    }
                }
            }
        }
        label
    }

    fn assert_same_partition(a: &[u32], b: &[u32]) {
        // Two labelings induce the same partition iff the mapping
        // between labels is a bijection consistent across all items.
        assert_eq!(a.len(), b.len());
        let mut map = std::collections::HashMap::new();
        let mut rev = std::collections::HashMap::new();
        for (&x, &y) in a.iter().zip(b.iter()) {
            assert_eq!(*map.entry(x).or_insert(y), y, "partition mismatch");
            assert_eq!(*rev.entry(y).or_insert(x), x, "partition mismatch");
        }
    }

    #[test]
    fn matches_seq_on_bubbles() {
        let g = gen::bubbles(20, 6, 3);
        assert_same_partition(&connected_components(&g), &seq_cc(&g));
    }

    #[test]
    fn disconnected_pieces_found() {
        // Two disjoint triangles + isolated vertex.
        let g = Graph::from_edges(
            7,
            &[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)],
            false,
        )
        .symmetrize();
        let l = connected_components(&g);
        assert_eq!(component_count(&l), 3);
        assert_eq!(l[0], l[1]);
        assert_eq!(l[3], l[5]);
        assert_ne!(l[0], l[3]);
        assert_eq!(l[6], 6);
    }

    #[test]
    fn forest_has_n_minus_c_edges_and_spans() {
        forall(0xCC, |rng: &mut Rng| {
            let n = rng.range(2, 300);
            let m = rng.range(0, 3 * n);
            let edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            let g = Graph::from_edges(n, &edges, true).symmetrize();
            let (labels, forest) = spanning_forest(&g);
            let c = component_count(&labels);
            assert_eq!(forest.len(), n - c, "forest edge count");
            // Forest edges connect same-component endpoints and form
            // an acyclic set (checked via union-find replay).
            let uf = UnionFind::new(n);
            for &(u, v) in &forest {
                assert_eq!(labels[u as usize], labels[v as usize]);
                assert!(uf.unite(u, v), "forest contains a cycle");
            }
            // Replaying the forest reproduces the same partition.
            assert_same_partition(&uf.labels(), &labels);
        });
    }

    #[test]
    fn parallel_matches_seq_on_random_graphs() {
        forall(0xCC2, |rng: &mut Rng| {
            let n = rng.range(1, 400);
            let m = rng.range(0, 2 * n);
            let edges: Vec<(V, V)> = (0..m)
                .map(|_| (rng.below(n as u64) as V, rng.below(n as u64) as V))
                .collect();
            let g = Graph::from_edges(n, &edges, true).symmetrize();
            assert_same_partition(&connected_components(&g), &seq_cc(&g));
        });
    }

    #[test]
    fn warm_workspace_reuse_matches_fresh_calls() {
        let mut ws = crate::algo::workspace::CcWorkspace::new();
        let a = gen::bubbles(8, 5, 1);
        let b = gen::path(30).symmetrize();
        for _ in 0..3 {
            assert_same_partition(&connected_components_ws(&a, &mut ws).to_vec(), &seq_cc(&a));
            assert_same_partition(&connected_components_ws(&b, &mut ws).to_vec(), &seq_cc(&b));
        }
    }

    #[test]
    fn big_social_graph_one_giant_component() {
        let g = gen::social(13, 16, 5).symmetrize();
        let l = connected_components(&g);
        let giant = l.iter().filter(|&&x| x == l[0]).count();
        assert!(giant > g.n() / 2, "rmat giant component expected");
    }
}
