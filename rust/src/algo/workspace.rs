//! Reusable per-query workspaces — the zero-allocation query engine.
//!
//! Every PASGAL algorithm has two entry points: the classic
//! allocate-per-call function (`vgc_bfs`, `rho_stepping`, ...) and a
//! `_ws` variant taking one of the workspace structs below. The `_ws`
//! variants own no O(n) state of their own: distances, marks, pending
//! flags and reachability masks live in epoch-stamped arrays
//! ([`StampedU32`] / [`StampedU64`]) whose logical reset is O(1), and
//! frontier containers ([`HashBag`]) are rebound with
//! [`HashBag::reset`] instead of reallocated. After the first query
//! warms a workspace, subsequent queries on same-sized (or smaller)
//! graphs perform **zero O(n)/O(m) allocations** — the remaining
//! per-round scratch is O(frontier), which is part of the traversal
//! work itself.
//!
//! A serving process holds one [`QueryWorkspace`] per worker (see
//! [`crate::coordinator::Coordinator`], which checks workspaces out of
//! a pool per request); the classic entry points stay available for
//! one-shot callers and are thin wrappers that allocate a fresh
//! workspace and delegate.
//!
//! Reusing one workspace across *different* graphs is safe: every
//! `_ws` entry advances the epochs of the arrays it uses before
//! touching them, so values from the previous query — same graph or
//! not — can never leak into the next one. See
//! [`crate::parallel::workspace`] for the stamping scheme, including
//! epoch wraparound.

use crate::algo::cc::UnionFind;
use crate::hashbag::HashBag;
use crate::parallel::workspace::{StampedU32, StampedU64};
use crate::V;
use std::collections::HashMap;

/// Scratch state for the BFS family (`vgc_bfs_ws`, `diropt_bfs_ws`).
#[derive(Default)]
pub struct BfsWorkspace {
    /// Hop distances (output; read via [`StampedU32::get`] /
    /// [`StampedU32::export_into`] after a query).
    pub dist: StampedU32,
    /// Per-algorithm vertex marks: "expanded at distance" for VGC BFS,
    /// level-stamped frontier flags for direction-optimizing BFS.
    pub aux: StampedU32,
    /// The 2^i-distance frontier bags of VGC BFS.
    pub bags: Vec<HashBag>,
    /// Current frontier (reused across rounds and queries).
    pub frontier: Vec<V>,
    /// Next frontier / candidate buffer.
    pub next: Vec<V>,
    /// Bag-drain scratch for multi-bag gathers.
    pub gather: Vec<V>,
    /// Frontier-degree prefix sums (sparse edge-map rounds).
    pub offs: Vec<usize>,
    /// Edge-map output buffer (sparse rounds).
    pub edge_buf: Vec<u32>,
}

impl BfsWorkspace {
    /// Fresh (cold) workspace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Make sure `count` bags exist, each able to hold `cap` values,
    /// and clear them. Warm calls allocate nothing.
    pub fn prepare_bags(&mut self, count: usize, cap: usize) {
        for bag in self.bags.iter_mut() {
            bag.reset(cap);
        }
        while self.bags.len() < count {
            self.bags.push(HashBag::new(cap));
        }
    }
}

/// Scratch state for batched multi-source BFS
/// ([`crate::algo::multi::multi_bfs_vgc_ws`],
/// [`crate::algo::multi::multi_bfs_diropt_ws`]): lane-striped
/// distances plus one 64-bit source-mask word per vertex. The lane
/// count tracks the *actual* batch width of the last query (`lanes`),
/// so a 4-source batch pays 4 lanes of storage and export, not 64.
#[derive(Default)]
pub struct MultiBfsWorkspace {
    /// Lane-striped hop distances: `dist[v * lanes + lane]` (output;
    /// demultiplex with [`MultiBfsWorkspace::export_lane_into`]).
    pub dist: StampedU32,
    /// Lane-striped "expanded at distance" marks (VGC engine
    /// re-expansion qualification).
    pub expanded: StampedU32,
    /// Active-source mask per vertex: lanes whose distance ever
    /// improved (VGC engine) / visited lanes (diropt engine).
    pub masks: StampedU64,
    /// Current-level frontier masks (diropt engine).
    pub cur_mask: StampedU64,
    /// Next-level frontier masks (ping-ponged with `cur_mask`).
    pub next_mask: StampedU64,
    /// Pending-vertex worklist flags (VGC engine).
    pub pending: StampedU32,
    /// Deferred-work bag (VGC engine).
    pub bag: HashBag,
    /// Frontier buffer.
    pub frontier: Vec<V>,
    /// Next-frontier / admitted-work buffer.
    pub next: Vec<V>,
    /// Frontier-degree prefix sums (diropt sparse rounds).
    pub offs: Vec<usize>,
    /// Edge-map output buffer (diropt sparse rounds).
    pub edge_buf: Vec<u32>,
    /// Batch width of the last query (the lane stride of `dist`).
    pub lanes: usize,
    /// Submission lane → physical lane after mid-walk compaction
    /// ([`crate::algo::multi::compact_lanes`]); empty means identity.
    pub lane_map: Vec<u32>,
    /// Lane compactions performed by the last query (drained into the
    /// `lane_compactions` counter by the coordinator).
    pub compactions: u64,
}

impl MultiBfsWorkspace {
    /// Fresh (cold) workspace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Physical lane currently holding submission lane `lane`.
    #[inline]
    fn physical(&self, lane: usize) -> usize {
        self.lane_map.get(lane).map_or(lane, |&p| p as usize)
    }

    /// Distances of one lane from the last query into `out` (parallel
    /// strided export — the coordinator's demultiplex path). `lane` is
    /// the submission lane; compaction-induced permutations are
    /// resolved here, so callers never see physical lane positions.
    pub fn export_lane_into(&self, lane: usize, n: usize, out: &mut Vec<u32>) {
        assert!(lane < self.lanes, "lane {lane} out of range ({})", self.lanes);
        self.dist
            .export_strided_into(self.physical(lane), self.lanes, n, out);
    }

    /// Per-lane distance vectors of the last query.
    pub fn export_all(&self, n: usize) -> Vec<Vec<u32>> {
        (0..self.lanes)
            .map(|lane| {
                let mut out = Vec::new();
                self.export_lane_into(lane, n, &mut out);
                out
            })
            .collect()
    }
}

/// Scratch state for batched multi-source ρ-stepping
/// ([`crate::algo::multi::multi_rho_ws`]): lane-striped f32 distances,
/// one shared threshold/bucket structure across lanes.
#[derive(Default)]
pub struct MultiSsspWorkspace {
    /// Lane-striped tentative distances as f32 bits (output;
    /// demultiplex with [`MultiSsspWorkspace::export_lane_into`]).
    pub dist: StampedU32,
    /// Lane-striped last-expanded distances (qualify step).
    pub settled: StampedU32,
    /// Active-source mask per vertex.
    pub masks: StampedU64,
    /// Pending-vertex worklist flags.
    pub flags: StampedU32,
    /// Pending bag shared by every lane.
    pub bag: HashBag,
    /// Pending-vertex buffer.
    pub pending: Vec<V>,
    /// Admitted-work buffer.
    pub work: Vec<V>,
    /// Threshold-sampling scratch (shared across lanes).
    pub sample: Vec<f32>,
    /// Batch width of the last query (the lane stride of `dist`).
    pub lanes: usize,
    /// Submission lane → physical lane after mid-walk compaction
    /// ([`crate::algo::multi::compact_lanes`]); empty means identity.
    pub lane_map: Vec<u32>,
    /// Lane compactions performed by the last query (drained into the
    /// `lane_compactions` counter by the coordinator).
    pub compactions: u64,
}

impl MultiSsspWorkspace {
    /// Fresh (cold) workspace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Physical lane currently holding submission lane `lane`.
    #[inline]
    fn physical(&self, lane: usize) -> usize {
        self.lane_map.get(lane).map_or(lane, |&p| p as usize)
    }

    /// Distances of one lane from the last query into `out` (parallel
    /// strided export). `lane` is the submission lane; compaction
    /// permutations are resolved here.
    pub fn export_lane_into(&self, lane: usize, n: usize, out: &mut Vec<f32>) {
        assert!(lane < self.lanes, "lane {lane} out of range ({})", self.lanes);
        self.dist
            .export_f32_strided_into(self.physical(lane), self.lanes, n, out);
    }

    /// Per-lane distance vectors of the last query.
    pub fn export_all(&self, n: usize) -> Vec<Vec<f32>> {
        (0..self.lanes)
            .map(|lane| {
                let mut out = Vec::new();
                self.export_lane_into(lane, n, &mut out);
                out
            })
            .collect()
    }
}

/// Scratch state for the SSSP family (`rho_stepping_ws`,
/// `delta_stepping_ws`).
#[derive(Default)]
pub struct SsspWorkspace {
    /// Tentative distances as f32 bits (output).
    pub dist: StampedU32,
    /// Pending-vertex flags (ρ-stepping worklist).
    pub flags: StampedU32,
    /// Last-expanded distances (ρ-stepping qualify step).
    pub settled: StampedU32,
    /// Pending bag (ρ) / staging bag (Δ relaxation rounds).
    pub bag: HashBag,
    /// Δ-stepping distance buckets (grown on demand, kept warm).
    pub buckets: Vec<HashBag>,
    /// Pending/frontier vertex buffer.
    pub pending: Vec<V>,
    /// Admitted-work buffer.
    pub work: Vec<V>,
    /// Threshold-sampling scratch.
    pub sample: Vec<f32>,
    /// Staged-update drain buffer (Δ-stepping).
    pub staged_buf: Vec<V>,
}

impl SsspWorkspace {
    /// Fresh (cold) workspace.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Scratch state for SCC decomposition and its multi-source
/// reachability sub-queries — the heaviest internal reuse win: one
/// decomposition issues two reachability searches per pivot batch, and
/// every one of them used to reallocate O(n) masks.
#[derive(Default)]
pub struct SccWorkspace {
    /// Forward-reachability masks for the current pivot batch.
    pub fwd: StampedU64,
    /// Backward-reachability masks.
    pub bwd: StampedU64,
    /// Pending-vertex flags shared by the reachability searches.
    pub pending: StampedU32,
    /// Frontier bag shared by trim and the reachability searches.
    pub bag: HashBag,
    /// Frontier buffer.
    pub frontier: Vec<V>,
    /// Per-vertex SCC labels (output of `decompose_ws`).
    pub labels: Vec<u32>,
    /// Subproblem labels.
    pub sub: Vec<u64>,
    /// Pivot permutation buffer.
    pub perm: Vec<V>,
    /// Active out-degrees (trim scratch).
    pub deg_out: Vec<u32>,
    /// Active in-degrees (trim scratch).
    pub deg_in: Vec<u32>,
    /// Subproblem-size histogram (singleton refinement).
    pub sub_count: HashMap<u64, u32>,
}

impl SccWorkspace {
    /// Fresh (cold) workspace.
    pub fn new() -> Self {
        Self::default()
    }

    /// SCC labels of the last `decompose_ws` run.
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }
}

/// Scratch state for k-core decomposition (`par_kcore_ws`): live
/// degrees and coreness in epoch-stamped arrays (O(1) logical clear;
/// the only O(n) work per query is one parallel pass seeding the
/// degrees), a reused peel bag and frontier buffer, and the exported
/// coreness vector.
#[derive(Default)]
pub struct KcoreWorkspace {
    /// Live degree of each unpeeled vertex (seeded per query, then
    /// decremented concurrently as neighbors peel).
    pub deg: StampedU32,
    /// Coreness once peeled; `u32::MAX` (the stale default) while the
    /// vertex is still unpeeled — the claim CAS runs on this array.
    pub core: StampedU32,
    /// Next-wave peel bag (reused across waves and queries).
    pub bag: HashBag,
    /// Current peel frontier.
    pub frontier: Vec<V>,
    /// Exported coreness of the last query (`par_kcore_ws` returns a
    /// slice of this).
    pub out: Vec<u32>,
}

impl KcoreWorkspace {
    /// Fresh (cold) workspace.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Scratch state for connectivity queries.
#[derive(Default)]
pub struct CcWorkspace {
    /// Reusable union-find (reset per query, storage kept).
    pub uf: UnionFind,
    /// Component labels (output).
    pub labels: Vec<u32>,
}

impl CcWorkspace {
    /// Fresh (cold) workspace.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Everything one serving worker needs to answer any query with zero
/// O(n) allocation after warm-up. Hold one per worker; never share one
/// across concurrent queries (the `&mut` receiver enforces this).
#[derive(Default)]
pub struct QueryWorkspace {
    /// BFS-family scratch.
    pub bfs: BfsWorkspace,
    /// SSSP-family scratch.
    pub sssp: SsspWorkspace,
    /// SCC/reachability scratch.
    pub scc: SccWorkspace,
    /// Connectivity scratch.
    pub cc: CcWorkspace,
    /// k-core peeling scratch.
    pub kcore: KcoreWorkspace,
    /// Batched multi-source BFS scratch (coordinator fusion).
    pub multi_bfs: MultiBfsWorkspace,
    /// Batched multi-source SSSP scratch (coordinator fusion).
    pub multi_sssp: MultiSsspWorkspace,
    /// Reused u32 export buffer (distances, labels).
    pub out_u32: Vec<u32>,
    /// Reused f32 export buffer (SSSP distances).
    pub out_f32: Vec<f32>,
}

impl QueryWorkspace {
    /// Fresh (cold) workspace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drain the lane-compaction tallies of the last fused walk (both
    /// multi-source workspaces), zeroing them so pooled reuse never
    /// double-counts.
    pub fn take_lane_compactions(&mut self) -> u64 {
        let c = self.multi_bfs.compactions + self.multi_sssp.compactions;
        self.multi_bfs.compactions = 0;
        self.multi_sssp.compactions = 0;
        c
    }
}

/// A plain-`Vec` pool of warm [`QueryWorkspace`]s — deliberately not
/// a concurrent structure. Each shard worker of the serving subsystem
/// owns one outright (checkout/checkin without any lock — half of
/// what makes the shard hot path Mutex-free); the coordinator's
/// shared pool wraps one in a `Mutex` for its single-threaded serve
/// loop and ad-hoc callers.
#[derive(Default)]
pub struct WorkspacePool {
    slots: Vec<QueryWorkspace>,
}

impl WorkspacePool {
    /// Empty pool (every checkout until the first checkin is cold).
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop a warm workspace, or build a cold one if the pool is empty.
    pub fn checkout(&mut self) -> QueryWorkspace {
        self.slots.pop().unwrap_or_default()
    }

    /// Return a workspace for the next request.
    pub fn checkin(&mut self, ws: QueryWorkspace) {
        self.slots.push(ws);
    }

    /// Number of idle workspaces.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when a checkout would build a cold workspace.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}
