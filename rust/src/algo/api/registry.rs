//! The static `AlgoRegistry`: one [`AlgoSpec`] per served algorithm,
//! zero dependencies, zero allocation — the single source of truth
//! for labels, aliases, parameter parsing, solo/batch/traced dispatch
//! and fusability. Every front end (coordinator execution, fusion
//! windows, CLI, bench harness) resolves algorithms here.
//!
//! Adding an algorithm: implement its engines in [`super::engines`],
//! append one `AlgoSpec` static + one [`REGISTRY`] line (its `id` is
//! its registry index) — nothing else. The channel serving protocol
//! is registry-native (`JobRequest` carries `&'static AlgoSpec` +
//! `Params` directly), so there is no per-algorithm table anywhere
//! else to keep in sync. The registry-completeness tests below (and
//! `tests/multi_source.rs`, which iterates every batch engine)
//! enforce the invariants so a new line cannot silently break
//! dispatch.

use super::engines as e;
use super::{AlgoSpec, Views};

/// PASGAL VGC BFS (τ-budget local searches over hash-bag frontiers).
pub static BFS_VGC: AlgoSpec = AlgoSpec {
    id: 0,
    label: "bfs-vgc",
    aliases: &["bfs"],
    needs_source: true,
    needs_engine: false,
    cacheable: false,
    views: Views::NONE,
    parse: e::parse_tau,
    solo: e::bfs_vgc_solo,
    batch: Some(&e::BFS_VGC_BATCH),
    traced: Some(e::bfs_vgc_traced),
    full: None,
};

/// GBBS-like frontier BFS (round-synchronous baseline).
pub static BFS_FRONTIER: AlgoSpec = AlgoSpec {
    id: 1,
    label: "bfs-frontier",
    aliases: &[],
    needs_source: true,
    needs_engine: false,
    cacheable: false,
    views: Views::NONE,
    parse: e::parse_none,
    solo: e::bfs_frontier_solo,
    batch: None,
    traced: Some(e::bfs_frontier_traced),
    full: None,
};

/// Direction-optimizing BFS (GAPBS-like baseline).
pub static BFS_DIROPT: AlgoSpec = AlgoSpec {
    id: 2,
    label: "bfs-diropt",
    aliases: &[],
    needs_source: true,
    needs_engine: false,
    cacheable: false,
    views: Views::TRANSPOSE,
    parse: e::parse_none,
    solo: e::bfs_diropt_solo,
    batch: Some(&e::BFS_DIROPT_BATCH),
    traced: Some(e::bfs_diropt_traced),
    full: None,
};

/// PASGAL VGC SCC.
pub static SCC_VGC: AlgoSpec = AlgoSpec {
    id: 3,
    label: "scc-vgc",
    aliases: &["scc"],
    needs_source: false,
    needs_engine: false,
    cacheable: true,
    views: Views::TRANSPOSE,
    parse: e::parse_tau,
    solo: e::scc_vgc_solo,
    batch: None,
    traced: Some(e::scc_vgc_traced),
    full: Some(e::full_from_out_u32),
};

/// Multistep SCC (trim + FW-BW + coloring baseline).
pub static SCC_MULTISTEP: AlgoSpec = AlgoSpec {
    id: 4,
    label: "scc-multistep",
    aliases: &[],
    needs_source: false,
    needs_engine: false,
    cacheable: true,
    views: Views::TRANSPOSE,
    parse: e::parse_none,
    solo: e::scc_multistep_solo,
    batch: None,
    traced: Some(e::scc_multistep_traced),
    full: Some(e::full_from_out_u32),
};

/// FAST-BCC.
pub static BCC_FAST: AlgoSpec = AlgoSpec {
    id: 5,
    label: "bcc-fast",
    aliases: &["bcc"],
    needs_source: false,
    needs_engine: false,
    cacheable: true,
    views: Views::SYMMETRIZED,
    parse: e::parse_none,
    solo: e::bcc_solo,
    batch: None,
    traced: Some(e::bcc_traced),
    full: None,
};

/// ρ-stepping SSSP with VGC.
pub static SSSP_RHO: AlgoSpec = AlgoSpec {
    id: 6,
    label: "sssp-rho",
    aliases: &["sssp"],
    needs_source: true,
    needs_engine: false,
    cacheable: false,
    views: Views::NONE,
    parse: e::parse_tau,
    solo: e::sssp_rho_solo,
    batch: Some(&e::SSSP_RHO_BATCH),
    traced: Some(e::sssp_rho_traced),
    full: None,
};

/// Δ-stepping SSSP (baseline).
pub static SSSP_DELTA: AlgoSpec = AlgoSpec {
    id: 7,
    label: "sssp-delta",
    aliases: &[],
    needs_source: true,
    needs_engine: false,
    cacheable: false,
    views: Views::NONE,
    parse: e::parse_none,
    solo: e::sssp_delta_solo,
    batch: None,
    traced: Some(e::sssp_delta_traced),
    full: None,
};

/// Dense-block closure on the AOT engine (the L1/L2 path).
pub static DENSE_CLOSURE: AlgoSpec = AlgoSpec {
    id: 8,
    label: "dense-closure",
    aliases: &["dense"],
    needs_source: false,
    needs_engine: true,
    cacheable: false,
    views: Views::NONE,
    parse: e::parse_block,
    solo: e::dense_closure_solo,
    batch: None,
    traced: None,
    full: None,
};

/// Parallel connectivity (hook/compress union-find).
pub static CC: AlgoSpec = AlgoSpec {
    id: 9,
    label: "cc",
    aliases: &["connectivity", "components"],
    needs_source: false,
    needs_engine: false,
    cacheable: true,
    views: Views::NONE,
    parse: e::parse_none,
    solo: e::cc_solo,
    batch: None,
    traced: None,
    full: Some(e::full_from_out_u32),
};

/// k-core decomposition (parallel peeling over hash bags).
pub static KCORE: AlgoSpec = AlgoSpec {
    id: 10,
    label: "kcore",
    aliases: &["k-core", "coreness"],
    needs_source: false,
    needs_engine: false,
    cacheable: true,
    views: Views::SYMMETRIZED,
    parse: e::parse_none,
    solo: e::kcore_solo,
    batch: None,
    traced: Some(e::kcore_traced),
    full: Some(e::full_from_out_u32),
};

/// Every registered algorithm, indexed by [`AlgoSpec::id`].
pub static REGISTRY: [&AlgoSpec; 11] = [
    &BFS_VGC,
    &BFS_FRONTIER,
    &BFS_DIROPT,
    &SCC_VGC,
    &SCC_MULTISTEP,
    &BCC_FAST,
    &SSSP_RHO,
    &SSSP_DELTA,
    &DENSE_CLOSURE,
    &CC,
    &KCORE,
];

/// All registered specs, in id order.
pub fn all() -> &'static [&'static AlgoSpec] {
    &REGISTRY
}

/// Look an algorithm up by label or alias.
pub fn find(name: &str) -> Option<&'static AlgoSpec> {
    REGISTRY.iter().copied().find(|s| s.answers_to(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::api::ParseArgs;
    use std::collections::HashSet;

    #[test]
    fn ids_are_registry_indices() {
        for (i, spec) in REGISTRY.iter().enumerate() {
            assert_eq!(spec.id as usize, i, "{} id out of order", spec.label);
        }
    }

    #[test]
    fn labels_are_unique_and_self_resolving() {
        let mut seen = HashSet::new();
        for spec in all() {
            assert!(seen.insert(spec.label), "duplicate label {}", spec.label);
            let found = find(spec.label).expect("label resolves");
            assert!(std::ptr::eq(found, *spec), "{} resolves to itself", spec.label);
        }
    }

    #[test]
    fn aliases_resolve_and_never_shadow() {
        let mut names: HashSet<&str> = all().iter().map(|s| s.label).collect();
        for spec in all() {
            for &alias in spec.aliases {
                assert!(
                    names.insert(alias),
                    "alias {alias:?} collides with another name"
                );
                let found = find(alias).expect("alias resolves");
                assert!(
                    std::ptr::eq(found, *spec),
                    "alias {alias:?} must resolve to {}",
                    spec.label
                );
            }
        }
        assert!(find("nope").is_none());
    }

    #[test]
    fn parse_keeps_only_understood_params() {
        let args = ParseArgs { tau: 77, block: 33 };
        assert_eq!((BFS_VGC.parse)(&args).tau, 77);
        assert_eq!((BFS_VGC.parse)(&args).block, 0, "τ specs ignore block");
        assert_eq!((DENSE_CLOSURE.parse)(&args).block, 33);
        assert_eq!((DENSE_CLOSURE.parse)(&args).tau, 0, "block specs ignore τ");
        for spec in [&BCC_FAST, &CC, &KCORE, &BFS_FRONTIER] {
            assert_eq!(
                (spec.parse)(&args),
                crate::algo::api::Params::NONE,
                "{} has no knobs",
                spec.label
            );
        }
    }

    #[test]
    fn only_dense_closure_needs_the_aot_engine() {
        for spec in all() {
            assert_eq!(
                spec.needs_engine,
                spec.label == "dense-closure",
                "{} needs_engine flag",
                spec.label
            );
        }
    }

    #[test]
    fn cacheable_covers_exactly_the_whole_graph_analyses() {
        let cacheable: Vec<&str> = all()
            .iter()
            .filter(|s| s.cacheable)
            .map(|s| s.label)
            .collect();
        assert_eq!(
            cacheable,
            ["scc-vgc", "scc-multistep", "bcc-fast", "cc", "kcore"]
        );
        for spec in all() {
            if spec.cacheable {
                // A cached output must be fully determined by
                // (graph version, spec id, Params): no source vertex,
                // no external engine, no batched (per-source) path.
                assert!(!spec.needs_source, "{} caches but reads a source", spec.label);
                assert!(!spec.needs_engine, "{} caches but reads the engine", spec.label);
                assert!(!spec.fusable(), "{} caches but has a batch engine", spec.label);
            }
        }
    }

    #[test]
    fn full_vectors_are_a_subset_of_cacheable_label_analyses() {
        let with_full: Vec<&str> = all()
            .iter()
            .filter(|s| s.full.is_some())
            .map(|s| s.label)
            .collect();
        // BCC summarizes block structure rather than a per-vertex
        // label vector, so it stays summary-only.
        assert_eq!(with_full, ["scc-vgc", "scc-multistep", "cc", "kcore"]);
        for spec in all() {
            if spec.full.is_some() {
                assert!(
                    spec.cacheable,
                    "{} exports a full vector but is not cacheable",
                    spec.label
                );
            }
        }
    }

    #[test]
    fn fusable_specs_all_carry_batch_engines() {
        let fusable: Vec<&str> = all()
            .iter()
            .filter(|s| s.fusable())
            .map(|s| s.label)
            .collect();
        assert_eq!(fusable, ["bfs-vgc", "bfs-diropt", "sssp-rho"]);
        // Fusable algorithms relax per-source state, so they must
        // validate sources.
        for spec in all().iter().filter(|s| s.fusable()) {
            assert!(spec.needs_source, "{} fusable but sourceless", spec.label);
        }
    }
}
