//! Per-algorithm engine implementations behind the [`AlgoSpec`]
//! registry entries — the code that used to be copy-pasted match arms
//! in `coordinator::server` (solo execution, fused demux) and
//! `main.rs` (traced single runs). One algorithm = one block of
//! functions here + one registry line in [`super::registry`].
//!
//! Solo engines answer out of the caller's warm [`QueryWorkspace`]
//! through the `_ws` entry points, so the steady-state serving path
//! keeps its zero-O(n)-allocation property. Batch engines run one
//! fused ≤ 64-lane multi-source walk ([`crate::algo::multi`]) and
//! demultiplex per-lane summaries with the parallel strided exports.
//! Traced engines use the classic allocate-per-call entry points and
//! record an [`AlgoTrace`] — they exist for the CLI `run` /
//! virtual-multicore measurement path, not for serving.
//!
//! [`AlgoSpec`]: super::AlgoSpec

use super::{BatchEngine, EngineCtx, Params, QueryOutput};
use crate::algo::workspace::QueryWorkspace;
use crate::algo::{bcc, bfs, cc, kcore, multi, scc, sssp, UNREACHED};
use crate::coordinator::dense::DenseBlock;
use crate::coordinator::directory::LoadedGraph;
use crate::error::{Context, Result};
use crate::sim::AlgoTrace;
use crate::{INF, V};
use std::collections::HashMap;

// ---------------------------------------------------------------
// Output summarizers (shared by solo and batch demux paths).
// ---------------------------------------------------------------

fn summarize_bfs(dist: &[u32]) -> QueryOutput {
    let mut reached = 0usize;
    let mut ecc = 0u32;
    for &d in dist {
        if d != UNREACHED {
            reached += 1;
            ecc = ecc.max(d);
        }
    }
    QueryOutput::Bfs { reached, ecc }
}

fn summarize_sssp(dist: &[f32]) -> QueryOutput {
    let mut reached = 0usize;
    let mut radius = 0.0f32;
    for &d in dist {
        if d < INF {
            reached += 1;
            radius = radius.max(d);
        }
    }
    QueryOutput::Sssp { reached, radius }
}

/// Shared by SCC and CC summaries: (#distinct labels, largest class).
fn label_histogram(labels: &[u32]) -> (usize, usize) {
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &l in labels {
        *counts.entry(l).or_insert(0) += 1;
    }
    (counts.len(), counts.values().copied().max().unwrap_or(0))
}

fn summarize_scc(labels: &[u32]) -> QueryOutput {
    let (count, largest) = label_histogram(labels);
    QueryOutput::Scc { count, largest }
}

fn summarize_cc(labels: &[u32]) -> QueryOutput {
    let (components, largest) = label_histogram(labels);
    QueryOutput::Cc {
        components,
        largest,
    }
}

fn summarize_kcore(core: &[u32]) -> QueryOutput {
    let degeneracy = core.iter().copied().max().unwrap_or(0);
    let in_max_core = core.iter().filter(|&&c| c == degeneracy).count();
    QueryOutput::Kcore {
        degeneracy,
        in_max_core,
    }
}

// ---------------------------------------------------------------
// Parsers: keep the knobs the algorithm understands, zero the rest.
// ---------------------------------------------------------------

pub(super) fn parse_none(_args: &super::ParseArgs) -> Params {
    Params::NONE
}

pub(super) fn parse_tau(args: &super::ParseArgs) -> Params {
    Params::tau(args.tau)
}

pub(super) fn parse_block(args: &super::ParseArgs) -> Params {
    Params::block(args.block)
}

/// Full-vector extractor shared by every label/coreness engine that
/// exports its per-vertex `u32` answer into [`QueryWorkspace::out_u32`]
/// (SCC labels, CC labels, k-core coreness). The clone is what gets
/// wrapped in an `Arc` and parked in the result cache, so the warm
/// workspace buffer itself is never retained past the query.
pub(super) fn full_from_out_u32(ws: &QueryWorkspace) -> Vec<u32> {
    ws.out_u32.clone()
}

// ---------------------------------------------------------------
// BFS family.
// ---------------------------------------------------------------

pub(super) fn bfs_vgc_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    p: Params,
    src: V,
    ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    let g = &*lg.graph;
    bfs::vgc_bfs_ws(g, src, p.tau, cx.recorder().as_deref_mut(), &mut ws.bfs);
    ws.bfs.dist.export_into(g.n(), &mut ws.out_u32);
    Ok(summarize_bfs(&ws.out_u32))
}

pub(super) fn bfs_vgc_traced(lg: &LoadedGraph, p: Params, src: V, trace: &mut AlgoTrace) {
    bfs::vgc_bfs(&lg.graph, src, p.tau, Some(trace));
}

pub(super) fn bfs_vgc_batch_run(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    p: Params,
    seeds: &[V],
    ws: &mut QueryWorkspace,
) {
    multi::multi_bfs_vgc_ws_cancel(
        &lg.graph,
        seeds,
        p.tau,
        cx.recorder().as_deref_mut(),
        &mut ws.multi_bfs,
        cx.cancel,
    );
}

pub(super) fn bfs_batch_demux(ws: &mut QueryWorkspace, lane: usize, n: usize) -> QueryOutput {
    ws.multi_bfs.export_lane_into(lane, n, &mut ws.out_u32);
    summarize_bfs(&ws.out_u32)
}

pub(super) static BFS_VGC_BATCH: BatchEngine = BatchEngine {
    run: bfs_vgc_batch_run,
    demux: bfs_batch_demux,
};

pub(super) fn bfs_frontier_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    _p: Params,
    src: V,
    _ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    Ok(summarize_bfs(&bfs::frontier_bfs(
        &lg.graph,
        src,
        cx.recorder().as_deref_mut(),
    )))
}

pub(super) fn bfs_frontier_traced(lg: &LoadedGraph, _p: Params, src: V, trace: &mut AlgoTrace) {
    bfs::frontier_bfs(&lg.graph, src, Some(trace));
}

pub(super) fn bfs_diropt_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    _p: Params,
    src: V,
    ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    let g = &*lg.graph;
    bfs::diropt_bfs_ws(
        g,
        Some(lg.transpose()),
        src,
        cx.recorder().as_deref_mut(),
        &mut ws.bfs,
    );
    ws.bfs.dist.export_into(g.n(), &mut ws.out_u32);
    Ok(summarize_bfs(&ws.out_u32))
}

pub(super) fn bfs_diropt_traced(lg: &LoadedGraph, _p: Params, src: V, trace: &mut AlgoTrace) {
    bfs::diropt_bfs(&lg.graph, Some(lg.transpose()), src, Some(trace));
}

pub(super) fn bfs_diropt_batch_run(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    _p: Params,
    seeds: &[V],
    ws: &mut QueryWorkspace,
) {
    multi::multi_bfs_diropt_ws_cancel(
        &lg.graph,
        Some(lg.transpose()),
        seeds,
        cx.recorder().as_deref_mut(),
        &mut ws.multi_bfs,
        cx.cancel,
    );
}

pub(super) static BFS_DIROPT_BATCH: BatchEngine = BatchEngine {
    run: bfs_diropt_batch_run,
    demux: bfs_batch_demux,
};

// ---------------------------------------------------------------
// SCC family.
// ---------------------------------------------------------------

pub(super) fn scc_vgc_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    p: Params,
    _src: V,
    ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    scc::vgc_scc_ws_cancel(
        &lg.graph,
        Some(lg.transpose()),
        p.tau,
        42,
        cx.recorder().as_deref_mut(),
        &mut ws.scc,
        cx.cancel,
    );
    // Export labels so the registry's `full` extractor (and thus the
    // full-vector cache) sees the complete per-vertex answer.
    ws.out_u32.clear();
    ws.out_u32.extend_from_slice(ws.scc.labels());
    Ok(summarize_scc(&ws.out_u32))
}

pub(super) fn scc_vgc_traced(lg: &LoadedGraph, p: Params, _src: V, trace: &mut AlgoTrace) {
    scc::vgc_scc(&lg.graph, Some(lg.transpose()), p.tau, 42, Some(trace));
}

pub(super) fn scc_multistep_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    _p: Params,
    _src: V,
    ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    let labels = scc::multistep_scc(&lg.graph, Some(lg.transpose()), cx.recorder().as_deref_mut());
    ws.out_u32.clear();
    ws.out_u32.extend_from_slice(&labels);
    Ok(summarize_scc(&ws.out_u32))
}

pub(super) fn scc_multistep_traced(lg: &LoadedGraph, _p: Params, _src: V, trace: &mut AlgoTrace) {
    scc::multistep_scc(&lg.graph, Some(lg.transpose()), Some(trace));
}

// ---------------------------------------------------------------
// BCC.
// ---------------------------------------------------------------

pub(super) fn bcc_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    _p: Params,
    _src: V,
    _ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    let r = bcc::fast_bcc(lg.symmetrized(), cx.recorder().as_deref_mut());
    Ok(QueryOutput::Bcc {
        blocks: r.n_bcc,
        articulation: r.articulation.iter().filter(|&&a| a).count(),
    })
}

pub(super) fn bcc_traced(lg: &LoadedGraph, _p: Params, _src: V, trace: &mut AlgoTrace) {
    bcc::fast_bcc(lg.symmetrized(), Some(trace));
}

// ---------------------------------------------------------------
// SSSP family.
// ---------------------------------------------------------------

pub(super) fn sssp_rho_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    p: Params,
    src: V,
    ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    let g = &*lg.graph;
    sssp::rho_stepping_ws_cancel(g, src, p.tau, cx.recorder().as_deref_mut(), &mut ws.sssp, cx.cancel);
    ws.sssp.dist.export_f32_into(g.n(), &mut ws.out_f32);
    Ok(summarize_sssp(&ws.out_f32))
}

pub(super) fn sssp_rho_traced(lg: &LoadedGraph, p: Params, src: V, trace: &mut AlgoTrace) {
    sssp::rho_stepping(&lg.graph, src, p.tau, Some(trace));
}

pub(super) fn sssp_rho_batch_run(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    p: Params,
    seeds: &[V],
    ws: &mut QueryWorkspace,
) {
    multi::multi_rho_ws_cancel(
        &lg.graph,
        seeds,
        p.tau,
        cx.recorder().as_deref_mut(),
        &mut ws.multi_sssp,
        cx.cancel,
    );
}

pub(super) fn sssp_batch_demux(ws: &mut QueryWorkspace, lane: usize, n: usize) -> QueryOutput {
    ws.multi_sssp.export_lane_into(lane, n, &mut ws.out_f32);
    summarize_sssp(&ws.out_f32)
}

pub(super) static SSSP_RHO_BATCH: BatchEngine = BatchEngine {
    run: sssp_rho_batch_run,
    demux: sssp_batch_demux,
};

pub(super) fn sssp_delta_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    _p: Params,
    src: V,
    ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    let g = &*lg.graph;
    sssp::delta_stepping_ws_cancel(g, src, None, cx.recorder().as_deref_mut(), &mut ws.sssp, cx.cancel);
    ws.sssp.dist.export_f32_into(g.n(), &mut ws.out_f32);
    Ok(summarize_sssp(&ws.out_f32))
}

pub(super) fn sssp_delta_traced(lg: &LoadedGraph, _p: Params, src: V, trace: &mut AlgoTrace) {
    sssp::delta_stepping(&lg.graph, src, None, Some(trace));
}

// ---------------------------------------------------------------
// Connectivity (opened for serving by the registry).
// ---------------------------------------------------------------

pub(super) fn cc_solo(
    _cx: &EngineCtx,
    lg: &LoadedGraph,
    _p: Params,
    _src: V,
    ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    // `connected_components` treats every edge as bidirectional, so
    // the raw graph works for directed inputs too — no symmetrized
    // view needs materializing.
    let labels = cc::connected_components_ws(&lg.graph, &mut ws.cc);
    ws.out_u32.clear();
    ws.out_u32.extend_from_slice(labels);
    Ok(summarize_cc(&ws.out_u32))
}

// ---------------------------------------------------------------
// k-core (opened for serving by the registry).
// ---------------------------------------------------------------

pub(super) fn kcore_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    _p: Params,
    _src: V,
    ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    // Peeling requires a symmetric view; degree/core live in the
    // stamped workspace, so serving k-core is zero-allocation once
    // warm like the rest.
    let core = kcore::par_kcore_ws(lg.symmetrized(), cx.recorder().as_deref_mut(), &mut ws.kcore);
    ws.out_u32.clear();
    ws.out_u32.extend_from_slice(core);
    Ok(summarize_kcore(&ws.out_u32))
}

pub(super) fn kcore_traced(lg: &LoadedGraph, _p: Params, _src: V, trace: &mut AlgoTrace) {
    kcore::par_kcore(lg.symmetrized(), Some(trace));
}

// ---------------------------------------------------------------
// Dense-block closure (PJRT engine path).
// ---------------------------------------------------------------

pub(super) fn dense_closure_solo(
    cx: &EngineCtx,
    lg: &LoadedGraph,
    p: Params,
    _src: V,
    _ws: &mut QueryWorkspace,
) -> Result<QueryOutput> {
    let g = &*lg.graph;
    let engine = cx
        .engine
        .context("no dense engine attached (run `make artifacts`)")?;
    let tile = engine
        .closure_tiles()
        .into_iter()
        .filter(|&t| t >= p.block.min(g.n()))
        .min()
        .context("no closure artifact large enough")?;
    let k = p.block.min(g.n()).min(tile);
    let vs = DenseBlock::top_degree_block(g, k);
    let db = DenseBlock::extract(g, &vs, tile);
    let closure = db.closure(engine)?;
    let finite = closure.iter().filter(|&&d| d < INF).count();
    Ok(QueryOutput::Dense {
        block: k,
        finite_pairs: finite,
    })
}
