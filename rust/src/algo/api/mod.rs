//! `algo::api` — the open Query API: one registry entry per
//! algorithm, one dispatch path for every front end.
//!
//! PASGAL's value is a *library* of interchangeable parallel
//! algorithms. Before this module the serving layer hard-coded a
//! closed per-algorithm enum whose dispatch logic was copy-pasted across
//! five match sites (solo execution, batch fusion + demux, the fusion
//! window's grouping key, CLI parsing, labels) — so algorithms that
//! already lived in `algo/` (connectivity, k-core) could not be served
//! at all. Following GBBS's uniform-interface design, this module
//! inverts that: every algorithm is described **once**, by a static
//! [`AlgoSpec`], and every front end (the coordinator's [`ExecCore`],
//! the sharded server's fusion window, the CLI, the bench harness)
//! dispatches through the [`registry`].
//!
//! * [`Query`] — one request: a graph name, a `&'static AlgoSpec`, a
//!   source vertex, and parsed [`Params`]. Built by
//!   [`Query::new`] from an algorithm name (label or alias) via
//!   registry lookup.
//! * [`AlgoSpec`] — the registry entry: `label`, `aliases`,
//!   `parse` (CLI/request params → [`Params`]), a **solo engine**
//!   (answers one query against a [`LoadedGraph`] + [`QueryWorkspace`],
//!   returns a typed [`QueryOutput`]), an optional **batch engine**
//!   (the ≤ 64-lane fused multi-source walk + per-lane demux), and an
//!   optional **traced engine** (single run recording an
//!   [`AlgoTrace`] for the virtual-multicore studies — the CLI `run`
//!   path).
//! * [`registry`] — the static `AlgoRegistry`: an array of
//!   `&'static AlgoSpec` (zero dependencies, no allocation), lookup by
//!   label or alias ([`find`]), iteration ([`all`]).
//!
//! **Registering an algorithm touches one module**: implement its
//! engine functions in [`engines`], add one `AlgoSpec` line to
//! [`registry::REGISTRY`], and it is servable everywhere — CLI,
//! single-threaded serve loop, sharded server, workload generator,
//! tests. The channel serving protocol is registry-native too: a
//! [`JobRequest`](crate::coordinator::JobRequest) carries its
//! `&'static AlgoSpec` and parsed [`Params`] directly (no closed
//! per-algorithm wire enum survives). CC and k-core entered the registry
//! exactly this way.
//!
//! Specs whose output depends only on the graph — no source vertex,
//! no external engine — declare [`AlgoSpec::cacheable`]: the serving
//! layer answers repeated queries for them out of a versioned
//! [`ResultCache`](crate::coordinator::ResultCache) keyed on
//! `(graph version, spec id, Params)`, invalidated automatically when
//! `load_graph` republishes the graph.
//!
//! [`ExecCore`]: crate::coordinator::server
//! [`LoadedGraph`]: crate::coordinator::LoadedGraph
//! [`QueryWorkspace`]: crate::algo::QueryWorkspace
//! [`AlgoTrace`]: crate::sim::AlgoTrace

pub mod engines;
pub mod registry;

pub use registry::{all, find};

use crate::algo::cancel::Cancel;
use crate::algo::workspace::QueryWorkspace;
use crate::coordinator::directory::LoadedGraph;
use crate::coordinator::faults::FailKind;
use crate::error::{Error, Result};
use crate::runtime::EngineHandle;
use crate::sim::AlgoTrace;
use crate::V;

/// Parsed per-query algorithm parameters. One flat POD so the batch
/// grouping key `(graph, spec id, Params)` stays `Copy + Eq + Hash`:
/// two queries fuse only when *every* parameter matches. Specs zero
/// the fields they ignore (via their [`AlgoSpec::parse`]), so e.g.
/// all `bcc-fast` queries share one group regardless of the CLI τ.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Params {
    /// VGC local-search budget τ (BFS-VGC, SCC-VGC, ρ-stepping).
    pub tau: usize,
    /// Dense-block edge length (dense-closure).
    pub block: usize,
}

impl Params {
    /// No parameters (algorithms whose behavior has no knobs).
    pub const NONE: Params = Params { tau: 0, block: 0 };

    /// τ only.
    pub const fn tau(tau: usize) -> Params {
        Params { tau, block: 0 }
    }

    /// Block size only.
    pub const fn block(block: usize) -> Params {
        Params { tau: 0, block }
    }
}

/// Raw parameter values as supplied by a caller (CLI flags, request
/// fields) before a spec's [`AlgoSpec::parse`] keeps the ones it
/// understands and zeroes the rest.
#[derive(Debug, Clone, Copy)]
pub struct ParseArgs {
    /// `--tau` (default 512, the paper's setting).
    pub tau: usize,
    /// `--block` (default 64 — previously hard-coded in
    /// the old wire-enum parse, now threaded through like τ).
    pub block: usize,
}

impl Default for ParseArgs {
    fn default() -> Self {
        ParseArgs {
            tau: 512,
            block: 64,
        }
    }
}

/// Execution-environment context handed to solo engines: everything a
/// spec may need beyond the graph and its workspace. Today that is
/// the optional dense engine, the cooperative-cancellation token, and
/// the optional round-telemetry recorder; future backends slot in
/// here without touching any engine signature.
pub struct EngineCtx<'a> {
    /// The AOT dense-kernel engine, when one is attached.
    pub engine: Option<&'a EngineHandle>,
    /// Cooperative-cancellation token for this query, when the caller
    /// enforces a deadline or can abandon the query. Engines that
    /// support cancellation poll it once per frontier round / bucket
    /// epoch (never per edge) and exit early leaving partial state the
    /// caller must not summarize. `None` = run to completion.
    pub cancel: Cancel<'a>,
    /// Per-round telemetry side-channel (the `Cancel`-style optional
    /// plumbing, for observability): when set, engines that support
    /// round recording push their [`AlgoTrace`] here and the serving
    /// layer distills it into
    /// [`EngineTelemetry`](crate::coordinator::trace::EngineTelemetry)
    /// on the traced result. A `RefCell` because the context is shared
    /// by `&` while the recorder needs `&mut`; engines borrow it only
    /// for the duration of their run. `None` (production default)
    /// costs one branch per round.
    pub trace: Option<&'a core::cell::RefCell<AlgoTrace>>,
}

impl EngineCtx<'_> {
    /// Borrow the telemetry recorder, if tracing. Engines thread
    /// `cx.recorder().as_deref_mut()` into their
    /// [`Recorder`](crate::sim::trace::Recorder) parameter.
    pub fn recorder(&self) -> Option<core::cell::RefMut<'_, AlgoTrace>> {
        self.trace.map(|c| c.borrow_mut())
    }
}

/// Compact typed algorithm output (the full vectors stay with the
/// caller when run through the library API; the serving layer reports
/// summaries).
#[derive(Debug, Clone, PartialEq)]
pub enum QueryOutput {
    /// (#reached, max distance) for BFS.
    Bfs { reached: usize, ecc: u32 },
    /// (#components, largest component size) for SCC.
    Scc { count: usize, largest: usize },
    /// (#blocks, #articulation points).
    Bcc { blocks: usize, articulation: usize },
    /// (#reached, max finite distance).
    Sssp { reached: usize, radius: f32 },
    /// (#connected components, largest component size).
    Cc { components: usize, largest: usize },
    /// (degeneracy = max coreness, #vertices in the max core).
    Kcore { degeneracy: u32, in_max_core: usize },
    /// (block size, #finite pairwise distances).
    Dense { block: usize, finite_pairs: usize },
    /// The request failed (unknown graph, out-of-range source,
    /// expired deadline, shed under overload, caught engine panic,
    /// ...): the serving loops answer *every* accepted request, so
    /// failures come back on the result channel with the request's id
    /// instead of vanishing into a log line. `kind` is the typed
    /// failure taxonomy ([`FailKind`]) clients branch on — retry
    /// later for `Overloaded`, don't bother for `InvalidGraph` — and
    /// `error` the human-readable detail.
    Failed { kind: FailKind, error: String },
}

/// A solo engine: answer one query against a loaded graph out of the
/// caller's warm workspace.
pub type SoloFn =
    fn(&EngineCtx, &LoadedGraph, Params, V, &mut QueryWorkspace) -> Result<QueryOutput>;

/// A traced engine: run once recording an execution trace for the
/// virtual-multicore scalability studies (the CLI `run` path). Uses
/// the classic allocate-per-call entry points — tracing is a
/// measurement mode, not a serving mode.
pub type TracedFn = fn(&LoadedGraph, Params, V, &mut AlgoTrace);

/// The batched multi-source engine of a fusable algorithm: `run` one
/// fused frontier walk over ≤ 64 seed lanes, then `demux` each lane
/// into a typed output (a parallel strided export out of the
/// workspace). Replaces the old per-algorithm fusability table + hard-coded
/// match arms in the coordinator.
pub struct BatchEngine {
    /// One fused walk over all `seeds` (≤ [`crate::algo::multi::MAX_LANES`]).
    /// The context carries the cancellation token (armed with the
    /// *tightest* lane deadline by the serving layer; polled once per
    /// round — a cancelled walk exits early and the caller re-walks
    /// the still-live lanes) and the optional telemetry recorder.
    pub run: fn(&EngineCtx, &LoadedGraph, Params, &[V], &mut QueryWorkspace),
    /// Summarize one lane of the walk just run (`lane < seeds.len()`,
    /// `n` = vertex count of the graph walked).
    pub demux: fn(&mut QueryWorkspace, usize, usize) -> QueryOutput,
}

/// Which derived graph views an algorithm's engines read. Callers use
/// this to materialize exactly the views a timed run will touch
/// *before* timing starts — and nothing more.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Views {
    /// Reads [`LoadedGraph::transpose`].
    pub transpose: bool,
    /// Reads [`LoadedGraph::symmetrized`].
    pub symmetrized: bool,
}

impl Views {
    /// Only the graph itself.
    pub const NONE: Views = Views {
        transpose: false,
        symmetrized: false,
    };
    /// The transpose (backward edges).
    pub const TRANSPOSE: Views = Views {
        transpose: true,
        symmetrized: false,
    };
    /// The symmetrized view (undirected algorithms on directed input).
    pub const SYMMETRIZED: Views = Views {
        transpose: false,
        symmetrized: true,
    };
}

/// One registry entry: everything the system needs to parse, label,
/// dispatch, fuse and trace an algorithm. Declared `static` so specs
/// are `'static` and a query can hold `&'static AlgoSpec` with no
/// lifetime plumbing and no allocation.
pub struct AlgoSpec {
    /// Dense stable id — the registry index; the fusion grouping key
    /// is `(graph, id, Params)`.
    pub id: u16,
    /// Canonical name; unique across the registry (metrics keys,
    /// CLI, `JobResult::algo` all use it).
    pub label: &'static str,
    /// Alternate names accepted by [`find`] (e.g. `"bfs"` for
    /// `"bfs-vgc"`).
    pub aliases: &'static [&'static str],
    /// True when the query's `source` must be a vertex of the graph
    /// (traversal algorithms); whole-graph analyses ignore it.
    pub needs_source: bool,
    /// True when the solo engine consults the AOT dense engine
    /// ([`EngineCtx::engine`]); callers only pay engine startup for
    /// specs that read it.
    pub needs_engine: bool,
    /// True when the output is fully determined by `(graph, Params)` —
    /// a whole-graph analysis reading no source vertex and no external
    /// engine — so the serving layer may answer repeated queries from
    /// the versioned result cache
    /// ([`crate::coordinator::ResultCache`]). Source-parameterized
    /// traversals must leave this false.
    pub cacheable: bool,
    /// The derived graph views the engines read (see [`Views`]).
    pub views: Views,
    /// Keep the parameters this algorithm understands, zero the rest
    /// (so the fusion grouping key never splits on irrelevant knobs).
    pub parse: fn(&ParseArgs) -> Params,
    /// The solo engine.
    pub solo: SoloFn,
    /// The batched multi-source engine, for algorithms that have one.
    pub batch: Option<&'static BatchEngine>,
    /// The trace-recording single-run engine (CLI `run` / sim).
    pub traced: Option<TracedFn>,
    /// Extracts the full per-vertex `u32` output (labels, coreness)
    /// the solo engine exported into the workspace — the payload of
    /// the full-vector result cache
    /// ([`crate::coordinator::ResultCache::lookup_vector`], served by
    /// `Coordinator::run_query_vector`). Only meaningful for
    /// `cacheable` specs whose engines fill
    /// [`QueryWorkspace::out_u32`]; `None` for summary-only specs.
    pub full: Option<fn(&QueryWorkspace) -> Vec<u32>>,
}

impl AlgoSpec {
    /// True when this spec has a batched multi-source engine — the
    /// coordinator fuses same-`(graph, id, Params)` groups of these
    /// into shared frontier walks.
    pub fn fusable(&self) -> bool {
        self.batch.is_some()
    }

    /// Does `name` name this spec (label or alias)?
    pub fn answers_to(&self, name: &str) -> bool {
        self.label == name || self.aliases.contains(&name)
    }

    /// Materialize exactly the derived views this spec's engines
    /// read, so a timed run afterwards measures the algorithm and
    /// not one-off view construction.
    pub fn prewarm(&self, lg: &LoadedGraph) {
        if self.views.transpose {
            lg.transpose();
        }
        if self.views.symmetrized {
            lg.symmetrized();
        }
    }
}

impl PartialEq for AlgoSpec {
    fn eq(&self, other: &Self) -> bool {
        self.id == other.id
    }
}

impl Eq for AlgoSpec {}

impl std::fmt::Debug for AlgoSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlgoSpec")
            .field("id", &self.id)
            .field("label", &self.label)
            .field("fusable", &self.fusable())
            .finish()
    }
}

/// One analysis request against the open API: which graph, which
/// registered algorithm, which source, which parameters. The
/// serving-layer [`JobRequest`](crate::coordinator::JobRequest) is
/// the same information plus a request id for the channel protocol
/// ([`JobRequest::from_query`](crate::coordinator::JobRequest::from_query)
/// converts losslessly); `Query` is the library-level type (see
/// [`crate::coordinator::Coordinator::run_query`]).
#[derive(Debug, Clone)]
pub struct Query {
    /// Name of a graph registered with the coordinator.
    pub graph: String,
    /// The registry entry to dispatch through.
    pub algo: &'static AlgoSpec,
    /// Source vertex (ignored when `algo.needs_source` is false).
    pub source: V,
    /// Parsed parameters (what [`AlgoSpec::parse`] kept).
    pub params: Params,
}

impl Query {
    /// Build a query by registry lookup: `algo` may be a label or any
    /// alias; `args` carries the raw parameter values, of which the
    /// spec keeps the ones it understands.
    pub fn new(graph: impl Into<String>, algo: &str, args: &ParseArgs) -> Result<Query> {
        let spec = find(algo)
            .ok_or_else(|| Error::msg(format!("unknown algorithm {algo:?} (not in the registry)")))?;
        Ok(Query {
            graph: graph.into(),
            algo: spec,
            source: 0,
            params: (spec.parse)(args),
        })
    }

    /// Set the source vertex (builder style).
    pub fn with_source(mut self, source: V) -> Query {
        self.source = source;
        self
    }
}
