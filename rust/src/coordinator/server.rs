//! The coordinator server: graph registry, per-graph batching, job
//! execution, and a channel-based serving loop.

use super::dense::DenseBlock;
use super::job::{AlgoKind, JobOutput, JobRequest, JobResult};
use super::metrics::Metrics;
use crate::algo::{bcc, bfs, scc, sssp, UNREACHED};
use crate::graph::Graph;
use crate::runtime::EngineHandle;
use crate::{INF, V};
use anyhow::{bail, Context, Result};
use once_cell::sync::OnceCell;
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A registered graph with lazily materialized derived views.
pub struct LoadedGraph {
    pub graph: Arc<Graph>,
    transpose: OnceCell<Arc<Graph>>,
    symmetrized: OnceCell<Arc<Graph>>,
}

impl LoadedGraph {
    pub fn new(graph: Graph) -> Self {
        LoadedGraph {
            graph: Arc::new(graph),
            transpose: OnceCell::new(),
            symmetrized: OnceCell::new(),
        }
    }

    /// Transpose, computed once on first use.
    pub fn transpose(&self) -> &Graph {
        if self.graph.symmetric {
            return &self.graph;
        }
        self.transpose
            .get_or_init(|| Arc::new(self.graph.transpose()))
    }

    /// Symmetrized view (identity for already-symmetric graphs).
    pub fn symmetrized(&self) -> &Graph {
        if self.graph.symmetric {
            return &self.graph;
        }
        self.symmetrized
            .get_or_init(|| Arc::new(self.graph.symmetrize()))
    }
}

/// The analysis-job coordinator.
pub struct Coordinator {
    graphs: Mutex<HashMap<String, Arc<LoadedGraph>>>,
    engine: Option<EngineHandle>,
    pub metrics: Metrics,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// Coordinator without a dense engine (sparse algorithms only).
    pub fn new() -> Self {
        Coordinator {
            graphs: Mutex::new(HashMap::new()),
            engine: None,
            metrics: Metrics::new(),
        }
    }

    /// Coordinator with the PJRT dense engine attached.
    pub fn with_engine(engine: EngineHandle) -> Self {
        Coordinator {
            graphs: Mutex::new(HashMap::new()),
            engine: Some(engine),
            metrics: Metrics::new(),
        }
    }

    /// Register a graph under `name` (replaces any previous one).
    pub fn load_graph(&self, name: &str, graph: Graph) {
        self.graphs
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::new(LoadedGraph::new(graph)));
        self.metrics.bump("graphs_loaded", 1);
    }

    /// Fetch a registered graph.
    pub fn graph(&self, name: &str) -> Option<Arc<LoadedGraph>> {
        self.graphs.lock().unwrap().get(name).cloned()
    }

    /// Execute one request immediately (no queueing).
    pub fn execute(&self, req: &JobRequest) -> Result<JobResult> {
        let submitted = Instant::now();
        let lg = self
            .graph(&req.graph)
            .with_context(|| format!("unknown graph {:?}", req.graph))?;
        let g = &*lg.graph;
        if matches!(
            req.algo,
            AlgoKind::BfsVgc { .. }
                | AlgoKind::BfsFrontier
                | AlgoKind::BfsDirOpt
                | AlgoKind::SsspRho { .. }
                | AlgoKind::SsspDelta
        ) && (req.source as usize) >= g.n()
        {
            bail!("source {} out of range (n={})", req.source, g.n());
        }

        let exec_start = Instant::now();
        let output = match req.algo {
            AlgoKind::BfsVgc { tau } => summarize_bfs(&bfs::vgc_bfs(g, req.source, tau, None)),
            AlgoKind::BfsFrontier => summarize_bfs(&bfs::frontier_bfs(g, req.source, None)),
            AlgoKind::BfsDirOpt => {
                summarize_bfs(&bfs::diropt_bfs(g, Some(lg.transpose()), req.source, None))
            }
            AlgoKind::SccVgc { tau } => {
                summarize_scc(&scc::vgc_scc(g, Some(lg.transpose()), tau, 42, None))
            }
            AlgoKind::SccMultistep => {
                summarize_scc(&scc::multistep_scc(g, Some(lg.transpose()), None))
            }
            AlgoKind::Bcc => {
                let r = bcc::fast_bcc(lg.symmetrized(), None);
                JobOutput::Bcc {
                    blocks: r.n_bcc,
                    articulation: r.articulation.iter().filter(|&&a| a).count(),
                }
            }
            AlgoKind::SsspRho { tau } => {
                summarize_sssp(&sssp::rho_stepping(g, req.source, tau, None))
            }
            AlgoKind::SsspDelta => {
                summarize_sssp(&sssp::delta_stepping(g, req.source, None, None))
            }
            AlgoKind::DenseClosure { block } => {
                let engine = self
                    .engine
                    .as_ref()
                    .context("no dense engine attached (run `make artifacts`)")?;
                let tile = engine
                    .closure_tiles()
                    .into_iter()
                    .filter(|&t| t >= block.min(g.n()))
                    .min()
                    .context("no closure artifact large enough")?;
                let k = block.min(g.n()).min(tile);
                let vs = DenseBlock::top_degree_block(g, k);
                let db = DenseBlock::extract(g, &vs, tile);
                let closure = db.closure(engine)?;
                let finite = closure.iter().filter(|&&d| d < INF).count();
                JobOutput::Dense {
                    block: k,
                    finite_pairs: finite,
                }
            }
        };
        let exec = exec_start.elapsed();
        let latency = submitted.elapsed();
        self.metrics.bump("jobs_executed", 1);
        self.metrics.observe(&format!("exec/{}", req.algo.label()), exec);
        Ok(JobResult {
            id: req.id,
            algo: req.algo.label(),
            output,
            exec,
            latency,
        })
    }

    /// Run a batch: requests grouped by graph (cache-warm batching),
    /// results returned in submission order. Latencies include the
    /// in-batch queueing delay.
    pub fn run_batch(&self, reqs: &[JobRequest]) -> Vec<Result<JobResult>> {
        let t0 = Instant::now();
        // Group indices by graph, preserving order within groups.
        let mut groups: HashMap<&str, Vec<usize>> = HashMap::new();
        for (i, r) in reqs.iter().enumerate() {
            groups.entry(r.graph.as_str()).or_default().push(i);
        }
        let mut order: Vec<&str> = groups.keys().copied().collect();
        order.sort();
        let mut results: Vec<Option<Result<JobResult>>> = (0..reqs.len()).map(|_| None).collect();
        for name in order {
            for &i in &groups[name] {
                let mut res = self.execute(&reqs[i]);
                if let Ok(r) = res.as_mut() {
                    r.latency = t0.elapsed(); // include batch queueing
                    self.metrics.observe("latency", r.latency);
                }
                results[i] = Some(res);
            }
        }
        self.metrics.bump("batches", 1);
        results.into_iter().map(|r| r.unwrap()).collect()
    }

    /// Serving loop: drain the request channel, batch what is
    /// immediately available (up to `max_batch`), execute, respond.
    /// Returns when the request channel closes.
    pub fn serve(&self, rx: Receiver<JobRequest>, tx: Sender<JobResult>, max_batch: usize) {
        loop {
            // Block for the first request.
            let Ok(first) = rx.recv() else { return };
            let mut batch = vec![first];
            while batch.len() < max_batch {
                match rx.try_recv() {
                    Ok(r) => batch.push(r),
                    Err(_) => break,
                }
            }
            self.metrics.bump("batched_requests", batch.len() as u64);
            for res in self.run_batch(&batch) {
                match res {
                    Ok(r) => {
                        if tx.send(r).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        self.metrics.bump("errors", 1);
                        eprintln!("coordinator: job failed: {e:#}");
                    }
                }
            }
        }
    }
}

fn summarize_bfs(dist: &[u32]) -> JobOutput {
    let mut reached = 0usize;
    let mut ecc = 0u32;
    for &d in dist {
        if d != UNREACHED {
            reached += 1;
            ecc = ecc.max(d);
        }
    }
    JobOutput::Bfs { reached, ecc }
}

fn summarize_scc(labels: &[u32]) -> JobOutput {
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &l in labels {
        *counts.entry(l).or_insert(0) += 1;
    }
    JobOutput::Scc {
        count: counts.len(),
        largest: counts.values().copied().max().unwrap_or(0),
    }
}

fn summarize_sssp(dist: &[f32]) -> JobOutput {
    let mut reached = 0usize;
    let mut radius = 0.0f32;
    for &d in dist {
        if d < INF {
            reached += 1;
            radius = radius.max(d);
        }
    }
    JobOutput::Sssp { reached, radius }
}

/// Convenience: build requests for a synthetic workload trace.
pub fn workload(graphs: &[&str], algos: &[AlgoKind], queries: usize, seed: u64) -> Vec<JobRequest> {
    let mut rng = crate::prop::Rng::new(seed);
    (0..queries as u64)
        .map(|id| JobRequest {
            id,
            graph: graphs[rng.range(0, graphs.len())].to_string(),
            algo: *rng.pick(algos),
            source: rng.below(1 << 14) as V, // clamped by caller's graphs
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn coord_with_graphs() -> Coordinator {
        let c = Coordinator::new();
        c.load_graph("road", gen::road(8, 12, 1));
        c.load_graph("social", gen::social(9, 8, 2));
        c
    }

    #[test]
    fn execute_bfs_and_scc() {
        let c = coord_with_graphs();
        let r = c
            .execute(&JobRequest {
                id: 1,
                graph: "road".into(),
                algo: AlgoKind::BfsVgc { tau: 64 },
                source: 0,
            })
            .unwrap();
        match r.output {
            JobOutput::Bfs { reached, .. } => assert!(reached > 1),
            other => panic!("wrong output {other:?}"),
        }
        let r = c
            .execute(&JobRequest {
                id: 2,
                graph: "social".into(),
                algo: AlgoKind::SccVgc { tau: 64 },
                source: 0,
            })
            .unwrap();
        match r.output {
            JobOutput::Scc { count, largest } => {
                assert!(count >= 1 && largest >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn unknown_graph_and_bad_source_error() {
        let c = coord_with_graphs();
        assert!(c
            .execute(&JobRequest {
                id: 1,
                graph: "nope".into(),
                algo: AlgoKind::BfsFrontier,
                source: 0,
            })
            .is_err());
        assert!(c
            .execute(&JobRequest {
                id: 2,
                graph: "road".into(),
                algo: AlgoKind::BfsFrontier,
                source: u32::MAX - 1,
            })
            .is_err());
    }

    #[test]
    fn variants_agree_through_the_server() {
        let c = coord_with_graphs();
        let mk = |algo| JobRequest {
            id: 0,
            graph: "road".into(),
            algo,
            source: 3,
        };
        let a = c.execute(&mk(AlgoKind::BfsVgc { tau: 32 })).unwrap();
        let b = c.execute(&mk(AlgoKind::BfsFrontier)).unwrap();
        let d = c.execute(&mk(AlgoKind::BfsDirOpt)).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(b.output, d.output);
        let x = c.execute(&mk(AlgoKind::SsspRho { tau: 32 })).unwrap();
        let y = c.execute(&mk(AlgoKind::SsspDelta)).unwrap();
        match (&x.output, &y.output) {
            (
                JobOutput::Sssp {
                    reached: r1,
                    radius: d1,
                },
                JobOutput::Sssp {
                    reached: r2,
                    radius: d2,
                },
            ) => {
                assert_eq!(r1, r2);
                assert!((d1 - d2).abs() <= 1e-2 * d2.max(1.0));
            }
            other => panic!("wrong outputs {other:?}"),
        }
    }

    #[test]
    fn batch_returns_in_submission_order_and_observes_metrics() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..6)
            .map(|i| JobRequest {
                id: i,
                graph: if i % 2 == 0 { "road" } else { "social" }.into(),
                algo: AlgoKind::BfsVgc { tau: 64 },
                source: (i % 3) as V,
            })
            .collect();
        let out = c.run_batch(&reqs);
        assert_eq!(out.len(), 6);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().id, i as u64);
        }
        assert_eq!(c.metrics.counter("jobs_executed"), 6);
        assert!(c.metrics.summary("latency").unwrap().count == 6);
    }

    #[test]
    fn serve_loop_over_channels() {
        let c = Arc::new(coord_with_graphs());
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let server = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || c.serve(req_rx, res_tx, 8))
        };
        for i in 0..10u64 {
            req_tx
                .send(JobRequest {
                    id: i,
                    graph: "road".into(),
                    algo: AlgoKind::SsspRho { tau: 64 },
                    source: (i % 5) as V,
                })
                .unwrap();
        }
        drop(req_tx);
        let mut got: Vec<u64> = res_rx.iter().map(|r| r.id).collect();
        server.join().unwrap();
        got.sort();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn workload_generator_is_deterministic() {
        let a = workload(&["g1", "g2"], &[AlgoKind::BfsFrontier], 20, 7);
        let b = workload(&["g1", "g2"], &[AlgoKind::BfsFrontier], 20, 7);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.graph, y.graph);
            assert_eq!(x.source, y.source);
        }
    }
}
