//! The coordinator server: graph registry, per-graph batching,
//! multi-source query fusion, job execution, a per-worker
//! [`QueryWorkspace`] pool, and a channel-based serving loop.
//!
//! The workspace pool is what makes the serving path a
//! *zero-allocation query engine*: each request checks a warm
//! [`QueryWorkspace`] out of the pool, answers through the `_ws`
//! algorithm entry points (epoch-stamped scratch, reused hash bags —
//! see [`crate::algo::workspace`]), and returns it. After each
//! workspace has served one query per graph size, steady-state queries
//! perform no O(n)/O(m) allocation at all.
//!
//! **Dispatch is table-driven**: execution resolves each request's
//! [`AlgoSpec`] out of the algorithm registry ([`crate::algo::api`])
//! and calls the spec's engines — there are no per-algorithm match
//! arms here. Registering an algorithm (one registry line) makes it
//! servable through every path in this file.
//!
//! On top of that, [`ExecCore::run_batch_from`] **fuses** queries:
//! requests are grouped by `(graph, spec id, params)` — same-graph
//! batching for cache warmth, as before — and groups whose spec has a
//! batched multi-source engine ([`AlgoSpec::fusable`]) run through its
//! [`BatchEngine`] in chunks of up to 64 sources per frontier walk.
//! Per-lane results are demultiplexed (a parallel strided export)
//! back into per-request [`JobResult`]s in submission order; fusion is
//! invisible to clients except in the `queries_fused` /
//! `queries_solo` metrics and the latency column.
//!
//! Execution itself lives in [`ExecCore`], which owns **no** shared
//! state: it borrows an engine and a metrics registry and is handed a
//! workspace and a graph-lookup function per call. [`Coordinator`]
//! drives it with the global Mutex-guarded pool and registry; the
//! sharded server ([`super::shard`]) drives the same core with
//! shard-local pools and lock-free registry snapshots, so both paths
//! execute — and meter — queries identically.
//!
//! [`BatchEngine`]: crate::algo::api::BatchEngine

use super::directory::{GraphDirectory, LoadedGraph};
use super::job::{JobOutput, JobRequest, JobResult};
use super::metrics::Metrics;
use super::shard::admit_batch;
use crate::algo::api::{AlgoSpec, EngineCtx, Params, Query};
use crate::algo::workspace::{QueryWorkspace, WorkspacePool};
use crate::bail;
use crate::error::{Context, Error, Result};
use crate::runtime::EngineHandle;
use crate::V;
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Most sources per fused frontier walk (one mask bit each — see
/// [`crate::algo::multi`]).
pub(crate) const MAX_FUSE: usize = crate::algo::multi::MAX_LANES;

/// The analysis-job coordinator.
pub struct Coordinator {
    /// Snapshot-published graph registry; shard workers read it
    /// through lock-free [`super::directory::SnapshotCache`]s.
    pub(crate) directory: GraphDirectory,
    engine: Option<EngineHandle>,
    /// Warm per-worker query workspaces: checked out per request,
    /// returned after, so the steady-state serving path performs zero
    /// O(n) allocation (see module docs). Shard workers bypass this
    /// Mutex entirely with pools of their own.
    workspaces: Mutex<WorkspacePool>,
    pub metrics: Metrics,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// Coordinator without a dense engine (sparse algorithms only).
    pub fn new() -> Self {
        Coordinator {
            directory: GraphDirectory::new(),
            engine: None,
            workspaces: Mutex::new(WorkspacePool::new()),
            metrics: Metrics::new(),
        }
    }

    /// Coordinator with the dense engine attached.
    pub fn with_engine(engine: EngineHandle) -> Self {
        Coordinator {
            directory: GraphDirectory::new(),
            engine: Some(engine),
            workspaces: Mutex::new(WorkspacePool::new()),
            metrics: Metrics::new(),
        }
    }

    /// The graph registry (shard workers cache snapshots of it).
    pub fn directory(&self) -> &GraphDirectory {
        &self.directory
    }

    /// The dense engine, if one is attached.
    pub(crate) fn engine(&self) -> Option<&EngineHandle> {
        self.engine.as_ref()
    }

    /// The execution core bound to this coordinator's engine and
    /// global metrics.
    pub(crate) fn core(&self) -> ExecCore<'_> {
        ExecCore {
            engine: self.engine.as_ref(),
            metrics: &self.metrics,
        }
    }

    /// Check a workspace out of the pool (fresh if none is warm).
    fn checkout_workspace(&self) -> QueryWorkspace {
        let mut pool = self.workspaces.lock().unwrap();
        if pool.is_empty() {
            self.metrics.bump("workspaces_created", 1);
        }
        pool.checkout()
    }

    /// Return a workspace to the pool for the next request.
    fn checkin_workspace(&self, ws: QueryWorkspace) {
        self.workspaces.lock().unwrap().checkin(ws);
    }

    /// Run `f` with a pooled workspace checked out for its duration —
    /// the one checkout/execute/checkin pattern every ad-hoc execution
    /// path shares.
    fn with_workspace<R>(&self, f: impl FnOnce(&mut QueryWorkspace) -> R) -> R {
        let mut ws = self.checkout_workspace();
        let out = f(&mut ws);
        self.checkin_workspace(ws);
        out
    }

    /// Number of idle workspaces in the global pool (tests/metrics).
    pub fn idle_workspaces(&self) -> usize {
        self.workspaces.lock().unwrap().len()
    }

    /// Register a graph under `name` (replaces any previous one) by
    /// publishing a new registry snapshot.
    pub fn load_graph(&self, name: &str, graph: crate::graph::Graph) {
        self.directory.publish(name, graph);
        self.metrics.bump("graphs_loaded", 1);
    }

    /// Fetch a registered graph.
    pub fn graph(&self, name: &str) -> Option<Arc<LoadedGraph>> {
        self.directory.lookup(name)
    }

    /// Execute one request immediately (no queueing).
    pub fn execute(&self, req: &JobRequest) -> Result<JobResult> {
        self.with_workspace(|ws| self.core().execute_one(req, self.graph(&req.graph), ws))
    }

    /// Execute one [`Query`] from the open API immediately. This is
    /// the fully registry-native path: it dispatches on the query's
    /// `&'static AlgoSpec` directly, so it serves *any* registered
    /// spec — including future ones with no [`AlgoKind`] shim
    /// encoding for the channel protocol. A [`Query`] carries no
    /// request id (ids belong to the channel protocol), so the
    /// returned [`JobResult::id`] is always 0 — correlate by call
    /// site.
    ///
    /// [`AlgoKind`]: super::job::AlgoKind
    pub fn run_query(&self, q: &Query) -> Result<JobResult> {
        self.with_workspace(|ws| {
            self.core().execute_resolved(
                0,
                &q.graph,
                q.algo,
                q.params,
                q.source,
                self.graph(&q.graph),
                ws,
            )
        })
    }

    /// Run a batch: requests grouped by (graph, algorithm, params) —
    /// same-graph batching for cache warmth, same-spec grouping for
    /// multi-source fusion — results returned in submission order.
    /// See [`ExecCore::run_batch_from`].
    pub fn run_batch(&self, reqs: &[JobRequest]) -> Vec<Result<JobResult>> {
        self.run_batch_from(Instant::now(), reqs)
    }

    /// [`Coordinator::run_batch`] with an explicit latency epoch: the
    /// serving loops pass the head request's arrival time so reported
    /// latencies include the fusion-window wait.
    fn run_batch_from(&self, t0: Instant, reqs: &[JobRequest]) -> Vec<Result<JobResult>> {
        self.with_workspace(|ws| self.core().run_batch_from(t0, reqs, |name| self.graph(name), ws))
    }

    /// Serving loop: drain the request channel, batch what is
    /// immediately available (up to `max_batch`), execute, respond.
    /// Returns when the request channel closes. Equivalent to
    /// [`Coordinator::serve_windowed`] with a zero fusion window.
    pub fn serve(&self, rx: Receiver<JobRequest>, tx: Sender<JobResult>, max_batch: usize) {
        self.serve_windowed(rx, tx, max_batch, Duration::ZERO);
    }

    /// Serving loop with a fusion-window admission queue: when the
    /// head request is fusable and `window` is nonzero, wait up to the
    /// window deadline draining the channel to accumulate same-(graph,
    /// spec, params) lanes before dispatching; non-fusable heads fall
    /// through immediately (see [`super::shard::admit_batch`]).
    ///
    /// **Shutdown invariant:** when the request channel closes
    /// mid-window, requests already drained into the current batch are
    /// still executed and answered — closing the channel never drops
    /// accepted work. Failures are answered too, as
    /// [`JobOutput::Failed`] results carrying the request id.
    pub fn serve_windowed(
        &self,
        rx: Receiver<JobRequest>,
        tx: Sender<JobResult>,
        max_batch: usize,
        window: Duration,
    ) {
        let max_batch = max_batch.max(1);
        loop {
            // Block for the first request.
            let Ok(first) = rx.recv() else { return };
            // Latency epoch: the head request is waiting from here on,
            // so the fusion-window wait counts toward its latency.
            let t0 = Instant::now();
            let mut batch = vec![first];
            admit_batch(&rx, &mut batch, max_batch, window, &self.metrics);
            self.metrics.bump("batched_requests", batch.len() as u64);
            let results = self.run_batch_from(t0, &batch);
            for (req, res) in batch.iter().zip(results) {
                let jr = answer(req, res, t0, &self.metrics);
                if tx.send(jr).is_err() {
                    return;
                }
            }
        }
    }
}

/// The request-execution core: registry dispatch, batching and
/// fusion, decoupled from any particular workspace pool or registry.
/// Holds no shared state of its own — callers hand it a workspace and
/// a graph-lookup function, so the shard hot path runs it without
/// taking a single Mutex.
pub(crate) struct ExecCore<'a> {
    pub engine: Option<&'a EngineHandle>,
    pub metrics: &'a Metrics,
}

impl ExecCore<'_> {
    /// Execute one request against an already-resolved graph.
    pub(crate) fn execute_one(
        &self,
        req: &JobRequest,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
    ) -> Result<JobResult> {
        self.execute_resolved(
            req.id,
            &req.graph,
            req.algo.spec(),
            req.algo.params(),
            req.source,
            lg,
            ws,
        )
    }

    /// The shared solo execution path: every request — shim-encoded
    /// [`JobRequest`] or registry-native [`Query`] — resolves to
    /// `(spec, params, source)` and runs the spec's solo engine out of
    /// the caller's warm workspace.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_resolved(
        &self,
        id: u64,
        graph: &str,
        spec: &'static AlgoSpec,
        params: Params,
        source: V,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
    ) -> Result<JobResult> {
        let submitted = Instant::now();
        let lg = lg.with_context(|| format!("unknown graph {graph:?}"))?;
        // Answer out of the caller's warm workspace: the steady-state
        // query path performs zero O(n)/O(m) allocation (epoch-stamped
        // scratch, reused bags and export buffers).
        let exec_start = Instant::now();
        let output = self.run_spec(spec, params, source, &lg, ws)?;
        let exec = exec_start.elapsed();
        let latency = submitted.elapsed();
        self.metrics.bump("jobs_executed", 1);
        self.metrics.observe(&format!("exec/{}", spec.label), exec);
        Ok(JobResult {
            id,
            algo: spec.label,
            output,
            exec,
            latency,
        })
    }

    /// Validate and dispatch one query through its spec's solo engine.
    fn run_spec(
        &self,
        spec: &'static AlgoSpec,
        params: Params,
        source: V,
        lg: &LoadedGraph,
        ws: &mut QueryWorkspace,
    ) -> Result<JobOutput> {
        let g = &*lg.graph;
        if spec.needs_source && (source as usize) >= g.n() {
            bail!("source {} out of range (n={})", source, g.n());
        }
        (spec.solo)(&EngineCtx { engine: self.engine }, lg, params, source, ws)
    }

    /// Run a batch against `lookup`: requests grouped by `(graph,
    /// spec id, params)`, groups of ≥ 2 requests whose spec has a
    /// [`BatchEngine`](crate::algo::api::BatchEngine) answered by one
    /// batched frontier walk per ≤ 64 sources, everything else run
    /// solo — results in submission order. Latencies are measured
    /// from `t0`: the serving loops pass the head request's arrival
    /// time, so the fusion-window wait and in-batch queueing delay are
    /// both included. The whole batch shares the one `ws` (batch
    /// execution is serial on the calling worker).
    pub(crate) fn run_batch_from(
        &self,
        t0: Instant,
        reqs: &[JobRequest],
        lookup: impl Fn(&str) -> Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
    ) -> Vec<Result<JobResult>> {
        // Group indices by the registry key (graph, spec id, params),
        // preserving order within groups. Params is part of the key,
        // so e.g. two BfsVgc τ values never fuse together.
        let mut groups: HashMap<(&str, u16, Params), Vec<usize>> = HashMap::new();
        for (i, r) in reqs.iter().enumerate() {
            groups
                .entry((r.graph.as_str(), r.algo.spec().id, r.algo.params()))
                .or_default()
                .push(i);
        }
        // Deterministic batch schedule: graph name, then registry id,
        // then params.
        let mut order: Vec<(&str, u16, Params)> = groups.keys().copied().collect();
        order.sort_unstable();
        let mut results: Vec<Option<Result<JobResult>>> = (0..reqs.len()).map(|_| None).collect();
        for key in order {
            let idxs = &groups[&key];
            let spec = reqs[idxs[0]].algo.spec();
            if spec.fusable() && idxs.len() >= 2 {
                let lg = lookup(&reqs[idxs[0]].graph);
                self.run_fused_group(reqs, idxs, spec, key.2, lg, ws, &mut results);
            } else {
                for &i in idxs {
                    self.metrics.bump("queries_solo", 1);
                    results[i] = Some(self.execute_one(&reqs[i], lookup(&reqs[i].graph), ws));
                }
            }
        }
        self.metrics.bump("batches", 1);
        results
            .into_iter()
            .map(|r| {
                let mut res = r.expect("every request answered");
                if let Ok(jr) = res.as_mut() {
                    jr.latency = t0.elapsed(); // include batch queueing
                    self.metrics.observe("latency", jr.latency);
                }
                res
            })
            .collect()
    }

    /// Answer one (graph, spec, params) group of fusable requests with
    /// the spec's batched multi-source engine (≤ [`MAX_FUSE`] sources
    /// per walk) and demultiplex per-lane results back into the slots
    /// of `results`.
    #[allow(clippy::too_many_arguments)]
    fn run_fused_group(
        &self,
        reqs: &[JobRequest],
        idxs: &[usize],
        spec: &'static AlgoSpec,
        params: Params,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        results: &mut [Option<Result<JobResult>>],
    ) {
        let be = spec.batch.expect("fused group requires a batch engine");
        // queries_fused counts every request *routed* to the fused
        // path (errors included), so queries_fused + queries_solo
        // always equals the batch size and fused_fraction stays exact.
        let Some(lg) = lg else {
            for &i in idxs {
                self.metrics.bump("queries_fused", 1);
                results[i] = Some(Err(Error::msg(format!(
                    "unknown graph {:?}",
                    reqs[i].graph
                ))));
            }
            return;
        };
        let n = lg.graph.n();
        // Out-of-range sources fail individually; the rest still fuse.
        let mut valid: Vec<usize> = Vec::with_capacity(idxs.len());
        for &i in idxs {
            if (reqs[i].source as usize) >= n {
                self.metrics.bump("queries_fused", 1);
                results[i] = Some(Err(Error::msg(format!(
                    "source {} out of range (n={n})",
                    reqs[i].source
                ))));
            } else {
                valid.push(i);
            }
        }
        for chunk in valid.chunks(MAX_FUSE) {
            let seeds: Vec<V> = chunk.iter().map(|&i| reqs[i].source).collect();
            let lanes = seeds.len();
            let exec_start = Instant::now();
            (be.run)(&lg, params, &seeds, ws);
            // The walk is shared: each fused request's exec is the
            // whole walk's time (vs. k walks unfused).
            let exec = exec_start.elapsed();
            for (lane, &i) in chunk.iter().enumerate() {
                let output = (be.demux)(ws, lane, n);
                self.metrics.bump("jobs_executed", 1);
                self.metrics.bump("queries_fused", 1);
                self.metrics.observe(&format!("exec/{}", spec.label), exec);
                results[i] = Some(Ok(JobResult {
                    id: reqs[i].id,
                    algo: spec.label,
                    output,
                    exec,
                    // Placeholder: run_batch stamps every Ok result
                    // with the batch-relative latency.
                    latency: exec,
                }));
            }
            self.metrics.bump("fused_walks", 1);
            self.metrics.bump("fused_lanes", lanes as u64);
        }
    }
}

/// Turn one batch slot into the response sent to the client: failures
/// become [`JobOutput::Failed`] results carrying the request's id (and
/// bump the `errors` counter), so every accepted request is answered
/// and clients correlating responses by id never hang on an error.
pub(crate) fn answer(
    req: &JobRequest,
    res: Result<JobResult>,
    t0: Instant,
    metrics: &Metrics,
) -> JobResult {
    match res {
        Ok(r) => r,
        Err(e) => {
            metrics.bump("errors", 1);
            let latency = t0.elapsed();
            // Failures count toward the latency series too — a
            // half-failing workload must not report the percentiles
            // of its successes only.
            metrics.observe("latency", latency);
            JobResult {
                id: req.id,
                algo: req.algo.label(),
                output: JobOutput::Failed {
                    error: format!("{e:#}"),
                },
                exec: Duration::ZERO,
                latency,
            }
        }
    }
}

/// Convenience: build requests for a synthetic workload trace.
pub fn workload(
    graphs: &[&str],
    algos: &[super::job::AlgoKind],
    queries: usize,
    seed: u64,
) -> Vec<JobRequest> {
    let mut rng = crate::prop::Rng::new(seed);
    (0..queries as u64)
        .map(|id| JobRequest {
            id,
            graph: graphs[rng.range(0, graphs.len())].to_string(),
            algo: *rng.pick(algos),
            source: rng.below(1 << 14) as V, // clamped by caller's graphs
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::api::ParseArgs;
    use crate::coordinator::job::AlgoKind;
    use crate::graph::gen;

    fn coord_with_graphs() -> Coordinator {
        let c = Coordinator::new();
        c.load_graph("road", gen::road(8, 12, 1));
        c.load_graph("social", gen::social(9, 8, 2));
        c
    }

    #[test]
    fn execute_bfs_and_scc() {
        let c = coord_with_graphs();
        let r = c
            .execute(&JobRequest {
                id: 1,
                graph: "road".into(),
                algo: AlgoKind::BfsVgc { tau: 64 },
                source: 0,
            })
            .unwrap();
        match r.output {
            JobOutput::Bfs { reached, .. } => assert!(reached > 1),
            other => panic!("wrong output {other:?}"),
        }
        let r = c
            .execute(&JobRequest {
                id: 2,
                graph: "social".into(),
                algo: AlgoKind::SccVgc { tau: 64 },
                source: 0,
            })
            .unwrap();
        match r.output {
            JobOutput::Scc { count, largest } => {
                assert!(count >= 1 && largest >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn execute_registry_opened_cc_and_kcore() {
        // The algorithms the registry opened for serving: CC and
        // k-core answer through the same workspace path as everything
        // else.
        let c = coord_with_graphs();
        let r = c
            .execute(&JobRequest {
                id: 1,
                graph: "road".into(),
                algo: AlgoKind::Cc,
                source: 0,
            })
            .unwrap();
        assert_eq!(r.algo, "cc");
        match r.output {
            JobOutput::Cc { components, largest } => {
                assert!(components >= 1 && largest >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
        let r = c
            .execute(&JobRequest {
                id: 2,
                graph: "social".into(),
                algo: AlgoKind::Kcore,
                source: 0,
            })
            .unwrap();
        assert_eq!(r.algo, "kcore");
        match r.output {
            JobOutput::Kcore {
                degeneracy,
                in_max_core,
            } => {
                assert!(degeneracy >= 1 && in_max_core >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn run_query_matches_shim_execution() {
        // The registry-native Query path and the AlgoKind shim path
        // must answer identically.
        let c = coord_with_graphs();
        let q = Query::new("road", "bfs", &ParseArgs { tau: 64, block: 64 })
            .unwrap()
            .with_source(3);
        let via_query = c.run_query(&q).unwrap();
        let via_shim = c
            .execute(&JobRequest {
                id: 0,
                graph: "road".into(),
                algo: AlgoKind::BfsVgc { tau: 64 },
                source: 3,
            })
            .unwrap();
        assert_eq!(via_query.output, via_shim.output);
        assert_eq!(via_query.algo, via_shim.algo);
        // Unknown graphs fail the same way.
        let q = Query::new("ghost", "cc", &ParseArgs::default()).unwrap();
        assert!(c.run_query(&q).is_err());
    }

    #[test]
    fn unknown_graph_and_bad_source_error() {
        let c = coord_with_graphs();
        assert!(c
            .execute(&JobRequest {
                id: 1,
                graph: "nope".into(),
                algo: AlgoKind::BfsFrontier,
                source: 0,
            })
            .is_err());
        assert!(c
            .execute(&JobRequest {
                id: 2,
                graph: "road".into(),
                algo: AlgoKind::BfsFrontier,
                source: u32::MAX - 1,
            })
            .is_err());
    }

    #[test]
    fn variants_agree_through_the_server() {
        let c = coord_with_graphs();
        let mk = |algo| JobRequest {
            id: 0,
            graph: "road".into(),
            algo,
            source: 3,
        };
        let a = c.execute(&mk(AlgoKind::BfsVgc { tau: 32 })).unwrap();
        let b = c.execute(&mk(AlgoKind::BfsFrontier)).unwrap();
        let d = c.execute(&mk(AlgoKind::BfsDirOpt)).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(b.output, d.output);
        let x = c.execute(&mk(AlgoKind::SsspRho { tau: 32 })).unwrap();
        let y = c.execute(&mk(AlgoKind::SsspDelta)).unwrap();
        match (&x.output, &y.output) {
            (
                JobOutput::Sssp {
                    reached: r1,
                    radius: d1,
                },
                JobOutput::Sssp {
                    reached: r2,
                    radius: d2,
                },
            ) => {
                assert_eq!(r1, r2);
                assert!((d1 - d2).abs() <= 1e-2 * d2.max(1.0));
            }
            other => panic!("wrong outputs {other:?}"),
        }
    }

    #[test]
    fn batch_returns_in_submission_order_and_observes_metrics() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..6)
            .map(|i| JobRequest {
                id: i,
                graph: if i % 2 == 0 { "road" } else { "social" }.into(),
                algo: AlgoKind::BfsVgc { tau: 64 },
                source: (i % 3) as V,
            })
            .collect();
        let out = c.run_batch(&reqs);
        assert_eq!(out.len(), 6);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().id, i as u64);
        }
        assert_eq!(c.metrics.counter("jobs_executed"), 6);
        assert!(c.metrics.summary("latency").unwrap().count == 6);
    }

    #[test]
    fn workspace_pool_reuses_one_workspace_for_serial_queries() {
        let c = coord_with_graphs();
        for i in 0..12u64 {
            let algo = match i % 4 {
                0 => AlgoKind::BfsVgc { tau: 64 },
                1 => AlgoKind::SsspRho { tau: 64 },
                2 => AlgoKind::SccVgc { tau: 64 },
                _ => AlgoKind::SsspDelta,
            };
            c.execute(&JobRequest {
                id: i,
                graph: if i % 2 == 0 { "road" } else { "social" }.into(),
                algo,
                source: (i % 3) as V,
            })
            .unwrap();
        }
        // Serial queries always find the previously checked-in
        // workspace: exactly one is ever created.
        assert_eq!(c.metrics.counter("workspaces_created"), 1);
        assert_eq!(c.idle_workspaces(), 1);
    }

    #[test]
    fn workspace_and_fresh_paths_agree() {
        let c = coord_with_graphs();
        let mk = |algo| JobRequest {
            id: 0,
            graph: "road".into(),
            algo,
            source: 5,
        };
        // Run everything twice: the second pass uses warm workspaces
        // and must produce identical summaries.
        for algo in [
            AlgoKind::BfsVgc { tau: 64 },
            AlgoKind::BfsDirOpt,
            AlgoKind::SccVgc { tau: 64 },
            AlgoKind::SsspRho { tau: 64 },
            AlgoKind::SsspDelta,
            AlgoKind::Cc,
            AlgoKind::Kcore,
        ] {
            let cold = c.execute(&mk(algo)).unwrap();
            let warm = c.execute(&mk(algo)).unwrap();
            assert_eq!(cold.output, warm.output, "{:?}", algo);
        }
    }

    #[test]
    fn fused_batch_matches_unfused_execution() {
        let c = coord_with_graphs();
        let reference = coord_with_graphs();
        let mut reqs = Vec::new();
        for i in 0..24u64 {
            let algo = match i % 4 {
                0 => AlgoKind::BfsVgc { tau: 64 },
                1 => AlgoKind::SsspRho { tau: 64 },
                2 => AlgoKind::BfsDirOpt,
                _ => AlgoKind::BfsFrontier, // not fusable: solo path
            };
            reqs.push(JobRequest {
                id: i,
                graph: if i % 2 == 0 { "road" } else { "social" }.into(),
                algo,
                source: (i % 7) as crate::V,
            });
        }
        let fused = c.run_batch(&reqs);
        for (i, r) in fused.iter().enumerate() {
            let r = r.as_ref().unwrap();
            assert_eq!(r.id, i as u64, "submission order");
            let want = reference.execute(&reqs[i]).unwrap();
            assert_eq!(r.output, want.output, "request {i}");
        }
        // 18 fusable (3 groups of 6), 6 solo frontier-BFS.
        assert_eq!(c.metrics.counter("queries_fused"), 18);
        assert_eq!(c.metrics.counter("queries_solo"), 6);
        assert_eq!(c.metrics.counter("fused_walks"), 3);
        assert_eq!(c.metrics.counter("jobs_executed"), 24);
    }

    #[test]
    fn fusion_splits_walks_at_64_lanes() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..70)
            .map(|i| JobRequest {
                id: i,
                graph: "road".into(),
                algo: AlgoKind::BfsVgc { tau: 64 },
                source: (i % 50) as crate::V,
            })
            .collect();
        let out = c.run_batch(&reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        assert_eq!(c.metrics.counter("fused_walks"), 2, "70 = 64 + 6 lanes");
        assert_eq!(c.metrics.counter("queries_fused"), 70);
        assert_eq!(c.metrics.counter("fused_lanes"), 70);
    }

    #[test]
    fn fused_group_reports_bad_sources_individually() {
        let c = coord_with_graphs();
        let mut reqs: Vec<JobRequest> = (0..4)
            .map(|i| JobRequest {
                id: i,
                graph: "road".into(),
                algo: AlgoKind::SsspRho { tau: 32 },
                source: i as crate::V,
            })
            .collect();
        reqs.push(JobRequest {
            id: 4,
            graph: "road".into(),
            algo: AlgoKind::SsspRho { tau: 32 },
            source: u32::MAX - 1,
        });
        reqs.push(JobRequest {
            id: 5,
            graph: "missing".into(),
            algo: AlgoKind::BfsVgc { tau: 32 },
            source: 0,
        });
        reqs.push(JobRequest {
            id: 6,
            graph: "missing".into(),
            algo: AlgoKind::BfsVgc { tau: 32 },
            source: 1,
        });
        let out = c.run_batch(&reqs);
        for r in &out[..4] {
            assert!(r.is_ok());
        }
        assert!(out[4].as_ref().unwrap_err().to_string().contains("out of range"));
        assert!(out[5].as_ref().unwrap_err().to_string().contains("unknown graph"));
        assert!(out[6].is_err());
        // queries_fused counts routed requests, errors included: the 5
        // SsspRho (one bad source) + the 2 unknown-graph BfsVgc.
        assert_eq!(c.metrics.counter("queries_fused"), 7);
        assert_eq!(c.metrics.counter("fused_lanes"), 4, "only valid sources ran");
    }

    #[test]
    fn different_tau_groups_do_not_fuse_together() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| JobRequest {
                id: i,
                graph: "road".into(),
                algo: AlgoKind::BfsVgc {
                    tau: if i % 2 == 0 { 16 } else { 64 },
                },
                source: i as crate::V,
            })
            .collect();
        let out = c.run_batch(&reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        // Two groups of two, each fused separately.
        assert_eq!(c.metrics.counter("fused_walks"), 2);
        assert_eq!(c.metrics.counter("queries_fused"), 4);
    }

    #[test]
    fn serve_loop_over_channels() {
        let c = Arc::new(coord_with_graphs());
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let server = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || c.serve(req_rx, res_tx, 8))
        };
        for i in 0..10u64 {
            req_tx
                .send(JobRequest {
                    id: i,
                    graph: "road".into(),
                    algo: AlgoKind::SsspRho { tau: 64 },
                    source: (i % 5) as V,
                })
                .unwrap();
        }
        drop(req_tx);
        let mut got: Vec<u64> = res_rx.iter().map(|r| r.id).collect();
        server.join().unwrap();
        got.sort();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn serve_windowed_answers_requests_queued_before_shutdown() {
        // Regression: the request channel closes while the fusion
        // window is still draining — everything already queued must be
        // executed and answered, and the server must return promptly
        // instead of sleeping out the window.
        let c = Arc::new(coord_with_graphs());
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        for i in 0..5u64 {
            req_tx
                .send(JobRequest {
                    id: i,
                    graph: "road".into(),
                    algo: AlgoKind::BfsVgc { tau: 64 },
                    source: (i % 5) as V,
                })
                .unwrap();
        }
        // Close before the server even starts: the head recv succeeds
        // (messages are buffered) and the window hits Disconnected.
        drop(req_tx);
        let t0 = Instant::now();
        let server = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                c.serve_windowed(req_rx, res_tx, 64, Duration::from_secs(30))
            })
        };
        let mut got: Vec<u64> = res_rx.iter().map(|r| r.id).collect();
        server.join().unwrap();
        got.sort();
        assert_eq!(got, (0..5).collect::<Vec<_>>(), "no request dropped");
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "shutdown must not sleep out the fusion window"
        );
        // All five fused into one walk by the window admission.
        assert_eq!(c.metrics.counter("queries_fused"), 5);
    }

    #[test]
    fn workload_generator_is_deterministic() {
        let a = workload(&["g1", "g2"], &[AlgoKind::BfsFrontier], 20, 7);
        let b = workload(&["g1", "g2"], &[AlgoKind::BfsFrontier], 20, 7);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.graph, y.graph);
            assert_eq!(x.source, y.source);
        }
    }
}
