//! The coordinator server: graph registry, per-graph batching,
//! multi-source query fusion, job execution, a per-worker
//! [`QueryWorkspace`] pool, and a channel-based serving loop.
//!
//! The workspace pool is what makes the serving path a
//! *zero-allocation query engine*: each request checks a warm
//! [`QueryWorkspace`] out of the pool, answers through the `_ws`
//! algorithm entry points (epoch-stamped scratch, reused hash bags —
//! see [`crate::algo::workspace`]), and returns it. After each
//! workspace has served one query per graph size, steady-state queries
//! perform no O(n)/O(m) allocation at all.
//!
//! **Dispatch is table-driven**: execution resolves each request's
//! [`AlgoSpec`] out of the algorithm registry ([`crate::algo::api`])
//! and calls the spec's engines — there are no per-algorithm match
//! arms here. Registering an algorithm (one registry line) makes it
//! servable through every path in this file.
//!
//! On top of that, [`ExecCore::run_batch_from`] **fuses** queries:
//! requests are grouped by `(graph, spec id, params)` — same-graph
//! batching for cache warmth, as before — and groups whose spec has a
//! batched multi-source engine ([`AlgoSpec::fusable`]) run through its
//! [`BatchEngine`] in chunks of up to 64 sources per frontier walk.
//! Per-lane results are demultiplexed (a parallel strided export)
//! back into per-request [`JobResult`]s in submission order; fusion is
//! invisible to clients except in the `queries_fused` /
//! `queries_solo` metrics and the latency column.
//!
//! Whole-graph analyses are **cached**: specs declaring
//! [`AlgoSpec::cacheable`] (SCC summary, CC, k-core, BCC — outputs
//! fully determined by `(graph, Params)`) consult a
//! [`ResultCache`] keyed `(graph name, spec id, Params)` and guarded
//! by the resolved graph's publish version, so a repeated query on an
//! unchanged graph is answered for free (`cache_hits` /
//! `cache_misses` count the split) and `load_graph` republishing
//! invalidates by version mismatch alone. Source-parameterized
//! traversals never enter the cache.
//!
//! Execution itself lives in [`ExecCore`], which owns **no** shared
//! state: it borrows an engine and a metrics registry and is handed a
//! workspace, a result cache and a graph-lookup function per call.
//! [`Coordinator`] drives it with the global Mutex-guarded pool,
//! cache and registry; the sharded server ([`super::shard`]) drives
//! the same core with shard-local pools, shard-local caches and
//! lock-free registry snapshots, so both paths execute — and meter —
//! queries identically.
//!
//! [`BatchEngine`]: crate::algo::api::BatchEngine

use super::directory::{GraphDirectory, LoadedGraph, ResultCache};
use super::faults::{self, BreakerState, FailKind, FaultPlan, PanicBreaker};
use super::job::{JobOutput, JobRequest, JobResult};
use super::lock_or_recover;
use super::metrics::Metrics;
use super::shard::{admit_batch, Inbox};
use super::trace::{EngineTelemetry, QueryTrace};
use crate::algo::api::{AlgoSpec, EngineCtx, Params, Query};
use crate::algo::cancel::CancelToken;
use crate::algo::workspace::{QueryWorkspace, WorkspacePool};
use crate::error::{Error, Result};
use crate::runtime::EngineHandle;
use crate::sim::AlgoTrace;
use crate::V;
use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Most sources per fused frontier walk (one mask bit each — see
/// [`crate::algo::multi`]).
pub(crate) const MAX_FUSE: usize = crate::algo::multi::MAX_LANES;

/// The analysis-job coordinator.
pub struct Coordinator {
    /// Snapshot-published graph registry; shard workers read it
    /// through lock-free [`super::directory::SnapshotCache`]s.
    pub(crate) directory: GraphDirectory,
    engine: Option<EngineHandle>,
    /// Artifact directory the dense engine was spawned from, when
    /// known: lets shard workers replicate an engine of their own
    /// ([`ShardState`]) instead of funneling every dense closure
    /// through one executor thread.
    ///
    /// [`ShardState`]: super::shard
    engine_dir: Option<std::path::PathBuf>,
    /// Warm per-worker query workspaces: checked out per request,
    /// returned after, so the steady-state serving path performs zero
    /// O(n) allocation (see module docs). Shard workers bypass this
    /// Mutex entirely with pools of their own.
    workspaces: Mutex<WorkspacePool>,
    /// Whole-graph result cache for [`cacheable`] specs, guarded by
    /// the graph's publish version. Shard workers bypass this Mutex
    /// too, with caches of their own.
    ///
    /// [`cacheable`]: crate::algo::api::AlgoSpec::cacheable
    results: Mutex<ResultCache>,
    /// Panic circuit breaker for the ad-hoc execution paths (shard
    /// workers own breakers of their own, like pools and caches).
    breaker: Mutex<PanicBreaker>,
    /// Installed fault-injection plan ([`super::faults`]); `None` —
    /// the production state — costs one `Option` check per execution.
    faults: Mutex<Option<Arc<FaultPlan>>>,
    pub metrics: Metrics,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// Coordinator without a dense engine (sparse algorithms only).
    pub fn new() -> Self {
        Coordinator {
            directory: GraphDirectory::new(),
            engine: None,
            engine_dir: None,
            workspaces: Mutex::new(WorkspacePool::new()),
            results: Mutex::new(ResultCache::new()),
            breaker: Mutex::new(PanicBreaker::new()),
            faults: Mutex::new(None),
            metrics: Metrics::new(),
        }
    }

    /// Coordinator with the dense engine attached.
    pub fn with_engine(engine: EngineHandle) -> Self {
        Coordinator {
            engine: Some(engine),
            ..Self::new()
        }
    }

    /// Coordinator with the dense engine attached *and* its artifact
    /// directory recorded, so the sharded server can replicate one
    /// engine per shard worker (dense traffic stops funneling through
    /// a single executor thread). [`Coordinator::with_engine`] keeps
    /// the directory unknown — shards then fall back to this shared
    /// handle.
    pub fn with_engine_at(engine: EngineHandle, dir: std::path::PathBuf) -> Self {
        Coordinator {
            engine: Some(engine),
            engine_dir: Some(dir),
            ..Self::new()
        }
    }

    /// Install a fault-injection plan ([`super::faults`]): matching
    /// engine executions panic or stall per the plan, exercising the
    /// real isolation paths. Install *before* serving starts — shard
    /// workers snapshot the plan when they spawn.
    pub fn set_faults(&self, plan: Arc<FaultPlan>) {
        *lock_or_recover(&self.faults) = Some(plan);
    }

    /// Remove any installed fault plan (ad-hoc paths pick the removal
    /// up immediately; running shard workers keep their snapshot).
    pub fn clear_faults(&self) {
        *lock_or_recover(&self.faults) = None;
    }

    /// The currently installed fault plan, if any.
    pub(crate) fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        lock_or_recover(&self.faults).clone()
    }

    /// The graph registry (shard workers cache snapshots of it).
    pub fn directory(&self) -> &GraphDirectory {
        &self.directory
    }

    /// The dense engine, if one is attached.
    pub(crate) fn engine(&self) -> Option<&EngineHandle> {
        self.engine.as_ref()
    }

    /// The artifact directory the dense engine came from, when known
    /// (the basis of per-shard engine replication).
    pub(crate) fn engine_dir(&self) -> Option<&std::path::PathBuf> {
        self.engine_dir.as_ref()
    }

    /// The execution core bound to this coordinator's engine and
    /// global metrics.
    pub(crate) fn core(&self) -> ExecCore<'_> {
        ExecCore {
            engine: self.engine.as_ref(),
            metrics: &self.metrics,
            faults: self.fault_plan(),
            cancel: None,
        }
    }

    /// The Mutex-shared cache and breaker handles the ad-hoc paths
    /// execute with (shard workers build [`Guards`] over state they
    /// own outright).
    fn guards(&self) -> Guards<'_> {
        Guards {
            cache: CacheHandle::Shared(&self.results),
            breaker: BreakerHandle::Shared(&self.breaker),
        }
    }

    /// Check a workspace out of the pool (fresh if none is warm).
    fn checkout_workspace(&self) -> QueryWorkspace {
        let mut pool = lock_or_recover(&self.workspaces);
        if pool.is_empty() {
            self.metrics.bump("workspaces_created", 1);
        }
        pool.checkout()
    }

    /// Return a workspace to the pool for the next request.
    fn checkin_workspace(&self, ws: QueryWorkspace) {
        lock_or_recover(&self.workspaces).checkin(ws);
    }

    /// Run `f` with a pooled workspace checked out for its duration —
    /// the one checkout/execute/checkin pattern every ad-hoc execution
    /// path shares. The result cache is *not* locked here: execution
    /// takes a [`CacheHandle`] that locks the shared cache only around
    /// the individual lookup/insert, so concurrent callers sharing an
    /// `Arc<Coordinator>` still execute engines in parallel.
    fn with_workspace<R>(&self, f: impl FnOnce(&mut QueryWorkspace) -> R) -> R {
        let mut ws = self.checkout_workspace();
        let out = f(&mut ws);
        self.checkin_workspace(ws);
        out
    }

    /// Number of idle workspaces in the global pool (tests/metrics).
    pub fn idle_workspaces(&self) -> usize {
        lock_or_recover(&self.workspaces).len()
    }

    /// Number of entries in the shared result cache (tests/metrics;
    /// shard workers keep caches of their own, not counted here).
    pub fn cached_results(&self) -> usize {
        lock_or_recover(&self.results).len()
    }

    /// Register a graph under `name` (replaces any previous one) by
    /// publishing a new registry snapshot. Panics on structurally
    /// invalid CSR — callers with trusted (generated or IO-validated)
    /// graphs keep the infallible signature; untrusted bytes go
    /// through [`Coordinator::try_load_graph`].
    pub fn load_graph(&self, name: &str, graph: crate::graph::Graph) {
        self.try_load_graph(name, graph)
            .expect("load_graph: structurally invalid graph");
    }

    /// [`Coordinator::load_graph`] for untrusted input: validates the
    /// CSR structure first and rejects malformed graphs with a typed
    /// [`FailKind::InvalidGraph`] error, publishing nothing (see
    /// [`GraphDirectory::load_graph`]). Republishing a healthy graph
    /// also resets any open panic breaker for it — the version moves,
    /// which is the breaker's reset protocol.
    pub fn try_load_graph(&self, name: &str, graph: crate::graph::Graph) -> Result<()> {
        let t0 = Instant::now();
        self.directory.load_graph(name, graph)?;
        self.metrics.observe("graph_load_us", t0.elapsed());
        self.metrics.bump("graphs_loaded", 1);
        Ok(())
    }

    /// Publish a graph straight from a `.pgr` file
    /// ([`GraphDirectory::load_graph_from_path`]): one bulk read into
    /// a shared arena, checksum + CSR validation, zero-copy views for
    /// the plain encoding. Meters the publish like
    /// [`Coordinator::try_load_graph`] (`graph_load_us`,
    /// `graphs_loaded`) plus the store-specific `graphs_loaded_bytes`
    /// and `store_decode_us` counters. A failed load publishes
    /// nothing: serving on any already-published graph under `name`
    /// continues unaffected.
    pub fn load_graph_from_path(
        &self,
        name: &str,
        path: &std::path::Path,
    ) -> Result<crate::graph::store::LoadStats> {
        let t0 = Instant::now();
        let stats = self.directory.load_graph_from_path(name, path)?;
        self.metrics.observe("graph_load_us", t0.elapsed());
        self.metrics.bump("graphs_loaded", 1);
        self.metrics.bump("graphs_loaded_bytes", stats.file_bytes);
        self.metrics
            .bump("store_decode_us", stats.decode.as_micros() as u64);
        Ok(stats)
    }

    /// Fetch a registered graph.
    pub fn graph(&self, name: &str) -> Option<Arc<LoadedGraph>> {
        self.directory.lookup(name)
    }

    /// Answer a cacheable request straight from the shared result
    /// cache — probed *before* any workspace checkout, so duplicate
    /// ad-hoc whole-graph traffic stops cycling pooled workspaces it
    /// never touches. `None` (non-cacheable spec, unknown graph, cache
    /// miss) falls through to the full execution path, which meters
    /// the miss itself.
    fn cache_fast_path(
        &self,
        id: u64,
        graph: &str,
        spec: &'static AlgoSpec,
        params: Params,
        traced: bool,
    ) -> Option<JobResult> {
        if !spec.cacheable {
            return None;
        }
        let submitted = Instant::now();
        let mut qt = traced.then(QueryTrace::new);
        if let Some(t) = qt.as_mut() {
            t.begin("cache_probe");
        }
        let lg = self.graph(graph)?;
        let hit = lock_or_recover(&self.results).lookup(graph, spec.id, params, lg.version)?;
        self.metrics.bump("cache_hits", 1);
        self.metrics.bump("cache_fast_path", 1);
        self.metrics.bump("jobs_executed", 1);
        let latency = submitted.elapsed();
        let trace = qt.map(|mut t| {
            t.end();
            t.seal(latency);
            Box::new(t)
        });
        Some(JobResult {
            id,
            algo: spec.label,
            output: (*hit).clone(),
            exec: Duration::ZERO,
            latency,
            trace,
        })
    }

    /// Execute one request immediately (no queueing).
    pub fn execute(&self, req: &JobRequest) -> Result<JobResult> {
        if !req.expired() {
            if let Some(hit) =
                self.cache_fast_path(req.id, &req.graph, req.algo, req.params, req.trace)
            {
                return Ok(hit);
            }
        }
        self.with_workspace(|ws| {
            self.core()
                .execute_one(req, self.graph(&req.graph), ws, &mut self.guards())
        })
    }

    /// Execute one [`Query`] from the open API immediately — the same
    /// registry-native dispatch as the channel protocol (a
    /// [`JobRequest`] is a `Query` plus a request id). A [`Query`]
    /// carries no request id, so the returned [`JobResult::id`] is
    /// always 0 — correlate by call site.
    pub fn run_query(&self, q: &Query) -> Result<JobResult> {
        if let Some(hit) = self.cache_fast_path(0, &q.graph, q.algo, q.params, false) {
            return Ok(hit);
        }
        self.with_workspace(|ws| {
            self.core().execute_resolved(
                0,
                &q.graph,
                q.algo,
                q.params,
                q.source,
                None,
                false,
                self.graph(&q.graph),
                ws,
                &mut self.guards(),
            )
        })
    }

    /// Answer a whole-graph label analysis with its **full per-vertex
    /// output vector** (SCC/CC labels, coreness), served from the
    /// versioned [`ResultCache`]: a hit returns the stored
    /// `Arc<Vec<u32>>` without touching an engine or copying a label;
    /// a miss computes through [`Coordinator::run_query`] (priming
    /// both the summary and the vector under the graph's publish
    /// version) and then answers from the fresh entry. Errors typed:
    /// specs without a full-vector export
    /// ([`AlgoSpec::full`](crate::algo::api::AlgoSpec::full) `None`)
    /// are rejected, and engine/deadline/unknown-graph failures
    /// propagate unchanged from the compute path.
    pub fn run_query_vector(&self, q: &Query) -> Result<Arc<Vec<u32>>> {
        let spec = q.algo;
        if spec.full.is_none() {
            return Err(Error::msg(format!(
                "{} has no full-vector output (only cacheable label analyses do)",
                spec.label
            )));
        }
        if let Some(lg) = self.graph(&q.graph) {
            if let Some(v) =
                lock_or_recover(&self.results).lookup_vector(&q.graph, spec.id, q.params, lg.version)
            {
                self.metrics.bump("vector_hits", 1);
                return Ok(v);
            }
        }
        self.run_query(q)?;
        let lg = self
            .graph(&q.graph)
            .ok_or_else(|| faults::unknown_graph_error(&q.graph))?;
        lock_or_recover(&self.results)
            .lookup_vector(&q.graph, spec.id, q.params, lg.version)
            .ok_or_else(|| {
                // Only a republish or eviction racing between compute
                // and re-probe can land here; the caller just retries.
                Error::msg(format!(
                    "full vector for {} on {:?} displaced before read (graph republished?)",
                    spec.label, q.graph
                ))
            })
    }

    /// Run a batch: requests grouped by (graph, algorithm, params) —
    /// same-graph batching for cache warmth, same-spec grouping for
    /// multi-source fusion — results returned in submission order.
    /// See [`ExecCore::run_batch_from`].
    pub fn run_batch(&self, reqs: &[JobRequest]) -> Vec<Result<JobResult>> {
        self.run_batch_from(Instant::now(), reqs)
    }

    /// [`Coordinator::run_batch`] with an explicit latency epoch: the
    /// serving loops pass the head request's arrival time so reported
    /// latencies include the fusion-window wait.
    fn run_batch_from(&self, t0: Instant, reqs: &[JobRequest]) -> Vec<Result<JobResult>> {
        self.with_workspace(|ws| {
            self.core()
                .run_batch_from(t0, reqs, |name| self.graph(name), ws, &mut self.guards())
        })
    }

    /// Serving loop: drain the request channel, batch what is
    /// immediately available (up to `max_batch`), execute, respond.
    /// Returns when the request channel closes. Equivalent to
    /// [`Coordinator::serve_windowed`] with a zero fusion window.
    pub fn serve(&self, rx: Receiver<JobRequest>, tx: Sender<JobResult>, max_batch: usize) {
        self.serve_windowed(rx, tx, max_batch, Duration::ZERO);
    }

    /// Serving loop with a fusion-window admission queue: when the
    /// head request is fusable and `window` is nonzero, wait up to the
    /// window deadline draining the channel to accumulate same-(graph,
    /// spec, params) lanes before dispatching; non-fusable heads fall
    /// through immediately (see [`super::shard::admit_batch`]).
    ///
    /// **Shutdown invariant:** when the request channel closes
    /// mid-window, requests already drained into the current batch are
    /// still executed and answered — closing the channel never drops
    /// accepted work. Failures are answered too, as
    /// [`JobOutput::Failed`] results carrying the request id.
    pub fn serve_windowed(
        &self,
        rx: Receiver<JobRequest>,
        tx: Sender<JobResult>,
        max_batch: usize,
        window: Duration,
    ) {
        let max_batch = max_batch.max(1);
        let inbox = Inbox::new(&rx);
        loop {
            // Block for the first request.
            let Ok(first) = inbox.recv() else { return };
            // Latency epoch: the head request is waiting from here on,
            // so the fusion-window wait counts toward its latency.
            let t0 = Instant::now();
            // An already-expired head never opens a fusion window:
            // answer it dead and move on to live work.
            if first.expired() {
                self.metrics.bump("deadline_exceeded", 1);
                let err = faults::deadline_error(&first.graph, first.algo.label);
                if tx.send(answer(&first, Err(err), t0, &self.metrics)).is_err() {
                    return;
                }
                continue;
            }
            let mut batch = vec![first];
            admit_batch(&inbox, &mut batch, max_batch, window, &self.metrics);
            self.metrics.bump("batched_requests", batch.len() as u64);
            let results = self.run_batch_from(t0, &batch);
            for (req, res) in batch.iter().zip(results) {
                let jr = answer(req, res, t0, &self.metrics);
                if tx.send(jr).is_err() {
                    return;
                }
            }
        }
    }
}

/// How an execution path reaches its [`ResultCache`]: shard workers
/// own one outright (zero locks on the hot path); the coordinator's
/// ad-hoc paths share one behind a Mutex that is taken only around
/// the individual lookup/insert — never across an engine run, so
/// concurrent callers sharing an `Arc<Coordinator>` still compute in
/// parallel. (With the shared handle, two concurrent misses on the
/// same key may both compute and race the insert; cacheable outputs
/// are deterministic, so last-write-wins is correct.)
pub(crate) enum CacheHandle<'a> {
    Owned(&'a mut ResultCache),
    Shared(&'a Mutex<ResultCache>),
}

impl CacheHandle<'_> {
    fn lookup(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        version: u64,
    ) -> Option<Arc<JobOutput>> {
        match self {
            CacheHandle::Owned(c) => c.lookup(graph, spec, params, version),
            CacheHandle::Shared(m) => lock_or_recover(m).lookup(graph, spec, params, version),
        }
    }

    /// Returns the number of LRU evictions the insert forced.
    /// `vector` carries the full per-vertex output for specs that
    /// export one ([`ResultCache::insert_full`]).
    #[allow(clippy::too_many_arguments)]
    fn insert_full(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        version: u64,
        output: Arc<JobOutput>,
        vector: Option<Arc<Vec<u32>>>,
    ) -> usize {
        match self {
            CacheHandle::Owned(c) => c.insert_full(graph, spec, params, version, output, vector),
            CacheHandle::Shared(m) => {
                lock_or_recover(m).insert_full(graph, spec, params, version, output, vector)
            }
        }
    }

    /// Source-keyed lookup — the negative-caching path (typed
    /// `Failed{UnknownGraph, InvalidSource}` outputs; see
    /// [`ResultCache::lookup_src`]).
    fn lookup_src(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        source: Option<V>,
        version: u64,
    ) -> Option<Arc<JobOutput>> {
        match self {
            CacheHandle::Owned(c) => c.lookup_src(graph, spec, params, source, version),
            CacheHandle::Shared(m) => {
                lock_or_recover(m).lookup_src(graph, spec, params, source, version)
            }
        }
    }

    /// Source-keyed insert (see [`ResultCache::insert_src`]).
    fn insert_src(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        source: Option<V>,
        version: u64,
        output: Arc<JobOutput>,
    ) -> usize {
        match self {
            CacheHandle::Owned(c) => c.insert_src(graph, spec, params, source, version, output),
            CacheHandle::Shared(m) => {
                lock_or_recover(m).insert_src(graph, spec, params, source, version, output)
            }
        }
    }
}

/// How an execution path reaches its [`PanicBreaker`] — the same
/// owned/shared split as [`CacheHandle`], for the same reason: shard
/// workers own a breaker outright (graph→shard affinity means one
/// worker sees a graph's full consecutive-panic streak), the ad-hoc
/// paths share one behind a Mutex taken only around the individual
/// check/record.
pub(crate) enum BreakerHandle<'a> {
    Owned(&'a mut PanicBreaker),
    Shared(&'a Mutex<PanicBreaker>),
}

impl BreakerHandle<'_> {
    /// The breaker's admission decision for this execution —
    /// [`BreakerState::Probe`] additionally *claims* the half-open
    /// probe slot, so call this exactly once per admission.
    fn check(&mut self, graph: &str, spec: u16, version: u64) -> BreakerState {
        match self {
            BreakerHandle::Owned(b) => b.check(graph, spec, version),
            BreakerHandle::Shared(m) => lock_or_recover(m).check(graph, spec, version),
        }
    }

    fn record_panic(&mut self, graph: &str, spec: u16, version: u64) -> bool {
        match self {
            BreakerHandle::Owned(b) => b.record_panic(graph, spec, version),
            BreakerHandle::Shared(m) => lock_or_recover(m).record_panic(graph, spec, version),
        }
    }

    /// Returns true when the success closed a tripped breaker (the
    /// half-open probe recovered it); callers meter these as
    /// `breaker_recoveries`.
    fn record_ok(&mut self, graph: &str, spec: u16) -> bool {
        match self {
            BreakerHandle::Owned(b) => b.record_ok(graph, spec),
            BreakerHandle::Shared(m) => lock_or_recover(m).record_ok(graph, spec),
        }
    }

    /// Current consecutive-panic streak (0 when clean) — the
    /// bounded-retry gate reads it to retry only *first-time* panics.
    fn streak(&mut self, graph: &str, spec: u16) -> u32 {
        match self {
            BreakerHandle::Owned(b) => b.streak(graph, spec),
            BreakerHandle::Shared(m) => lock_or_recover(m).streak(graph, spec),
        }
    }
}

/// The per-call shared-state handles an execution borrows: result
/// cache + panic breaker. One parameter instead of a growing list on
/// every `ExecCore` entry point.
pub(crate) struct Guards<'a> {
    pub cache: CacheHandle<'a>,
    pub breaker: BreakerHandle<'a>,
}

/// The request-execution core: registry dispatch, batching and
/// fusion, decoupled from any particular workspace pool or registry.
/// Holds no shared state of its own — callers hand it a workspace, a
/// cache handle and a graph-lookup function, so the shard hot path
/// runs it without taking a single Mutex.
pub(crate) struct ExecCore<'a> {
    pub engine: Option<&'a EngineHandle>,
    pub metrics: &'a Metrics,
    /// Fault-injection plan, if one is installed on the coordinator
    /// ([`Coordinator::set_faults`]). Snapshotted at core construction:
    /// shard workers capture it once at spawn, so install the plan
    /// *before* serving starts.
    pub faults: Option<Arc<FaultPlan>>,
    /// The worker-shared cancellation token, when this core runs under
    /// shard supervision: the router's watchdog condemns it to reclaim
    /// a stuck worker. `None` (ad-hoc paths) — each execution arms a
    /// local token carrying only the request deadline.
    pub cancel: Option<&'a CancelToken>,
}

impl ExecCore<'_> {
    /// Execute one request against an already-resolved graph. Expired
    /// requests fail typed ([`FailKind::DeadlineExceeded`]) without
    /// touching the engine — this is the last-line deadline check
    /// covering mid-window expiry (the router and window admission
    /// check earlier).
    pub(crate) fn execute_one(
        &self,
        req: &JobRequest,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        guards: &mut Guards<'_>,
    ) -> Result<JobResult> {
        if req.expired() {
            self.metrics.bump("deadline_exceeded", 1);
            return Err(faults::deadline_error(&req.graph, req.algo.label));
        }
        self.execute_resolved(
            req.id,
            &req.graph,
            req.algo,
            req.params,
            req.source,
            req.deadline,
            req.trace,
            lg,
            ws,
            guards,
        )
    }

    /// The shared solo execution path: every request — channel
    /// [`JobRequest`] or library [`Query`] — resolves to `(spec,
    /// params, source)` and runs the spec's solo engine out of the
    /// caller's warm workspace. Cacheable specs (whole-graph
    /// analyses) first consult the caller's [`ResultCache`] keyed on
    /// the resolved graph's publish version: a hit answers with the
    /// stored output (bit-identical — it *is* the stored output),
    /// `exec` zero and `cache_hits` bumped; a miss computes, stores,
    /// and bumps `cache_misses`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_resolved(
        &self,
        id: u64,
        graph: &str,
        spec: &'static AlgoSpec,
        params: Params,
        source: V,
        deadline: Option<Instant>,
        traced: bool,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        guards: &mut Guards<'_>,
    ) -> Result<JobResult> {
        let submitted = Instant::now();
        // Trace epoch = resolution start: queue time before this point
        // shows up as the synthetic `wait` span when the serving loop
        // re-seals with the batch-relative latency.
        let mut qt = traced.then(QueryTrace::new);
        // Unknown graph: a typed negative entry (keyed at the version-0
        // sentinel — published graphs always carry version ≥ 1) answers
        // repeats without re-resolving; the first miss seeds it. The
        // entry is dropped wholesale the moment a real publish inserts
        // positive results for the name.
        let Some(lg) = lg else {
            if let Some(hit) = guards.cache.lookup_src(graph, spec.id, params, None, 0) {
                self.metrics.bump("negative_hits", 1);
                self.metrics.bump("jobs_executed", 1);
                let latency = submitted.elapsed();
                let trace = qt.take().map(|mut t| {
                    t.seal(latency);
                    Box::new(t)
                });
                return Ok(JobResult {
                    id,
                    algo: spec.label,
                    output: (*hit).clone(),
                    exec: Duration::ZERO,
                    latency,
                    trace,
                });
            }
            let err = faults::unknown_graph_error(graph);
            let msg = format!("{err:#}");
            guards.cache.insert_src(
                graph,
                spec.id,
                params,
                None,
                0,
                Arc::new(JobOutput::Failed {
                    kind: FailKind::classify(&msg),
                    error: msg,
                }),
            );
            return Err(err);
        };
        // Out-of-range source: same negative-caching protocol, keyed
        // per source at the *graph's* publish version — a republish
        // (possibly with more vertices) invalidates the rejection.
        if spec.needs_source && (source as usize) >= lg.graph.n() {
            if let Some(hit) =
                guards
                    .cache
                    .lookup_src(graph, spec.id, params, Some(source), lg.version)
            {
                self.metrics.bump("negative_hits", 1);
                self.metrics.bump("jobs_executed", 1);
                let latency = submitted.elapsed();
                let trace = qt.take().map(|mut t| {
                    t.seal(latency);
                    Box::new(t)
                });
                return Ok(JobResult {
                    id,
                    algo: spec.label,
                    output: (*hit).clone(),
                    exec: Duration::ZERO,
                    latency,
                    trace,
                });
            }
            let err = faults::invalid_source_error(source, lg.graph.n());
            let msg = format!("{err:#}");
            guards.cache.insert_src(
                graph,
                spec.id,
                params,
                Some(source),
                lg.version,
                Arc::new(JobOutput::Failed {
                    kind: FailKind::classify(&msg),
                    error: msg,
                }),
            );
            return Err(err);
        }
        if spec.cacheable {
            if let Some(t) = qt.as_mut() {
                t.begin("cache_probe");
            }
            let hit = guards.cache.lookup(graph, spec.id, params, lg.version);
            if let Some(t) = qt.as_mut() {
                t.end();
            }
            if let Some(hit) = hit {
                // Served for free: no engine ran, so `exec` is zero
                // and no `exec/<label>` sample is recorded — the
                // series keeps measuring real computes. A valid cached
                // result is served even when the breaker is open: the
                // answer is already known-good.
                self.metrics.bump("cache_hits", 1);
                self.metrics.bump("jobs_executed", 1);
                let latency = submitted.elapsed();
                let trace = qt.take().map(|mut t| {
                    t.seal(latency);
                    Box::new(t)
                });
                return Ok(JobResult {
                    id,
                    algo: spec.label,
                    output: (*hit).clone(),
                    exec: Duration::ZERO,
                    latency,
                    trace,
                });
            }
            self.metrics.bump("cache_misses", 1);
        }
        // Circuit breaker: after BREAKER_TRIP consecutive panics on
        // this (graph, spec) at this version, fail fast instead of
        // re-running an engine that keeps dying. Republishing the
        // graph (new version) resets the breaker; with a cooldown
        // configured, an open breaker also goes half-open after it
        // elapses and admits exactly one probe execution.
        match guards.breaker.check(graph, spec.id, lg.version) {
            BreakerState::Open => {
                self.metrics.bump("breaker_open", 1);
                return Err(faults::breaker_error(graph, spec.label));
            }
            BreakerState::Probe => self.metrics.bump("breaker_probes", 1),
            BreakerState::Closed => {}
        }
        // Answer out of the caller's warm workspace: the steady-state
        // query path performs zero O(n)/O(m) allocation (epoch-stamped
        // scratch, reused bags and export buffers).
        let exec_start = Instant::now();
        let mut run = self.run_spec(graph, spec, params, source, deadline, &lg, ws, qt.as_mut());
        if let Err(e) = &run {
            if FailKind::classify(&e.to_string()) == FailKind::EnginePanic {
                if guards.breaker.record_panic(graph, spec.id, lg.version) {
                    self.metrics.bump("breaker_trips", 1);
                }
                // Bounded retry: a *first-time* panic on this (graph,
                // spec) may be transient (the panic isolation already
                // swapped in a fresh workspace), so a solo request
                // with deadline budget left gets exactly one more
                // attempt. Streaks ≥ 2 never retry — that's the
                // breaker's territory — and requests without a
                // deadline never retry, keeping failure counts exact
                // for deadline-less workloads.
                if guards.breaker.streak(graph, spec.id) == 1
                    && deadline.is_some_and(|d| Instant::now() < d)
                {
                    self.metrics.bump("panic_retries", 1);
                    run =
                        self.run_spec(graph, spec, params, source, deadline, &lg, ws, qt.as_mut());
                    if let Err(e2) = &run {
                        if FailKind::classify(&e2.to_string()) == FailKind::EnginePanic
                            && guards.breaker.record_panic(graph, spec.id, lg.version)
                        {
                            self.metrics.bump("breaker_trips", 1);
                        }
                    }
                }
            }
            // Plain errors (deadline, stall, …) don't trip the breaker.
        }
        if run.is_ok() && guards.breaker.record_ok(graph, spec.id) {
            self.metrics.bump("breaker_recoveries", 1);
        }
        let output = run?;
        let exec = exec_start.elapsed();
        if spec.cacheable {
            // Label analyses also publish their full per-vertex vector
            // (left in the workspace by the engine) into the same
            // version-guarded slot, so `run_query_vector` callers stop
            // recomputing whole-graph labelings.
            let vector = spec.full.map(|f| Arc::new(f(ws)));
            let evicted = guards.cache.insert_full(
                graph,
                spec.id,
                params,
                lg.version,
                Arc::new(output.clone()),
                vector,
            );
            if evicted > 0 {
                self.metrics.bump("cache_evictions", evicted as u64);
            }
        }
        let latency = submitted.elapsed();
        self.metrics.bump("jobs_executed", 1);
        self.metrics.observe(&format!("exec/{}", spec.label), exec);
        let trace = qt.map(|mut t| {
            t.seal(latency);
            Box::new(t)
        });
        Ok(JobResult {
            id,
            algo: spec.label,
            output,
            exec,
            latency,
            trace,
        })
    }

    /// Validate and dispatch one query through its spec's solo engine,
    /// with panic isolation: the engine runs inside `catch_unwind`, so
    /// a panicking engine answers this one request
    /// [`FailKind::EnginePanic`] instead of killing the serving
    /// worker. The workspace the panic may have left half-mutated is
    /// dropped and replaced with a fresh one — corrupt scratch is
    /// never checked back into a pool. The fault-injection hook fires
    /// *inside* the guard, so injected panics exercise the real
    /// isolation path.
    #[allow(clippy::too_many_arguments)]
    fn run_spec(
        &self,
        graph: &str,
        spec: &'static AlgoSpec,
        params: Params,
        source: V,
        deadline: Option<Instant>,
        lg: &LoadedGraph,
        ws: &mut QueryWorkspace,
        mut qt: Option<&mut QueryTrace>,
    ) -> Result<JobOutput> {
        let g = &*lg.graph;
        if spec.needs_source && (source as usize) >= g.n() {
            return Err(faults::invalid_source_error(source, g.n()));
        }
        // Arm this execution's cancellation token: the worker-shared
        // token when the core runs under shard supervision (the
        // router's watchdog condemns it to reclaim a stuck worker),
        // else a local one carrying only the request deadline.
        let local = CancelToken::new();
        let token = self.cancel.unwrap_or(&local);
        if !token.rearm(deadline) {
            // Condemned before the engine even started: the watchdog
            // already declared this worker stuck.
            return Err(faults::stalled_error(graph, spec.label));
        }
        // Round-telemetry side-channel: engines record into the cell
        // through `EngineCtx::recorder`; a successful traced run
        // distills it into the trace's `EngineTelemetry`.
        let cell = RefCell::new(AlgoTrace::new());
        if let Some(t) = qt.as_deref_mut() {
            t.begin("engine_run");
        }
        let guarded = catch_unwind(AssertUnwindSafe(|| {
            if let Some(f) = &self.faults {
                f.before_execute(graph, spec.label, Some(token));
            }
            (spec.solo)(
                &EngineCtx {
                    engine: self.engine,
                    cancel: Some(token),
                    trace: if qt.is_some() { Some(&cell) } else { None },
                },
                lg,
                params,
                source,
                ws,
            )
        }));
        let out = match guarded {
            Ok(res) => {
                if token.is_hard_cancelled() {
                    // The watchdog condemned us mid-run; the engine
                    // exited early with partial workspace state that
                    // must not be summarized as an answer.
                    Err(faults::stalled_error(graph, spec.label))
                } else if res.is_ok() && token.is_cancelled() {
                    // Deadline expired mid-run: the engine broke out of
                    // its round loop early, so the "output" would be a
                    // partial traversal — answer typed dead instead.
                    self.metrics.bump("deadline_exceeded", 1);
                    Err(faults::deadline_error(graph, spec.label))
                } else {
                    res
                }
            }
            Err(payload) => {
                *ws = QueryWorkspace::default();
                self.metrics.bump("engine_panics", 1);
                self.metrics.bump("workspaces_dropped", 1);
                Err(faults::panic_error(graph, spec.label, payload.as_ref()))
            }
        };
        if let Some(t) = qt.as_deref_mut() {
            t.end();
            if out.is_ok() {
                let at = cell.borrow();
                if at.num_rounds() > 0 {
                    t.telemetry = Some(EngineTelemetry::from_trace(&at));
                }
            }
        }
        out
    }

    /// Run a batch against `lookup`: requests grouped by `(graph,
    /// spec id, params)`, groups of ≥ 2 requests whose spec has a
    /// [`BatchEngine`](crate::algo::api::BatchEngine) answered by one
    /// batched frontier walk per ≤ 64 sources, everything else run
    /// solo — results in submission order. Latencies are measured
    /// from `t0`: the serving loops pass the head request's arrival
    /// time, so the fusion-window wait and in-batch queueing delay are
    /// both included. The whole batch shares the one `ws` (batch
    /// execution is serial on the calling worker).
    pub(crate) fn run_batch_from(
        &self,
        t0: Instant,
        reqs: &[JobRequest],
        lookup: impl Fn(&str) -> Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        guards: &mut Guards<'_>,
    ) -> Vec<Result<JobResult>> {
        let mut results: Vec<Option<Result<JobResult>>> = (0..reqs.len()).map(|_| None).collect();
        // Group indices by the registry key (graph, spec id, params),
        // preserving order within groups. Params is part of the key,
        // so e.g. two bfs-vgc τ values never fuse together. Requests
        // whose deadline already passed are answered dead here and
        // never grouped — an expired request must not consume a fusion
        // lane or an engine run (and counts toward neither
        // queries_solo nor queries_fused: it was never routed to an
        // execution path).
        let mut groups: HashMap<(&str, u16, Params), Vec<usize>> = HashMap::new();
        for (i, r) in reqs.iter().enumerate() {
            if r.expired() {
                self.metrics.bump("deadline_exceeded", 1);
                results[i] = Some(Err(faults::deadline_error(&r.graph, r.algo.label)));
                continue;
            }
            let (id, params) = r.group_key();
            groups
                .entry((r.graph.as_str(), id, params))
                .or_default()
                .push(i);
        }
        // Deterministic batch schedule: graph name, then registry id,
        // then params.
        let mut order: Vec<(&str, u16, Params)> = groups.keys().copied().collect();
        order.sort_unstable();
        for key in order {
            let idxs = &groups[&key];
            let spec = reqs[idxs[0]].algo;
            if spec.fusable() && idxs.len() >= 2 {
                let lg = lookup(&reqs[idxs[0]].graph);
                self.run_fused_group(reqs, idxs, spec, key.2, lg, ws, guards, &mut results);
            } else {
                // Solo path — duplicate cacheable requests within one
                // batch hit the cache the first of them just filled.
                for &i in idxs {
                    self.metrics.bump("queries_solo", 1);
                    results[i] =
                        Some(self.execute_one(&reqs[i], lookup(&reqs[i].graph), ws, guards));
                }
            }
        }
        self.metrics.bump("batches", 1);
        results
            .into_iter()
            .map(|r| {
                let mut res = r.expect("every request answered");
                if let Ok(jr) = res.as_mut() {
                    jr.latency = t0.elapsed(); // include batch queueing
                    if let Some(t) = jr.trace.as_deref_mut() {
                        // Re-seal from the batch epoch: the extra time
                        // (fusion window, in-batch queueing) grows the
                        // synthetic `wait` span, keeping span sums
                        // equal to the reported latency.
                        t.seal(jr.latency);
                    }
                    self.metrics.observe("latency", jr.latency);
                }
                res
            })
            .collect()
    }

    /// Answer one (graph, spec, params) group of fusable requests with
    /// the spec's batched multi-source engine (≤ [`MAX_FUSE`] sources
    /// per walk) and demultiplex per-lane results back into the slots
    /// of `results`. Each ≤ MAX_FUSE walk runs inside `catch_unwind`:
    /// a panicking batch engine fails that chunk's requests typed
    /// ([`FailKind::EnginePanic`]) and the remaining chunks still run.
    #[allow(clippy::too_many_arguments)]
    fn run_fused_group(
        &self,
        reqs: &[JobRequest],
        idxs: &[usize],
        spec: &'static AlgoSpec,
        params: Params,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        guards: &mut Guards<'_>,
        results: &mut [Option<Result<JobResult>>],
    ) {
        let be = spec.batch.expect("fused group requires a batch engine");
        // queries_fused counts every request *routed* to the fused
        // path (errors included), so queries_fused + queries_solo
        // always equals the batch size and fused_fraction stays exact.
        let Some(lg) = lg else {
            for &i in idxs {
                self.metrics.bump("queries_fused", 1);
                results[i] = Some(Err(faults::unknown_graph_error(&reqs[i].graph)));
            }
            return;
        };
        let graph = reqs[idxs[0]].graph.as_str();
        // Breaker fast-fail covers the whole group: a fused walk is
        // one engine run, so an open breaker fails all its lanes (and
        // a half-open probe admits the whole group as its one probe).
        match guards.breaker.check(graph, spec.id, lg.version) {
            BreakerState::Open => {
                for &i in idxs {
                    self.metrics.bump("queries_fused", 1);
                    self.metrics.bump("breaker_open", 1);
                    results[i] = Some(Err(faults::breaker_error(graph, spec.label)));
                }
                return;
            }
            BreakerState::Probe => self.metrics.bump("breaker_probes", 1),
            BreakerState::Closed => {}
        }
        let n = lg.graph.n();
        // Out-of-range sources fail individually; the rest still fuse.
        let mut valid: Vec<usize> = Vec::with_capacity(idxs.len());
        for &i in idxs {
            if (reqs[i].source as usize) >= n {
                self.metrics.bump("queries_fused", 1);
                results[i] = Some(Err(faults::invalid_source_error(reqs[i].source, n)));
            } else {
                valid.push(i);
            }
        }
        for chunk in valid.chunks(MAX_FUSE) {
            // Re-walk loop: each walk's token carries the *tightest*
            // live lane deadline. When it expires mid-walk the engine
            // exits within one round, the expired lanes are answered
            // dead, and the still-live lanes re-walk — so one
            // tight-deadline lane can only delay, never fail, its
            // batchmates. Progress: every re-walk iteration retires at
            // least the lane whose deadline cancelled the walk.
            let mut live: Vec<usize> = chunk.to_vec();
            let exec_start = Instant::now();
            loop {
                live.retain(|&i| {
                    if reqs[i].expired() {
                        self.metrics.bump("deadline_exceeded", 1);
                        self.metrics.bump("queries_fused", 1);
                        results[i] = Some(Err(faults::deadline_error(graph, spec.label)));
                        false
                    } else {
                        true
                    }
                });
                if live.is_empty() {
                    break;
                }
                let seeds: Vec<V> = live.iter().map(|&i| reqs[i].source).collect();
                let lanes = seeds.len();
                let any_traced = live.iter().any(|&i| reqs[i].trace);
                let cell = RefCell::new(AlgoTrace::new());
                let tightest = live.iter().filter_map(|&i| reqs[i].deadline).min();
                let local = CancelToken::new();
                let token = self.cancel.unwrap_or(&local);
                if !token.rearm(tightest) {
                    // Condemned before the walk started: the watchdog
                    // already declared this worker stuck.
                    let msg = faults::stalled_error(graph, spec.label).to_string();
                    for &i in &live {
                        self.metrics.bump("queries_fused", 1);
                        results[i] = Some(Err(Error::msg(msg.clone())));
                    }
                    break;
                }
                let walk_t0 = Instant::now();
                let walked = catch_unwind(AssertUnwindSafe(|| {
                    if let Some(f) = &self.faults {
                        f.before_execute(graph, spec.label, Some(token));
                    }
                    (be.run)(
                        &EngineCtx {
                            engine: self.engine,
                            cancel: Some(token),
                            trace: if any_traced { Some(&cell) } else { None },
                        },
                        &lg,
                        params,
                        &seeds,
                        ws,
                    );
                }));
                let walk_dur = walk_t0.elapsed();
                if let Err(payload) = walked {
                    *ws = QueryWorkspace::default();
                    self.metrics.bump("engine_panics", 1);
                    self.metrics.bump("workspaces_dropped", 1);
                    if guards.breaker.record_panic(graph, spec.id, lg.version) {
                        self.metrics.bump("breaker_trips", 1);
                    }
                    let msg = faults::panic_error(graph, spec.label, payload.as_ref()).to_string();
                    for &i in &live {
                        self.metrics.bump("queries_fused", 1);
                        results[i] = Some(Err(Error::msg(msg.clone())));
                    }
                    break;
                }
                // Drain the engines' mid-walk lane-compaction tally —
                // even a cancelled walk paid for its re-packs.
                let compacted = ws.take_lane_compactions();
                if compacted > 0 {
                    self.metrics.bump("lane_compactions", compacted);
                }
                if token.is_hard_cancelled() {
                    let msg = faults::stalled_error(graph, spec.label).to_string();
                    for &i in &live {
                        self.metrics.bump("queries_fused", 1);
                        results[i] = Some(Err(Error::msg(msg.clone())));
                    }
                    break;
                }
                if token.is_cancelled() {
                    // The tightest lane deadline expired mid-walk: the
                    // lane-striped state is partial for *every* lane,
                    // so nothing is demuxed; expired lanes are retired
                    // at the top and the rest walk again.
                    self.metrics.bump("fused_rewalks", 1);
                    continue;
                }
                if guards.breaker.record_ok(graph, spec.id) {
                    self.metrics.bump("breaker_recoveries", 1);
                }
                // The walk is shared: each fused request's exec is the
                // whole walk's time (vs. k walks unfused).
                let exec = exec_start.elapsed();
                let telemetry = {
                    let at = cell.borrow();
                    (at.num_rounds() > 0).then(|| EngineTelemetry::from_trace(&at))
                };
                for (lane, &i) in live.iter().enumerate() {
                    let demux_t0 = Instant::now();
                    let output = (be.demux)(ws, lane, n);
                    self.metrics.bump("jobs_executed", 1);
                    self.metrics.bump("queries_fused", 1);
                    self.metrics.observe(&format!("exec/{}", spec.label), exec);
                    // Traced lanes share the walk's measured span and
                    // telemetry; run_batch's latency restamp seals them.
                    let trace = reqs[i].trace.then(|| {
                        let mut t = QueryTrace::new_at(exec_start);
                        t.push_span(
                            "fused_walk",
                            walk_t0.duration_since(exec_start),
                            walk_dur,
                        );
                        t.push_span(
                            "demux",
                            demux_t0.duration_since(exec_start),
                            demux_t0.elapsed(),
                        );
                        t.telemetry = telemetry;
                        Box::new(t)
                    });
                    results[i] = Some(Ok(JobResult {
                        id: reqs[i].id,
                        algo: spec.label,
                        output,
                        exec,
                        // Placeholder: run_batch stamps every Ok result
                        // with the batch-relative latency.
                        latency: exec,
                        trace,
                    }));
                }
                self.metrics.bump("fused_walks", 1);
                self.metrics.bump("fused_lanes", lanes as u64);
                break;
            }
        }
    }
}

/// Turn one batch slot into the response sent to the client: failures
/// become [`JobOutput::Failed`] results carrying the request's id (and
/// bump the `errors` counter), so every accepted request is answered
/// and clients correlating responses by id never hang on an error.
pub(crate) fn answer(
    req: &JobRequest,
    res: Result<JobResult>,
    t0: Instant,
    metrics: &Metrics,
) -> JobResult {
    match res {
        Ok(r) => r,
        Err(e) => {
            metrics.bump("errors", 1);
            let latency = t0.elapsed();
            // Failures count toward the latency series too — a
            // half-failing workload must not report the percentiles
            // of its successes only.
            metrics.observe("latency", latency);
            // The typed kind is recovered from the stable message
            // prefix at this one boundary — robustness errors are
            // never context-wrapped, so the prefix match is exact.
            let msg = format!("{e:#}");
            JobResult {
                id: req.id,
                algo: req.algo.label,
                output: JobOutput::Failed {
                    kind: FailKind::classify(&msg),
                    error: msg,
                },
                exec: Duration::ZERO,
                latency,
                trace: None,
            }
        }
    }
}

/// Convenience: build requests for a synthetic workload trace. Each
/// algorithm in the mix is a registry spec plus its parsed
/// parameters — resolve names with [`crate::algo::api::find`] or
/// build the pairs directly from `registry` statics.
pub fn workload(
    graphs: &[&str],
    algos: &[(&'static AlgoSpec, Params)],
    queries: usize,
    seed: u64,
) -> Vec<JobRequest> {
    let mut rng = crate::prop::Rng::new(seed);
    (0..queries as u64)
        .map(|id| {
            let (spec, params) = *rng.pick(algos);
            JobRequest {
                id,
                graph: graphs[rng.range(0, graphs.len())].to_string(),
                algo: spec,
                params,
                source: rng.below(1 << 14) as V, // clamped by caller's graphs
                deadline: None,
                trace: false,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::api::{registry as reg, ParseArgs};
    use crate::graph::gen;

    fn coord_with_graphs() -> Coordinator {
        let c = Coordinator::new();
        c.load_graph("road", gen::road(8, 12, 1));
        c.load_graph("social", gen::social(9, 8, 2));
        c
    }

    /// Registry-native request with an explicit τ (block stays 64).
    fn req(id: u64, graph: &str, algo: &str, tau: usize, source: V) -> JobRequest {
        JobRequest::parse(id, graph, algo, &ParseArgs { tau, block: 64 })
            .unwrap()
            .with_source(source)
    }

    #[test]
    fn execute_bfs_and_scc() {
        let c = coord_with_graphs();
        let r = c.execute(&req(1, "road", "bfs-vgc", 64, 0)).unwrap();
        match r.output {
            JobOutput::Bfs { reached, .. } => assert!(reached > 1),
            other => panic!("wrong output {other:?}"),
        }
        let r = c.execute(&req(2, "social", "scc-vgc", 64, 0)).unwrap();
        match r.output {
            JobOutput::Scc { count, largest } => {
                assert!(count >= 1 && largest >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn execute_registry_opened_cc_and_kcore() {
        // The algorithms the registry opened for serving: CC and
        // k-core answer through the same workspace path as everything
        // else.
        let c = coord_with_graphs();
        let r = c.execute(&req(1, "road", "cc", 64, 0)).unwrap();
        assert_eq!(r.algo, "cc");
        match r.output {
            JobOutput::Cc { components, largest } => {
                assert!(components >= 1 && largest >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
        let r = c.execute(&req(2, "social", "kcore", 64, 0)).unwrap();
        assert_eq!(r.algo, "kcore");
        match r.output {
            JobOutput::Kcore {
                degeneracy,
                in_max_core,
            } => {
                assert!(degeneracy >= 1 && in_max_core >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn run_query_matches_channel_execution() {
        // The library Query path and the channel JobRequest path are
        // one dispatch path: identical answers.
        let c = coord_with_graphs();
        let q = Query::new("road", "bfs", &ParseArgs { tau: 64, block: 64 })
            .unwrap()
            .with_source(3);
        let via_query = c.run_query(&q).unwrap();
        let via_channel = c.execute(&JobRequest::from_query(7, &q)).unwrap();
        assert_eq!(via_query.output, via_channel.output);
        assert_eq!(via_query.algo, via_channel.algo);
        assert_eq!(via_channel.id, 7);
        // Unknown graphs fail the same way.
        let q = Query::new("ghost", "cc", &ParseArgs::default()).unwrap();
        assert!(c.run_query(&q).is_err());
    }

    #[test]
    fn unknown_graph_and_bad_source_error() {
        let c = coord_with_graphs();
        assert!(c.execute(&req(1, "nope", "bfs-frontier", 64, 0)).is_err());
        assert!(c
            .execute(&req(2, "road", "bfs-frontier", 64, u32::MAX - 1))
            .is_err());
    }

    #[test]
    fn variants_agree_through_the_server() {
        let c = coord_with_graphs();
        let a = c.execute(&req(0, "road", "bfs-vgc", 32, 3)).unwrap();
        let b = c.execute(&req(0, "road", "bfs-frontier", 32, 3)).unwrap();
        let d = c.execute(&req(0, "road", "bfs-diropt", 32, 3)).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(b.output, d.output);
        let x = c.execute(&req(0, "road", "sssp-rho", 32, 3)).unwrap();
        let y = c.execute(&req(0, "road", "sssp-delta", 32, 3)).unwrap();
        match (&x.output, &y.output) {
            (
                JobOutput::Sssp {
                    reached: r1,
                    radius: d1,
                },
                JobOutput::Sssp {
                    reached: r2,
                    radius: d2,
                },
            ) => {
                assert_eq!(r1, r2);
                assert!((d1 - d2).abs() <= 1e-2 * d2.max(1.0));
            }
            other => panic!("wrong outputs {other:?}"),
        }
    }

    #[test]
    fn batch_returns_in_submission_order_and_observes_metrics() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..6)
            .map(|i| {
                req(
                    i,
                    if i % 2 == 0 { "road" } else { "social" },
                    "bfs-vgc",
                    64,
                    (i % 3) as V,
                )
            })
            .collect();
        let out = c.run_batch(&reqs);
        assert_eq!(out.len(), 6);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().id, i as u64);
        }
        assert_eq!(c.metrics.counter("jobs_executed"), 6);
        assert!(c.metrics.summary("latency").unwrap().count == 6);
    }

    #[test]
    fn workspace_pool_reuses_one_workspace_for_serial_queries() {
        let c = coord_with_graphs();
        for i in 0..12u64 {
            let algo = match i % 4 {
                0 => "bfs-vgc",
                1 => "sssp-rho",
                2 => "scc-vgc",
                _ => "sssp-delta",
            };
            c.execute(&req(
                i,
                if i % 2 == 0 { "road" } else { "social" },
                algo,
                64,
                (i % 3) as V,
            ))
            .unwrap();
        }
        // Serial queries always find the previously checked-in
        // workspace: exactly one is ever created.
        assert_eq!(c.metrics.counter("workspaces_created"), 1);
        assert_eq!(c.idle_workspaces(), 1);
    }

    #[test]
    fn workspace_and_fresh_paths_agree() {
        let c = coord_with_graphs();
        // Run everything twice: the second pass uses warm workspaces
        // (or, for cacheable specs, the result cache) and must produce
        // identical summaries.
        for algo in [
            "bfs-vgc",
            "bfs-diropt",
            "scc-vgc",
            "sssp-rho",
            "sssp-delta",
            "cc",
            "kcore",
        ] {
            let cold = c.execute(&req(0, "road", algo, 64, 5)).unwrap();
            let warm = c.execute(&req(0, "road", algo, 64, 5)).unwrap();
            assert_eq!(cold.output, warm.output, "{algo}");
        }
    }

    #[test]
    fn whole_graph_duplicates_hit_the_result_cache() {
        let c = coord_with_graphs();
        let first = c.execute(&req(0, "road", "cc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_misses"), 1);
        assert_eq!(c.metrics.counter("cache_hits"), 0);
        for i in 1..4u64 {
            let dup = c.execute(&req(i, "road", "cc", 64, 0)).unwrap();
            assert_eq!(dup.output, first.output, "bit-identical from cache");
            assert_eq!(dup.exec, Duration::ZERO, "no engine ran");
        }
        assert_eq!(c.metrics.counter("cache_hits"), 3);
        assert_eq!(c.metrics.counter("cache_misses"), 1);
        assert_eq!(c.cached_results(), 1);
        // A traversal on the same graph never touches the cache.
        c.execute(&req(9, "road", "bfs-vgc", 64, 0)).unwrap();
        c.execute(&req(10, "road", "bfs-vgc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_hits"), 3);
        assert_eq!(c.metrics.counter("cache_misses"), 1);
        assert_eq!(c.cached_results(), 1);
    }

    #[test]
    fn republish_invalidates_cached_results() {
        let c = Coordinator::new();
        c.load_graph("g", gen::grid(3, 3).symmetrize());
        let small = c.execute(&req(0, "g", "cc", 64, 0)).unwrap();
        assert_eq!(
            small.output,
            JobOutput::Cc {
                components: 1,
                largest: 9
            }
        );
        c.execute(&req(1, "g", "cc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_hits"), 1);
        // Republish under the same name: the version moves, so the
        // next query must recompute against the new graph.
        c.load_graph("g", gen::grid(4, 4).symmetrize());
        let big = c.execute(&req(2, "g", "cc", 64, 0)).unwrap();
        assert_eq!(
            big.output,
            JobOutput::Cc {
                components: 1,
                largest: 16
            },
            "must not answer with the replaced graph's output"
        );
        assert_eq!(c.metrics.counter("cache_hits"), 1);
        assert_eq!(c.metrics.counter("cache_misses"), 2);
        // And the fresh entry serves the next duplicate.
        c.execute(&req(3, "g", "cc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_hits"), 2);
    }

    #[test]
    fn fused_batch_matches_unfused_execution() {
        let c = coord_with_graphs();
        let reference = coord_with_graphs();
        let mut reqs = Vec::new();
        for i in 0..24u64 {
            let algo = match i % 4 {
                0 => "bfs-vgc",
                1 => "sssp-rho",
                2 => "bfs-diropt",
                _ => "bfs-frontier", // not fusable: solo path
            };
            reqs.push(req(
                i,
                if i % 2 == 0 { "road" } else { "social" },
                algo,
                64,
                (i % 7) as V,
            ));
        }
        let fused = c.run_batch(&reqs);
        for (i, r) in fused.iter().enumerate() {
            let r = r.as_ref().unwrap();
            assert_eq!(r.id, i as u64, "submission order");
            let want = reference.execute(&reqs[i]).unwrap();
            assert_eq!(r.output, want.output, "request {i}");
        }
        // 18 fusable (3 groups of 6), 6 solo frontier-BFS.
        assert_eq!(c.metrics.counter("queries_fused"), 18);
        assert_eq!(c.metrics.counter("queries_solo"), 6);
        assert_eq!(c.metrics.counter("fused_walks"), 3);
        assert_eq!(c.metrics.counter("jobs_executed"), 24);
    }

    #[test]
    fn fusion_splits_walks_at_64_lanes() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..70)
            .map(|i| req(i, "road", "bfs-vgc", 64, (i % 50) as V))
            .collect();
        let out = c.run_batch(&reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        assert_eq!(c.metrics.counter("fused_walks"), 2, "70 = 64 + 6 lanes");
        assert_eq!(c.metrics.counter("queries_fused"), 70);
        assert_eq!(c.metrics.counter("fused_lanes"), 70);
    }

    #[test]
    fn fused_group_reports_bad_sources_individually() {
        let c = coord_with_graphs();
        let mut reqs: Vec<JobRequest> = (0..4)
            .map(|i| req(i, "road", "sssp-rho", 32, i as V))
            .collect();
        reqs.push(req(4, "road", "sssp-rho", 32, u32::MAX - 1));
        reqs.push(req(5, "missing", "bfs-vgc", 32, 0));
        reqs.push(req(6, "missing", "bfs-vgc", 32, 1));
        let out = c.run_batch(&reqs);
        for r in &out[..4] {
            assert!(r.is_ok());
        }
        assert!(out[4].as_ref().unwrap_err().to_string().contains("out of range"));
        assert!(out[5].as_ref().unwrap_err().to_string().contains("unknown graph"));
        assert!(out[6].is_err());
        // queries_fused counts routed requests, errors included: the 5
        // sssp-rho (one bad source) + the 2 unknown-graph bfs-vgc.
        assert_eq!(c.metrics.counter("queries_fused"), 7);
        assert_eq!(c.metrics.counter("fused_lanes"), 4, "only valid sources ran");
    }

    #[test]
    fn different_tau_groups_do_not_fuse_together() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| {
                req(
                    i,
                    "road",
                    "bfs-vgc",
                    if i % 2 == 0 { 16 } else { 64 },
                    i as V,
                )
            })
            .collect();
        let out = c.run_batch(&reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        // Two groups of two, each fused separately.
        assert_eq!(c.metrics.counter("fused_walks"), 2);
        assert_eq!(c.metrics.counter("queries_fused"), 4);
    }

    #[test]
    fn serve_loop_over_channels() {
        let c = Arc::new(coord_with_graphs());
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let server = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || c.serve(req_rx, res_tx, 8))
        };
        for i in 0..10u64 {
            req_tx
                .send(req(i, "road", "sssp-rho", 64, (i % 5) as V))
                .unwrap();
        }
        drop(req_tx);
        let mut got: Vec<u64> = res_rx.iter().map(|r| r.id).collect();
        server.join().unwrap();
        got.sort();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn serve_windowed_answers_requests_queued_before_shutdown() {
        // Regression: the request channel closes while the fusion
        // window is still draining — everything already queued must be
        // executed and answered, and the server must return promptly
        // instead of sleeping out the window.
        let c = Arc::new(coord_with_graphs());
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        for i in 0..5u64 {
            req_tx
                .send(req(i, "road", "bfs-vgc", 64, (i % 5) as V))
                .unwrap();
        }
        // Close before the server even starts: the head recv succeeds
        // (messages are buffered) and the window hits Disconnected.
        drop(req_tx);
        let t0 = Instant::now();
        let server = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                c.serve_windowed(req_rx, res_tx, 64, Duration::from_secs(30))
            })
        };
        let mut got: Vec<u64> = res_rx.iter().map(|r| r.id).collect();
        server.join().unwrap();
        got.sort();
        assert_eq!(got, (0..5).collect::<Vec<_>>(), "no request dropped");
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "shutdown must not sleep out the fusion window"
        );
        // All five fused into one walk by the window admission.
        assert_eq!(c.metrics.counter("queries_fused"), 5);
    }

    #[test]
    fn cache_fast_path_answers_before_workspace_checkout() {
        let c = coord_with_graphs();
        let first = c.execute(&req(0, "road", "cc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_fast_path"), 0, "first compute misses");
        let created = c.metrics.counter("workspaces_created");
        for i in 1..4u64 {
            let dup = c.execute(&req(i, "road", "cc", 64, 0)).unwrap();
            assert_eq!(dup.output, first.output, "bit-identical from cache");
            assert_eq!(dup.exec, Duration::ZERO);
        }
        assert_eq!(c.metrics.counter("cache_fast_path"), 3);
        assert_eq!(c.metrics.counter("cache_hits"), 3);
        assert_eq!(c.metrics.counter("cache_misses"), 1);
        assert_eq!(
            c.metrics.counter("workspaces_created"),
            created,
            "fast-path hits never touch the workspace pool"
        );
        // The Query path shares the fast path.
        let q = Query::new("road", "cc", &ParseArgs { tau: 64, block: 64 }).unwrap();
        assert_eq!(c.run_query(&q).unwrap().output, first.output);
        assert_eq!(c.metrics.counter("cache_fast_path"), 4);
    }

    #[test]
    fn expired_requests_in_a_batch_fail_without_executing() {
        let c = coord_with_graphs();
        let mut reqs: Vec<JobRequest> = (0..4)
            .map(|i| req(i, "road", "bfs-vgc", 64, i as V))
            .collect();
        reqs[2] = req(2, "road", "bfs-vgc", 64, 2).with_budget(Duration::ZERO);
        let out = c.run_batch(&reqs);
        assert!(out[0].is_ok() && out[1].is_ok() && out[3].is_ok());
        let err = out[2].as_ref().unwrap_err().to_string();
        assert_eq!(FailKind::classify(&err), FailKind::DeadlineExceeded);
        assert_eq!(c.metrics.counter("deadline_exceeded"), 1);
        assert_eq!(c.metrics.counter("jobs_executed"), 3, "the dead request never ran");
        // The three live requests still fused; the expired one was
        // never routed to an execution path.
        assert_eq!(c.metrics.counter("queries_fused"), 3);
        assert_eq!(c.metrics.counter("queries_solo"), 0);
    }

    #[test]
    fn injected_panics_are_isolated_and_answered_typed() {
        faults::silence_injected_panics();
        let c = coord_with_graphs();
        c.set_faults(Arc::new(FaultPlan::new().panic_on(
            Some("road"),
            Some("bfs-frontier"),
            0,
            1,
        )));
        let err = c.execute(&req(0, "road", "bfs-frontier", 64, 0)).unwrap_err();
        assert_eq!(FailKind::classify(&err.to_string()), FailKind::EnginePanic);
        assert_eq!(c.metrics.counter("engine_panics"), 1);
        assert_eq!(c.metrics.counter("workspaces_dropped"), 1);
        // The one-panic budget is spent: the same request now succeeds,
        // out of a replacement workspace.
        let ok = c.execute(&req(1, "road", "bfs-frontier", 64, 0)).unwrap();
        assert!(matches!(ok.output, JobOutput::Bfs { .. }));
        // Other specs never saw the fault.
        c.execute(&req(2, "road", "cc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("engine_panics"), 1);
    }

    #[test]
    fn breaker_opens_after_repeated_panics_and_republish_resets() {
        faults::silence_injected_panics();
        let c = Coordinator::new();
        c.load_graph("g", gen::grid(4, 4).symmetrize());
        c.set_faults(Arc::new(FaultPlan::new().panic_on(
            Some("g"),
            Some("bfs-frontier"),
            0,
            faults::BREAKER_TRIP as u64,
        )));
        for i in 0..faults::BREAKER_TRIP as u64 {
            let err = c.execute(&req(i, "g", "bfs-frontier", 64, 0)).unwrap_err();
            assert_eq!(
                FailKind::classify(&err.to_string()),
                FailKind::EnginePanic,
                "attempt {i} panics"
            );
        }
        assert_eq!(c.metrics.counter("breaker_trips"), 1);
        // Open: identical requests fail fast, classified EnginePanic,
        // without running (and so without consuming fault-plan hits).
        let err = c.execute(&req(9, "g", "bfs-frontier", 64, 0)).unwrap_err();
        assert_eq!(FailKind::classify(&err.to_string()), FailKind::EnginePanic);
        assert!(err.to_string().contains("breaker"));
        assert_eq!(c.metrics.counter("breaker_open"), 1);
        assert_eq!(
            c.metrics.counter("engine_panics"),
            faults::BREAKER_TRIP as u64,
            "fast fail never reached the engine"
        );
        // Other (graph, spec) pairs on the same graph are unaffected.
        c.execute(&req(10, "g", "cc", 64, 0)).unwrap();
        // Republish resets the breaker; the panic budget is exhausted,
        // so the spec serves again.
        c.load_graph("g", gen::grid(4, 4).symmetrize());
        let ok = c.execute(&req(11, "g", "bfs-frontier", 64, 0)).unwrap();
        assert!(matches!(ok.output, JobOutput::Bfs { .. }));
        assert_eq!(c.metrics.counter("breaker_open"), 1, "no further fast fails");
    }

    #[test]
    fn workload_generator_is_deterministic() {
        let mix = [(&reg::BFS_FRONTIER, Params::NONE)];
        let a = workload(&["g1", "g2"], &mix, 20, 7);
        let b = workload(&["g1", "g2"], &mix, 20, 7);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.graph, y.graph);
            assert_eq!(x.source, y.source);
            assert!(std::ptr::eq(x.algo, y.algo));
        }
    }
}
