//! The coordinator server: graph registry, per-graph batching,
//! multi-source query fusion, job execution, a per-worker
//! [`QueryWorkspace`] pool, and a channel-based serving loop.
//!
//! The workspace pool is what makes the serving path a
//! *zero-allocation query engine*: each request checks a warm
//! [`QueryWorkspace`] out of the pool, answers through the `_ws`
//! algorithm entry points (epoch-stamped scratch, reused hash bags —
//! see [`crate::algo::workspace`]), and returns it. After each
//! workspace has served one query per graph size, steady-state queries
//! perform no O(n)/O(m) allocation at all.
//!
//! **Dispatch is table-driven**: execution resolves each request's
//! [`AlgoSpec`] out of the algorithm registry ([`crate::algo::api`])
//! and calls the spec's engines — there are no per-algorithm match
//! arms here. Registering an algorithm (one registry line) makes it
//! servable through every path in this file.
//!
//! On top of that, [`ExecCore::run_batch_from`] **fuses** queries:
//! requests are grouped by `(graph, spec id, params)` — same-graph
//! batching for cache warmth, as before — and groups whose spec has a
//! batched multi-source engine ([`AlgoSpec::fusable`]) run through its
//! [`BatchEngine`] in chunks of up to 64 sources per frontier walk.
//! Per-lane results are demultiplexed (a parallel strided export)
//! back into per-request [`JobResult`]s in submission order; fusion is
//! invisible to clients except in the `queries_fused` /
//! `queries_solo` metrics and the latency column.
//!
//! Whole-graph analyses are **cached**: specs declaring
//! [`AlgoSpec::cacheable`] (SCC summary, CC, k-core, BCC — outputs
//! fully determined by `(graph, Params)`) consult a
//! [`ResultCache`] keyed `(graph name, spec id, Params)` and guarded
//! by the resolved graph's publish version, so a repeated query on an
//! unchanged graph is answered for free (`cache_hits` /
//! `cache_misses` count the split) and `load_graph` republishing
//! invalidates by version mismatch alone. Source-parameterized
//! traversals never enter the cache.
//!
//! Execution itself lives in [`ExecCore`], which owns **no** shared
//! state: it borrows an engine and a metrics registry and is handed a
//! workspace, a result cache and a graph-lookup function per call.
//! [`Coordinator`] drives it with the global Mutex-guarded pool,
//! cache and registry; the sharded server ([`super::shard`]) drives
//! the same core with shard-local pools, shard-local caches and
//! lock-free registry snapshots, so both paths execute — and meter —
//! queries identically.
//!
//! [`BatchEngine`]: crate::algo::api::BatchEngine

use super::directory::{GraphDirectory, LoadedGraph, ResultCache};
use super::job::{JobOutput, JobRequest, JobResult};
use super::metrics::Metrics;
use super::shard::admit_batch;
use crate::algo::api::{AlgoSpec, EngineCtx, Params, Query};
use crate::algo::workspace::{QueryWorkspace, WorkspacePool};
use crate::bail;
use crate::error::{Context, Error, Result};
use crate::runtime::EngineHandle;
use crate::V;
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Most sources per fused frontier walk (one mask bit each — see
/// [`crate::algo::multi`]).
pub(crate) const MAX_FUSE: usize = crate::algo::multi::MAX_LANES;

/// The analysis-job coordinator.
pub struct Coordinator {
    /// Snapshot-published graph registry; shard workers read it
    /// through lock-free [`super::directory::SnapshotCache`]s.
    pub(crate) directory: GraphDirectory,
    engine: Option<EngineHandle>,
    /// Warm per-worker query workspaces: checked out per request,
    /// returned after, so the steady-state serving path performs zero
    /// O(n) allocation (see module docs). Shard workers bypass this
    /// Mutex entirely with pools of their own.
    workspaces: Mutex<WorkspacePool>,
    /// Whole-graph result cache for [`cacheable`] specs, guarded by
    /// the graph's publish version. Shard workers bypass this Mutex
    /// too, with caches of their own.
    ///
    /// [`cacheable`]: crate::algo::api::AlgoSpec::cacheable
    results: Mutex<ResultCache>,
    pub metrics: Metrics,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// Coordinator without a dense engine (sparse algorithms only).
    pub fn new() -> Self {
        Coordinator {
            directory: GraphDirectory::new(),
            engine: None,
            workspaces: Mutex::new(WorkspacePool::new()),
            results: Mutex::new(ResultCache::new()),
            metrics: Metrics::new(),
        }
    }

    /// Coordinator with the dense engine attached.
    pub fn with_engine(engine: EngineHandle) -> Self {
        Coordinator {
            directory: GraphDirectory::new(),
            engine: Some(engine),
            workspaces: Mutex::new(WorkspacePool::new()),
            results: Mutex::new(ResultCache::new()),
            metrics: Metrics::new(),
        }
    }

    /// The graph registry (shard workers cache snapshots of it).
    pub fn directory(&self) -> &GraphDirectory {
        &self.directory
    }

    /// The dense engine, if one is attached.
    pub(crate) fn engine(&self) -> Option<&EngineHandle> {
        self.engine.as_ref()
    }

    /// The execution core bound to this coordinator's engine and
    /// global metrics.
    pub(crate) fn core(&self) -> ExecCore<'_> {
        ExecCore {
            engine: self.engine.as_ref(),
            metrics: &self.metrics,
        }
    }

    /// Check a workspace out of the pool (fresh if none is warm).
    fn checkout_workspace(&self) -> QueryWorkspace {
        let mut pool = self.workspaces.lock().unwrap();
        if pool.is_empty() {
            self.metrics.bump("workspaces_created", 1);
        }
        pool.checkout()
    }

    /// Return a workspace to the pool for the next request.
    fn checkin_workspace(&self, ws: QueryWorkspace) {
        self.workspaces.lock().unwrap().checkin(ws);
    }

    /// Run `f` with a pooled workspace checked out for its duration —
    /// the one checkout/execute/checkin pattern every ad-hoc execution
    /// path shares. The result cache is *not* locked here: execution
    /// takes a [`CacheHandle`] that locks the shared cache only around
    /// the individual lookup/insert, so concurrent callers sharing an
    /// `Arc<Coordinator>` still execute engines in parallel.
    fn with_workspace<R>(&self, f: impl FnOnce(&mut QueryWorkspace) -> R) -> R {
        let mut ws = self.checkout_workspace();
        let out = f(&mut ws);
        self.checkin_workspace(ws);
        out
    }

    /// Number of idle workspaces in the global pool (tests/metrics).
    pub fn idle_workspaces(&self) -> usize {
        self.workspaces.lock().unwrap().len()
    }

    /// Number of entries in the shared result cache (tests/metrics;
    /// shard workers keep caches of their own, not counted here).
    pub fn cached_results(&self) -> usize {
        self.results.lock().unwrap().len()
    }

    /// Register a graph under `name` (replaces any previous one) by
    /// publishing a new registry snapshot.
    pub fn load_graph(&self, name: &str, graph: crate::graph::Graph) {
        self.directory.publish(name, graph);
        self.metrics.bump("graphs_loaded", 1);
    }

    /// Fetch a registered graph.
    pub fn graph(&self, name: &str) -> Option<Arc<LoadedGraph>> {
        self.directory.lookup(name)
    }

    /// Execute one request immediately (no queueing).
    pub fn execute(&self, req: &JobRequest) -> Result<JobResult> {
        self.with_workspace(|ws| {
            self.core().execute_one(
                req,
                self.graph(&req.graph),
                ws,
                &mut CacheHandle::Shared(&self.results),
            )
        })
    }

    /// Execute one [`Query`] from the open API immediately — the same
    /// registry-native dispatch as the channel protocol (a
    /// [`JobRequest`] is a `Query` plus a request id). A [`Query`]
    /// carries no request id, so the returned [`JobResult::id`] is
    /// always 0 — correlate by call site.
    pub fn run_query(&self, q: &Query) -> Result<JobResult> {
        self.with_workspace(|ws| {
            self.core().execute_resolved(
                0,
                &q.graph,
                q.algo,
                q.params,
                q.source,
                self.graph(&q.graph),
                ws,
                &mut CacheHandle::Shared(&self.results),
            )
        })
    }

    /// Run a batch: requests grouped by (graph, algorithm, params) —
    /// same-graph batching for cache warmth, same-spec grouping for
    /// multi-source fusion — results returned in submission order.
    /// See [`ExecCore::run_batch_from`].
    pub fn run_batch(&self, reqs: &[JobRequest]) -> Vec<Result<JobResult>> {
        self.run_batch_from(Instant::now(), reqs)
    }

    /// [`Coordinator::run_batch`] with an explicit latency epoch: the
    /// serving loops pass the head request's arrival time so reported
    /// latencies include the fusion-window wait.
    fn run_batch_from(&self, t0: Instant, reqs: &[JobRequest]) -> Vec<Result<JobResult>> {
        self.with_workspace(|ws| {
            self.core().run_batch_from(
                t0,
                reqs,
                |name| self.graph(name),
                ws,
                &mut CacheHandle::Shared(&self.results),
            )
        })
    }

    /// Serving loop: drain the request channel, batch what is
    /// immediately available (up to `max_batch`), execute, respond.
    /// Returns when the request channel closes. Equivalent to
    /// [`Coordinator::serve_windowed`] with a zero fusion window.
    pub fn serve(&self, rx: Receiver<JobRequest>, tx: Sender<JobResult>, max_batch: usize) {
        self.serve_windowed(rx, tx, max_batch, Duration::ZERO);
    }

    /// Serving loop with a fusion-window admission queue: when the
    /// head request is fusable and `window` is nonzero, wait up to the
    /// window deadline draining the channel to accumulate same-(graph,
    /// spec, params) lanes before dispatching; non-fusable heads fall
    /// through immediately (see [`super::shard::admit_batch`]).
    ///
    /// **Shutdown invariant:** when the request channel closes
    /// mid-window, requests already drained into the current batch are
    /// still executed and answered — closing the channel never drops
    /// accepted work. Failures are answered too, as
    /// [`JobOutput::Failed`] results carrying the request id.
    pub fn serve_windowed(
        &self,
        rx: Receiver<JobRequest>,
        tx: Sender<JobResult>,
        max_batch: usize,
        window: Duration,
    ) {
        let max_batch = max_batch.max(1);
        loop {
            // Block for the first request.
            let Ok(first) = rx.recv() else { return };
            // Latency epoch: the head request is waiting from here on,
            // so the fusion-window wait counts toward its latency.
            let t0 = Instant::now();
            let mut batch = vec![first];
            admit_batch(&rx, &mut batch, max_batch, window, &self.metrics);
            self.metrics.bump("batched_requests", batch.len() as u64);
            let results = self.run_batch_from(t0, &batch);
            for (req, res) in batch.iter().zip(results) {
                let jr = answer(req, res, t0, &self.metrics);
                if tx.send(jr).is_err() {
                    return;
                }
            }
        }
    }
}

/// How an execution path reaches its [`ResultCache`]: shard workers
/// own one outright (zero locks on the hot path); the coordinator's
/// ad-hoc paths share one behind a Mutex that is taken only around
/// the individual lookup/insert — never across an engine run, so
/// concurrent callers sharing an `Arc<Coordinator>` still compute in
/// parallel. (With the shared handle, two concurrent misses on the
/// same key may both compute and race the insert; cacheable outputs
/// are deterministic, so last-write-wins is correct.)
pub(crate) enum CacheHandle<'a> {
    Owned(&'a mut ResultCache),
    Shared(&'a Mutex<ResultCache>),
}

impl CacheHandle<'_> {
    fn lookup(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        version: u64,
    ) -> Option<Arc<JobOutput>> {
        match self {
            CacheHandle::Owned(c) => c.lookup(graph, spec, params, version),
            CacheHandle::Shared(m) => m.lock().unwrap().lookup(graph, spec, params, version),
        }
    }

    fn insert(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        version: u64,
        output: Arc<JobOutput>,
    ) {
        match self {
            CacheHandle::Owned(c) => c.insert(graph, spec, params, version, output),
            CacheHandle::Shared(m) => m.lock().unwrap().insert(graph, spec, params, version, output),
        }
    }
}

/// The request-execution core: registry dispatch, batching and
/// fusion, decoupled from any particular workspace pool or registry.
/// Holds no shared state of its own — callers hand it a workspace, a
/// cache handle and a graph-lookup function, so the shard hot path
/// runs it without taking a single Mutex.
pub(crate) struct ExecCore<'a> {
    pub engine: Option<&'a EngineHandle>,
    pub metrics: &'a Metrics,
}

impl ExecCore<'_> {
    /// Execute one request against an already-resolved graph.
    pub(crate) fn execute_one(
        &self,
        req: &JobRequest,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        cache: &mut CacheHandle<'_>,
    ) -> Result<JobResult> {
        self.execute_resolved(
            req.id,
            &req.graph,
            req.algo,
            req.params,
            req.source,
            lg,
            ws,
            cache,
        )
    }

    /// The shared solo execution path: every request — channel
    /// [`JobRequest`] or library [`Query`] — resolves to `(spec,
    /// params, source)` and runs the spec's solo engine out of the
    /// caller's warm workspace. Cacheable specs (whole-graph
    /// analyses) first consult the caller's [`ResultCache`] keyed on
    /// the resolved graph's publish version: a hit answers with the
    /// stored output (bit-identical — it *is* the stored output),
    /// `exec` zero and `cache_hits` bumped; a miss computes, stores,
    /// and bumps `cache_misses`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_resolved(
        &self,
        id: u64,
        graph: &str,
        spec: &'static AlgoSpec,
        params: Params,
        source: V,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        cache: &mut CacheHandle<'_>,
    ) -> Result<JobResult> {
        let submitted = Instant::now();
        let lg = lg.with_context(|| format!("unknown graph {graph:?}"))?;
        if spec.cacheable {
            if let Some(hit) = cache.lookup(graph, spec.id, params, lg.version) {
                // Served for free: no engine ran, so `exec` is zero
                // and no `exec/<label>` sample is recorded — the
                // series keeps measuring real computes.
                self.metrics.bump("cache_hits", 1);
                self.metrics.bump("jobs_executed", 1);
                return Ok(JobResult {
                    id,
                    algo: spec.label,
                    output: (*hit).clone(),
                    exec: Duration::ZERO,
                    latency: submitted.elapsed(),
                });
            }
            self.metrics.bump("cache_misses", 1);
        }
        // Answer out of the caller's warm workspace: the steady-state
        // query path performs zero O(n)/O(m) allocation (epoch-stamped
        // scratch, reused bags and export buffers).
        let exec_start = Instant::now();
        let output = self.run_spec(spec, params, source, &lg, ws)?;
        let exec = exec_start.elapsed();
        if spec.cacheable {
            cache.insert(graph, spec.id, params, lg.version, Arc::new(output.clone()));
        }
        let latency = submitted.elapsed();
        self.metrics.bump("jobs_executed", 1);
        self.metrics.observe(&format!("exec/{}", spec.label), exec);
        Ok(JobResult {
            id,
            algo: spec.label,
            output,
            exec,
            latency,
        })
    }

    /// Validate and dispatch one query through its spec's solo engine.
    fn run_spec(
        &self,
        spec: &'static AlgoSpec,
        params: Params,
        source: V,
        lg: &LoadedGraph,
        ws: &mut QueryWorkspace,
    ) -> Result<JobOutput> {
        let g = &*lg.graph;
        if spec.needs_source && (source as usize) >= g.n() {
            bail!("source {} out of range (n={})", source, g.n());
        }
        (spec.solo)(&EngineCtx { engine: self.engine }, lg, params, source, ws)
    }

    /// Run a batch against `lookup`: requests grouped by `(graph,
    /// spec id, params)`, groups of ≥ 2 requests whose spec has a
    /// [`BatchEngine`](crate::algo::api::BatchEngine) answered by one
    /// batched frontier walk per ≤ 64 sources, everything else run
    /// solo — results in submission order. Latencies are measured
    /// from `t0`: the serving loops pass the head request's arrival
    /// time, so the fusion-window wait and in-batch queueing delay are
    /// both included. The whole batch shares the one `ws` (batch
    /// execution is serial on the calling worker).
    pub(crate) fn run_batch_from(
        &self,
        t0: Instant,
        reqs: &[JobRequest],
        lookup: impl Fn(&str) -> Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        cache: &mut CacheHandle<'_>,
    ) -> Vec<Result<JobResult>> {
        // Group indices by the registry key (graph, spec id, params),
        // preserving order within groups. Params is part of the key,
        // so e.g. two bfs-vgc τ values never fuse together.
        let mut groups: HashMap<(&str, u16, Params), Vec<usize>> = HashMap::new();
        for (i, r) in reqs.iter().enumerate() {
            let (id, params) = r.group_key();
            groups
                .entry((r.graph.as_str(), id, params))
                .or_default()
                .push(i);
        }
        // Deterministic batch schedule: graph name, then registry id,
        // then params.
        let mut order: Vec<(&str, u16, Params)> = groups.keys().copied().collect();
        order.sort_unstable();
        let mut results: Vec<Option<Result<JobResult>>> = (0..reqs.len()).map(|_| None).collect();
        for key in order {
            let idxs = &groups[&key];
            let spec = reqs[idxs[0]].algo;
            if spec.fusable() && idxs.len() >= 2 {
                let lg = lookup(&reqs[idxs[0]].graph);
                self.run_fused_group(reqs, idxs, spec, key.2, lg, ws, &mut results);
            } else {
                // Solo path — duplicate cacheable requests within one
                // batch hit the cache the first of them just filled.
                for &i in idxs {
                    self.metrics.bump("queries_solo", 1);
                    results[i] =
                        Some(self.execute_one(&reqs[i], lookup(&reqs[i].graph), ws, cache));
                }
            }
        }
        self.metrics.bump("batches", 1);
        results
            .into_iter()
            .map(|r| {
                let mut res = r.expect("every request answered");
                if let Ok(jr) = res.as_mut() {
                    jr.latency = t0.elapsed(); // include batch queueing
                    self.metrics.observe("latency", jr.latency);
                }
                res
            })
            .collect()
    }

    /// Answer one (graph, spec, params) group of fusable requests with
    /// the spec's batched multi-source engine (≤ [`MAX_FUSE`] sources
    /// per walk) and demultiplex per-lane results back into the slots
    /// of `results`.
    #[allow(clippy::too_many_arguments)]
    fn run_fused_group(
        &self,
        reqs: &[JobRequest],
        idxs: &[usize],
        spec: &'static AlgoSpec,
        params: Params,
        lg: Option<Arc<LoadedGraph>>,
        ws: &mut QueryWorkspace,
        results: &mut [Option<Result<JobResult>>],
    ) {
        let be = spec.batch.expect("fused group requires a batch engine");
        // queries_fused counts every request *routed* to the fused
        // path (errors included), so queries_fused + queries_solo
        // always equals the batch size and fused_fraction stays exact.
        let Some(lg) = lg else {
            for &i in idxs {
                self.metrics.bump("queries_fused", 1);
                results[i] = Some(Err(Error::msg(format!(
                    "unknown graph {:?}",
                    reqs[i].graph
                ))));
            }
            return;
        };
        let n = lg.graph.n();
        // Out-of-range sources fail individually; the rest still fuse.
        let mut valid: Vec<usize> = Vec::with_capacity(idxs.len());
        for &i in idxs {
            if (reqs[i].source as usize) >= n {
                self.metrics.bump("queries_fused", 1);
                results[i] = Some(Err(Error::msg(format!(
                    "source {} out of range (n={n})",
                    reqs[i].source
                ))));
            } else {
                valid.push(i);
            }
        }
        for chunk in valid.chunks(MAX_FUSE) {
            let seeds: Vec<V> = chunk.iter().map(|&i| reqs[i].source).collect();
            let lanes = seeds.len();
            let exec_start = Instant::now();
            (be.run)(&lg, params, &seeds, ws);
            // The walk is shared: each fused request's exec is the
            // whole walk's time (vs. k walks unfused).
            let exec = exec_start.elapsed();
            for (lane, &i) in chunk.iter().enumerate() {
                let output = (be.demux)(ws, lane, n);
                self.metrics.bump("jobs_executed", 1);
                self.metrics.bump("queries_fused", 1);
                self.metrics.observe(&format!("exec/{}", spec.label), exec);
                results[i] = Some(Ok(JobResult {
                    id: reqs[i].id,
                    algo: spec.label,
                    output,
                    exec,
                    // Placeholder: run_batch stamps every Ok result
                    // with the batch-relative latency.
                    latency: exec,
                }));
            }
            self.metrics.bump("fused_walks", 1);
            self.metrics.bump("fused_lanes", lanes as u64);
        }
    }
}

/// Turn one batch slot into the response sent to the client: failures
/// become [`JobOutput::Failed`] results carrying the request's id (and
/// bump the `errors` counter), so every accepted request is answered
/// and clients correlating responses by id never hang on an error.
pub(crate) fn answer(
    req: &JobRequest,
    res: Result<JobResult>,
    t0: Instant,
    metrics: &Metrics,
) -> JobResult {
    match res {
        Ok(r) => r,
        Err(e) => {
            metrics.bump("errors", 1);
            let latency = t0.elapsed();
            // Failures count toward the latency series too — a
            // half-failing workload must not report the percentiles
            // of its successes only.
            metrics.observe("latency", latency);
            JobResult {
                id: req.id,
                algo: req.algo.label,
                output: JobOutput::Failed {
                    error: format!("{e:#}"),
                },
                exec: Duration::ZERO,
                latency,
            }
        }
    }
}

/// Convenience: build requests for a synthetic workload trace. Each
/// algorithm in the mix is a registry spec plus its parsed
/// parameters — resolve names with [`crate::algo::api::find`] or
/// build the pairs directly from `registry` statics.
pub fn workload(
    graphs: &[&str],
    algos: &[(&'static AlgoSpec, Params)],
    queries: usize,
    seed: u64,
) -> Vec<JobRequest> {
    let mut rng = crate::prop::Rng::new(seed);
    (0..queries as u64)
        .map(|id| {
            let (spec, params) = *rng.pick(algos);
            JobRequest {
                id,
                graph: graphs[rng.range(0, graphs.len())].to_string(),
                algo: spec,
                params,
                source: rng.below(1 << 14) as V, // clamped by caller's graphs
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::api::{registry as reg, ParseArgs};
    use crate::graph::gen;

    fn coord_with_graphs() -> Coordinator {
        let c = Coordinator::new();
        c.load_graph("road", gen::road(8, 12, 1));
        c.load_graph("social", gen::social(9, 8, 2));
        c
    }

    /// Registry-native request with an explicit τ (block stays 64).
    fn req(id: u64, graph: &str, algo: &str, tau: usize, source: V) -> JobRequest {
        JobRequest::parse(id, graph, algo, &ParseArgs { tau, block: 64 })
            .unwrap()
            .with_source(source)
    }

    #[test]
    fn execute_bfs_and_scc() {
        let c = coord_with_graphs();
        let r = c.execute(&req(1, "road", "bfs-vgc", 64, 0)).unwrap();
        match r.output {
            JobOutput::Bfs { reached, .. } => assert!(reached > 1),
            other => panic!("wrong output {other:?}"),
        }
        let r = c.execute(&req(2, "social", "scc-vgc", 64, 0)).unwrap();
        match r.output {
            JobOutput::Scc { count, largest } => {
                assert!(count >= 1 && largest >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn execute_registry_opened_cc_and_kcore() {
        // The algorithms the registry opened for serving: CC and
        // k-core answer through the same workspace path as everything
        // else.
        let c = coord_with_graphs();
        let r = c.execute(&req(1, "road", "cc", 64, 0)).unwrap();
        assert_eq!(r.algo, "cc");
        match r.output {
            JobOutput::Cc { components, largest } => {
                assert!(components >= 1 && largest >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
        let r = c.execute(&req(2, "social", "kcore", 64, 0)).unwrap();
        assert_eq!(r.algo, "kcore");
        match r.output {
            JobOutput::Kcore {
                degeneracy,
                in_max_core,
            } => {
                assert!(degeneracy >= 1 && in_max_core >= 1);
            }
            other => panic!("wrong output {other:?}"),
        }
    }

    #[test]
    fn run_query_matches_channel_execution() {
        // The library Query path and the channel JobRequest path are
        // one dispatch path: identical answers.
        let c = coord_with_graphs();
        let q = Query::new("road", "bfs", &ParseArgs { tau: 64, block: 64 })
            .unwrap()
            .with_source(3);
        let via_query = c.run_query(&q).unwrap();
        let via_channel = c.execute(&JobRequest::from_query(7, &q)).unwrap();
        assert_eq!(via_query.output, via_channel.output);
        assert_eq!(via_query.algo, via_channel.algo);
        assert_eq!(via_channel.id, 7);
        // Unknown graphs fail the same way.
        let q = Query::new("ghost", "cc", &ParseArgs::default()).unwrap();
        assert!(c.run_query(&q).is_err());
    }

    #[test]
    fn unknown_graph_and_bad_source_error() {
        let c = coord_with_graphs();
        assert!(c.execute(&req(1, "nope", "bfs-frontier", 64, 0)).is_err());
        assert!(c
            .execute(&req(2, "road", "bfs-frontier", 64, u32::MAX - 1))
            .is_err());
    }

    #[test]
    fn variants_agree_through_the_server() {
        let c = coord_with_graphs();
        let a = c.execute(&req(0, "road", "bfs-vgc", 32, 3)).unwrap();
        let b = c.execute(&req(0, "road", "bfs-frontier", 32, 3)).unwrap();
        let d = c.execute(&req(0, "road", "bfs-diropt", 32, 3)).unwrap();
        assert_eq!(a.output, b.output);
        assert_eq!(b.output, d.output);
        let x = c.execute(&req(0, "road", "sssp-rho", 32, 3)).unwrap();
        let y = c.execute(&req(0, "road", "sssp-delta", 32, 3)).unwrap();
        match (&x.output, &y.output) {
            (
                JobOutput::Sssp {
                    reached: r1,
                    radius: d1,
                },
                JobOutput::Sssp {
                    reached: r2,
                    radius: d2,
                },
            ) => {
                assert_eq!(r1, r2);
                assert!((d1 - d2).abs() <= 1e-2 * d2.max(1.0));
            }
            other => panic!("wrong outputs {other:?}"),
        }
    }

    #[test]
    fn batch_returns_in_submission_order_and_observes_metrics() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..6)
            .map(|i| {
                req(
                    i,
                    if i % 2 == 0 { "road" } else { "social" },
                    "bfs-vgc",
                    64,
                    (i % 3) as V,
                )
            })
            .collect();
        let out = c.run_batch(&reqs);
        assert_eq!(out.len(), 6);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().id, i as u64);
        }
        assert_eq!(c.metrics.counter("jobs_executed"), 6);
        assert!(c.metrics.summary("latency").unwrap().count == 6);
    }

    #[test]
    fn workspace_pool_reuses_one_workspace_for_serial_queries() {
        let c = coord_with_graphs();
        for i in 0..12u64 {
            let algo = match i % 4 {
                0 => "bfs-vgc",
                1 => "sssp-rho",
                2 => "scc-vgc",
                _ => "sssp-delta",
            };
            c.execute(&req(
                i,
                if i % 2 == 0 { "road" } else { "social" },
                algo,
                64,
                (i % 3) as V,
            ))
            .unwrap();
        }
        // Serial queries always find the previously checked-in
        // workspace: exactly one is ever created.
        assert_eq!(c.metrics.counter("workspaces_created"), 1);
        assert_eq!(c.idle_workspaces(), 1);
    }

    #[test]
    fn workspace_and_fresh_paths_agree() {
        let c = coord_with_graphs();
        // Run everything twice: the second pass uses warm workspaces
        // (or, for cacheable specs, the result cache) and must produce
        // identical summaries.
        for algo in [
            "bfs-vgc",
            "bfs-diropt",
            "scc-vgc",
            "sssp-rho",
            "sssp-delta",
            "cc",
            "kcore",
        ] {
            let cold = c.execute(&req(0, "road", algo, 64, 5)).unwrap();
            let warm = c.execute(&req(0, "road", algo, 64, 5)).unwrap();
            assert_eq!(cold.output, warm.output, "{algo}");
        }
    }

    #[test]
    fn whole_graph_duplicates_hit_the_result_cache() {
        let c = coord_with_graphs();
        let first = c.execute(&req(0, "road", "cc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_misses"), 1);
        assert_eq!(c.metrics.counter("cache_hits"), 0);
        for i in 1..4u64 {
            let dup = c.execute(&req(i, "road", "cc", 64, 0)).unwrap();
            assert_eq!(dup.output, first.output, "bit-identical from cache");
            assert_eq!(dup.exec, Duration::ZERO, "no engine ran");
        }
        assert_eq!(c.metrics.counter("cache_hits"), 3);
        assert_eq!(c.metrics.counter("cache_misses"), 1);
        assert_eq!(c.cached_results(), 1);
        // A traversal on the same graph never touches the cache.
        c.execute(&req(9, "road", "bfs-vgc", 64, 0)).unwrap();
        c.execute(&req(10, "road", "bfs-vgc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_hits"), 3);
        assert_eq!(c.metrics.counter("cache_misses"), 1);
        assert_eq!(c.cached_results(), 1);
    }

    #[test]
    fn republish_invalidates_cached_results() {
        let c = Coordinator::new();
        c.load_graph("g", gen::grid(3, 3).symmetrize());
        let small = c.execute(&req(0, "g", "cc", 64, 0)).unwrap();
        assert_eq!(
            small.output,
            JobOutput::Cc {
                components: 1,
                largest: 9
            }
        );
        c.execute(&req(1, "g", "cc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_hits"), 1);
        // Republish under the same name: the version moves, so the
        // next query must recompute against the new graph.
        c.load_graph("g", gen::grid(4, 4).symmetrize());
        let big = c.execute(&req(2, "g", "cc", 64, 0)).unwrap();
        assert_eq!(
            big.output,
            JobOutput::Cc {
                components: 1,
                largest: 16
            },
            "must not answer with the replaced graph's output"
        );
        assert_eq!(c.metrics.counter("cache_hits"), 1);
        assert_eq!(c.metrics.counter("cache_misses"), 2);
        // And the fresh entry serves the next duplicate.
        c.execute(&req(3, "g", "cc", 64, 0)).unwrap();
        assert_eq!(c.metrics.counter("cache_hits"), 2);
    }

    #[test]
    fn fused_batch_matches_unfused_execution() {
        let c = coord_with_graphs();
        let reference = coord_with_graphs();
        let mut reqs = Vec::new();
        for i in 0..24u64 {
            let algo = match i % 4 {
                0 => "bfs-vgc",
                1 => "sssp-rho",
                2 => "bfs-diropt",
                _ => "bfs-frontier", // not fusable: solo path
            };
            reqs.push(req(
                i,
                if i % 2 == 0 { "road" } else { "social" },
                algo,
                64,
                (i % 7) as V,
            ));
        }
        let fused = c.run_batch(&reqs);
        for (i, r) in fused.iter().enumerate() {
            let r = r.as_ref().unwrap();
            assert_eq!(r.id, i as u64, "submission order");
            let want = reference.execute(&reqs[i]).unwrap();
            assert_eq!(r.output, want.output, "request {i}");
        }
        // 18 fusable (3 groups of 6), 6 solo frontier-BFS.
        assert_eq!(c.metrics.counter("queries_fused"), 18);
        assert_eq!(c.metrics.counter("queries_solo"), 6);
        assert_eq!(c.metrics.counter("fused_walks"), 3);
        assert_eq!(c.metrics.counter("jobs_executed"), 24);
    }

    #[test]
    fn fusion_splits_walks_at_64_lanes() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..70)
            .map(|i| req(i, "road", "bfs-vgc", 64, (i % 50) as V))
            .collect();
        let out = c.run_batch(&reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        assert_eq!(c.metrics.counter("fused_walks"), 2, "70 = 64 + 6 lanes");
        assert_eq!(c.metrics.counter("queries_fused"), 70);
        assert_eq!(c.metrics.counter("fused_lanes"), 70);
    }

    #[test]
    fn fused_group_reports_bad_sources_individually() {
        let c = coord_with_graphs();
        let mut reqs: Vec<JobRequest> = (0..4)
            .map(|i| req(i, "road", "sssp-rho", 32, i as V))
            .collect();
        reqs.push(req(4, "road", "sssp-rho", 32, u32::MAX - 1));
        reqs.push(req(5, "missing", "bfs-vgc", 32, 0));
        reqs.push(req(6, "missing", "bfs-vgc", 32, 1));
        let out = c.run_batch(&reqs);
        for r in &out[..4] {
            assert!(r.is_ok());
        }
        assert!(out[4].as_ref().unwrap_err().to_string().contains("out of range"));
        assert!(out[5].as_ref().unwrap_err().to_string().contains("unknown graph"));
        assert!(out[6].is_err());
        // queries_fused counts routed requests, errors included: the 5
        // sssp-rho (one bad source) + the 2 unknown-graph bfs-vgc.
        assert_eq!(c.metrics.counter("queries_fused"), 7);
        assert_eq!(c.metrics.counter("fused_lanes"), 4, "only valid sources ran");
    }

    #[test]
    fn different_tau_groups_do_not_fuse_together() {
        let c = coord_with_graphs();
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| {
                req(
                    i,
                    "road",
                    "bfs-vgc",
                    if i % 2 == 0 { 16 } else { 64 },
                    i as V,
                )
            })
            .collect();
        let out = c.run_batch(&reqs);
        assert!(out.iter().all(|r| r.is_ok()));
        // Two groups of two, each fused separately.
        assert_eq!(c.metrics.counter("fused_walks"), 2);
        assert_eq!(c.metrics.counter("queries_fused"), 4);
    }

    #[test]
    fn serve_loop_over_channels() {
        let c = Arc::new(coord_with_graphs());
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let server = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || c.serve(req_rx, res_tx, 8))
        };
        for i in 0..10u64 {
            req_tx
                .send(req(i, "road", "sssp-rho", 64, (i % 5) as V))
                .unwrap();
        }
        drop(req_tx);
        let mut got: Vec<u64> = res_rx.iter().map(|r| r.id).collect();
        server.join().unwrap();
        got.sort();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn serve_windowed_answers_requests_queued_before_shutdown() {
        // Regression: the request channel closes while the fusion
        // window is still draining — everything already queued must be
        // executed and answered, and the server must return promptly
        // instead of sleeping out the window.
        let c = Arc::new(coord_with_graphs());
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        for i in 0..5u64 {
            req_tx
                .send(req(i, "road", "bfs-vgc", 64, (i % 5) as V))
                .unwrap();
        }
        // Close before the server even starts: the head recv succeeds
        // (messages are buffered) and the window hits Disconnected.
        drop(req_tx);
        let t0 = Instant::now();
        let server = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                c.serve_windowed(req_rx, res_tx, 64, Duration::from_secs(30))
            })
        };
        let mut got: Vec<u64> = res_rx.iter().map(|r| r.id).collect();
        server.join().unwrap();
        got.sort();
        assert_eq!(got, (0..5).collect::<Vec<_>>(), "no request dropped");
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "shutdown must not sleep out the fusion window"
        );
        // All five fused into one walk by the window admission.
        assert_eq!(c.metrics.counter("queries_fused"), 5);
    }

    #[test]
    fn workload_generator_is_deterministic() {
        let mix = [(&reg::BFS_FRONTIER, Params::NONE)];
        let a = workload(&["g1", "g2"], &mix, 20, 7);
        let b = workload(&["g1", "g2"], &mix, 20, 7);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.graph, y.graph);
            assert_eq!(x.source, y.source);
            assert!(std::ptr::eq(x.algo, y.algo));
        }
    }
}
