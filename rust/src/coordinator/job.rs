//! Job types for the coordinator's channel serving protocol.
//!
//! The algorithm registry ([`crate::algo::api`]) is the source of
//! truth for labels, aliases, parameters, fusability and dispatch;
//! [`AlgoKind`] survives only as a **deprecated thin shim** — a
//! `Copy + Eq + Hash` encoding of `(spec, params)` that keeps existing
//! callers, tests and benches compiling while they migrate to
//! [`Query`](crate::algo::api::Query). Every method delegates to the
//! registry; the only per-algorithm knowledge left in this file is the
//! variant ↔ spec mapping itself (checked exhaustively against the
//! registry by the round-trip test below).

use crate::algo::api::{self, AlgoSpec, Params, ParseArgs};
use crate::V;
use std::time::Duration;

pub use crate::algo::api::QueryOutput as JobOutput;

/// Which analysis to run — **deprecated shim**: an enum encoding of
/// `(&'static AlgoSpec, Params)` for the channel protocol and for
/// pre-registry callers. New code should address algorithms through
/// [`crate::algo::api::Query`] / registry lookup instead; this enum
/// only exists so `(graph, algo)` stays a cheap `Copy + Eq + Hash`
/// message field.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    /// PASGAL VGC BFS (τ from the request).
    BfsVgc { tau: usize },
    /// GBBS-like frontier BFS (baseline).
    BfsFrontier,
    /// Direction-optimizing BFS (baseline).
    BfsDirOpt,
    /// PASGAL VGC SCC.
    SccVgc { tau: usize },
    /// Multistep SCC (baseline).
    SccMultistep,
    /// FAST-BCC.
    Bcc,
    /// ρ-stepping SSSP with VGC.
    SsspRho { tau: usize },
    /// Δ-stepping SSSP (baseline).
    SsspDelta,
    /// Dense-block closure on the PJRT engine: all-pairs distances
    /// within a extracted dense subgraph (the L1/L2 path).
    DenseClosure { block: usize },
    /// Parallel connectivity (union-find).
    Cc,
    /// k-core decomposition (parallel peeling).
    Kcore,
}

impl AlgoKind {
    /// The registry entry this shim variant encodes.
    pub fn spec(&self) -> &'static AlgoSpec {
        use crate::algo::api::registry as r;
        match self {
            AlgoKind::BfsVgc { .. } => &r::BFS_VGC,
            AlgoKind::BfsFrontier => &r::BFS_FRONTIER,
            AlgoKind::BfsDirOpt => &r::BFS_DIROPT,
            AlgoKind::SccVgc { .. } => &r::SCC_VGC,
            AlgoKind::SccMultistep => &r::SCC_MULTISTEP,
            AlgoKind::Bcc => &r::BCC_FAST,
            AlgoKind::SsspRho { .. } => &r::SSSP_RHO,
            AlgoKind::SsspDelta => &r::SSSP_DELTA,
            AlgoKind::DenseClosure { .. } => &r::DENSE_CLOSURE,
            AlgoKind::Cc => &r::CC,
            AlgoKind::Kcore => &r::KCORE,
        }
    }

    /// The parameters this shim variant encodes.
    pub fn params(&self) -> Params {
        match *self {
            AlgoKind::BfsVgc { tau }
            | AlgoKind::SccVgc { tau }
            | AlgoKind::SsspRho { tau } => Params::tau(tau),
            AlgoKind::DenseClosure { block } => Params::block(block),
            _ => Params::NONE,
        }
    }

    /// Encode a registry spec + parameters as a shim variant. `None`
    /// for specs without an enum encoding (none today; a future
    /// registry entry may opt out of the shim and be reachable through
    /// [`crate::algo::api::Query`] only).
    pub fn from_spec(spec: &'static AlgoSpec, p: Params) -> Option<AlgoKind> {
        Some(match spec.label {
            "bfs-vgc" => AlgoKind::BfsVgc { tau: p.tau },
            "bfs-frontier" => AlgoKind::BfsFrontier,
            "bfs-diropt" => AlgoKind::BfsDirOpt,
            "scc-vgc" => AlgoKind::SccVgc { tau: p.tau },
            "scc-multistep" => AlgoKind::SccMultistep,
            "bcc-fast" => AlgoKind::Bcc,
            "sssp-rho" => AlgoKind::SsspRho { tau: p.tau },
            "sssp-delta" => AlgoKind::SsspDelta,
            "dense-closure" => AlgoKind::DenseClosure { block: p.block },
            "cc" => AlgoKind::Cc,
            "kcore" => AlgoKind::Kcore,
            _ => return None,
        })
    }

    /// Registry-backed parse with every raw parameter threaded through
    /// (`--tau` *and* `--block`): label or alias → shim variant.
    pub fn parse_with(s: &str, args: &ParseArgs) -> Option<AlgoKind> {
        let spec = api::find(s)?;
        AlgoKind::from_spec(spec, (spec.parse)(args))
    }

    /// Pre-registry parse signature (τ only; block takes its default).
    /// Prefer [`AlgoKind::parse_with`] or
    /// [`crate::algo::api::Query::new`].
    pub fn parse(s: &str, tau: usize) -> Option<AlgoKind> {
        AlgoKind::parse_with(
            s,
            &ParseArgs {
                tau,
                ..ParseArgs::default()
            },
        )
    }

    /// Canonical registry label.
    pub fn label(&self) -> &'static str {
        self.spec().label
    }

    /// True for algorithms with a batched multi-source engine
    /// (delegates to [`AlgoSpec::fusable`]): the coordinator fuses
    /// same-graph groups of these into one frontier walk. Parameterized
    /// variants only fuse within the same parameter value — the
    /// `(graph, spec id, Params)` grouping key guarantees that.
    pub fn fusable(&self) -> bool {
        self.spec().fusable()
    }
}

/// One analysis request.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub id: u64,
    /// Name of a graph previously loaded into the coordinator.
    pub graph: String,
    pub algo: AlgoKind,
    /// Source vertex for traversal queries.
    pub source: V,
}

impl JobRequest {
    /// Stable FNV-1a hash of the graph name: the shard-router key.
    /// Same name ⇒ same hash ⇒ same shard, which is what guarantees a
    /// shard's fusion window sees every request that could fuse with
    /// it (and keeps one graph's derived views hot in one worker).
    pub fn route_hash(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x1_0000_0000_01b3;
        let mut h = FNV_OFFSET;
        for &b in self.graph.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        h
    }

    /// Encode a [`Query`](crate::algo::api::Query) for the channel
    /// protocol. `None` when the query's spec has no [`AlgoKind`]
    /// shim encoding (such specs are served through
    /// [`crate::coordinator::Coordinator::run_query`] instead).
    pub fn from_query(id: u64, q: &crate::algo::api::Query) -> Option<JobRequest> {
        Some(JobRequest {
            id,
            graph: q.graph.clone(),
            algo: AlgoKind::from_spec(q.algo, q.params)?,
            source: q.source,
        })
    }
}

/// A finished job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub algo: &'static str,
    pub output: JobOutput,
    /// Pure execution time.
    pub exec: Duration,
    /// Queue + execution (request-to-response) latency.
    pub latency: Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_labels() {
        for s in [
            "bfs-vgc",
            "bfs-frontier",
            "bfs-diropt",
            "scc-vgc",
            "scc-multistep",
            "bcc-fast",
            "sssp-rho",
            "sssp-delta",
            "dense-closure",
            "cc",
            "kcore",
        ] {
            let k = AlgoKind::parse(s, 512).unwrap();
            assert_eq!(k.label(), s);
        }
        assert!(AlgoKind::parse("nope", 1).is_none());
    }

    #[test]
    fn every_registered_spec_roundtrips_through_the_shim() {
        // Registry-completeness: label → parse → label round-trips,
        // the shim points back at the exact spec, and aliases resolve
        // to the same variant. Iterates the registry, not a hand-kept
        // list, so adding a spec without a shim arm fails here.
        let args = ParseArgs { tau: 77, block: 48 };
        for spec in api::all() {
            let k = AlgoKind::parse_with(spec.label, &args)
                .unwrap_or_else(|| panic!("{} has no AlgoKind shim", spec.label));
            assert_eq!(k.label(), spec.label, "label round-trip");
            assert!(std::ptr::eq(k.spec(), *spec), "shim points at its spec");
            assert_eq!(k.params(), (spec.parse)(&args), "params survive encoding");
            assert_eq!(k.fusable(), spec.fusable());
            for alias in spec.aliases {
                assert_eq!(
                    AlgoKind::parse_with(alias, &args),
                    Some(k),
                    "alias {alias:?} must encode identically"
                );
            }
        }
    }

    #[test]
    fn parse_threads_block_size_through() {
        // Regression: `--block` used to be hard-coded to 64 in parse.
        let k = AlgoKind::parse_with("dense-closure", &ParseArgs { tau: 512, block: 96 });
        assert_eq!(k, Some(AlgoKind::DenseClosure { block: 96 }));
        // The τ-only signature keeps the old default.
        assert_eq!(
            AlgoKind::parse("dense-closure", 512),
            Some(AlgoKind::DenseClosure { block: 64 })
        );
    }

    #[test]
    fn fusable_covers_exactly_the_multi_source_engines() {
        assert!(AlgoKind::BfsVgc { tau: 64 }.fusable());
        assert!(AlgoKind::BfsDirOpt.fusable());
        assert!(AlgoKind::SsspRho { tau: 64 }.fusable());
        assert!(!AlgoKind::BfsFrontier.fusable());
        assert!(!AlgoKind::SsspDelta.fusable());
        assert!(!AlgoKind::SccVgc { tau: 64 }.fusable());
        assert!(!AlgoKind::Bcc.fusable());
        assert!(!AlgoKind::Cc.fusable());
        assert!(!AlgoKind::Kcore.fusable());
    }

    #[test]
    fn route_hash_keys_on_graph_name_only() {
        let a = JobRequest {
            id: 1,
            graph: "road".into(),
            algo: AlgoKind::BfsVgc { tau: 8 },
            source: 0,
        };
        let b = JobRequest {
            id: 2,
            graph: "road".into(),
            algo: AlgoKind::Bcc,
            source: 77,
        };
        let c = JobRequest {
            id: 1,
            graph: "social".into(),
            algo: AlgoKind::BfsVgc { tau: 8 },
            source: 0,
        };
        assert_eq!(a.route_hash(), b.route_hash(), "same graph, same shard");
        assert_ne!(a.route_hash(), c.route_hash(), "FNV separates these names");
        // Distinct names spread across a small shard count.
        let shards: std::collections::HashSet<u64> = ["g0", "g1", "g2", "g3", "g4", "g5"]
            .iter()
            .map(|g| {
                let r = JobRequest {
                    id: 0,
                    graph: g.to_string(),
                    algo: AlgoKind::Bcc,
                    source: 0,
                };
                r.route_hash() % 4
            })
            .collect();
        assert!(shards.len() >= 2, "six names must not all collide mod 4");
    }

    #[test]
    fn aliases_accepted() {
        assert_eq!(AlgoKind::parse("bfs", 7), Some(AlgoKind::BfsVgc { tau: 7 }));
        assert_eq!(AlgoKind::parse("scc", 9), Some(AlgoKind::SccVgc { tau: 9 }));
        assert_eq!(AlgoKind::parse("bcc", 1), Some(AlgoKind::Bcc));
        assert_eq!(AlgoKind::parse("connectivity", 1), Some(AlgoKind::Cc));
        assert_eq!(AlgoKind::parse("k-core", 1), Some(AlgoKind::Kcore));
    }

    #[test]
    fn request_encodes_query() {
        let q = crate::algo::api::Query::new(
            "road",
            "sssp",
            &ParseArgs { tau: 31, block: 64 },
        )
        .unwrap()
        .with_source(5);
        let r = JobRequest::from_query(9, &q).unwrap();
        assert_eq!(r.id, 9);
        assert_eq!(r.graph, "road");
        assert_eq!(r.source, 5);
        assert_eq!(r.algo, AlgoKind::SsspRho { tau: 31 });
    }
}
