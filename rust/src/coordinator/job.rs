//! Job types for the coordinator's channel serving protocol.
//!
//! The protocol is **registry-native**: a [`JobRequest`] carries its
//! `&'static AlgoSpec` and parsed [`Params`] directly — the same
//! `(spec id, Params)` pair every other layer dispatches and groups
//! on — plus the graph name, source vertex and a request id for
//! response correlation. There is no per-algorithm table in this file
//! (the deprecated per-algorithm wire enum, the last one, is gone): any spec
//! added to [`crate::algo::api::registry`] travels the channel
//! protocol with no further registration, and
//! [`JobRequest::from_query`] converts the library-level
//! [`Query`] losslessly.

use crate::algo::api::{AlgoSpec, Params, ParseArgs, Query};
use crate::V;
use std::time::{Duration, Instant};

pub use crate::algo::api::QueryOutput as JobOutput;

/// One analysis request on the channel serving protocol: a
/// registry-native [`Query`] plus the request id clients correlate
/// responses by.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub id: u64,
    /// Name of a graph previously loaded into the coordinator.
    pub graph: String,
    /// The registry entry to dispatch through.
    pub algo: &'static AlgoSpec,
    /// Parsed parameters (what [`AlgoSpec::parse`] kept; part of the
    /// fusion grouping key and the result-cache key).
    pub params: Params,
    /// Source vertex for traversal queries (ignored when
    /// `algo.needs_source` is false).
    pub source: V,
    /// Optional deadline: past this instant the request is answered
    /// [`Failed`](crate::coordinator::faults::FailKind::DeadlineExceeded)
    /// without executing — checked at the router, at window admission
    /// (an expired head never opens a fusion window) and again at
    /// execution (mid-window expiry). `None` (the default) never
    /// expires.
    pub deadline: Option<Instant>,
    /// Request an end-to-end [`QueryTrace`](super::trace::QueryTrace)
    /// for this job: spans + engine telemetry ride back on the
    /// successful [`JobResult`]. Off by default; the serve CLI sets it
    /// on every n-th request under `--trace-sample-n`.
    pub trace: bool,
}

impl JobRequest {
    /// Build a request by registry lookup: `algo` may be a label or
    /// any alias; `args` carries the raw parameter values, of which
    /// the spec keeps the ones it understands. Source starts at 0 —
    /// chain [`JobRequest::with_source`]. `None` for names not in the
    /// registry. (Delegates to [`Query::new`] so the lookup/parse
    /// logic lives once.)
    pub fn parse(
        id: u64,
        graph: impl Into<String>,
        algo: &str,
        args: &ParseArgs,
    ) -> Option<JobRequest> {
        let q = Query::new(graph, algo, args).ok()?;
        Some(JobRequest {
            id,
            graph: q.graph,
            algo: q.algo,
            params: q.params,
            source: q.source,
            deadline: None,
            trace: false,
        })
    }

    /// Set the source vertex (builder style).
    pub fn with_source(mut self, source: V) -> JobRequest {
        self.source = source;
        self
    }

    /// Set an absolute deadline (builder style).
    pub fn with_deadline(mut self, deadline: Instant) -> JobRequest {
        self.deadline = Some(deadline);
        self
    }

    /// Set the deadline as a budget from now (builder style) — what
    /// `--deadline-ms` applies per request.
    pub fn with_budget(self, budget: Duration) -> JobRequest {
        self.with_deadline(Instant::now() + budget)
    }

    /// Request an end-to-end trace for this job (builder style).
    pub fn with_trace(mut self) -> JobRequest {
        self.trace = true;
        self
    }

    /// Has this request's deadline passed? Requests without one never
    /// expire.
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Encode a [`Query`] for the channel protocol. Lossless and
    /// infallible: the wire type *is* the registry type now.
    pub fn from_query(id: u64, q: &Query) -> JobRequest {
        JobRequest {
            id,
            graph: q.graph.clone(),
            algo: q.algo,
            params: q.params,
            source: q.source,
            deadline: None,
            trace: false,
        }
    }

    /// The non-graph half of the batch grouping key: requests fuse
    /// (and whole-graph results cache) per `(graph, spec id, Params)`.
    pub fn group_key(&self) -> (u16, Params) {
        (self.algo.id, self.params)
    }

    /// Stable FNV-1a hash of the graph name: the shard-router key.
    /// Same name ⇒ same hash ⇒ same shard, which is what guarantees a
    /// shard's fusion window sees every request that could fuse with
    /// it (and keeps one graph's derived views, warm workspaces and
    /// cached whole-graph results hot in one worker).
    pub fn route_hash(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x1_0000_0000_01b3;
        let mut h = FNV_OFFSET;
        for &b in self.graph.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        h
    }
}

/// A finished job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub algo: &'static str,
    pub output: JobOutput,
    /// Pure execution time (zero for result-cache hits).
    pub exec: Duration,
    /// Queue + execution (request-to-response) latency.
    pub latency: Duration,
    /// End-to-end trace, present iff the request asked for one
    /// ([`JobRequest::with_trace`]) and the job succeeded. Boxed so an
    /// untraced result stays one pointer wider, not a span buffer
    /// wider.
    pub trace: Option<Box<super::trace::QueryTrace>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::api;
    use crate::algo::api::registry as r;

    fn req(id: u64, graph: &str, algo: &str) -> JobRequest {
        JobRequest::parse(id, graph, algo, &ParseArgs::default()).unwrap()
    }

    #[test]
    fn every_registered_spec_travels_the_wire() {
        // Registry-completeness: every spec — and every alias — builds
        // a request that points at the exact spec with the exact
        // parsed params. Iterates the registry, not a hand-kept list,
        // so a new registry line is wire-servable by construction.
        let args = ParseArgs { tau: 77, block: 48 };
        for spec in api::all() {
            let jr = JobRequest::parse(1, "g", spec.label, &args)
                .unwrap_or_else(|| panic!("{} must parse", spec.label));
            assert!(std::ptr::eq(jr.algo, *spec), "request points at its spec");
            assert_eq!(jr.params, (spec.parse)(&args), "params survive parse");
            assert_eq!(jr.group_key(), (spec.id, (spec.parse)(&args)));
            for alias in spec.aliases {
                let ja = JobRequest::parse(1, "g", alias, &args).unwrap();
                assert!(
                    std::ptr::eq(ja.algo, *spec),
                    "alias {alias:?} must resolve identically"
                );
                assert_eq!(ja.group_key(), jr.group_key());
            }
        }
        assert!(JobRequest::parse(0, "g", "nope", &args).is_none());
    }

    #[test]
    fn parse_threads_block_size_through() {
        // Regression: `--block` used to be hard-coded to 64 in parse.
        let jr = JobRequest::parse(0, "g", "dense-closure", &ParseArgs { tau: 512, block: 96 })
            .unwrap();
        assert_eq!(jr.params.block, 96);
        assert_eq!(jr.params.tau, 0, "block specs ignore τ");
    }

    #[test]
    fn params_split_groups_but_irrelevant_knobs_do_not() {
        let a = JobRequest::parse(0, "g", "bfs", &ParseArgs { tau: 16, block: 64 }).unwrap();
        let b = JobRequest::parse(1, "g", "bfs", &ParseArgs { tau: 64, block: 64 }).unwrap();
        assert_ne!(a.group_key(), b.group_key(), "different τ never fuses");
        // bcc ignores τ entirely: one group regardless of the CLI τ.
        let c = JobRequest::parse(2, "g", "bcc", &ParseArgs { tau: 16, block: 64 }).unwrap();
        let d = JobRequest::parse(3, "g", "bcc", &ParseArgs { tau: 64, block: 1 }).unwrap();
        assert_eq!(c.group_key(), d.group_key());
    }

    #[test]
    fn route_hash_keys_on_graph_name_only() {
        let a = req(1, "road", "bfs").with_source(0);
        let b = req(2, "road", "bcc").with_source(77);
        let c = req(1, "social", "bfs");
        assert_eq!(a.route_hash(), b.route_hash(), "same graph, same shard");
        assert_ne!(a.route_hash(), c.route_hash(), "FNV separates these names");
        // Distinct names spread across a small shard count.
        let shards: std::collections::HashSet<u64> = ["g0", "g1", "g2", "g3", "g4", "g5"]
            .iter()
            .map(|g| req(0, g, "bcc").route_hash() % 4)
            .collect();
        assert!(shards.len() >= 2, "six names must not all collide mod 4");
    }

    #[test]
    fn fusable_covers_exactly_the_multi_source_engines() {
        assert!(r::BFS_VGC.fusable());
        assert!(r::BFS_DIROPT.fusable());
        assert!(r::SSSP_RHO.fusable());
        for spec in [&r::BFS_FRONTIER, &r::SSSP_DELTA, &r::SCC_VGC, &r::BCC_FAST, &r::CC, &r::KCORE]
        {
            assert!(!spec.fusable(), "{} must stay solo", spec.label);
        }
    }

    #[test]
    fn deadlines_expire_and_default_to_never() {
        let r = req(0, "g", "bfs");
        assert!(r.deadline.is_none());
        assert!(!r.expired(), "no deadline never expires");
        let r = req(1, "g", "bfs").with_budget(Duration::from_secs(3600));
        assert!(!r.expired(), "generous budget still live");
        let r = req(2, "g", "bfs").with_deadline(std::time::Instant::now());
        assert!(r.expired(), "past deadline expires");
        assert!(req(3, "g", "bfs").with_budget(Duration::ZERO).expired());
    }

    #[test]
    fn request_encodes_query() {
        let q = Query::new("road", "sssp", &ParseArgs { tau: 31, block: 64 })
            .unwrap()
            .with_source(5);
        let jr = JobRequest::from_query(9, &q);
        assert_eq!(jr.id, 9);
        assert_eq!(jr.graph, "road");
        assert_eq!(jr.source, 5);
        assert!(std::ptr::eq(jr.algo, &r::SSSP_RHO));
        assert_eq!(jr.params, Params::tau(31));
    }
}
