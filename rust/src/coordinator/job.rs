//! Job types for the coordinator.

use crate::V;
use std::time::Duration;

/// Which analysis to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    /// PASGAL VGC BFS (τ from the request).
    BfsVgc { tau: usize },
    /// GBBS-like frontier BFS (baseline).
    BfsFrontier,
    /// Direction-optimizing BFS (baseline).
    BfsDirOpt,
    /// PASGAL VGC SCC.
    SccVgc { tau: usize },
    /// Multistep SCC (baseline).
    SccMultistep,
    /// FAST-BCC.
    Bcc,
    /// ρ-stepping SSSP with VGC.
    SsspRho { tau: usize },
    /// Δ-stepping SSSP (baseline).
    SsspDelta,
    /// Dense-block closure on the PJRT engine: all-pairs distances
    /// within a extracted dense subgraph (the L1/L2 path).
    DenseClosure { block: usize },
}

impl AlgoKind {
    pub fn parse(s: &str, tau: usize) -> Option<AlgoKind> {
        Some(match s {
            "bfs" | "bfs-vgc" => AlgoKind::BfsVgc { tau },
            "bfs-frontier" => AlgoKind::BfsFrontier,
            "bfs-diropt" => AlgoKind::BfsDirOpt,
            "scc" | "scc-vgc" => AlgoKind::SccVgc { tau },
            "scc-multistep" => AlgoKind::SccMultistep,
            "bcc" | "bcc-fast" => AlgoKind::Bcc,
            "sssp" | "sssp-rho" => AlgoKind::SsspRho { tau },
            "sssp-delta" => AlgoKind::SsspDelta,
            "dense-closure" => AlgoKind::DenseClosure { block: 64 },
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            AlgoKind::BfsVgc { .. } => "bfs-vgc",
            AlgoKind::BfsFrontier => "bfs-frontier",
            AlgoKind::BfsDirOpt => "bfs-diropt",
            AlgoKind::SccVgc { .. } => "scc-vgc",
            AlgoKind::SccMultistep => "scc-multistep",
            AlgoKind::Bcc => "bcc-fast",
            AlgoKind::SsspRho { .. } => "sssp-rho",
            AlgoKind::SsspDelta => "sssp-delta",
            AlgoKind::DenseClosure { .. } => "dense-closure",
        }
    }

    /// True for algorithms with a batched multi-source engine: the
    /// coordinator fuses same-graph groups of these into one frontier
    /// walk (see [`crate::algo::multi`]). Parameterized variants only
    /// fuse within the same parameter value — the derived `Eq`/`Hash`
    /// grouping key guarantees that.
    pub fn fusable(&self) -> bool {
        matches!(
            self,
            AlgoKind::BfsVgc { .. } | AlgoKind::BfsDirOpt | AlgoKind::SsspRho { .. }
        )
    }

    /// Deterministic tiebreak for batch scheduling order among kinds
    /// sharing a label (e.g. two `BfsVgc` τ values).
    pub(crate) fn param(&self) -> usize {
        match self {
            AlgoKind::BfsVgc { tau } | AlgoKind::SccVgc { tau } | AlgoKind::SsspRho { tau } => *tau,
            AlgoKind::DenseClosure { block } => *block,
            _ => 0,
        }
    }
}

/// One analysis request.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub id: u64,
    /// Name of a graph previously loaded into the coordinator.
    pub graph: String,
    pub algo: AlgoKind,
    /// Source vertex for traversal queries.
    pub source: V,
}

impl JobRequest {
    /// Stable FNV-1a hash of the graph name: the shard-router key.
    /// Same name ⇒ same hash ⇒ same shard, which is what guarantees a
    /// shard's fusion window sees every request that could fuse with
    /// it (and keeps one graph's derived views hot in one worker).
    pub fn route_hash(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x1_0000_0000_01b3;
        let mut h = FNV_OFFSET;
        for &b in self.graph.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        h
    }
}

/// Compact algorithm output (the full vectors stay with the caller
/// when run through the library API; the server reports summaries).
#[derive(Debug, Clone, PartialEq)]
pub enum JobOutput {
    /// (#reached, max distance) for BFS.
    Bfs { reached: usize, ecc: u32 },
    /// (#components, largest component size).
    Scc { count: usize, largest: usize },
    /// (#blocks, #articulation points).
    Bcc { blocks: usize, articulation: usize },
    /// (#reached, max finite distance).
    Sssp { reached: usize, radius: f32 },
    /// (block size, #finite pairwise distances).
    Dense { block: usize, finite_pairs: usize },
    /// The request failed (unknown graph, out-of-range source, no
    /// dense engine, ...): the serving loops answer *every* accepted
    /// request, so failures come back on the result channel with the
    /// request's id instead of vanishing into a log line.
    Failed { error: String },
}

/// A finished job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub algo: &'static str,
    pub output: JobOutput,
    /// Pure execution time.
    pub exec: Duration,
    /// Queue + execution (request-to-response) latency.
    pub latency: Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_labels() {
        for s in [
            "bfs-vgc",
            "bfs-frontier",
            "bfs-diropt",
            "scc-vgc",
            "scc-multistep",
            "bcc-fast",
            "sssp-rho",
            "sssp-delta",
            "dense-closure",
        ] {
            let k = AlgoKind::parse(s, 512).unwrap();
            assert_eq!(k.label(), s);
        }
        assert!(AlgoKind::parse("nope", 1).is_none());
    }

    #[test]
    fn fusable_covers_exactly_the_multi_source_engines() {
        assert!(AlgoKind::BfsVgc { tau: 64 }.fusable());
        assert!(AlgoKind::BfsDirOpt.fusable());
        assert!(AlgoKind::SsspRho { tau: 64 }.fusable());
        assert!(!AlgoKind::BfsFrontier.fusable());
        assert!(!AlgoKind::SsspDelta.fusable());
        assert!(!AlgoKind::SccVgc { tau: 64 }.fusable());
        assert!(!AlgoKind::Bcc.fusable());
    }

    #[test]
    fn route_hash_keys_on_graph_name_only() {
        let a = JobRequest {
            id: 1,
            graph: "road".into(),
            algo: AlgoKind::BfsVgc { tau: 8 },
            source: 0,
        };
        let b = JobRequest {
            id: 2,
            graph: "road".into(),
            algo: AlgoKind::Bcc,
            source: 77,
        };
        let c = JobRequest {
            id: 1,
            graph: "social".into(),
            algo: AlgoKind::BfsVgc { tau: 8 },
            source: 0,
        };
        assert_eq!(a.route_hash(), b.route_hash(), "same graph, same shard");
        assert_ne!(a.route_hash(), c.route_hash(), "FNV separates these names");
        // Distinct names spread across a small shard count.
        let shards: std::collections::HashSet<u64> = ["g0", "g1", "g2", "g3", "g4", "g5"]
            .iter()
            .map(|g| {
                let r = JobRequest {
                    id: 0,
                    graph: g.to_string(),
                    algo: AlgoKind::Bcc,
                    source: 0,
                };
                r.route_hash() % 4
            })
            .collect();
        assert!(shards.len() >= 2, "six names must not all collide mod 4");
    }

    #[test]
    fn aliases_accepted() {
        assert_eq!(AlgoKind::parse("bfs", 7), Some(AlgoKind::BfsVgc { tau: 7 }));
        assert_eq!(AlgoKind::parse("scc", 9), Some(AlgoKind::SccVgc { tau: 9 }));
        assert_eq!(AlgoKind::parse("bcc", 1), Some(AlgoKind::Bcc));
    }
}
