//! End-to-end query tracing for the serving path.
//!
//! A [`QueryTrace`] is a flat list of nested wall-clock spans
//! (cache probe, engine run, fused walk, demux, ...) measured from a
//! single epoch, plus optional per-round [`EngineTelemetry`] harvested
//! from the engines' [`AlgoTrace`] side-channel. Traces are requested
//! per `JobRequest` under a sampling knob (`--trace-sample-n`),
//! attached to successful `JobResult`s, and rendered as JSON lines.
//!
//! Span accounting: [`QueryTrace::seal`] stamps the reported request
//! latency and computes a synthetic top-level `wait` span covering
//! everything the measured spans did not (inbox time, fusion-window
//! time, inter-span gaps). By construction, `wait` plus the measured
//! top-level spans sum exactly to the reported latency. Sealing is
//! idempotent — when a batch path re-stamps a result's latency from
//! the batch epoch, re-sealing just grows `wait`.

use crate::sim::AlgoTrace;
use std::time::{Duration, Instant};

/// Per-round engine telemetry distilled from an [`AlgoTrace`]: the
/// numbers behind the paper's large-diameter claim (round count is the
/// O(D) bottleneck; local-search steps are the VGC spawns that hide
/// scheduling overhead).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EngineTelemetry {
    /// Synchronized parallel rounds the engine executed.
    pub rounds: usize,
    /// Vertices expanded by the busiest round (peak frontier size).
    pub peak_frontier: u64,
    /// Total edges scanned across all rounds.
    pub edges_scanned: u64,
    /// Total parallel tasks spawned (VGC local searches).
    pub local_search_steps: u64,
}

impl EngineTelemetry {
    pub fn from_trace(t: &AlgoTrace) -> Self {
        let total = t.total();
        EngineTelemetry {
            rounds: t.num_rounds(),
            peak_frontier: t.peak_round_vertices(),
            edges_scanned: total.edges,
            local_search_steps: t.total_tasks(),
        }
    }
}

/// One timed span, offsets in microseconds from the trace epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub name: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
    /// Nesting depth: 0 = top-level; a span at depth d+1 is enclosed
    /// by the nearest preceding span at depth d.
    pub depth: u8,
}

/// A lightweight per-query trace: nested spans + engine telemetry.
#[derive(Debug, Clone)]
pub struct QueryTrace {
    epoch: Instant,
    spans: Vec<Span>,
    /// Indices of currently open spans (a stack).
    open: Vec<usize>,
    /// Synthetic wait time (reported latency minus measured top-level
    /// spans), computed by `seal`.
    wait_us: u64,
    /// Reported request latency, stamped by `seal`.
    total_us: u64,
    pub telemetry: Option<EngineTelemetry>,
}

impl Default for QueryTrace {
    fn default() -> Self {
        Self::new()
    }
}

impl QueryTrace {
    /// A trace whose epoch is now.
    pub fn new() -> Self {
        Self::new_at(Instant::now())
    }

    /// A trace measured from an explicit epoch (fused walks share one
    /// epoch across lanes).
    pub fn new_at(epoch: Instant) -> Self {
        QueryTrace {
            epoch,
            spans: Vec::new(),
            open: Vec::new(),
            wait_us: 0,
            total_us: 0,
            telemetry: None,
        }
    }

    #[inline]
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Open a span; nested inside any currently open span.
    pub fn begin(&mut self, name: &'static str) {
        let depth = self.open.len().min(u8::MAX as usize) as u8;
        self.spans.push(Span {
            name,
            start_us: self.now_us(),
            dur_us: 0,
            depth,
        });
        self.open.push(self.spans.len() - 1);
    }

    /// Close the innermost open span.
    pub fn end(&mut self) {
        if let Some(idx) = self.open.pop() {
            let now = self.now_us();
            let s = &mut self.spans[idx];
            s.dur_us = now.saturating_sub(s.start_us);
        }
    }

    /// Record an externally measured, already-complete span (the fused
    /// path measures one walk shared by many lanes).
    pub fn push_span(&mut self, name: &'static str, start: Duration, dur: Duration) {
        self.spans.push(Span {
            name,
            start_us: start.as_micros() as u64,
            dur_us: dur.as_micros() as u64,
            depth: self.open.len().min(u8::MAX as usize) as u8,
        });
    }

    /// Stamp the reported latency and account the unmeasured remainder
    /// to a synthetic top-level `wait` span. Idempotent: re-sealing
    /// with a larger latency (batch paths re-stamp from the batch
    /// epoch) recomputes `wait` from scratch.
    pub fn seal(&mut self, total: Duration) {
        while !self.open.is_empty() {
            self.end();
        }
        self.total_us = total.as_micros() as u64;
        let measured: u64 = self
            .spans
            .iter()
            .filter(|s| s.depth == 0)
            .map(|s| s.dur_us)
            .sum();
        self.wait_us = self.total_us.saturating_sub(measured);
    }

    /// Measured spans (excludes the synthetic `wait`).
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Synthetic wait time computed by `seal` (µs).
    pub fn wait_us(&self) -> u64 {
        self.wait_us
    }

    /// Reported request latency stamped by `seal` (µs).
    pub fn total_us(&self) -> u64 {
        self.total_us
    }

    /// Sum of all top-level span durations including `wait` — equals
    /// `total_us` by construction unless measured spans exceeded the
    /// reported latency (sub-µs rounding), in which case it may exceed
    /// it by at most the rounding error.
    pub fn top_level_sum_us(&self) -> u64 {
        self.wait_us
            + self
                .spans
                .iter()
                .filter(|s| s.depth == 0)
                .map(|s| s.dur_us)
                .sum::<u64>()
    }

    /// One JSON line (`pasgal-trace/1`): identity, total, spans
    /// (synthetic `wait` first), telemetry (or `null`).
    pub fn json_line(&self, id: u64, graph: &str, algo: &str) -> String {
        use super::metrics::json_escape;
        let mut out = String::from("{\"schema\":\"pasgal-trace/1\",\"id\":");
        out.push_str(&id.to_string());
        out.push_str(",\"graph\":\"");
        json_escape(graph, &mut out);
        out.push_str("\",\"algo\":\"");
        json_escape(algo, &mut out);
        out.push_str(&format!("\",\"total_us\":{},\"spans\":[", self.total_us));
        out.push_str(&format!(
            "{{\"name\":\"wait\",\"start_us\":0,\"dur_us\":{},\"depth\":0}}",
            self.wait_us
        ));
        for s in &self.spans {
            out.push_str(&format!(
                ",{{\"name\":\"{}\",\"start_us\":{},\"dur_us\":{},\"depth\":{}}}",
                s.name, s.start_us, s.dur_us, s.depth
            ));
        }
        out.push_str("],\"telemetry\":");
        match &self.telemetry {
            Some(t) => out.push_str(&format!(
                "{{\"rounds\":{},\"peak_frontier\":{},\"edges_scanned\":{},\"local_search_steps\":{}}}",
                t.rounds, t.peak_frontier, t.edges_scanned, t.local_search_steps
            )),
            None => out.push_str("null"),
        }
        out.push('}');
        out
    }
}

/// Client-side sampling policy for `--trace-sample-n`: marks every
/// n-th request starting with the first (`n == 1` traces everything,
/// `n == 0` traces nothing).
#[derive(Debug, Clone)]
pub struct TraceSampler {
    n: u64,
    seen: u64,
}

impl TraceSampler {
    pub fn new(n: u64) -> Self {
        TraceSampler { n, seen: 0 }
    }

    /// Whether the next request should carry a trace.
    pub fn sample(&mut self) -> bool {
        if self.n == 0 {
            return false;
        }
        let pick = self.seen % self.n == 0;
        self.seen += 1;
        pick
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::sleep;

    #[test]
    fn spans_nest_and_wait_absorbs_the_rest() {
        let mut t = QueryTrace::new();
        t.begin("exec");
        t.begin("cache_probe");
        sleep(Duration::from_millis(2));
        t.end();
        t.begin("engine_run");
        sleep(Duration::from_millis(2));
        t.end();
        t.end();
        t.seal(Duration::from_millis(50));
        assert_eq!(t.spans().len(), 3);
        assert_eq!(t.spans()[0].depth, 0);
        assert_eq!(t.spans()[1].depth, 1);
        assert_eq!(t.spans()[2].depth, 1);
        // Top-level spans + wait sum exactly to the sealed total.
        assert_eq!(t.top_level_sum_us(), t.total_us());
        assert_eq!(t.total_us(), 50_000);
        // Children are contained in the parent.
        let exec = t.spans()[0];
        for child in &t.spans()[1..] {
            assert!(child.start_us >= exec.start_us);
            assert!(child.start_us + child.dur_us <= exec.start_us + exec.dur_us);
        }
    }

    #[test]
    fn seal_is_idempotent_and_restampable() {
        let mut t = QueryTrace::new();
        t.begin("engine_run");
        sleep(Duration::from_millis(1));
        t.end();
        t.seal(Duration::from_millis(10));
        let wait_first = t.wait_us();
        assert_eq!(t.top_level_sum_us(), 10_000);
        // Re-seal with a larger latency (batch restamp): wait grows.
        t.seal(Duration::from_millis(20));
        assert_eq!(t.top_level_sum_us(), 20_000);
        assert!(t.wait_us() > wait_first);
    }

    #[test]
    fn seal_closes_dangling_spans() {
        let mut t = QueryTrace::new();
        t.begin("engine_run");
        t.seal(Duration::from_millis(5));
        assert_eq!(t.spans().len(), 1);
        assert_eq!(t.top_level_sum_us(), 5_000);
    }

    #[test]
    fn telemetry_derives_from_algo_trace() {
        use crate::sim::trace::TaskCost;
        let mut at = AlgoTrace::new();
        at.push_round(vec![
            TaskCost { vertices: 3, edges: 10 },
            TaskCost { vertices: 1, edges: 2 },
        ]);
        at.push_round(vec![TaskCost { vertices: 9, edges: 4 }]);
        let tel = EngineTelemetry::from_trace(&at);
        assert_eq!(tel.rounds, 2);
        assert_eq!(tel.peak_frontier, 9);
        assert_eq!(tel.edges_scanned, 16);
        assert_eq!(tel.local_search_steps, 3);
    }

    #[test]
    fn json_line_has_schema_and_escapes() {
        let mut t = QueryTrace::new();
        t.begin("engine_run");
        t.end();
        t.seal(Duration::from_micros(123));
        let line = t.json_line(7, "gr\"aph", "bfs-vgc");
        assert!(line.contains("\"schema\":\"pasgal-trace/1\""));
        assert!(line.contains("\"id\":7"));
        assert!(line.contains("gr\\\"aph"));
        assert!(line.contains("\"name\":\"wait\""));
        assert!(line.contains("\"telemetry\":null"));
        assert!(!line.contains('\n'));
    }

    #[test]
    fn sampler_picks_every_nth() {
        let mut s = TraceSampler::new(3);
        let picks: Vec<bool> = (0..7).map(|_| s.sample()).collect();
        assert_eq!(picks, vec![true, false, false, true, false, false, true]);
        let mut never = TraceSampler::new(0);
        assert!((0..5).all(|_| !never.sample()));
        let mut always = TraceSampler::new(1);
        assert!((0..5).all(|_| always.sample()));
    }
}
