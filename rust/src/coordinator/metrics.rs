//! Lightweight metrics registry: counters + latency summaries.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::lock_or_recover;
use std::time::Duration;

/// Percentile summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// Thread-safe metrics: named counters and named latency series.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, AtomicU64>>,
    series: Mutex<HashMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter. Warm counters (every bump after the
    /// first for a given name) take the fast path: no allocation, one
    /// uncontended lock and an atomic add — this runs several times
    /// per request on the serving hot path.
    pub fn bump(&self, name: &str, by: u64) {
        let map = lock_or_recover(&self.counters);
        if let Some(c) = map.get(name) {
            c.fetch_add(by, Ordering::Relaxed);
            return;
        }
        drop(map);
        let mut map = lock_or_recover(&self.counters);
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    /// Current counter value.
    pub fn counter(&self, name: &str) -> u64 {
        lock_or_recover(&self.counters)
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a latency observation.
    pub fn observe(&self, name: &str, d: Duration) {
        lock_or_recover(&self.series)
            .entry(name.to_string())
            .or_default()
            .push(d.as_secs_f64() * 1e3);
    }

    /// Summarize a latency series (None if empty/unknown).
    pub fn summary(&self, name: &str) -> Option<Summary> {
        let map = lock_or_recover(&self.series);
        let xs = map.get(name)?;
        if xs.is_empty() {
            return None;
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
            sorted[idx]
        };
        Some(Summary {
            count: sorted.len(),
            mean_ms: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50_ms: q(0.50),
            p95_ms: q(0.95),
            p99_ms: q(0.99),
            max_ms: *sorted.last().unwrap(),
        })
    }

    /// All series names (sorted).
    pub fn series_names(&self) -> Vec<String> {
        let mut names: Vec<String> = lock_or_recover(&self.series).keys().cloned().collect();
        names.sort();
        names
    }

    /// All counter names (sorted) — e.g. to report the
    /// `queries_fused` / `queries_solo` split after a serving run.
    pub fn counter_names(&self) -> Vec<String> {
        let mut names: Vec<String> = lock_or_recover(&self.counters).keys().cloned().collect();
        names.sort();
        names
    }

    /// Fold another registry into this one: counters add, latency
    /// series concatenate. The shard server uses this to aggregate
    /// each worker's shard-local registry into the coordinator's
    /// global one — per-shard counters (`shard_dispatches`,
    /// `window_waits`, `window_timeouts`, `registry_snapshots`, ...)
    /// sum across shards. Both sides' values are snapshotted before
    /// writing, so merging is safe while either registry is still
    /// being written to (merging a registry into itself doubles it —
    /// don't).
    pub fn merge(&self, other: &Metrics) {
        let counters: Vec<(String, u64)> = {
            let theirs = lock_or_recover(&other.counters);
            theirs
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect()
        };
        for (name, v) in counters {
            if v > 0 {
                self.bump(&name, v);
            }
        }
        let series: Vec<(String, Vec<f64>)> = {
            let theirs = lock_or_recover(&other.series);
            theirs.iter().map(|(k, xs)| (k.clone(), xs.clone())).collect()
        };
        let mut mine = lock_or_recover(&self.series);
        for (name, xs) in series {
            mine.entry(name).or_default().extend(xs);
        }
    }

    /// Fraction of batch queries routed to the fused multi-source path
    /// (errors included on both sides; 0.0 when no batch queries ran
    /// yet).
    pub fn fused_fraction(&self) -> f64 {
        let fused = self.counter("queries_fused") as f64;
        let solo = self.counter("queries_solo") as f64;
        if fused + solo == 0.0 {
            0.0
        } else {
            fused / (fused + solo)
        }
    }

    /// Fraction of cacheable (whole-graph) queries answered from the
    /// result cache (0.0 when none ran yet). `cache_hits` and
    /// `cache_misses` merge across shards like every other counter,
    /// so this is meaningful on both a shard-local and the aggregated
    /// global registry.
    pub fn cache_hit_rate(&self) -> f64 {
        let hits = self.counter("cache_hits") as f64;
        let misses = self.counter("cache_misses") as f64;
        if hits + misses == 0.0 {
            0.0
        } else {
            hits / (hits + misses)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.bump("jobs", 1);
        m.bump("jobs", 2);
        assert_eq!(m.counter("jobs"), 3);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn summary_percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.observe("lat", Duration::from_millis(i));
        }
        let s = m.summary("lat").unwrap();
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 50.0).abs() <= 1.5, "p50={}", s.p50_ms);
        assert!((s.p95_ms - 95.0).abs() <= 1.5);
        assert!((s.max_ms - 100.0).abs() < 1e-9);
        assert!((s.mean_ms - 50.5).abs() < 0.1);
    }

    #[test]
    fn summary_of_unknown_is_none() {
        assert!(Metrics::new().summary("nope").is_none());
    }

    #[test]
    fn cache_hit_rate_tracks_the_counters() {
        let m = Metrics::new();
        assert_eq!(m.cache_hit_rate(), 0.0);
        m.bump("cache_misses", 1);
        assert_eq!(m.cache_hit_rate(), 0.0);
        m.bump("cache_hits", 3);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn counter_names_and_fused_fraction() {
        let m = Metrics::new();
        assert_eq!(m.fused_fraction(), 0.0);
        m.bump("queries_fused", 3);
        m.bump("queries_solo", 1);
        assert!((m.fused_fraction() - 0.75).abs() < 1e-12);
        assert_eq!(
            m.counter_names(),
            vec!["queries_fused".to_string(), "queries_solo".to_string()]
        );
    }

    #[test]
    fn merge_sums_counters_and_concatenates_series() {
        let global = Metrics::new();
        global.bump("jobs_executed", 2);
        global.observe("latency", Duration::from_millis(1));
        let shard_a = Metrics::new();
        shard_a.bump("jobs_executed", 3);
        shard_a.bump("shard_dispatches", 1);
        shard_a.observe("latency", Duration::from_millis(2));
        let shard_b = Metrics::new();
        shard_b.bump("jobs_executed", 5);
        shard_b.bump("window_timeouts", 4);
        global.merge(&shard_a);
        global.merge(&shard_b);
        assert_eq!(global.counter("jobs_executed"), 10);
        assert_eq!(global.counter("shard_dispatches"), 1);
        assert_eq!(global.counter("window_timeouts"), 4);
        assert_eq!(global.summary("latency").unwrap().count, 2);
        // Sources are untouched.
        assert_eq!(shard_a.counter("jobs_executed"), 3);
        assert_eq!(shard_b.counter("jobs_executed"), 5);
    }

    #[test]
    fn concurrent_observes_all_recorded() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for i in 0..250 {
                        m.observe("x", Duration::from_micros(i));
                    }
                });
            }
        });
        assert_eq!(m.summary("x").unwrap().count, 1000);
    }
}
