//! Metrics registry: counters + bounded latency histograms + snapshot export.
//!
//! Counters are named `AtomicU64`s behind a map lock (warm bumps take
//! one uncontended lock and an atomic add). Latency series are
//! fixed-size log-bucketed atomic [`Histogram`]s: recording is
//! lock-free after the first observation of a name, memory is O(1) in
//! the number of observations (the histogram footprint is ~30 KiB per
//! series, allocated once), and [`Summary`] percentiles come from a
//! bucket scan with a bounded relative error (see [`Histogram`]).
//!
//! [`Metrics::snapshot`] renders the whole registry — sorted counters,
//! sorted series summaries, and derived rates — as a stable
//! [`MetricsSnapshot`] with Prometheus-style text and JSON encoders,
//! which the `stats --metrics` CLI, `serve --metrics-out`, and the
//! `bench/trajectory` driver all consume.
//!
//! Elasticity observability (see `coordinator::shard`): the
//! `steal_attempts` / `steal_conflicts` / `batches_stolen` counters
//! trace the cross-shard work-stealing protocol, `lane_compactions`
//! counts mid-walk re-packs of fused multi-source walks,
//! `engines_replicated` counts per-shard dense-engine replicas spawned
//! at serve start, and the `fusion_window_us` series records the
//! (possibly load-adaptive) admission window each dispatch actually
//! opened — its exact `max`/`mean` make the shrink-vs-grow behaviour
//! assertable in tests.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::lock_or_recover;
use std::time::Duration;

/// Sub-bucket resolution: 2^6 = 64 linear sub-buckets per octave.
const SUB_BITS: u32 = 6;
const SUB: usize = 1 << SUB_BITS;
/// Groups: one exact group for values < 64 ns, then one per octave for
/// the 58 remaining magnitudes of a u64 nanosecond value.
const GROUPS: usize = 64 - SUB_BITS as usize + 1;
/// Total bucket count (59 × 64 = 3776; ~30 KiB of `AtomicU64`s).
const NUM_BUCKETS: usize = GROUPS * SUB;

/// Fixed-size log-bucketed histogram of nanosecond durations.
///
/// Values below 64 ns land in exact unit-width buckets; every larger
/// value lands in one of 64 linear sub-buckets of its octave
/// `[2^k, 2^(k+1))`, so the bucket width is at most `value / 64`
/// (relative error ≤ 1.5625%, ≤ 0.79% reporting bucket midpoints).
/// Count, sum, and max are kept in dedicated atomics, so `mean` and
/// `max` are exact; only percentiles carry the bucket error.
///
/// All operations are lock-free; `record` is a handful of relaxed
/// atomic RMWs. Histograms merge bucket-wise (shard registries fold
/// into the global one exactly like counters).
pub struct Histogram {
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    buckets: Box<[AtomicU64]>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Index of the bucket covering `ns`.
#[inline]
fn bucket_index(ns: u64) -> usize {
    if ns < SUB as u64 {
        ns as usize
    } else {
        let msb = 63 - ns.leading_zeros();
        let shift = msb - SUB_BITS;
        let group = (shift + 1) as usize;
        let sub = ((ns >> shift) as usize) & (SUB - 1);
        (group << SUB_BITS) | sub
    }
}

/// Midpoint (in ns) of bucket `idx` — the value percentiles report.
#[inline]
fn bucket_mid_ns(idx: usize) -> f64 {
    let group = idx >> SUB_BITS;
    let sub = (idx & (SUB - 1)) as u64;
    if group == 0 {
        sub as f64
    } else {
        let shift = (group - 1) as u32;
        let lo = (sub + SUB as u64) << shift;
        lo as f64 + (1u64 << shift) as f64 / 2.0
    }
}

impl Histogram {
    pub fn new() -> Self {
        let buckets: Box<[AtomicU64]> =
            (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Self {
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            buckets,
        }
    }

    /// Record one duration. Lock-free; relaxed atomics only.
    pub fn record(&self, d: Duration) {
        let ns = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Fixed memory footprint of one histogram in bytes (buckets +
    /// header); the bound the bounded-memory regression test asserts.
    pub fn footprint_bytes() -> usize {
        NUM_BUCKETS * std::mem::size_of::<AtomicU64>()
            + std::mem::size_of::<Histogram>()
    }

    /// Fold `other`'s observations into `self` (bucket-wise add,
    /// max-of-max). Safe while either side is still recording; values
    /// are snapshotted per-bucket.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = theirs.load(Ordering::Relaxed);
            if v > 0 {
                mine.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns
            .fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_ns
            .fetch_max(other.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Percentile summary from a bucket scan. No heap allocation; the
    /// scan buffer is a fixed-size stack array, so cost is independent
    /// of how many observations were recorded.
    pub fn summary(&self) -> Option<Summary> {
        let mut local = [0u64; NUM_BUCKETS];
        for (slot, b) in local.iter_mut().zip(self.buckets.iter()) {
            *slot = b.load(Ordering::Relaxed);
        }
        let total: u64 = local.iter().sum();
        if total == 0 {
            return None;
        }
        // Smallest recorded value whose cumulative count reaches
        // ceil(p * total) — the standard nearest-rank percentile.
        let q = |p: f64| -> f64 {
            let rank = ((p * total as f64).ceil() as u64).clamp(1, total);
            let mut cum = 0u64;
            for (i, &c) in local.iter().enumerate() {
                cum += c;
                if cum >= rank {
                    return bucket_mid_ns(i) / 1e6;
                }
            }
            self.max_ns.load(Ordering::Relaxed) as f64 / 1e6
        };
        Some(Summary {
            count: total as usize,
            mean_ms: self.sum_ns.load(Ordering::Relaxed) as f64
                / total as f64
                / 1e6,
            p50_ms: q(0.50),
            p95_ms: q(0.95),
            p99_ms: q(0.99),
            max_ms: self.max_ns.load(Ordering::Relaxed) as f64 / 1e6,
        })
    }
}

/// Percentile summary of a latency series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// Thread-safe metrics: named counters and named latency histograms.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, AtomicU64>>,
    series: Mutex<HashMap<String, Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter. Warm counters (every bump after the
    /// first for a given name) take the fast path: no allocation, one
    /// uncontended lock and an atomic add — this runs several times
    /// per request on the serving hot path.
    pub fn bump(&self, name: &str, by: u64) {
        let map = lock_or_recover(&self.counters);
        if let Some(c) = map.get(name) {
            c.fetch_add(by, Ordering::Relaxed);
            return;
        }
        drop(map);
        let mut map = lock_or_recover(&self.counters);
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    /// Ensure a counter exists (at its current value, or 0) so it
    /// appears in snapshots and reports even if never bumped — used to
    /// make end-of-run reports complete and diffable across runs.
    pub fn register(&self, name: &str) {
        self.bump(name, 0);
    }

    /// Current counter value.
    pub fn counter(&self, name: &str) -> u64 {
        lock_or_recover(&self.counters)
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a latency observation into the named histogram. Warm
    /// series (every observation after the first for a name) are
    /// allocation-free: one short map lock to clone the `Arc`, then a
    /// lock-free bucket increment.
    pub fn observe(&self, name: &str, d: Duration) {
        let hist = {
            let map = lock_or_recover(&self.series);
            map.get(name).cloned()
        };
        let hist = match hist {
            Some(h) => h,
            None => {
                let mut map = lock_or_recover(&self.series);
                Arc::clone(
                    map.entry(name.to_string())
                        .or_insert_with(|| Arc::new(Histogram::new())),
                )
            }
        };
        hist.record(d);
    }

    /// The named histogram, if any observation was ever recorded.
    pub fn histogram(&self, name: &str) -> Option<Arc<Histogram>> {
        lock_or_recover(&self.series).get(name).cloned()
    }

    /// Summarize a latency series (None if empty/unknown). A fixed
    /// bucket scan — cost and allocation are independent of how many
    /// observations the series holds.
    pub fn summary(&self, name: &str) -> Option<Summary> {
        self.histogram(name)?.summary()
    }

    /// All series names (sorted).
    pub fn series_names(&self) -> Vec<String> {
        let mut names: Vec<String> = lock_or_recover(&self.series).keys().cloned().collect();
        names.sort();
        names
    }

    /// All counter names (sorted) — e.g. to report the
    /// `queries_fused` / `queries_solo` split after a serving run.
    pub fn counter_names(&self) -> Vec<String> {
        let mut names: Vec<String> = lock_or_recover(&self.counters).keys().cloned().collect();
        names.sort();
        names
    }

    /// Fold another registry into this one: counters add, latency
    /// histograms merge bucket-wise. The shard server uses this to
    /// aggregate each worker's shard-local registry into the
    /// coordinator's global one — per-shard counters
    /// (`shard_dispatches`, `window_waits`, `window_timeouts`,
    /// `registry_snapshots`, ...) sum across shards. Both sides'
    /// values are snapshotted before writing, so merging is safe while
    /// either registry is still being written to (merging a registry
    /// into itself doubles it — don't).
    pub fn merge(&self, other: &Metrics) {
        let counters: Vec<(String, u64)> = {
            let theirs = lock_or_recover(&other.counters);
            theirs
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect()
        };
        for (name, v) in counters {
            if v > 0 {
                self.bump(&name, v);
            }
        }
        let series: Vec<(String, Arc<Histogram>)> = {
            let theirs = lock_or_recover(&other.series);
            theirs
                .iter()
                .map(|(k, h)| (k.clone(), Arc::clone(h)))
                .collect()
        };
        for (name, theirs) in series {
            let mine = {
                let mut map = lock_or_recover(&self.series);
                Arc::clone(
                    map.entry(name)
                        .or_insert_with(|| Arc::new(Histogram::new())),
                )
            };
            mine.merge_from(&theirs);
        }
    }

    /// Fraction of batch queries routed to the fused multi-source path
    /// (errors included on both sides; 0.0 when no batch queries ran
    /// yet).
    pub fn fused_fraction(&self) -> f64 {
        let fused = self.counter("queries_fused") as f64;
        let solo = self.counter("queries_solo") as f64;
        if fused + solo == 0.0 {
            0.0
        } else {
            fused / (fused + solo)
        }
    }

    /// Fraction of cacheable (whole-graph) queries answered from the
    /// result cache (0.0 when none ran yet). `cache_hits` and
    /// `cache_misses` merge across shards like every other counter,
    /// so this is meaningful on both a shard-local and the aggregated
    /// global registry.
    pub fn cache_hit_rate(&self) -> f64 {
        let hits = self.counter("cache_hits") as f64;
        let misses = self.counter("cache_misses") as f64;
        if hits + misses == 0.0 {
            0.0
        } else {
            hits / (hits + misses)
        }
    }

    /// Point-in-time snapshot of the whole registry: every counter and
    /// every series summary in sorted name order, plus the derived
    /// rates. The rendering of a snapshot is a pure function of its
    /// values — two runs with equal metrics render byte-identically.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters: Vec<(String, u64)> = self
            .counter_names()
            .into_iter()
            .map(|n| {
                let v = self.counter(&n);
                (n, v)
            })
            .collect();
        let series: Vec<(String, Summary)> = self
            .series_names()
            .into_iter()
            .filter_map(|n| {
                let s = self.summary(&n)?;
                Some((n, s))
            })
            .collect();
        MetricsSnapshot {
            counters,
            series,
            cache_hit_rate: self.cache_hit_rate(),
            fused_fraction: self.fused_fraction(),
        }
    }
}

/// Stable, sorted, machine-readable view of a [`Metrics`] registry.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// (name, value), sorted by name.
    pub counters: Vec<(String, u64)>,
    /// (name, summary), sorted by name.
    pub series: Vec<(String, Summary)>,
    pub cache_hit_rate: f64,
    pub fused_fraction: f64,
}

/// Escape a string for a JSON string literal (quotes, backslashes,
/// control characters).
pub fn json_escape(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// Render a finite float for JSON/Prometheus output (non-finite
/// values, which the registry never produces on its own, render as 0).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "0".to_string()
    }
}

/// Escape a Prometheus label value (`\`, `"`, newline).
fn prom_escape(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

impl MetricsSnapshot {
    /// Prometheus text exposition. Counter and series names carry
    /// slashes (`exec/bfs-vgc`, `graph_seen/road`), which are invalid
    /// in metric names, so names are encoded as label values under
    /// three fixed metric families.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        out.push_str("# TYPE pasgal_counter counter\n");
        for (name, v) in &self.counters {
            out.push_str("pasgal_counter{name=\"");
            prom_escape(name, &mut out);
            out.push_str(&format!("\"}} {v}\n"));
        }
        out.push_str("# TYPE pasgal_derived_ratio gauge\n");
        out.push_str(&format!(
            "pasgal_derived_ratio{{name=\"cache_hit_rate\"}} {}\n",
            fmt_f64(self.cache_hit_rate)
        ));
        out.push_str(&format!(
            "pasgal_derived_ratio{{name=\"fused_fraction\"}} {}\n",
            fmt_f64(self.fused_fraction)
        ));
        out.push_str("# TYPE pasgal_series_ms gauge\n");
        for (name, s) in &self.series {
            let stats = [
                ("count", s.count as f64),
                ("mean", s.mean_ms),
                ("p50", s.p50_ms),
                ("p95", s.p95_ms),
                ("p99", s.p99_ms),
                ("max", s.max_ms),
            ];
            for (stat, v) in stats {
                out.push_str("pasgal_series_ms{series=\"");
                prom_escape(name, &mut out);
                out.push_str(&format!("\",stat=\"{stat}\"}} {}\n", fmt_f64(v)));
            }
        }
        out
    }

    /// Single-object JSON rendering (sorted keys, stable across runs
    /// with equal values).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"schema\":\"pasgal-metrics/1\",\"counters\":{");
        for (i, (name, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            json_escape(name, &mut out);
            out.push_str(&format!("\":{v}"));
        }
        out.push_str("},\"derived\":{\"cache_hit_rate\":");
        out.push_str(&fmt_f64(self.cache_hit_rate));
        out.push_str(",\"fused_fraction\":");
        out.push_str(&fmt_f64(self.fused_fraction));
        out.push_str("},\"series\":{");
        for (i, (name, s)) in self.series.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            json_escape(name, &mut out);
            out.push_str(&format!(
                "\":{{\"count\":{},\"mean_ms\":{},\"p50_ms\":{},\"p95_ms\":{},\"p99_ms\":{},\"max_ms\":{}}}",
                s.count,
                fmt_f64(s.mean_ms),
                fmt_f64(s.p50_ms),
                fmt_f64(s.p95_ms),
                fmt_f64(s.p99_ms),
                fmt_f64(s.max_ms),
            ));
        }
        out.push_str("}}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.bump("jobs", 1);
        m.bump("jobs", 2);
        assert_eq!(m.counter("jobs"), 3);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn summary_percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.observe("lat", Duration::from_millis(i));
        }
        let s = m.summary("lat").unwrap();
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 50.0).abs() <= 1.5, "p50={}", s.p50_ms);
        assert!((s.p95_ms - 95.0).abs() <= 1.5);
        assert!((s.max_ms - 100.0).abs() < 1e-9);
        assert!((s.mean_ms - 50.5).abs() < 0.1);
    }

    #[test]
    fn summary_of_unknown_is_none() {
        assert!(Metrics::new().summary("nope").is_none());
    }

    #[test]
    fn cache_hit_rate_tracks_the_counters() {
        let m = Metrics::new();
        assert_eq!(m.cache_hit_rate(), 0.0);
        m.bump("cache_misses", 1);
        assert_eq!(m.cache_hit_rate(), 0.0);
        m.bump("cache_hits", 3);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn counter_names_and_fused_fraction() {
        let m = Metrics::new();
        assert_eq!(m.fused_fraction(), 0.0);
        m.bump("queries_fused", 3);
        m.bump("queries_solo", 1);
        assert!((m.fused_fraction() - 0.75).abs() < 1e-12);
        assert_eq!(
            m.counter_names(),
            vec!["queries_fused".to_string(), "queries_solo".to_string()]
        );
    }

    #[test]
    fn merge_sums_counters_and_merges_series() {
        let global = Metrics::new();
        global.bump("jobs_executed", 2);
        global.observe("latency", Duration::from_millis(1));
        let shard_a = Metrics::new();
        shard_a.bump("jobs_executed", 3);
        shard_a.bump("shard_dispatches", 1);
        shard_a.observe("latency", Duration::from_millis(2));
        let shard_b = Metrics::new();
        shard_b.bump("jobs_executed", 5);
        shard_b.bump("window_timeouts", 4);
        global.merge(&shard_a);
        global.merge(&shard_b);
        assert_eq!(global.counter("jobs_executed"), 10);
        assert_eq!(global.counter("shard_dispatches"), 1);
        assert_eq!(global.counter("window_timeouts"), 4);
        assert_eq!(global.summary("latency").unwrap().count, 2);
        // Sources are untouched.
        assert_eq!(shard_a.counter("jobs_executed"), 3);
        assert_eq!(shard_b.counter("jobs_executed"), 5);
    }

    #[test]
    fn concurrent_observes_all_recorded() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for i in 0..250 {
                        m.observe("x", Duration::from_micros(i));
                    }
                });
            }
        });
        assert_eq!(m.summary("x").unwrap().count, 1000);
    }

    #[test]
    fn bucket_index_is_monotone_and_bounded() {
        let mut prev = 0usize;
        // Exponential sweep across all magnitudes plus the exact range.
        for ns in 0..SUB as u64 {
            let i = bucket_index(ns);
            assert!(i >= prev || ns == 0);
            assert!(i < NUM_BUCKETS);
            prev = i;
        }
        // Continue from the first non-exact value (the sweep is
        // monotone only from where the previous loop left off).
        let mut v = SUB as u64;
        while v < u64::MAX / 3 {
            let i = bucket_index(v);
            assert!(i >= prev, "index regressed at {v}");
            assert!(i < NUM_BUCKETS);
            prev = i;
            v = v.saturating_mul(3) / 2 + 1;
        }
        assert!(bucket_index(u64::MAX) < NUM_BUCKETS);
    }

    #[test]
    fn bucket_midpoint_relative_error_is_bounded() {
        // Every value's bucket midpoint is within 1/128 of the value
        // (half of the 1/64 bucket width), for values past the exact
        // range.
        let mut v = SUB as u64;
        while v < 1 << 40 {
            let mid = bucket_mid_ns(bucket_index(v));
            let rel = (mid - v as f64).abs() / v as f64;
            assert!(rel <= 1.0 / 64.0, "v={v} mid={mid} rel={rel}");
            v = v * 7 / 4 + 3;
        }
    }

    #[test]
    fn histogram_merge_matches_combined_recording() {
        let a = Histogram::new();
        let b = Histogram::new();
        let combined = Histogram::new();
        for i in 1..=500u64 {
            let d = Duration::from_micros(i * 37 % 1000 + 1);
            if i % 2 == 0 { a.record(d) } else { b.record(d) };
            combined.record(d);
        }
        let merged = Histogram::new();
        merged.merge_from(&a);
        merged.merge_from(&b);
        let (m, c) = (merged.summary().unwrap(), combined.summary().unwrap());
        assert_eq!(m.count, c.count);
        assert_eq!(m.p50_ms, c.p50_ms);
        assert_eq!(m.p99_ms, c.p99_ms);
        assert_eq!(m.max_ms, c.max_ms);
    }

    #[test]
    fn register_makes_zero_counters_visible() {
        let m = Metrics::new();
        m.register("workers_respawned");
        assert_eq!(m.counter("workers_respawned"), 0);
        assert_eq!(m.counter_names(), vec!["workers_respawned".to_string()]);
        // Registering an existing counter does not reset it.
        m.bump("workers_respawned", 2);
        m.register("workers_respawned");
        assert_eq!(m.counter("workers_respawned"), 2);
    }

    #[test]
    fn snapshot_is_sorted_and_renders_deterministically() {
        let m = Metrics::new();
        m.bump("zeta", 1);
        m.bump("alpha", 2);
        m.bump("cache_hits", 3);
        m.bump("cache_misses", 1);
        m.observe("latency", Duration::from_millis(7));
        m.observe("exec/bfs-vgc", Duration::from_millis(3));
        let snap = m.snapshot();
        let names: Vec<&str> = snap.counters.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["alpha", "cache_hits", "cache_misses", "zeta"]);
        let series: Vec<&str> = snap.series.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(series, vec!["exec/bfs-vgc", "latency"]);
        assert!((snap.cache_hit_rate - 0.75).abs() < 1e-12);
        // Same values → byte-identical renderings.
        let again = m.snapshot();
        assert_eq!(snap.to_json(), again.to_json());
        assert_eq!(snap.to_prometheus(), again.to_prometheus());
        let prom = snap.to_prometheus();
        assert!(prom.contains("pasgal_counter{name=\"cache_hits\"} 3"));
        assert!(prom.contains("pasgal_series_ms{series=\"exec/bfs-vgc\",stat=\"count\"} 1.0000"));
        let json = snap.to_json();
        assert!(json.contains("\"schema\":\"pasgal-metrics/1\""));
        assert!(json.contains("\"alpha\":2"));
        assert!(json.contains("\"cache_hit_rate\":0.7500"));
    }
}
