//! Fault taxonomy, panic circuit breaker, and the zero-dep
//! fault-injection harness behind the serving stack's robustness
//! layer.
//!
//! Three concerns live here because they share one vocabulary:
//!
//! * **[`FailKind`]** — the typed failure taxonomy every answered
//!   failure carries ([`QueryOutput::Failed`] has a `kind` field).
//!   The in-tree error type is string-backed (see [`crate::error`]),
//!   so the kind travels as a stable message prefix (the `MSG_*`
//!   constants) and [`FailKind::classify`] recovers it at the answer
//!   boundary. The robustness-layer errors are constructed here and
//!   never context-wrapped, so prefix classification is exact.
//! * **[`PanicBreaker`]** — the per-`(graph, spec)` circuit breaker:
//!   after [`BREAKER_TRIP`] *consecutive* engine panics on one key,
//!   identical requests fail fast (no engine run, no workspace churn)
//!   until the graph is republished — the entry records the publish
//!   version it tripped at, so a republish resets it with no explicit
//!   protocol, exactly like the result cache's invalidation.
//! * **[`FaultPlan`]** — injectable failure points (panic on the
//!   N-th execution, slow-engine delay) that the execution core fires
//!   *inside* its `catch_unwind` guard, so chaos tests exercise the
//!   real isolation path, plus [`malformed`] CSR constructors for
//!   input-validation tests. Zero dependencies, zero overhead when no
//!   plan is installed (an `Option` that is `None` in production).
//!
//! [`QueryOutput::Failed`]: crate::algo::api::QueryOutput::Failed

use crate::algo::cancel::{cancelled, Cancel};
use crate::error::Error;
use crate::V;
use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Once;
use std::time::{Duration, Instant};

/// Consecutive engine panics on one `(graph, spec)` key before the
/// circuit breaker opens (see [`PanicBreaker`]).
pub const BREAKER_TRIP: u32 = 3;

/// Stable message prefixes — the wire encoding of [`FailKind`] over
/// the string-backed error type. `classify` matches on these, so the
/// constructors below are the only places allowed to mint them.
/// `MSG_DEADLINE` / `MSG_STALLED` are authored in
/// [`crate::algo::cancel`] (the cancellation substrate owns those two
/// conditions) and re-exported here so the taxonomy stays one list.
pub use crate::algo::cancel::{MSG_DEADLINE, MSG_STALLED};
pub const MSG_OVERLOAD: &str = "shard overloaded";
pub const MSG_PANIC: &str = "engine panic";
pub const MSG_BREAKER: &str = "engine panic breaker open";
pub const MSG_INVALID: &str = "invalid graph";
pub const MSG_UNKNOWN_GRAPH: &str = "unknown graph";
pub const MSG_BAD_SOURCE: &str = "invalid source";

/// Typed failure taxonomy for answered requests (see module docs and
/// the crate-level "Failure semantics" section).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailKind {
    /// The request's deadline budget expired before (or while) it
    /// could execute; answered without running an engine.
    DeadlineExceeded,
    /// The target shard's inbox was at capacity: shed at the router
    /// instead of queueing unboundedly.
    Overloaded,
    /// The engine panicked (caught, worker alive) — or its breaker
    /// was already open and the request failed fast.
    EnginePanic,
    /// The graph bytes failed structural validation at publish time.
    InvalidGraph,
    /// The shard watchdog condemned the worker running this request:
    /// its engine overran `stall_limit` and was cancelled; the batch
    /// was answered by the router while a fresh worker respawned.
    EngineStalled,
    /// No graph is published under the requested name.
    UnknownGraph,
    /// The source vertex is out of range for the resolved graph.
    InvalidSource,
    /// Everything else.
    Other,
}

impl FailKind {
    /// Recover the kind from an error message (see `MSG_*`).
    /// `MSG_BREAKER` starts with `MSG_PANIC` by construction, so
    /// breaker fast-fails classify as `EnginePanic` — to a client
    /// they are the same condition, reported sooner.
    pub fn classify(msg: &str) -> FailKind {
        if msg.starts_with(MSG_DEADLINE) {
            FailKind::DeadlineExceeded
        } else if msg.starts_with(MSG_OVERLOAD) {
            FailKind::Overloaded
        } else if msg.starts_with(MSG_STALLED) {
            FailKind::EngineStalled
        } else if msg.starts_with(MSG_PANIC) {
            FailKind::EnginePanic
        } else if msg.starts_with(MSG_INVALID) {
            FailKind::InvalidGraph
        } else if msg.starts_with(MSG_UNKNOWN_GRAPH) {
            FailKind::UnknownGraph
        } else if msg.starts_with(MSG_BAD_SOURCE) {
            FailKind::InvalidSource
        } else {
            FailKind::Other
        }
    }
}

/// The error an expired request is answered with (never executed).
pub fn deadline_error(graph: &str, algo: &str) -> Error {
    Error::msg(format!("{MSG_DEADLINE}: {algo} on {graph:?}"))
}

/// The error a shed request is answered with at the router.
pub fn overload_error(shard: usize, cap: usize) -> Error {
    Error::msg(format!("{MSG_OVERLOAD}: shard {shard} inbox at capacity {cap}"))
}

/// The error a caught engine panic is answered with.
pub fn panic_error(graph: &str, algo: &str, payload: &(dyn Any + Send)) -> Error {
    Error::msg(format!(
        "{MSG_PANIC}: {algo} on {graph:?}: {}",
        panic_message(payload)
    ))
}

/// The fast-fail error while a `(graph, spec)` breaker is open.
pub fn breaker_error(graph: &str, algo: &str) -> Error {
    Error::msg(format!(
        "{MSG_BREAKER}: {algo} on {graph:?} after {BREAKER_TRIP} consecutive panics; republish the graph to reset"
    ))
}

/// The typed rejection for graph bytes that fail CSR validation.
pub fn invalid_graph_error(name: &str, reason: &str) -> Error {
    Error::msg(format!("{MSG_INVALID} {name:?}: {reason}"))
}

/// The error a watchdog-condemned (hard-cancelled) request is
/// answered with: its engine overran `stall_limit` and the worker was
/// respawned.
pub fn stalled_error(graph: &str, algo: &str) -> Error {
    Error::msg(format!(
        "{MSG_STALLED}: {algo} on {graph:?} cancelled past the stall limit; worker respawned"
    ))
}

/// The typed rejection for a graph name nothing is published under.
pub fn unknown_graph_error(name: &str) -> Error {
    Error::msg(format!("{MSG_UNKNOWN_GRAPH} {name:?}"))
}

/// The typed rejection for a source vertex outside the graph.
pub fn invalid_source_error(source: V, n: usize) -> Error {
    Error::msg(format!("{MSG_BAD_SOURCE}: {source} out of range (n={n})"))
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads — what `panic!` produces — else a placeholder).
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Marker carried by every injected panic payload — lets the panic
/// hook installed by [`silence_injected_panics`] suppress the noise
/// of *expected* panics without hiding genuine ones.
pub const INJECTED_MARKER: &str = "injected engine fault";

/// Install (once) a panic hook that swallows the default "thread
/// panicked" report for injected faults and forwards everything else
/// to the previous hook. Chaos tests call this so hundreds of caught,
/// intentional panics don't bury real failures in stderr.
pub fn silence_injected_panics() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.contains(INJECTED_MARKER))
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

/// What an armed [`FaultPoint`] does when it matches.
#[derive(Debug, Clone)]
pub enum FaultKind {
    /// Panic on matching hits `from .. from + count` (0-based per
    /// point), mimicking a buggy engine that dies on specific inputs.
    Panic { from: u64, count: u64 },
    /// Sleep before executing, mimicking a pathologically slow engine
    /// (drives the overload/deadline paths without burning CPU).
    Delay(Duration),
    /// Park until the dispatch token cancels: an *unbounded* stall.
    /// A bounded [`FaultKind::Delay`] cannot model a wedged engine
    /// without racing the watchdog's clock; this one stalls exactly
    /// until condemned, so the supervision path is testable without
    /// timing flakes.
    StallForever,
}

/// One injectable failure point: fires on executions whose graph and
/// algorithm label match (`None` matches anything).
pub struct FaultPoint {
    graph: Option<String>,
    algo: Option<String>,
    kind: FaultKind,
    hits: AtomicU64,
}

impl FaultPoint {
    fn matches(&self, graph: &str, algo: &str) -> bool {
        self.graph.as_deref().map_or(true, |g| g == graph)
            && self.algo.as_deref().map_or(true, |a| a == algo)
    }
}

/// A set of injectable failure points, installed on a coordinator
/// with [`Coordinator::set_faults`] and consulted by the execution
/// core *inside* its panic guard. Immutable once installed (interior
/// hit counters only), so it shares across shard workers as a plain
/// `Arc` with no locking.
///
/// [`Coordinator::set_faults`]: super::Coordinator::set_faults
#[derive(Default)]
pub struct FaultPlan {
    points: Vec<FaultPoint>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm a panic on matching executions `from .. from + count`
    /// (builder style). `None` for graph/algo matches anything.
    pub fn panic_on(
        mut self,
        graph: Option<&str>,
        algo: Option<&str>,
        from: u64,
        count: u64,
    ) -> Self {
        self.points.push(FaultPoint {
            graph: graph.map(str::to_string),
            algo: algo.map(str::to_string),
            kind: FaultKind::Panic { from, count },
            hits: AtomicU64::new(0),
        });
        self
    }

    /// Arm a pre-execution delay on every matching execution.
    pub fn delay(mut self, graph: Option<&str>, algo: Option<&str>, by: Duration) -> Self {
        self.points.push(FaultPoint {
            graph: graph.map(str::to_string),
            algo: algo.map(str::to_string),
            kind: FaultKind::Delay(by),
            hits: AtomicU64::new(0),
        });
        self
    }

    /// Arm an unbounded, cancellation-interruptible stall on every
    /// matching execution (the watchdog test hook — see
    /// [`FaultKind::StallForever`]).
    pub fn stall_forever(mut self, graph: Option<&str>, algo: Option<&str>) -> Self {
        self.points.push(FaultPoint {
            graph: graph.map(str::to_string),
            algo: algo.map(str::to_string),
            kind: FaultKind::StallForever,
            hits: AtomicU64::new(0),
        });
        self
    }

    /// Hits recorded by point `idx` (tests verifying a fault fired).
    pub fn hits(&self, idx: usize) -> u64 {
        self.points[idx].hits.load(Ordering::Relaxed)
    }

    /// The hook the execution core fires inside `catch_unwind`, right
    /// before running an engine: matching points count a hit, sleep,
    /// stall, or panic per their [`FaultKind`]. No-op for non-matching
    /// executions; breaker fast-fails never reach here (the engine is
    /// not executed), so open breakers don't consume panic budgets.
    /// `cancel` is the dispatch token: an armed
    /// [`FaultKind::StallForever`] parks until it cancels, exactly
    /// like a wedged engine loop observing its round check.
    pub fn before_execute(&self, graph: &str, algo: &str, cancel: Cancel<'_>) {
        for p in &self.points {
            if !p.matches(graph, algo) {
                continue;
            }
            let hit = p.hits.fetch_add(1, Ordering::Relaxed);
            match p.kind {
                FaultKind::Panic { from, count } => {
                    if hit >= from && hit - from < count {
                        panic!("{INJECTED_MARKER}: {algo} on {graph:?} (hit {hit})");
                    }
                }
                FaultKind::Delay(by) => std::thread::sleep(by),
                FaultKind::StallForever => {
                    while !cancelled(cancel) {
                        std::thread::park_timeout(Duration::from_millis(1));
                    }
                }
            }
        }
    }
}

/// What a breaker check answers for one `(graph, spec, version)` key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Below the trip threshold (or reset by a republish): execute.
    Closed,
    /// Tripped and not yet eligible for a probe: fail fast.
    Open,
    /// Tripped, cooldown elapsed, and this check is the **one**
    /// half-open probe admitted: execute; the outcome decides whether
    /// the breaker closes ([`PanicBreaker::record_ok`]) or re-opens
    /// with a fresh cooldown ([`PanicBreaker::record_panic`]).
    Probe,
}

/// Per-`(graph, spec)` panic circuit breaker (see module docs): an
/// entry counts *consecutive* caught panics at one publish version;
/// at [`BREAKER_TRIP`] the breaker is open and identical requests
/// fail fast with [`breaker_error`]. A success closes the entry; a
/// republish (version mismatch) resets it on the next check; and with
/// a cooldown armed ([`PanicBreaker::with_cooldown`]) an open breaker
/// self-heals: once the cooldown elapses [`PanicBreaker::check`]
/// admits exactly one half-open probe, which closes the breaker on
/// success and re-opens it (restarting the cooldown) on failure.
/// Owned per shard worker (graph→shard affinity means one worker sees
/// all relevant traffic) or Mutex-shared on the coordinator's ad-hoc
/// paths.
#[derive(Default)]
pub struct PanicBreaker {
    threshold: u32,
    /// Half-open recovery cooldown; `None` (the default) disables
    /// probing — an open breaker then resets only on republish.
    cooldown: Option<Duration>,
    entries: HashMap<String, HashMap<u16, BreakerEntry>>,
}

struct BreakerEntry {
    version: u64,
    consecutive: u32,
    /// When the entry last recorded a panic at/past the threshold —
    /// the instant the cooldown runs from.
    opened_at: Option<Instant>,
    /// A half-open probe was admitted and its outcome is pending:
    /// further checks stay `Open` until `record_ok`/`record_panic`.
    probing: bool,
}

impl PanicBreaker {
    pub fn new() -> Self {
        Self::with_threshold(BREAKER_TRIP)
    }

    /// A breaker tripping after `threshold` consecutive panics
    /// (clamped to ≥ 1; tests use small thresholds).
    pub fn with_threshold(threshold: u32) -> Self {
        PanicBreaker {
            threshold: threshold.max(1),
            cooldown: None,
            entries: HashMap::new(),
        }
    }

    /// Arm half-open recovery: an open breaker admits one probe per
    /// elapsed `cooldown` (builder style). `Duration::ZERO` disables
    /// probing — the republish-only behavior.
    pub fn with_cooldown(mut self, cooldown: Duration) -> Self {
        self.cooldown = (cooldown > Duration::ZERO).then_some(cooldown);
        self
    }

    /// The closed/open/half-open decision for `(graph, spec)` at
    /// `version` (see [`BreakerState`]). A stale entry (the graph was
    /// republished since it tripped) is removed and reported closed —
    /// republishing is still a reset protocol.
    pub fn check(&mut self, graph: &str, spec: u16, version: u64) -> BreakerState {
        let Some(specs) = self.entries.get_mut(graph) else {
            return BreakerState::Closed;
        };
        let Some(e) = specs.get_mut(&spec) else {
            return BreakerState::Closed;
        };
        if e.version != version {
            specs.remove(&spec);
            if specs.is_empty() {
                self.entries.remove(graph);
            }
            return BreakerState::Closed;
        }
        if e.consecutive < self.threshold {
            return BreakerState::Closed;
        }
        let Some(cd) = self.cooldown else {
            return BreakerState::Open;
        };
        if e.probing {
            return BreakerState::Open; // one probe in flight at a time
        }
        if e.opened_at.map_or(true, |t| t.elapsed() >= cd) {
            e.probing = true;
            BreakerState::Probe
        } else {
            BreakerState::Open
        }
    }

    /// Is the breaker open for `(graph, spec)` at `version`? The
    /// pre-half-open compat view: with no cooldown armed it is exactly
    /// `check(..) == Open`; with one armed it *admits a probe* when
    /// eligible (reporting closed), so callers that execute on `false`
    /// still drive the recovery protocol.
    pub fn is_open(&mut self, graph: &str, spec: u16, version: u64) -> bool {
        self.check(graph, spec, version) == BreakerState::Open
    }

    /// Record a caught engine panic; returns true iff this panic is
    /// the one that tripped the breaker open (callers count trips). A
    /// failed half-open probe lands here too: the entry re-opens and
    /// its cooldown restarts.
    pub fn record_panic(&mut self, graph: &str, spec: u16, version: u64) -> bool {
        let e = self
            .entries
            .entry(graph.to_string())
            .or_default()
            .entry(spec)
            .or_insert(BreakerEntry {
                version,
                consecutive: 0,
                opened_at: None,
                probing: false,
            });
        if e.version != version {
            e.version = version;
            e.consecutive = 0;
        }
        e.consecutive += 1;
        e.probing = false;
        e.opened_at = Some(Instant::now());
        e.consecutive == self.threshold
    }

    /// Record a successful execution: closes the key's entry (the
    /// consecutive-panic streak is broken). Returns true iff the entry
    /// removed was a *tripped* one — i.e. a half-open probe just
    /// healed an open breaker (callers count recoveries). Cheap no-op
    /// while no entries exist — the healthy steady state.
    pub fn record_ok(&mut self, graph: &str, spec: u16) -> bool {
        if self.entries.is_empty() {
            return false;
        }
        let mut recovered = false;
        if let Some(specs) = self.entries.get_mut(graph) {
            if let Some(e) = specs.remove(&spec) {
                recovered = e.consecutive >= self.threshold;
            }
            if specs.is_empty() {
                self.entries.remove(graph);
            }
        }
        recovered
    }

    /// Current consecutive-panic streak for `(graph, spec)` — the
    /// retry gate reads this to recognize a *first* panic (streak 1).
    pub fn streak(&self, graph: &str, spec: u16) -> u32 {
        self.entries
            .get(graph)
            .and_then(|m| m.get(&spec))
            .map_or(0, |e| e.consecutive)
    }

    /// Number of currently-open breakers (tests/metrics).
    pub fn open_count(&self) -> usize {
        self.entries
            .values()
            .flat_map(|m| m.values())
            .filter(|e| e.consecutive >= self.threshold)
            .count()
    }
}

/// Malformed CSR constructors for input-validation tests: each breaks
/// exactly one [`Graph::validate`](crate::graph::Graph::validate)
/// invariant, so `load_graph` must reject it with a typed
/// [`FailKind::InvalidGraph`] error instead of deferring to an index
/// panic deep in an engine.
pub mod malformed {
    use crate::graph::Graph;

    /// Offsets go backwards (3 then 1): degree computation underflows.
    pub fn non_monotone_offsets() -> Graph {
        Graph::from_raw_parts(vec![0, 3, 1, 4], vec![0, 1, 2, 0], None, false)
    }

    /// An edge target ≥ n: any frontier walk would index out of
    /// bounds.
    pub fn target_out_of_range() -> Graph {
        Graph::from_raw_parts(vec![0, 1, 2], vec![0, 7], None, false)
    }

    /// The terminal offset claims more edges than the target array
    /// holds: the last vertex's neighbor slice would read past the
    /// end.
    pub fn offset_overflow() -> Graph {
        Graph::from_raw_parts(vec![0, 1, 5], vec![0, 1], None, false)
    }

    /// Weights array shorter than the edge count.
    pub fn weights_length_mismatch() -> Graph {
        Graph::from_raw_parts(vec![0, 1, 2], vec![1, 0], Some(vec![1.0]), false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_recovers_every_kind() {
        assert_eq!(
            FailKind::classify(&deadline_error("g", "cc").to_string()),
            FailKind::DeadlineExceeded
        );
        assert_eq!(
            FailKind::classify(&overload_error(2, 64).to_string()),
            FailKind::Overloaded
        );
        assert_eq!(
            FailKind::classify(&breaker_error("g", "cc").to_string()),
            FailKind::EnginePanic,
            "breaker fast-fails are the panic condition, reported sooner"
        );
        assert_eq!(
            FailKind::classify(&invalid_graph_error("g", "offsets not monotone").to_string()),
            FailKind::InvalidGraph
        );
        assert_eq!(
            FailKind::classify(&unknown_graph_error("x").to_string()),
            FailKind::UnknownGraph
        );
        assert_eq!(
            FailKind::classify(&invalid_source_error(99, 10).to_string()),
            FailKind::InvalidSource
        );
        assert_eq!(
            FailKind::classify(&stalled_error("g", "cc").to_string()),
            FailKind::EngineStalled
        );
        let payload: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(
            FailKind::classify(&panic_error("g", "cc", &*payload).to_string()),
            FailKind::EnginePanic
        );
    }

    #[test]
    fn panic_payload_messages_extracted() {
        let s: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_message(&*s), "static str");
        let owned: Box<dyn std::any::Any + Send> = Box::new("owned".to_string());
        assert_eq!(panic_message(&*owned), "owned");
        let other: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(&*other), "opaque panic payload");
    }

    #[test]
    fn fault_plan_panics_on_exactly_the_armed_window() {
        silence_injected_panics();
        let plan = FaultPlan::new().panic_on(Some("bad"), None, 1, 2);
        // Hit 0: armed from hit 1 — no panic.
        plan.before_execute("bad", "cc", None);
        // Hits 1 and 2 panic; hit 3 is past the window.
        for expect_panic in [true, true, false] {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                plan.before_execute("bad", "cc", None)
            }));
            assert_eq!(r.is_err(), expect_panic);
        }
        assert_eq!(plan.hits(0), 4);
        // Non-matching graph never fires.
        plan.before_execute("good", "cc", None);
        assert_eq!(plan.hits(0), 4);
    }

    #[test]
    fn fault_plan_delay_sleeps_matching_executions() {
        let plan = FaultPlan::new().delay(Some("slow"), None, Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        plan.before_execute("slow", "bfs-vgc", None);
        assert!(t0.elapsed() >= Duration::from_millis(5));
        let t1 = std::time::Instant::now();
        plan.before_execute("fast", "bfs-vgc", None);
        assert!(t1.elapsed() < Duration::from_millis(5));
        assert_eq!(plan.hits(0), 1);
    }

    #[test]
    fn stall_forever_parks_until_the_token_cancels() {
        use crate::algo::cancel::CancelToken;
        use std::sync::Arc;
        let plan = Arc::new(FaultPlan::new().stall_forever(Some("wedge"), None));
        let token = Arc::new(CancelToken::new());
        // Non-matching executions sail through even with no token.
        plan.before_execute("fine", "cc", None);
        let (p, t) = (Arc::clone(&plan), Arc::clone(&token));
        let stalled = std::thread::spawn(move || p.before_execute("wedge", "cc", Some(&t)));
        // The stall is unbounded: give it time to park, then condemn.
        std::thread::sleep(Duration::from_millis(10));
        assert!(!stalled.is_finished(), "must stall until cancelled");
        token.cancel();
        stalled.join().expect("stall returns cleanly once condemned");
        assert_eq!(plan.hits(0), 1);
    }

    #[test]
    fn breaker_opens_after_consecutive_panics_only() {
        let mut b = PanicBreaker::with_threshold(3);
        assert!(!b.is_open("g", 9, 1));
        assert!(!b.record_panic("g", 9, 1));
        assert!(!b.record_panic("g", 9, 1));
        // A success breaks the streak.
        b.record_ok("g", 9);
        assert!(!b.record_panic("g", 9, 1));
        assert!(!b.record_panic("g", 9, 1));
        assert!(!b.is_open("g", 9, 1));
        assert!(b.record_panic("g", 9, 1), "third consecutive trips");
        assert!(b.is_open("g", 9, 1));
        assert_eq!(b.open_count(), 1);
        // Other keys unaffected.
        assert!(!b.is_open("g", 10, 1));
        assert!(!b.is_open("h", 9, 1));
    }

    #[test]
    fn republish_resets_an_open_breaker() {
        let mut b = PanicBreaker::with_threshold(2);
        b.record_panic("g", 9, 1);
        b.record_panic("g", 9, 1);
        assert!(b.is_open("g", 9, 1));
        // The graph was republished at version 2: closed again.
        assert!(!b.is_open("g", 9, 2));
        assert_eq!(b.open_count(), 0, "stale entry removed");
        // And the streak restarts from zero at the new version.
        assert!(!b.record_panic("g", 9, 2));
    }

    #[test]
    fn half_open_probe_admits_exactly_one_and_closes_on_success() {
        let mut b = PanicBreaker::with_threshold(2).with_cooldown(Duration::from_millis(5));
        b.record_panic("g", 1, 1);
        b.record_panic("g", 1, 1);
        assert_eq!(b.check("g", 1, 1), BreakerState::Open, "cooldown not elapsed");
        std::thread::sleep(Duration::from_millis(6));
        assert_eq!(b.check("g", 1, 1), BreakerState::Probe, "cooldown admits one probe");
        assert_eq!(b.check("g", 1, 1), BreakerState::Open, "only one probe in flight");
        assert!(b.record_ok("g", 1), "probe success is a recovery");
        assert_eq!(b.check("g", 1, 1), BreakerState::Closed, "healed without republish");
        assert!(!b.record_ok("g", 1), "nothing tripped left to recover");
        assert_eq!(b.streak("g", 1), 0);
    }

    #[test]
    fn half_open_probe_failure_reopens_and_restarts_the_cooldown() {
        let mut b = PanicBreaker::with_threshold(1).with_cooldown(Duration::from_millis(5));
        b.record_panic("g", 1, 1);
        std::thread::sleep(Duration::from_millis(6));
        assert_eq!(b.check("g", 1, 1), BreakerState::Probe);
        // The probe dies too: re-open, cooldown restarted from now.
        assert!(!b.record_panic("g", 1, 1), "already tripped — not a new trip");
        assert_eq!(b.check("g", 1, 1), BreakerState::Open, "fresh cooldown running");
        assert_eq!(b.streak("g", 1), 2);
        std::thread::sleep(Duration::from_millis(6));
        assert_eq!(b.check("g", 1, 1), BreakerState::Probe, "later probes keep coming");
    }

    #[test]
    fn without_a_cooldown_an_open_breaker_never_probes() {
        let mut b = PanicBreaker::with_threshold(1).with_cooldown(Duration::ZERO);
        b.record_panic("g", 1, 1);
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(b.check("g", 1, 1), BreakerState::Open);
        assert!(b.is_open("g", 1, 1), "republish-only behavior preserved");
    }

    #[test]
    fn malformed_graphs_fail_validation_for_distinct_reasons() {
        for (g, reason) in [
            (malformed::non_monotone_offsets(), "offsets not monotone"),
            (malformed::target_out_of_range(), "target out of range"),
            (malformed::offset_overflow(), "offsets[n] != m"),
            (malformed::weights_length_mismatch(), "weights length mismatch"),
        ] {
            assert_eq!(g.validate().unwrap_err(), reason);
        }
    }
}
