//! Read-mostly graph registry: versioned, `Arc`-swapped snapshots.
//!
//! The serving hot path must not contend on a registry lock: a shard
//! worker answers thousands of queries between registry mutations, and
//! PR 2's profile showed the two global Mutex hops (registry +
//! workspace pool) as the remaining shared state per request. The
//! [`GraphDirectory`] splits the two roles:
//!
//! * **Writers** ([`GraphDirectory::publish`], i.e. `load_graph`) take
//!   the writer Mutex, clone the current map (cheap: the values are
//!   `Arc<LoadedGraph>`), insert, swap in the new `Arc` snapshot and
//!   bump the version counter.
//! * **Readers** hold a [`SnapshotCache`]: the `Arc` of the last
//!   published map plus the version it was published at. Checking
//!   freshness is one atomic load; the Mutex is touched only when the
//!   version actually moved (a registry mutation — the control path,
//!   not the request path). Steady-state lookups are plain `HashMap`
//!   gets on a worker-local `Arc` — **zero locks**.
//!
//! Within one dispatched batch the snapshot is immutable by
//! construction: a shard refreshes once per dispatch, so every request
//! in the batch resolves graphs against the same registry state.

use super::faults;
use super::lock_or_recover;
use crate::algo::api::{Params, QueryOutput};
use crate::error::Result;
use crate::graph::Graph;
use crate::V;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A registered graph with lazily materialized derived views.
pub struct LoadedGraph {
    pub graph: Arc<Graph>,
    /// The directory version this graph was published at — the
    /// freshness guard of the [`ResultCache`]: a cached whole-graph
    /// output is valid iff its recorded version equals the version of
    /// the `LoadedGraph` the request resolved to, so republishing a
    /// name invalidates every cached result for it with no explicit
    /// eviction traffic. 0 for graphs built outside a directory.
    pub version: u64,
    transpose: OnceLock<Arc<Graph>>,
    symmetrized: OnceLock<Arc<Graph>>,
}

impl LoadedGraph {
    pub fn new(graph: Graph) -> Self {
        LoadedGraph::with_version(graph, 0)
    }

    /// A loaded graph stamped with the directory version it was
    /// published at (see [`GraphDirectory::publish`]).
    pub fn with_version(graph: Graph, version: u64) -> Self {
        LoadedGraph {
            graph: Arc::new(graph),
            version,
            transpose: OnceLock::new(),
            symmetrized: OnceLock::new(),
        }
    }

    /// Transpose, computed once on first use.
    pub fn transpose(&self) -> &Graph {
        if self.graph.symmetric {
            return &self.graph;
        }
        self.transpose
            .get_or_init(|| Arc::new(self.graph.transpose()))
    }

    /// Symmetrized view (identity for already-symmetric graphs).
    pub fn symmetrized(&self) -> &Graph {
        if self.graph.symmetric {
            return &self.graph;
        }
        self.symmetrized
            .get_or_init(|| Arc::new(self.graph.symmetrize()))
    }
}

/// One published registry state: name → loaded graph.
pub type GraphMap = HashMap<String, Arc<LoadedGraph>>;

/// The snapshot-published graph registry (see module docs).
pub struct GraphDirectory {
    published: Mutex<Arc<GraphMap>>,
    version: AtomicU64,
}

impl Default for GraphDirectory {
    fn default() -> Self {
        Self::new()
    }
}

impl GraphDirectory {
    pub fn new() -> Self {
        GraphDirectory {
            published: Mutex::new(Arc::new(HashMap::new())),
            version: AtomicU64::new(0),
        }
    }

    /// Register `graph` under `name` (replacing any previous one) by
    /// publishing a new snapshot. Existing snapshots held by readers
    /// stay valid and keep answering with the old state until they
    /// refresh. The published [`LoadedGraph`] is stamped with the new
    /// directory version — distinct per publish (the writer Mutex
    /// serializes them), so result-cache entries for the replaced
    /// graph can never match again.
    pub fn publish(&self, name: &str, graph: Graph) {
        let mut slot = lock_or_recover(&self.published);
        let v = self.version.load(Ordering::Relaxed) + 1;
        let mut map: GraphMap = (**slot).clone();
        map.insert(name.to_string(), Arc::new(LoadedGraph::with_version(graph, v)));
        *slot = Arc::new(map);
        // The bump is observed after the Mutex has the new Arc: a
        // reader that sees the new version and then locks is
        // guaranteed the new map (the lock fully orders it).
        self.version.store(v, Ordering::Release);
    }

    /// [`publish`] with structural validation first: malformed CSR
    /// bytes (non-monotone offsets, targets ≥ n, a terminal offset
    /// disagreeing with the edge count, truncated weights) are
    /// rejected with a typed
    /// [`FailKind::InvalidGraph`](super::faults::FailKind::InvalidGraph)
    /// error *before* they can reach an engine and defer the failure
    /// to an index panic mid-walk. Nothing is published on rejection:
    /// the directory (and any previously published graph under
    /// `name`) is untouched.
    ///
    /// [`publish`]: GraphDirectory::publish
    pub fn load_graph(&self, name: &str, graph: Graph) -> Result<()> {
        // `Graph::validate` delegates to `graph::csr::validate_csr` —
        // the same shared invariant check the `.pgr` loader runs, so
        // malformed graphs are rejected identically whether they
        // arrive in memory or from a file.
        if let Err(reason) = graph.validate() {
            return Err(faults::invalid_graph_error(name, &reason));
        }
        self.publish(name, graph);
        Ok(())
    }

    /// Load a `.pgr` file ([`crate::graph::store::load`]: one bulk
    /// read, checksum + shared CSR validation, zero-copy arena views
    /// for the plain encoding) and publish it under `name` through
    /// the normal Arc-swap/version protocol. On any load error
    /// (truncated, corrupt, wrong version) nothing is published and
    /// the previously published graph under `name` — and every
    /// in-flight query against it — is untouched.
    pub fn load_graph_from_path(
        &self,
        name: &str,
        path: &std::path::Path,
    ) -> Result<crate::graph::store::LoadStats> {
        let loaded = crate::graph::store::load(path)?;
        self.publish(name, loaded.graph);
        Ok(loaded.stats)
    }

    /// Current registry version (bumped by every [`publish`]).
    ///
    /// [`publish`]: GraphDirectory::publish
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// The latest published snapshot (takes the writer Mutex — use a
    /// [`SnapshotCache`] on hot paths).
    pub fn snapshot(&self) -> Arc<GraphMap> {
        lock_or_recover(&self.published).clone()
    }

    /// One-shot lookup (takes the writer Mutex — convenience for
    /// non-serving callers).
    pub fn lookup(&self, name: &str) -> Option<Arc<LoadedGraph>> {
        self.snapshot().get(name).cloned()
    }

    /// Number of registered graphs.
    pub fn len(&self) -> usize {
        self.snapshot().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A reader's cached registry snapshot: lookups are lock-free; the
/// directory Mutex is touched only when the version counter moved.
pub struct SnapshotCache {
    map: Arc<GraphMap>,
    version: u64,
}

impl Default for SnapshotCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotCache {
    /// Empty cache; the first [`refresh`] always fetches a snapshot.
    ///
    /// [`refresh`]: SnapshotCache::refresh
    pub fn new() -> Self {
        SnapshotCache {
            map: Arc::new(HashMap::new()),
            // Sentinel: never equals a real version, so the first
            // refresh against any directory fetches.
            version: u64::MAX,
        }
    }

    /// Re-fetch the snapshot if the directory moved since the last
    /// refresh. Returns true iff a new snapshot was fetched (callers
    /// count these as `registry_snapshots`). Costs one atomic load
    /// when nothing changed.
    pub fn refresh(&mut self, dir: &GraphDirectory) -> bool {
        let v = dir.version();
        if v == self.version {
            return false;
        }
        self.map = dir.snapshot();
        self.version = v;
        true
    }

    /// Lock-free lookup in the cached snapshot (no staleness check —
    /// call [`refresh`] at batch boundaries).
    ///
    /// [`refresh`]: SnapshotCache::refresh
    pub fn cached(&self, name: &str) -> Option<Arc<LoadedGraph>> {
        self.map.get(name).cloned()
    }

    /// Refresh, then look up: the convenience path for callers without
    /// a batch boundary.
    pub fn get(&mut self, dir: &GraphDirectory, name: &str) -> Option<Arc<LoadedGraph>> {
        self.refresh(dir);
        self.cached(name)
    }
}

/// Per-worker cache of whole-graph analysis outputs — the
/// registry-level result cache. Specs that declare
/// [`cacheable`](crate::algo::api::AlgoSpec::cacheable) (SCC summary,
/// CC, k-core, BCC: outputs fully determined by `(graph, Params)`)
/// are answered from here when the same query repeats against an
/// unchanged graph; source-parameterized traversals never enter.
///
/// Keyed `(graph name, spec id, Params)`; each entry records the
/// [`LoadedGraph::version`] it was computed against, and a lookup
/// only hits when that version equals the version of the graph the
/// request resolved to — so invalidation falls out of
/// [`GraphDirectory::publish`] bumping the version. A version
/// mismatch additionally drops the graph's entries **wholesale** (a
/// republish stales all of them at once), and the cache is
/// **memory-bounded**: at most `cap` entries total, evicting the
/// least-recently-used entry past it, so a long-lived server over an
/// unbounded stream of graph names and param settings can't grow the
/// cache without limit. Like
/// [`crate::algo::workspace::WorkspacePool`], this is deliberately
/// not a concurrent structure: each shard worker owns one outright
/// (zero locks on the hot path); the coordinator's shared instance
/// sits behind a Mutex next to its workspace pool.
pub struct ResultCache {
    entries: HashMap<String, GraphResults>,
    /// Most entries kept across all graphs (≥ 1).
    cap: usize,
    /// Logical clock for LRU ordering: bumped per lookup-hit/insert.
    tick: u64,
    /// Total entries across `entries` (maintained incrementally).
    len: usize,
}

/// One graph's cached outputs, keyed `(spec id, params, source)`.
/// `source` is `None` for whole-graph analyses (the cacheable specs)
/// and for graph-level negative entries (`Failed{UnknownGraph}`);
/// `Some(v)` keys per-source negative entries
/// (`Failed{InvalidSource}`) so a typed rejection for one out-of-range
/// source never shadows a different, valid source.
type GraphResults = HashMap<(u16, Params, Option<V>), CacheSlot>;

/// A cached output: the publish version it was computed at and the
/// LRU clock of its last use. Whole-graph label analyses additionally
/// carry the full per-vertex output vector, so library callers can
/// fetch labels/coreness without recomputing
/// ([`ResultCache::lookup_vector`]).
struct CacheSlot {
    version: u64,
    used: u64,
    output: Arc<QueryOutput>,
    vector: Option<Arc<Vec<u32>>>,
}

/// Default [`ResultCache`] capacity: far above any realistic
/// #graphs × #cacheable-specs × #param-settings working set, small
/// enough that each `Arc<QueryOutput>` summary stays negligible.
pub const DEFAULT_RESULT_CACHE_CAP: usize = 512;

impl Default for ResultCache {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_RESULT_CACHE_CAP)
    }
}

impl ResultCache {
    /// Empty cache with the default capacity.
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty cache holding at most `cap` entries (clamped to ≥ 1).
    pub fn with_capacity(cap: usize) -> Self {
        ResultCache {
            entries: HashMap::new(),
            cap: cap.max(1),
            tick: 0,
            len: 0,
        }
    }

    /// The cached output for `(graph, spec, params)` computed at
    /// exactly `version`, if any. A version mismatch (the graph was
    /// republished since) is a miss that also drops *all* of the
    /// graph's entries — every one of them went stale with the same
    /// publish, so holding them until individually overwritten would
    /// only squat capacity. A hit refreshes the entry's LRU clock.
    pub fn lookup(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        version: u64,
    ) -> Option<Arc<QueryOutput>> {
        self.lookup_src(graph, spec, params, None, version)
    }

    /// [`lookup`](ResultCache::lookup) with an explicit source key —
    /// the negative-caching path: typed `Failed{InvalidSource}`
    /// outputs are cached per `(spec, params, Some(source))`, and
    /// `Failed{UnknownGraph}` per `(spec, params, None)`, under the
    /// same version guard as positive entries.
    pub fn lookup_src(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        source: Option<V>,
        version: u64,
    ) -> Option<Arc<QueryOutput>> {
        let slots = self.entries.get_mut(graph)?;
        let slot = slots.get_mut(&(spec, params, source))?;
        if slot.version != version {
            self.len -= slots.len();
            self.entries.remove(graph);
            return None;
        }
        self.tick += 1;
        slot.used = self.tick;
        Some(Arc::clone(&slot.output))
    }

    /// The cached *full output vector* (per-vertex labels/coreness)
    /// for `(graph, spec, params)` at exactly `version`, if the spec
    /// published one ([`crate::algo::api::AlgoSpec::full`]). Same
    /// version guard and LRU accounting as
    /// [`lookup`](ResultCache::lookup); summary-only entries miss.
    pub fn lookup_vector(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        version: u64,
    ) -> Option<Arc<Vec<u32>>> {
        let slots = self.entries.get_mut(graph)?;
        let slot = slots.get_mut(&(spec, params, None))?;
        if slot.version != version {
            self.len -= slots.len();
            self.entries.remove(graph);
            return None;
        }
        let vector = slot.vector.as_ref().map(Arc::clone)?;
        self.tick += 1;
        slot.used = self.tick;
        Some(vector)
    }

    /// Record `output` as the answer for `(graph, spec, params)` at
    /// `version`. Entries the graph accumulated at an older publish
    /// are dropped wholesale first; past capacity, the globally
    /// least-recently-used entry is evicted. Returns the number of
    /// LRU evictions (callers meter them as `cache_evictions`).
    pub fn insert(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        version: u64,
        output: Arc<QueryOutput>,
    ) -> usize {
        self.insert_slot(graph, spec, params, None, version, output, None)
    }

    /// [`insert`](ResultCache::insert) carrying the full per-vertex
    /// output vector alongside the summary (cacheable label analyses;
    /// served back by [`lookup_vector`](ResultCache::lookup_vector)).
    pub fn insert_full(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        version: u64,
        output: Arc<QueryOutput>,
        vector: Option<Arc<Vec<u32>>>,
    ) -> usize {
        self.insert_slot(graph, spec, params, None, version, output, vector)
    }

    /// [`insert`](ResultCache::insert) with an explicit source key
    /// (see [`lookup_src`](ResultCache::lookup_src)).
    pub fn insert_src(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        source: Option<V>,
        version: u64,
        output: Arc<QueryOutput>,
    ) -> usize {
        self.insert_slot(graph, spec, params, source, version, output, None)
    }

    #[allow(clippy::too_many_arguments)]
    fn insert_slot(
        &mut self,
        graph: &str,
        spec: u16,
        params: Params,
        source: Option<V>,
        version: u64,
        output: Arc<QueryOutput>,
        vector: Option<Arc<Vec<u32>>>,
    ) -> usize {
        if let Some(slots) = self.entries.get(graph) {
            if slots.values().any(|s| s.version != version) {
                self.len -= slots.len();
                self.entries.remove(graph);
            }
        }
        self.tick += 1;
        let slot = CacheSlot {
            version,
            used: self.tick,
            output,
            vector,
        };
        let prev = self
            .entries
            .entry(graph.to_string())
            .or_default()
            .insert((spec, params, source), slot);
        if prev.is_none() {
            self.len += 1;
        }
        let mut evicted = 0;
        while self.len > self.cap {
            self.evict_lru();
            evicted += 1;
        }
        evicted
    }

    /// Remove the entry with the oldest LRU clock (linear scan: the
    /// cache is small by construction and eviction is the exceptional
    /// path, not the steady state).
    fn evict_lru(&mut self) {
        let mut victim: Option<(u64, String, (u16, Params, Option<V>))> = None;
        for (g, slots) in &self.entries {
            for (k, s) in slots {
                if victim.as_ref().map_or(true, |(used, _, _)| s.used < *used) {
                    victim = Some((s.used, g.clone(), *k));
                }
            }
        }
        if let Some((_, g, k)) = victim {
            if let Some(slots) = self.entries.get_mut(&g) {
                if slots.remove(&k).is_some() {
                    self.len -= 1;
                }
                if slots.is_empty() {
                    self.entries.remove(&g);
                }
            }
        }
    }

    /// Number of cached entries — bounded by the capacity, and within
    /// it by #graphs × #cacheable specs × #param settings, never by
    /// query volume.
    pub fn len(&self) -> usize {
        self.len
    }

    /// The configured entry capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn publish_bumps_version_and_replaces() {
        let dir = GraphDirectory::new();
        assert_eq!(dir.version(), 0);
        assert!(dir.lookup("g").is_none());
        dir.publish("g", gen::grid(3, 3));
        assert_eq!(dir.version(), 1);
        assert_eq!(dir.lookup("g").unwrap().graph.n(), 9);
        dir.publish("g", gen::grid(4, 4));
        assert_eq!(dir.version(), 2);
        assert_eq!(dir.lookup("g").unwrap().graph.n(), 16);
        assert_eq!(dir.len(), 1);
    }

    #[test]
    fn cache_refreshes_only_on_version_change() {
        let dir = GraphDirectory::new();
        dir.publish("a", gen::grid(2, 2));
        let mut cache = SnapshotCache::new();
        assert!(cache.refresh(&dir), "first refresh fetches");
        assert!(!cache.refresh(&dir), "no change, no fetch");
        assert!(cache.cached("a").is_some());
        assert!(cache.cached("b").is_none());
        dir.publish("b", gen::grid(2, 3));
        assert!(cache.cached("b").is_none(), "stale until refreshed");
        assert!(cache.refresh(&dir));
        assert_eq!(cache.cached("b").unwrap().graph.n(), 6);
    }

    #[test]
    fn old_snapshots_survive_republication() {
        let dir = GraphDirectory::new();
        dir.publish("g", gen::grid(3, 3));
        let mut cache = SnapshotCache::new();
        cache.refresh(&dir);
        let old = cache.cached("g").unwrap();
        dir.publish("g", gen::grid(5, 5));
        // The reader's snapshot still answers with the old graph.
        assert_eq!(old.graph.n(), 9);
        assert_eq!(cache.cached("g").unwrap().graph.n(), 9);
        cache.refresh(&dir);
        assert_eq!(cache.cached("g").unwrap().graph.n(), 25);
    }

    #[test]
    fn published_graphs_carry_distinct_versions() {
        let dir = GraphDirectory::new();
        dir.publish("a", gen::grid(2, 2));
        dir.publish("b", gen::grid(2, 3));
        let va = dir.lookup("a").unwrap().version;
        let vb = dir.lookup("b").unwrap().version;
        assert_ne!(va, vb);
        dir.publish("a", gen::grid(3, 3));
        let va2 = dir.lookup("a").unwrap().version;
        assert!(va2 > va, "republish must move the graph's version");
        assert_eq!(va2, dir.version(), "latest publish owns the counter");
        // Graphs built outside a directory are version 0 — never a
        // live published version.
        assert_eq!(LoadedGraph::new(gen::grid(2, 2)).version, 0);
    }

    #[test]
    fn result_cache_hits_only_on_matching_version() {
        let mut cache = ResultCache::new();
        let p = Params::NONE;
        assert!(cache.lookup("g", 9, p, 1).is_none());
        let out = Arc::new(QueryOutput::Cc {
            components: 3,
            largest: 5,
        });
        cache.insert("g", 9, p, 1, Arc::clone(&out));
        assert_eq!(cache.len(), 1);
        assert_eq!(*cache.lookup("g", 9, p, 1).unwrap(), *out);
        // Version moved (republish): stale entry is a miss — and the
        // graph's stale entries are dropped wholesale...
        assert!(cache.lookup("g", 9, p, 2).is_none());
        assert_eq!(cache.len(), 0, "republish drops the graph's entries");
        // ...until the fresh recompute re-primes the key.
        let out2 = Arc::new(QueryOutput::Cc {
            components: 1,
            largest: 9,
        });
        cache.insert("g", 9, p, 2, Arc::clone(&out2));
        assert_eq!(cache.len(), 1, "replaced, not accumulated");
        assert_eq!(*cache.lookup("g", 9, p, 2).unwrap(), *out2);
        // Other keys never collide: different spec, params, or graph.
        assert!(cache.lookup("g", 10, p, 2).is_none());
        assert!(cache.lookup("g", 9, Params::tau(8), 2).is_none());
        assert!(cache.lookup("h", 9, p, 2).is_none());
    }

    #[test]
    fn result_cache_evicts_lru_past_capacity() {
        let mut cache = ResultCache::with_capacity(3);
        assert_eq!(cache.capacity(), 3);
        let out = Arc::new(QueryOutput::Cc {
            components: 1,
            largest: 1,
        });
        for (i, g) in ["a", "b", "c"].iter().enumerate() {
            assert_eq!(cache.insert(g, i as u16, Params::NONE, 1, Arc::clone(&out)), 0);
        }
        assert_eq!(cache.len(), 3);
        // Touch "a": it becomes the most recently used.
        assert!(cache.lookup("a", 0, Params::NONE, 1).is_some());
        // A fourth entry evicts the LRU one — "b", not "a".
        assert_eq!(cache.insert("d", 3, Params::NONE, 1, Arc::clone(&out)), 1);
        assert_eq!(cache.len(), 3);
        assert!(cache.lookup("a", 0, Params::NONE, 1).is_some());
        assert!(cache.lookup("b", 1, Params::NONE, 1).is_none(), "b evicted");
        assert!(cache.lookup("c", 2, Params::NONE, 1).is_some());
        assert!(cache.lookup("d", 3, Params::NONE, 1).is_some());
        // Re-inserting an existing key replaces, never evicts.
        assert_eq!(cache.insert("d", 3, Params::NONE, 1, Arc::clone(&out)), 0);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn result_cache_source_keyed_entries_do_not_collide() {
        use crate::coordinator::faults::FailKind;
        let mut cache = ResultCache::new();
        let p = Params::NONE;
        let neg = Arc::new(QueryOutput::Failed {
            kind: FailKind::InvalidSource,
            error: "invalid source: 99 out of range (n=9)".into(),
        });
        cache.insert_src("g", 4, p, Some(99), 1, Arc::clone(&neg));
        assert!(cache.lookup_src("g", 4, p, Some(99), 1).is_some());
        assert!(
            cache.lookup_src("g", 4, p, Some(3), 1).is_none(),
            "a negative entry for one source never shadows another"
        );
        assert!(
            cache.lookup("g", 4, p, 1).is_none(),
            "the None (whole-graph) key is distinct from every source key"
        );
        // The version guard applies to negative entries too.
        assert!(cache.lookup_src("g", 4, p, Some(99), 2).is_none());
        assert_eq!(cache.len(), 0, "republish dropped the stale negative");
    }

    #[test]
    fn republish_drops_a_graphs_entries_wholesale() {
        let mut cache = ResultCache::with_capacity(8);
        let out = Arc::new(QueryOutput::Cc {
            components: 1,
            largest: 1,
        });
        for spec in 0..3u16 {
            cache.insert("g", spec, Params::NONE, 1, Arc::clone(&out));
        }
        cache.insert("h", 0, Params::NONE, 2, Arc::clone(&out));
        assert_eq!(cache.len(), 4);
        // Inserting g at a newer version first drops all three stale
        // g entries; h is untouched.
        cache.insert("g", 0, Params::NONE, 5, Arc::clone(&out));
        assert_eq!(cache.len(), 2, "3 stale g entries dropped, g+h remain");
        assert!(cache.lookup("h", 0, Params::NONE, 2).is_some());
        assert!(cache.lookup("g", 0, Params::NONE, 5).is_some());
        assert!(cache.lookup("g", 1, Params::NONE, 5).is_none());
    }

    #[test]
    fn load_graph_rejects_malformed_csr_and_publishes_nothing() {
        use crate::coordinator::faults::{malformed, FailKind};
        let dir = GraphDirectory::new();
        for g in [
            malformed::non_monotone_offsets(),
            malformed::target_out_of_range(),
            malformed::offset_overflow(),
            malformed::weights_length_mismatch(),
        ] {
            let err = dir.load_graph("bad", g).unwrap_err();
            assert_eq!(
                FailKind::classify(&err.to_string()),
                FailKind::InvalidGraph,
                "typed rejection: {err}"
            );
        }
        assert!(dir.lookup("bad").is_none(), "nothing published");
        assert_eq!(dir.version(), 0, "no version burned on rejection");
        // A previously published healthy graph survives a bad
        // republish attempt under the same name.
        dir.load_graph("g", gen::grid(3, 3)).unwrap();
        let v = dir.version();
        assert!(dir.load_graph("g", malformed::offset_overflow()).is_err());
        assert_eq!(dir.version(), v);
        assert_eq!(dir.lookup("g").unwrap().graph.n(), 9);
    }

    #[test]
    fn full_vectors_ride_the_same_version_guard() {
        let mut cache = ResultCache::new();
        let p = Params::NONE;
        let out = Arc::new(QueryOutput::Cc {
            components: 2,
            largest: 3,
        });
        let labels = Arc::new(vec![0u32, 0, 1, 1, 1]);
        cache.insert_full("g", 9, p, 1, Arc::clone(&out), Some(Arc::clone(&labels)));
        // Vector and summary hit from the same slot.
        let got = cache.lookup_vector("g", 9, p, 1).unwrap();
        assert!(Arc::ptr_eq(&got, &labels), "no copy on hit");
        assert!(cache.lookup("g", 9, p, 1).is_some());
        // A summary-only entry answers lookup but not lookup_vector.
        cache.insert("g", 5, p, 1, Arc::clone(&out));
        assert!(cache.lookup("g", 5, p, 1).is_some());
        assert!(cache.lookup_vector("g", 5, p, 1).is_none());
        // Republish stales the vector exactly like the summary.
        assert!(cache.lookup_vector("g", 9, p, 2).is_none());
        assert_eq!(cache.len(), 0, "wholesale drop on version mismatch");
    }

    #[test]
    fn load_graph_from_path_publishes_and_rejects_like_load_graph() {
        use crate::coordinator::faults::FailKind;
        use crate::graph::store;
        let d = std::env::temp_dir().join(format!("pasgal_dir_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("g.pgr");
        store::pack(&gen::grid(4, 4), &p, store::Encoding::Plain).unwrap();
        let dir = GraphDirectory::new();
        let stats = dir.load_graph_from_path("g", &p).unwrap();
        assert_eq!(stats.encoding, store::Encoding::Plain);
        assert_eq!(dir.lookup("g").unwrap().graph.n(), 16);
        let v = dir.version();
        // A corrupt file is rejected with the typed InvalidGraph error
        // and publishes nothing: same contract as load_graph.
        let mut img = std::fs::read(&p).unwrap();
        let last = img.len() - 1;
        img[last] ^= 0xff;
        let bad = d.join("bad.pgr");
        std::fs::write(&bad, img).unwrap();
        let err = dir.load_graph_from_path("g", &bad).unwrap_err();
        assert_eq!(FailKind::classify(&err.to_string()), FailKind::InvalidGraph);
        assert_eq!(dir.version(), v, "no version burned on rejection");
        assert_eq!(dir.lookup("g").unwrap().graph.n(), 16, "old graph intact");
    }

    #[test]
    fn concurrent_publish_and_cached_reads() {
        let dir = Arc::new(GraphDirectory::new());
        dir.publish("g", gen::grid(3, 3));
        std::thread::scope(|s| {
            let d = Arc::clone(&dir);
            s.spawn(move || {
                for i in 0..20 {
                    d.publish("g", gen::grid(3 + (i % 3), 3));
                }
            });
            for _ in 0..4 {
                let d = Arc::clone(&dir);
                s.spawn(move || {
                    let mut cache = SnapshotCache::new();
                    for _ in 0..200 {
                        let lg = cache.get(&d, "g").expect("g always registered");
                        assert!(lg.graph.n() >= 9);
                    }
                });
            }
        });
    }
}
