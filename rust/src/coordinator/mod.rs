//! L3 analysis-job coordinator: the serving layer around the library.
//!
//! A [`Coordinator`] owns the graph registry (snapshot-published
//! [`directory::GraphDirectory`] with lazily materialized
//! transposes/symmetrizations), a pool of warm
//! [`crate::algo::QueryWorkspace`]s (the zero-allocation query
//! engine), an optional [`crate::runtime::DenseEngine`] for
//! dense-block queries, and a metrics registry. Clients submit
//! [`job::JobRequest`]s; serving batches requests *by graph*
//! (amortizing cache warmth the way an inference router batches by
//! model), executes them through the workspace-carrying algorithm
//! entry points, and reports per-job latency plus queue/throughput
//! metrics.
//!
//! Algorithm dispatch is table-driven: every path here resolves
//! requests through the algorithm registry ([`crate::algo::api`]) —
//! one [`crate::algo::api::AlgoSpec`] per algorithm — so registering
//! an algorithm makes it servable everywhere at once. The channel
//! protocol is registry-native: a [`job::JobRequest`] *is* a
//! [`crate::algo::api::Query`] plus a request id (no per-algorithm
//! wire enum survives). Whole-graph analyses additionally answer
//! repeated queries from a versioned [`directory::ResultCache`].
//!
//! Two serving front ends share one execution core:
//!
//! * [`Coordinator::serve`] / [`Coordinator::serve_windowed`] — the
//!   single-threaded channel loop.
//! * [`shard::ShardServer`] — the sharded multi-worker subsystem: a
//!   router hashes each request's graph name to one of N shard
//!   workers, each owning a lock-free hot path (shard-local workspace
//!   pool, shard-local metrics, cached registry snapshot) and a
//!   fusion-window admission queue that accumulates fusable
//!   same-(graph, algo, τ) requests before dispatching a batch.
//!
//! Python never appears here: the dense path executes the AOT
//! artifact inventory through the in-tree engine.

pub mod dense;
pub mod directory;
pub mod job;
pub mod metrics;
pub mod server;
pub mod shard;

pub use crate::algo::api::{AlgoSpec, Params, ParseArgs, Query, QueryOutput};
pub use dense::DenseBlock;
pub use directory::{GraphDirectory, GraphMap, LoadedGraph, ResultCache, SnapshotCache};
pub use job::{JobOutput, JobRequest, JobResult};
pub use metrics::{Metrics, Summary};
pub use server::{workload, Coordinator};
pub use shard::{ShardConfig, ShardServer};
