//! L3 analysis-job coordinator: the serving layer around the library.
//!
//! A [`Coordinator`] owns the graph registry (snapshot-published
//! [`directory::GraphDirectory`] with lazily materialized
//! transposes/symmetrizations), a pool of warm
//! [`crate::algo::QueryWorkspace`]s (the zero-allocation query
//! engine), an optional [`crate::runtime::DenseEngine`] for
//! dense-block queries, and a metrics registry. Clients submit
//! [`job::JobRequest`]s; serving batches requests *by graph*
//! (amortizing cache warmth the way an inference router batches by
//! model), executes them through the workspace-carrying algorithm
//! entry points, and reports per-job latency plus queue/throughput
//! metrics.
//!
//! Algorithm dispatch is table-driven: every path here resolves
//! requests through the algorithm registry ([`crate::algo::api`]) —
//! one [`crate::algo::api::AlgoSpec`] per algorithm — so registering
//! an algorithm makes it servable everywhere at once. The channel
//! protocol is registry-native: a [`job::JobRequest`] *is* a
//! [`crate::algo::api::Query`] plus a request id (no per-algorithm
//! wire enum survives). Whole-graph analyses additionally answer
//! repeated queries from a versioned [`directory::ResultCache`].
//!
//! Two serving front ends share one execution core:
//!
//! * [`Coordinator::serve`] / [`Coordinator::serve_windowed`] — the
//!   single-threaded channel loop.
//! * [`shard::ShardServer`] — the sharded multi-worker subsystem: a
//!   router hashes each request's graph name to one of N shard
//!   workers, each owning a lock-free hot path (shard-local workspace
//!   pool, shard-local metrics, cached registry snapshot) and a
//!   fusion-window admission queue that accumulates fusable
//!   same-(graph, algo, τ) requests before dispatching a batch.
//!
//! The serve path is **fault-tolerant** ([`faults`], and the
//! crate-level "Failure semantics" section): requests carry optional
//! deadline budgets and expire with a typed failure instead of
//! executing; the shard router sheds load past a bounded inbox depth;
//! engine panics are caught (`catch_unwind`), answered as typed
//! failures, and counted by a per-`(graph, spec)` circuit breaker
//! that fails identical requests fast until the graph is republished;
//! and every coordinator-path Mutex recovers from poisoning
//! ([`lock_or_recover`]) so one panicked holder can't wedge the pool,
//! cache or directory.
//!
//! Python never appears here: the dense path executes the AOT
//! artifact inventory through the in-tree engine.

pub mod dense;
pub mod directory;
pub mod faults;
pub mod job;
pub mod metrics;
pub mod server;
pub mod shard;
pub mod trace;

pub use crate::algo::api::{AlgoSpec, Params, ParseArgs, Query, QueryOutput};
pub use dense::DenseBlock;
pub use directory::{GraphDirectory, GraphMap, LoadedGraph, ResultCache, SnapshotCache};
pub use faults::{FailKind, FaultPlan, PanicBreaker};
pub use job::{JobOutput, JobRequest, JobResult};
pub use metrics::{Metrics, MetricsSnapshot, Summary};
pub use server::{workload, Coordinator};
pub use shard::{ShardConfig, ShardServer};
pub use trace::{EngineTelemetry, QueryTrace, TraceSampler};

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a Mutex, recovering the guard if a previous holder panicked.
///
/// Every coordinator-path Mutex (workspace pool, shared result cache,
/// directory writer, metrics registries, breaker) guards state that
/// stays structurally valid across a panic: pools and caches are
/// checked-in-or-absent, the directory swaps complete `Arc`s, metrics
/// are append-only. Poisoning would turn one panicked holder into a
/// permanent denial of service for every later request — recovery is
/// strictly better than cascading the panic.
pub(crate) fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_or_recover_survives_a_poisoned_mutex() {
        let m = Mutex::new(5);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = m.lock().unwrap();
            panic!("poison the lock");
        }));
        assert!(m.is_poisoned());
        *lock_or_recover(&m) += 1;
        assert_eq!(*lock_or_recover(&m), 6, "state intact after recovery");
    }
}

