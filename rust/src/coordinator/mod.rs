//! L3 analysis-job coordinator: the serving layer around the library.
//!
//! A [`Coordinator`] owns loaded graphs (with lazily materialized
//! transposes/symmetrizations), the worker pool, an optional PJRT
//! [`crate::runtime::DenseEngine`] for dense-block queries, and a
//! metrics registry. Clients submit [`job::JobRequest`]s; the server
//! loop batches requests *by graph* (amortizing cache warmth the way
//! an inference router batches by model), executes them on the pool,
//! and reports per-job latency plus queue/throughput metrics.
//!
//! Python never appears here: the dense path executes AOT-compiled
//! HLO artifacts through PJRT.

pub mod dense;
pub mod job;
pub mod metrics;
pub mod server;

pub use dense::DenseBlock;
pub use job::{AlgoKind, JobOutput, JobRequest, JobResult};
pub use metrics::{Metrics, Summary};
pub use server::{workload, Coordinator, LoadedGraph};
