//! L3 analysis-job coordinator: the serving layer around the library.
//!
//! A [`Coordinator`] owns loaded graphs (with lazily materialized
//! transposes/symmetrizations), the worker pool, a pool of warm
//! [`crate::algo::QueryWorkspace`]s (the zero-allocation query
//! engine), an optional [`crate::runtime::DenseEngine`] for
//! dense-block queries, and a metrics registry. Clients submit
//! [`job::JobRequest`]s; the server loop batches requests *by graph*
//! (amortizing cache warmth the way an inference router batches by
//! model), executes them on the pool through the workspace-carrying
//! algorithm entry points, and reports per-job latency plus
//! queue/throughput metrics.
//!
//! Python never appears here: the dense path executes the AOT
//! artifact inventory through the in-tree engine.

pub mod dense;
pub mod job;
pub mod metrics;
pub mod server;

pub use dense::DenseBlock;
pub use job::{AlgoKind, JobOutput, JobRequest, JobResult};
pub use metrics::{Metrics, Summary};
pub use server::{workload, Coordinator, LoadedGraph};
