//! `coordinator::shard` — the sharded multi-worker serving subsystem.
//!
//! One serving thread pulling one channel through two global Mutexes
//! caps delivered throughput long before the kernels do, and it only
//! ever fuses requests that happen to be queued at the same instant.
//! This module turns the library into a multi-threaded server:
//!
//! ```text
//!             requests                    results
//!                │                           ▲
//!                ▼                           │
//!            ┌────────┐   hash(graph)   ┌────┴────┐
//!            │ router │ ───────────────▶│ shard i │──┐
//!            └────────┘                 └─────────┘  │ fusion window
//!                                       │ snapshot │ │ → run_batch
//!                                       │ ws pool  │ │ → demux
//!                                       │ metrics  │◀┘
//!                                       └──────────┘   × N workers
//! ```
//!
//! * **Router** — [`ShardServer::serve`] hashes each request's graph
//!   name ([`JobRequest::route_hash`], FNV-1a) and forwards it to one
//!   of N shard workers. Same graph ⇒ same shard, so every request
//!   that *could* fuse is visible to one fusion window, and each
//!   graph's derived views (transpose, symmetrization) and warm
//!   workspace arrays stay hot in one worker's cache.
//! * **Shard worker** — the hot path takes **no contended Mutex
//!   locks**: a worker-owned plain-`Vec` [`WorkspacePool`] and
//!   [`SnapshotCache`] of the graph registry (refreshed only when the
//!   [`GraphDirectory`] version counter moves — one atomic load per
//!   dispatch; `load_graph` publishes new snapshots without ever
//!   blocking request execution, and its version bump is what
//!   invalidates cached results), plus shard-level state behind
//!   uncontended Mutexes (only the shard's one live worker takes
//!   them, never across an engine run): a [`ResultCache`] answering
//!   repeated whole-graph analyses (SCC/CC/k-core/BCC) for free —
//!   valid because the router pins a graph to one shard, so that
//!   shard's cache sees every request that could hit — and the panic
//!   breaker. Both live in a per-shard `ShardState` rather than in
//!   the worker so they survive watchdog respawns. Shard-local
//!   metrics merge into the coordinator's global registry when
//!   serving ends.
//! * **Fusion-window admission** ([`admit_batch`]) — when the head
//!   request's registry spec has a batch engine and the window is
//!   nonzero, the worker keeps draining its inbox until the window
//!   deadline, the batch cap, or 64 same-(graph, spec id, params)
//!   lanes accumulate — then dispatches one
//!   [`ExecCore::run_batch_from`], which fuses the group into batched
//!   multi-source walks and demultiplexes per-lane results in
//!   submission order. Non-fusable heads fall through immediately
//!   (they only pick up what is already queued). When the request
//!   channel closes mid-window, the partial batch still executes:
//!   accepted work is never dropped. Every accepted request is also
//!   *answered* — failures come back on the result channel as
//!   [`Failed`](super::job::JobOutput::Failed) outputs carrying the
//!   request id (with the `errors` counter bumped), so clients
//!   correlating responses by id never hang on an error.
//! * **Adaptive fusion window** — with a nonzero
//!   [`ShardConfig::fusion_window_max`], the per-dispatch window
//!   deadline is load-driven ([`effective_window`]): `window(depth) =
//!   floor + (max − floor) · min(depth, max_batch) / max_batch`,
//!   where `floor = min(20µs, fusion_window, max)` and `depth` is the
//!   shard's queue gauge at dispatch. A shallow inbox dispatches after
//!   ~20µs (latency); a deep backlog waits up to the cap so fusion
//!   swallows it (throughput). Every opened window lands in the
//!   `fusion_window_us` histogram series.
//! * **Cross-shard work stealing** — an idle worker (no request for
//!   [`STEAL_POLL`]) picks the deepest sibling inbox by the router's
//!   depth gauges and tries to take it over ([`try_steal`]): lock via
//!   `try_lock` (a conflict with the owner or another thief is
//!   counted, never waited on), receive the head, then run the
//!   *whole* fusion-window admission itself — a steal moves complete
//!   batches, so a window or 64-lane fused walk is never split across
//!   workers. Stolen batches execute on the thief's snapshot cache,
//!   workspace pool and engine, but against the **owner shard's**
//!   [`ShardState`] (result cache + breaker), keeping affinity-keyed
//!   state coherent; the thief's own watchdog slot supervises the
//!   dispatch, so exactly-once answering holds across steals, stalls,
//!   respawns and shutdown drain. Gauge accounting stays exact: the
//!   takeover wraps the victim's receiver in an [`Inbox`] carrying
//!   the victim's depth gauge, so every steal-path receive decrements
//!   it like an owner receive would. Counters: `steal_attempts`,
//!   `steal_conflicts`, `batches_stolen`. Disable with
//!   [`ShardConfig::steal`] (`--no-steal`).
//! * **Per-shard engine affinity** — when the coordinator knows its
//!   dense engine's artifact directory
//!   ([`Coordinator::with_engine_at`]), every shard spawns an engine
//!   replica of its own (`engines_replicated` counter), so dense
//!   closures stop funneling through one executor thread; shards fall
//!   back to the shared handle when the directory is unknown or the
//!   spawn fails.
//!
//! The serve path is **fault-tolerant** (see [`super::faults`] and the
//! crate-level "Failure semantics" section):
//!
//! * **Bounded inboxes / load shedding** — the router tracks each
//!   shard's queue depth with a per-shard atomic gauge ([`Inbox`]
//!   decrements it on every successful receive). Past
//!   [`ShardConfig::inbox_cap`] queued requests, new arrivals for that
//!   shard are *shed*: answered immediately with a typed
//!   [`Overloaded`](super::faults::FailKind::Overloaded) failure
//!   (`shed` counter) instead of growing an unbounded queue and
//!   dragging every queued request's latency with it.
//! * **Deadlines** — already-expired requests are answered
//!   [`DeadlineExceeded`](super::faults::FailKind::DeadlineExceeded)
//!   at the router, and an expired head never opens a fusion window
//!   (`deadline_exceeded` counter).
//! * **Panic isolation** — engine panics are caught inside
//!   [`ExecCore`], answered as typed failures, and counted by a
//!   shard-level per-`(graph, spec)` circuit breaker (valid for the
//!   same graph→shard-affinity reason the result cache is): after
//!   [`BREAKER_TRIP`](super::faults::BREAKER_TRIP) consecutive panics
//!   the breaker fails identical requests fast until the graph is
//!   republished — or, with a nonzero
//!   [`ShardConfig::breaker_cooldown`], until a half-open probe
//!   succeeds and closes it again. No shard worker dies; the corrupt
//!   workspace is dropped, never checked back into the pool.
//! * **Worker supervision** — every worker shares a [`WorkerShared`]
//!   slot with the router: before a dispatch runs it publishes
//!   `(start, batch)` there, and on completion it takes the slot back.
//!   With a nonzero [`ShardConfig::stall_limit`] the router (no extra
//!   threads — it patrols between `recv_timeout` ticks) condemns any
//!   worker whose dispatch has run past the limit: it cancels the
//!   worker's [`CancelToken`] (engines poll it once per frontier
//!   round / bucket epoch and bail), answers the stuck batch
//!   [`EngineStalled`](super::faults::FailKind::EngineStalled)
//!   (`engine_stalled` per request, `workers_respawned` once), and
//!   spawns a fresh worker over the *same* inbox so queued requests
//!   behind the stuck batch are preserved. The condemned worker
//!   unwinds cooperatively, finds its inflight slot emptied, discards
//!   its results (every request is answered exactly once) and
//!   retires; its metrics still merge at join. State machine per
//!   worker: healthy → stalled (inflight past the limit) → respawned.
//!
//! Per-shard counters: `shard_dispatches`, `window_waits`,
//! `window_timeouts`, `registry_snapshots`, `graph_seen/<name>`,
//! `steal_attempts`, `steal_conflicts`, `batches_stolen`, plus
//! everything [`ExecCore`] meters (`queries_fused`, `jobs_executed`,
//! `engine_panics`, `lane_compactions`, ...). `graph_seen/<name>` is
//! bumped only for *owner* dispatches — it describes router placement,
//! which a steal does not change. [`Metrics::merge`] folds them into the
//! global registry (router-side `shed`/`deadline_exceeded` land in the
//! global registry directly); [`ShardServer::serve`] also returns the
//! per-shard registries so callers can inspect placement and balance.
//!
//! [`ExecCore`]: super::server::ExecCore
//! [`ExecCore::run_batch_from`]: super::server::ExecCore::run_batch_from
//! [`GraphDirectory`]: super::directory::GraphDirectory

use super::directory::{ResultCache, SnapshotCache};
use super::faults::{self, PanicBreaker};
use super::job::{JobRequest, JobResult};
use super::lock_or_recover;
use super::metrics::Metrics;
use super::server::{
    answer, BreakerHandle, CacheHandle, Coordinator, ExecCore, Guards, MAX_FUSE,
};
use crate::algo::cancel::CancelToken;
use crate::algo::workspace::WorkspacePool;
use crate::runtime::EngineHandle;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvError, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, TryLockError};
use std::thread::{Scope, ScopedJoinHandle};
use std::time::{Duration, Instant};

/// How long a steal-enabled worker blocks on its own (empty) inbox
/// before looking for a sibling to rob. Small enough that an idle
/// worker notices a skewed neighbor within a millisecond, large enough
/// that the idle-loop wakeups are noise.
pub(crate) const STEAL_POLL: Duration = Duration::from_micros(500);

/// The latency end of the adaptive fusion window: with an empty inbox
/// at dispatch, the window shrinks to ~this (capped by the configured
/// fixed window — see [`effective_window`]).
pub(crate) const ADAPTIVE_FLOOR: Duration = Duration::from_micros(20);

/// Tuning knobs for the sharded server.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Number of shard workers (default: the worker-pool width).
    pub shards: usize,
    /// Fusion-window deadline: how long a shard waits for more
    /// fusable requests before dispatching (default 200µs; zero
    /// disables waiting entirely).
    pub fusion_window: Duration,
    /// Most requests admitted into one dispatched batch.
    pub max_batch: usize,
    /// Most requests queued per shard before the router sheds new
    /// arrivals for that shard with a typed
    /// [`Overloaded`](super::faults::FailKind::Overloaded) failure
    /// (default 1024; `0` disables shedding — unbounded queues, the
    /// pre-backpressure behavior).
    pub inbox_cap: usize,
    /// How long one dispatched batch may run before the router's
    /// watchdog declares the worker stalled: cancels its token,
    /// answers the batch
    /// [`EngineStalled`](super::faults::FailKind::EngineStalled), and
    /// respawns a fresh worker over the same inbox (default 30s;
    /// `Duration::ZERO` disables the watchdog — the CLI exposes this
    /// as `--stall-limit-ms`).
    pub stall_limit: Duration,
    /// Cooldown after which an open panic breaker admits exactly one
    /// half-open probe; a successful probe closes it, another panic
    /// re-opens it (default `Duration::ZERO` = breakers stay open
    /// until the graph is republished — the CLI exposes this as
    /// `--breaker-cooldown-ms`).
    pub breaker_cooldown: Duration,
    /// Cross-shard work stealing: idle workers take whole admitted
    /// batches from the deepest sibling inbox (default true; the CLI
    /// exposes the off switch as `--no-steal`). Irrelevant with one
    /// shard.
    pub steal: bool,
    /// Upper bound of the *adaptive* fusion window: when nonzero, the
    /// per-dispatch window deadline scales with the shard's queue
    /// depth from ~[`ADAPTIVE_FLOOR`] (empty inbox) up to this cap
    /// (backlog ≥ `max_batch`) — see [`effective_window`]. Default
    /// `Duration::ZERO` keeps the fixed `fusion_window` behavior (the
    /// CLI exposes this as `--fusion-window-max-us`).
    pub fusion_window_max: Duration,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: crate::parallel::num_threads(),
            fusion_window: Duration::from_micros(200),
            max_batch: 64,
            inbox_cap: 1024,
            stall_limit: Duration::from_secs(30),
            breaker_cooldown: Duration::ZERO,
            steal: true,
            fusion_window_max: Duration::ZERO,
        }
    }
}

/// The fusion-window deadline for one dispatch, given the shard's
/// queue depth at that instant (the router-maintained gauge, read
/// *after* taking the head).
///
/// * Fixed mode (`fusion_window_max` zero, the default): always the
///   configured `fusion_window`.
/// * Adaptive mode: linear in the backlog —
///   `floor + (max − floor) · min(depth, max_batch) / max_batch`,
///   with `floor = min(ADAPTIVE_FLOOR, fusion_window, max)`. An empty
///   inbox buys latency (~20µs of waiting); a backlog of `max_batch`
///   or more buys throughput (wait out the full cap so fusion
///   swallows the queue).
///
/// `fusion_window == 0` disables windows entirely in both modes.
pub(crate) fn effective_window(config: &ShardConfig, depth: usize) -> Duration {
    let base = config.fusion_window;
    let max = config.fusion_window_max;
    if base.is_zero() || max.is_zero() {
        return base;
    }
    let floor = ADAPTIVE_FLOOR.min(base).min(max);
    let cap = config.max_batch.max(1) as f64;
    let fill = (depth as f64).min(cap) / cap;
    floor + (max - floor).mul_f64(fill)
}

/// State shared between one shard worker and the router's watchdog.
///
/// The worker publishes each dispatch here before any engine code
/// runs and takes it back when the dispatch completes; the watchdog
/// takes it instead when the dispatch overruns
/// [`ShardConfig::stall_limit`]. Whoever *takes* the slot answers the
/// batch — that handoff is what makes "answered exactly once" hold
/// across a respawn.
pub(crate) struct WorkerShared {
    /// The worker's cooperative-cancellation token, wired into its
    /// [`ExecCore`]: condemned (hard-cancelled) by the watchdog so
    /// in-flight engine loops bail at their next round check.
    token: CancelToken,
    /// `Some((dispatch start, batch))` while a dispatch is running.
    inflight: Mutex<Option<(Instant, Vec<JobRequest>)>>,
}

impl WorkerShared {
    fn new() -> Self {
        WorkerShared {
            token: CancelToken::new(),
            inflight: Mutex::new(None),
        }
    }
}

/// Per-shard guard state that must **survive worker respawns**: the
/// result cache (including negative entries) and the panic breaker.
/// An open breaker has to stay open — and keep its half-open cooldown
/// clock — across a respawn, or supervision would amnesty a failing
/// engine every time a neighboring request stalled. Each Mutex is
/// uncontended in steady state (only the shard's one live worker
/// takes it, once per cache/breaker touch, never across an engine
/// run) and recovers from poisoning like every coordinator-path lock.
struct ShardState {
    results: Mutex<ResultCache>,
    breaker: Mutex<PanicBreaker>,
    /// This shard's own dense-engine replica, when the coordinator
    /// knows the engine's artifact directory and the spawn succeeded:
    /// dense closures then stop funneling through the coordinator's
    /// one executor thread. `None` falls back to the shared handle.
    /// Lives here (not in the worker) so a watchdog respawn reuses the
    /// replica instead of leaking one executor thread per respawn.
    engine: Option<EngineHandle>,
}

impl ShardState {
    fn new(config: &ShardConfig, coord: &Coordinator) -> Self {
        // Replication only pays when there is more than one shard to
        // contend; a solo shard keeps the coordinator's handle.
        let engine = if config.shards.max(1) > 1 {
            coord
                .engine_dir()
                .and_then(|dir| EngineHandle::spawn(dir.clone()).ok())
        } else {
            None
        };
        ShardState {
            results: Mutex::new(ResultCache::new()),
            breaker: Mutex::new(PanicBreaker::new().with_cooldown(config.breaker_cooldown)),
            engine,
        }
    }
}

/// Everything a worker needs to see its *siblings*: the inbox handles
/// (steal takeover + respawn takeover), the router's depth gauges
/// (victim selection + exact accounting) and the per-shard guard
/// state (stolen batches must hit the owner's cache and breaker).
/// Index i is shard i; one `Arc<Shards>` is shared by the router and
/// every worker.
struct Shards {
    rxs: Vec<Arc<Mutex<Receiver<JobRequest>>>>,
    depths: Vec<Arc<AtomicUsize>>,
    states: Vec<Arc<ShardState>>,
}

/// A worker's receiving end of a request channel, with an optional
/// shared depth gauge: every successful receive decrements the gauge
/// the router increments on send, so `gauge == requests queued but
/// not yet picked up` and the router's shed decision reads one atomic.
/// The single-threaded serve loops wrap their receiver with
/// [`Inbox::new`] (no gauge, zero cost).
pub(crate) struct Inbox<'a> {
    rx: &'a Receiver<JobRequest>,
    depth: Option<&'a AtomicUsize>,
}

impl<'a> Inbox<'a> {
    pub(crate) fn new(rx: &'a Receiver<JobRequest>) -> Self {
        Inbox { rx, depth: None }
    }

    pub(crate) fn with_depth(rx: &'a Receiver<JobRequest>, depth: &'a AtomicUsize) -> Self {
        Inbox {
            rx,
            depth: Some(depth),
        }
    }

    fn took(&self) {
        if let Some(d) = self.depth {
            d.fetch_sub(1, Ordering::Relaxed);
        }
    }

    pub(crate) fn recv(&self) -> Result<JobRequest, RecvError> {
        let r = self.rx.recv();
        if r.is_ok() {
            self.took();
        }
        r
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<JobRequest, RecvTimeoutError> {
        let r = self.rx.recv_timeout(timeout);
        if r.is_ok() {
            self.took();
        }
        r
    }

    fn try_recv(&self) -> Result<JobRequest, TryRecvError> {
        let r = self.rx.try_recv();
        if r.is_ok() {
            self.took();
        }
        r
    }
}

/// The sharded serving front end over a [`Coordinator`]'s registry,
/// engine and metrics (see module docs).
pub struct ShardServer {
    coord: Arc<Coordinator>,
    config: ShardConfig,
}

impl ShardServer {
    pub fn new(coord: Arc<Coordinator>, config: ShardConfig) -> Self {
        ShardServer { coord, config }
    }

    /// The active configuration.
    pub fn config(&self) -> &ShardConfig {
        &self.config
    }

    /// Serve until the request channel closes: route every request to
    /// its graph's shard, run N shard workers with fusion-window
    /// admission, and answer on `tx` (shards interleave, so results
    /// are unordered across graphs; per-shard they follow dispatch
    /// order). Returns the per-shard metrics registries after merging
    /// each into the coordinator's global metrics.
    pub fn serve(&self, rx: Receiver<JobRequest>, tx: Sender<JobResult>) -> Vec<Metrics> {
        let n = self.config.shards.max(1);
        let coord = &*self.coord;
        let config = &self.config;
        let per_shard: Vec<Metrics> = std::thread::scope(|s| {
            let mut inboxes = Vec::with_capacity(n);
            // Each shard's receiver sits behind an Arc<Mutex<..>> so a
            // replacement worker can take over the *same* inbox after
            // a respawn — and an idle sibling can take it over for a
            // steal: requests queued behind a stuck batch are never
            // dropped. Workers hold a lock only while
            // receiving/admitting, never across a dispatch.
            let mut rxs: Vec<Arc<Mutex<Receiver<JobRequest>>>> = Vec::with_capacity(n);
            let mut depths: Vec<Arc<AtomicUsize>> = Vec::with_capacity(n);
            let mut states: Vec<Arc<ShardState>> = Vec::with_capacity(n);
            // Every per-shard handle exists before any worker spawns:
            // workers receive the whole `Shards` table plus their own
            // index, which is what lets an idle one see its siblings.
            for _ in 0..n {
                let (shard_tx, shard_rx) = std::sync::mpsc::channel::<JobRequest>();
                inboxes.push(shard_tx);
                rxs.push(Arc::new(Mutex::new(shard_rx)));
                depths.push(Arc::new(AtomicUsize::new(0)));
                states.push(Arc::new(ShardState::new(config, coord)));
            }
            let replicated = states.iter().filter(|st| st.engine.is_some()).count();
            if replicated > 0 {
                coord.metrics.bump("engines_replicated", replicated as u64);
            }
            let shards = Arc::new(Shards {
                rxs,
                depths,
                states,
            });
            let mut workers: Vec<Arc<WorkerShared>> = Vec::with_capacity(n);
            let mut handles = Vec::with_capacity(n);
            for idx in 0..n {
                let shared = Arc::new(WorkerShared::new());
                handles.push(spawn_worker(
                    s,
                    coord,
                    config,
                    &shards,
                    idx,
                    tx.clone(),
                    Arc::clone(&shared),
                ));
                workers.push(shared);
            }
            // The router: one hash (plus one atomic depth load) per
            // request, no locks held on the hot path. It answers shed
            // and already-expired requests itself on its own
            // result-sender clone — every accepted request is answered
            // exactly once, shed or not. With a nonzero stall limit it
            // doubles as the watchdog: between requests (recv_timeout
            // ticks) it patrols every worker's inflight slot — no new
            // threads. The workers hold their own sender clones; the
            // router's drops after the drain, so the result channel
            // still closes when the last shard finishes.
            let cap = config.inbox_cap;
            let stall = config.stall_limit;
            let tick = (stall / 4).clamp(Duration::from_millis(1), Duration::from_millis(25));
            let mut last_patrol = Instant::now();
            loop {
                let req = if stall.is_zero() {
                    match rx.recv() {
                        Ok(r) => r,
                        Err(RecvError) => break,
                    }
                } else {
                    match rx.recv_timeout(tick) {
                        Ok(r) => r,
                        Err(RecvTimeoutError::Timeout) => {
                            patrol_workers(
                                s, coord, config, &shards, &mut workers, &mut handles, &tx,
                            );
                            last_patrol = Instant::now();
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                };
                let t0 = Instant::now();
                if req.expired() {
                    coord.metrics.bump("deadline_exceeded", 1);
                    let err = faults::deadline_error(&req.graph, req.algo.label);
                    if tx.send(answer(&req, Err(err), t0, &coord.metrics)).is_err() {
                        break;
                    }
                } else {
                    let shard = (req.route_hash() % n as u64) as usize;
                    if cap > 0 && shards.depths[shard].load(Ordering::Relaxed) >= cap {
                        coord.metrics.bump("shed", 1);
                        let err = faults::overload_error(shard, cap);
                        if tx.send(answer(&req, Err(err), t0, &coord.metrics)).is_err() {
                            break;
                        }
                    } else {
                        shards.depths[shard].fetch_add(1, Ordering::Relaxed);
                        if inboxes[shard].send(req).is_err() {
                            break; // shard died (results receiver hung up)
                        }
                    }
                }
                // A steady request flood must not starve the patrol:
                // check the clock here too, not only on idle ticks.
                if !stall.is_zero() && last_patrol.elapsed() >= tick {
                    patrol_workers(s, coord, config, &shards, &mut workers, &mut handles, &tx);
                    last_patrol = Instant::now();
                }
            }
            drop(inboxes);
            // Post-disconnect drain: keep patrolling until every
            // worker (original or replacement) has exited — a worker
            // stuck when the client hung up would otherwise block the
            // join forever. Replacements see the closed inbox, drain
            // whatever is still buffered, and exit.
            if !stall.is_zero() {
                while handles.iter().any(|h| !h.is_finished()) {
                    std::thread::sleep(Duration::from_millis(1));
                    if last_patrol.elapsed() >= tick {
                        patrol_workers(s, coord, config, &shards, &mut workers, &mut handles, &tx);
                        last_patrol = Instant::now();
                    }
                }
            }
            drop(tx);
            handles
                .into_iter()
                .map(|w| w.join().expect("shard worker panicked"))
                .collect()
        });
        for m in &per_shard {
            self.coord.metrics.merge(m);
        }
        per_shard
    }
}

/// Spawn one shard worker over a (possibly already-used) inbox. The
/// worker gets the whole [`Shards`] table plus its own index — that is
/// what lets an idle worker find and rob a backlogged sibling. Its
/// metrics registry comes back through the join handle so retired and
/// replacement workers alike merge into the global registry.
fn spawn_worker<'scope, 'env>(
    s: &'scope Scope<'scope, 'env>,
    coord: &'env Coordinator,
    config: &'env ShardConfig,
    shards: &Arc<Shards>,
    idx: usize,
    tx: Sender<JobResult>,
    shared: Arc<WorkerShared>,
) -> ScopedJoinHandle<'scope, Metrics> {
    let shards = Arc::clone(shards);
    s.spawn(move || {
        let metrics = Metrics::new();
        shard_loop(coord, config, &shards, idx, tx, &metrics, &shared);
        metrics
    })
}

/// One watchdog sweep (router thread): condemn any worker whose
/// published dispatch has overrun [`ShardConfig::stall_limit`],
/// answer its batch [`EngineStalled`](super::faults::FailKind::EngineStalled),
/// and respawn a fresh worker over the same inbox.
fn patrol_workers<'scope, 'env>(
    s: &'scope Scope<'scope, 'env>,
    coord: &'env Coordinator,
    config: &'env ShardConfig,
    shards: &Arc<Shards>,
    workers: &mut [Arc<WorkerShared>],
    handles: &mut Vec<ScopedJoinHandle<'scope, Metrics>>,
    tx: &Sender<JobResult>,
) {
    let stall = config.stall_limit;
    for shard in 0..workers.len() {
        // Taking the slot is the claim to answer this batch: the
        // condemned worker finds it empty and discards its own
        // results, so each request is answered exactly once.
        let stuck = {
            let mut inflight = lock_or_recover(&workers[shard].inflight);
            match *inflight {
                Some((t0, _)) if t0.elapsed() >= stall => inflight.take(),
                _ => None,
            }
        };
        let Some((t0, reqs)) = stuck else { continue };
        workers[shard].token.cancel();
        coord.metrics.bump("workers_respawned", 1);
        for req in &reqs {
            coord.metrics.bump("engine_stalled", 1);
            let err = faults::stalled_error(&req.graph, req.algo.label);
            let _ = tx.send(answer(req, Err(err), t0, &coord.metrics));
        }
        let fresh = Arc::new(WorkerShared::new());
        workers[shard] = Arc::clone(&fresh);
        handles.push(spawn_worker(
            s,
            coord,
            config,
            shards,
            shard,
            tx.clone(),
            fresh,
        ));
    }
}

/// One shard worker: fusion-window admission over its inbox (or a
/// stolen takeover of a backlogged sibling's — see the module docs),
/// batch execution against shard-local state, results answered in
/// dispatch order. Exits when the inbox closes (after draining it),
/// when the result channel hangs up, or when the watchdog takes its
/// inflight dispatch (it has been replaced — retire without
/// answering).
fn shard_loop(
    coord: &Coordinator,
    config: &ShardConfig,
    shards: &Shards,
    idx: usize,
    tx: Sender<JobResult>,
    metrics: &Metrics,
    shared: &WorkerShared,
) {
    let state = &*shards.states[idx];
    let mut cache = SnapshotCache::new();
    let mut pool = WorkspacePool::new();
    let core = ExecCore {
        // Per-shard engine affinity: this shard's replica when one was
        // spawned, else the coordinator's shared handle.
        engine: state.engine.as_ref().or(coord.engine()),
        metrics,
        faults: coord.fault_plan(),
        cancel: Some(&shared.token),
    };
    let max_batch = config.max_batch.max(1);
    let steal = config.steal && shards.rxs.len() > 1;
    loop {
        // Which shard's work this dispatch is (`None` = our own), the
        // latency epoch, and the admitted batch — filled by either the
        // own-inbox path or the steal path below.
        let stolen_from: Option<usize>;
        let t0: Instant;
        let mut batch: Vec<JobRequest>;
        // The inbox lock is held only while receiving and admitting —
        // never across a dispatch — so a replacement worker (or a
        // thief) can take over this inbox while a condemned
        // predecessor is still unwinding. Note the flip side: an idle
        // worker blocked *receiving* holds its own lock, so thieves
        // only succeed against a victim that is mid-dispatch with a
        // backlog — exactly the skew that makes a steal worth it.
        let guard = lock_or_recover(&shards.rxs[idx]);
        let inbox = Inbox::with_depth(&guard, &shards.depths[idx]);
        let first = if steal {
            // Bounded wait: give our own inbox STEAL_POLL to produce
            // work before looking for a sibling to rob.
            match inbox.recv_timeout(STEAL_POLL) {
                Ok(r) => Some(r),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        } else {
            match inbox.recv() {
                Ok(r) => Some(r),
                Err(RecvError) => None,
            }
        };
        if let Some(first) = first {
            stolen_from = None;
            // Latency epoch: the head request waits from here on, so
            // the fusion-window wait counts toward reported latency.
            t0 = Instant::now();
            // An already-expired head never opens a fusion window:
            // answer it dead and move on to live work (the router
            // checks too, but a request can expire while queued).
            if first.expired() {
                drop(guard);
                metrics.bump("deadline_exceeded", 1);
                let err = faults::deadline_error(&first.graph, first.algo.label);
                if tx.send(answer(&first, Err(err), t0, metrics)).is_err() {
                    return;
                }
                continue;
            }
            batch = vec![first];
            // Adaptive mode reads the backlog *after* taking the head:
            // a shallow inbox dispatches fast, a deep one waits out a
            // longer window so fusion swallows it.
            let window =
                effective_window(config, shards.depths[idx].load(Ordering::Relaxed));
            admit_batch(&inbox, &mut batch, max_batch, window, metrics);
            drop(guard);
        } else {
            drop(guard);
            if !steal {
                return; // own inbox closed, nothing left to drain
            }
            match try_steal(idx, shards, config, metrics) {
                Some((steal_t0, stolen, victim)) => {
                    stolen_from = Some(victim);
                    t0 = steal_t0;
                    batch = stolen;
                }
                None => continue,
            }
        }
        // Heartbeat: publish the dispatch to the watchdog before any
        // engine code runs. The clone is the price of supervision —
        // the watchdog must be able to answer these requests itself.
        // Stolen batches are supervised by *this* worker's slot: the
        // thief is the one executing, so it is the one a stall
        // condemns.
        *lock_or_recover(&shared.inflight) = Some((t0, batch.clone()));
        metrics.bump("shard_dispatches", 1);
        // One freshness check per dispatch (an atomic load; the
        // registry Mutex only on an actual publish), so the whole
        // batch resolves graphs against one immutable snapshot and
        // request execution stays lock-free.
        if cache.refresh(coord.directory()) {
            metrics.bump("registry_snapshots", 1);
        }
        // Placement counters (`graph_seen/<name>`), once per distinct
        // *registered* graph per dispatch: bounded metric cardinality
        // (client-supplied names that resolve to nothing get no
        // counter) and O(distinct graphs), not O(requests), metric
        // work per batch. Skipped for stolen batches — the counter
        // describes router placement, which a steal does not change.
        if stolen_from.is_none() {
            let mut seen: Vec<(&str, u64)> = Vec::new();
            for r in &batch {
                if let Some(entry) = seen.iter_mut().find(|(g, _)| *g == r.graph.as_str()) {
                    entry.1 += 1;
                } else if cache.cached(&r.graph).is_some() {
                    seen.push((r.graph.as_str(), 1));
                }
            }
            for (g, count) in seen {
                metrics.bump(&format!("graph_seen/{g}"), count);
            }
        }
        if pool.is_empty() {
            metrics.bump("workspaces_created", 1);
        }
        let mut ws = pool.checkout();
        // Guard state follows the *batch's* shard, not the executing
        // worker: a stolen batch must hit the owner's result cache
        // (the router pins its graph there — hits and fills elsewhere
        // would be invisible to later requests) and the owner's
        // breaker (its panic streak must not reset just because a
        // thief ran the next repeat).
        let owner = stolen_from.map_or(state, |v| &*shards.states[v]);
        let results = core.run_batch_from(
            t0,
            &batch,
            |name| cache.cached(name),
            &mut ws,
            // Shard-level handles, not worker-owned: graph→shard
            // affinity still means the owner shard's cache/breaker see
            // the full hit and consecutive-panic streams, and keeping
            // them in ShardState lets them survive a watchdog respawn.
            &mut Guards {
                cache: CacheHandle::Shared(&owner.results),
                breaker: BreakerHandle::Shared(&owner.breaker),
            },
        );
        // Reclaim the dispatch. An empty slot means the watchdog
        // already answered this batch and spawned a replacement over
        // the inbox: discard these results (every request is answered
        // exactly once) and retire — the condemned token is sticky, so
        // this worker could never run another dispatch anyway.
        if lock_or_recover(&shared.inflight).take().is_none() {
            return;
        }
        pool.checkin(ws);
        for (req, res) in batch.iter().zip(results) {
            let jr = answer(req, res, t0, metrics);
            if tx.send(jr).is_err() {
                return;
            }
        }
    }
}

/// One steal attempt by idle worker `me`: pick the deepest sibling
/// inbox by the router's depth gauges, `try_lock` it (a conflict with
/// the owner or another thief is counted, never waited on — the owner
/// holds its lock while blocked receiving, so a successful steal
/// implies the victim is mid-dispatch with queued backlog), then run
/// the *whole* fusion-window admission against the victim's inbox.
/// Whole batches move, so a window or 64-lane fused walk is never
/// split; the [`Inbox`] wraps the victim's depth gauge, so gauge
/// accounting stays exact.
///
/// Returns `(latency epoch, batch, victim shard)` on success.
fn try_steal(
    me: usize,
    shards: &Shards,
    config: &ShardConfig,
    metrics: &Metrics,
) -> Option<(Instant, Vec<JobRequest>, usize)> {
    let mut victim = None;
    let mut deepest = 0usize;
    for (i, d) in shards.depths.iter().enumerate() {
        if i == me {
            continue;
        }
        let depth = d.load(Ordering::Relaxed);
        if depth > deepest {
            deepest = depth;
            victim = Some(i);
        }
    }
    // Every sibling idle: nothing worth robbing this poll.
    let victim = victim?;
    metrics.bump("steal_attempts", 1);
    let guard = match shards.rxs[victim].try_lock() {
        Ok(g) => g,
        Err(TryLockError::Poisoned(p)) => p.into_inner(),
        Err(TryLockError::WouldBlock) => {
            metrics.bump("steal_conflicts", 1);
            return None;
        }
    };
    let inbox = Inbox::with_depth(&guard, &shards.depths[victim]);
    // The gauge read raced the owner's receives: the backlog may be
    // gone by the time the lock lands.
    let Ok(first) = inbox.try_recv() else {
        metrics.bump("steal_conflicts", 1);
        return None;
    };
    let t0 = Instant::now();
    let mut batch = vec![first];
    // An expired stolen head opens no window (run_batch_from answers
    // it dead); a live one gets the same adaptive admission the owner
    // would have run, keyed to the *victim's* remaining backlog.
    if !batch[0].expired() {
        let window = effective_window(
            config,
            shards.depths[victim].load(Ordering::Relaxed),
        );
        admit_batch(&inbox, &mut batch, config.max_batch.max(1), window, metrics);
    }
    metrics.bump("batches_stolen", 1);
    Some((t0, batch, victim))
}

/// Fusion-window admission: grow `batch` (which already holds the
/// just-received head request) from `rx`.
///
/// * Fusable head (its registry spec has a batch engine) and a
///   nonzero `window`: block-drain the channel up to the window
///   deadline, stopping early at `max_batch` requests or once
///   [`MAX_FUSE`] requests share the head's `(graph, spec id,
///   params)` registry key — a full fused walk is ready, waiting
///   longer buys nothing.
/// * Otherwise: fall through immediately, picking up only what is
///   already queued (the pre-window behavior).
///
/// If the channel disconnects mid-window, the drained batch is left
/// intact for the caller to execute — shutdown never drops accepted
/// requests.
pub(crate) fn admit_batch(
    rx: &Inbox<'_>,
    batch: &mut Vec<JobRequest>,
    max_batch: usize,
    window: Duration,
    metrics: &Metrics,
) {
    // A window can only open when there is capacity to admit into
    // (max_batch > 1) — otherwise window_waits would count waits that
    // never happen (e.g. the unbatched max_batch=1 baseline).
    if !window.is_zero() && max_batch > 1 && batch[0].algo.fusable() {
        metrics.bump("window_waits", 1);
        // The opened window's width — under the adaptive policy this
        // series is the direct evidence of load-driven sizing
        // (shallow inbox ⇒ ~ADAPTIVE_FLOOR, backlog ⇒ the cap).
        metrics.observe("fusion_window_us", window);
        let deadline = Instant::now() + window;
        // The grouping key run_batch fuses on: registry spec id +
        // parsed params (+ the graph name) — exactly what the wire
        // request carries.
        let head_key = batch[0].group_key();
        let head_graph = batch[0].graph.clone();
        let mut same_key = 1usize;
        while batch.len() < max_batch && same_key < MAX_FUSE {
            let now = Instant::now();
            if now >= deadline {
                metrics.bump("window_timeouts", 1);
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    if r.group_key() == head_key && r.graph == head_graph {
                        same_key += 1;
                    }
                    batch.push(r);
                }
                Err(RecvTimeoutError::Timeout) => {
                    metrics.bump("window_timeouts", 1);
                    break;
                }
                // Senders gone and the buffer is empty: dispatch what
                // we have (the caller still executes this batch).
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    } else {
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::api::ParseArgs;
    use crate::V;

    fn req(id: u64, graph: &str, algo: &str, tau: usize) -> JobRequest {
        JobRequest::parse(id, graph, algo, &ParseArgs { tau, block: 64 })
            .unwrap()
            .with_source((id % 3) as V)
    }

    #[test]
    fn admit_batch_without_window_takes_only_whats_queued() {
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..3u64 {
            tx.send(req(i, "g", "bfs-vgc", 8)).unwrap();
        }
        let mut batch = vec![req(99, "g", "bfs-vgc", 8)];
        admit_batch(&Inbox::new(&rx), &mut batch, 64, Duration::ZERO, &m);
        assert_eq!(batch.len(), 4);
        assert_eq!(m.counter("window_waits"), 0);
        drop(tx);
    }

    #[test]
    fn admit_batch_nonfusable_head_falls_through() {
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        tx.send(req(1, "g", "bcc-fast", 8)).unwrap();
        let mut batch = vec![req(0, "g", "bcc-fast", 8)];
        let t0 = Instant::now();
        admit_batch(&Inbox::new(&rx), &mut batch, 64, Duration::from_secs(10), &m);
        assert!(t0.elapsed() < Duration::from_secs(5), "no window wait");
        assert_eq!(batch.len(), 2);
        assert_eq!(m.counter("window_waits"), 0);
        drop(tx);
    }

    #[test]
    fn admit_batch_window_stops_at_full_fused_walk() {
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        // 70 same-key requests pre-queued: the window must dispatch at
        // 64 same-key lanes without waiting out a long deadline.
        for i in 0..70u64 {
            tx.send(req(i, "g", "sssp-rho", 8)).unwrap();
        }
        let mut batch = vec![req(99, "g", "sssp-rho", 8)];
        let t0 = Instant::now();
        admit_batch(&Inbox::new(&rx), &mut batch, 1 << 20, Duration::from_secs(10), &m);
        assert!(t0.elapsed() < Duration::from_secs(5), "early dispatch");
        assert_eq!(batch.len(), MAX_FUSE, "stops at 64 same-key lanes");
        assert_eq!(m.counter("window_waits"), 1);
        assert_eq!(m.counter("window_timeouts"), 0);
        drop(tx);
    }

    #[test]
    fn admit_batch_times_out_and_survives_disconnect() {
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel::<JobRequest>();
        tx.send(req(1, "g", "bfs-vgc", 8)).unwrap();
        let mut batch = vec![req(0, "g", "bfs-vgc", 8)];
        admit_batch(&Inbox::new(&rx), &mut batch, 64, Duration::from_millis(5), &m);
        assert_eq!(batch.len(), 2, "drained the queued request");
        assert_eq!(m.counter("window_timeouts"), 1, "then timed out");
        // Disconnected mid-window: batch stays intact, returns fast.
        drop(tx);
        let (tx2, rx2) = std::sync::mpsc::channel::<JobRequest>();
        tx2.send(req(2, "g", "bfs-vgc", 8)).unwrap();
        drop(tx2);
        let mut batch2 = vec![req(0, "g", "bfs-vgc", 8)];
        let t0 = Instant::now();
        admit_batch(&Inbox::new(&rx2), &mut batch2, 64, Duration::from_secs(10), &m);
        assert_eq!(batch2.len(), 2, "buffered request drained after close");
        assert!(t0.elapsed() < Duration::from_secs(5), "no deadline sleep");
    }

    #[test]
    fn inbox_receives_decrement_the_depth_gauge() {
        // The router increments the gauge per send; every receive path
        // (blocking, timed, non-blocking) must decrement it, or the
        // shed decision reads a stale depth forever.
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let depth = AtomicUsize::new(0);
        for i in 0..5u64 {
            tx.send(req(i, "g", "bfs-vgc", 8)).unwrap();
            depth.fetch_add(1, Ordering::Relaxed);
        }
        let inbox = Inbox::with_depth(&rx, &depth);
        let first = inbox.recv().unwrap();
        assert_eq!(depth.load(Ordering::Relaxed), 4, "blocking recv decrements");
        let mut batch = vec![first];
        admit_batch(&inbox, &mut batch, 64, Duration::from_millis(5), &m);
        assert_eq!(batch.len(), 5);
        assert_eq!(
            depth.load(Ordering::Relaxed),
            0,
            "every admission-path receive decrements"
        );
        drop(tx);
    }

    #[test]
    fn effective_window_is_fixed_without_a_max_and_adaptive_with_one() {
        let mut config = ShardConfig {
            fusion_window: Duration::from_micros(200),
            fusion_window_max: Duration::ZERO,
            max_batch: 64,
            ..ShardConfig::default()
        };
        // Fixed mode: depth is irrelevant.
        assert_eq!(effective_window(&config, 0), Duration::from_micros(200));
        assert_eq!(effective_window(&config, 1000), Duration::from_micros(200));
        // Adaptive mode: floor at an empty inbox, the cap at a backlog
        // of max_batch or more, monotone in between.
        config.fusion_window_max = Duration::from_micros(2000);
        assert_eq!(effective_window(&config, 0), ADAPTIVE_FLOOR);
        assert_eq!(
            effective_window(&config, config.max_batch),
            Duration::from_micros(2000)
        );
        assert_eq!(
            effective_window(&config, 10 * config.max_batch),
            Duration::from_micros(2000),
            "backlog past max_batch clamps at the cap"
        );
        let mut prev = Duration::ZERO;
        for depth in 0..=config.max_batch {
            let w = effective_window(&config, depth);
            assert!(w >= prev, "adaptive window is monotone in depth");
            prev = w;
        }
        // A fixed window *below* the floor caps the floor: adaptivity
        // never waits longer than the configured minimum at depth 0.
        config.fusion_window = Duration::from_micros(5);
        assert_eq!(effective_window(&config, 0), Duration::from_micros(5));
        // Zero base window disables windows entirely in both modes.
        config.fusion_window = Duration::ZERO;
        assert_eq!(effective_window(&config, 64), Duration::ZERO);
    }

    fn test_shards(config: &ShardConfig, depths: &[usize]) -> (Vec<Sender<JobRequest>>, Shards) {
        let coord = Coordinator::new();
        let mut txs = Vec::new();
        let mut shards = Shards {
            rxs: Vec::new(),
            depths: Vec::new(),
            states: Vec::new(),
        };
        for &d in depths {
            let (tx, rx) = std::sync::mpsc::channel();
            for i in 0..d as u64 {
                tx.send(req(i, "g", "bfs-vgc", 8)).unwrap();
            }
            txs.push(tx);
            shards.rxs.push(Arc::new(Mutex::new(rx)));
            shards.depths.push(Arc::new(AtomicUsize::new(d)));
            shards.states.push(Arc::new(ShardState::new(config, &coord)));
        }
        (txs, shards)
    }

    #[test]
    fn try_steal_robs_the_deepest_sibling_and_keeps_gauges_exact() {
        let m = Metrics::new();
        let config = ShardConfig {
            fusion_window: Duration::from_millis(5),
            max_batch: 64,
            ..ShardConfig::default()
        };
        let (_txs, shards) = test_shards(&config, &[0, 3, 7]);
        // Thief is shard 0; shard 2 is deepest and must be the victim.
        let (t0, batch, victim) = try_steal(0, &shards, &config, &m).expect("backlog to steal");
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(victim, 2, "deepest sibling selected");
        assert_eq!(batch.len(), 7, "whole admitted window moves");
        assert_eq!(
            shards.depths[2].load(Ordering::Relaxed),
            0,
            "every steal-path receive decremented the victim's gauge"
        );
        assert_eq!(
            shards.depths[1].load(Ordering::Relaxed),
            3,
            "non-victim untouched"
        );
        assert_eq!(m.counter("steal_attempts"), 1);
        assert_eq!(m.counter("batches_stolen"), 1);
        assert_eq!(m.counter("steal_conflicts"), 0);
    }

    #[test]
    fn try_steal_counts_lock_conflicts_and_empty_races_without_waiting() {
        let m = Metrics::new();
        let config = ShardConfig::default();
        let (_txs, shards) = test_shards(&config, &[0, 4]);
        // The victim's own worker holds the inbox lock (as it does
        // while blocked receiving): the thief must bail immediately.
        let held = shards.rxs[1].lock().unwrap();
        let t0 = Instant::now();
        assert!(try_steal(0, &shards, &config, &m).is_none());
        assert!(t0.elapsed() < Duration::from_secs(1), "try_lock, never wait");
        assert_eq!(m.counter("steal_attempts"), 1);
        assert_eq!(m.counter("steal_conflicts"), 1);
        drop(held);
        // A stale gauge (backlog drained between the read and the
        // lock) is a conflict too, not a panic or a block.
        let rx = shards.rxs[1].lock().unwrap();
        while rx.try_recv().is_ok() {}
        drop(rx);
        assert!(try_steal(0, &shards, &config, &m).is_none());
        assert_eq!(m.counter("steal_conflicts"), 2);
        // All siblings idle: no attempt is even recorded.
        shards.depths[1].store(0, Ordering::Relaxed);
        assert!(try_steal(0, &shards, &config, &m).is_none());
        assert_eq!(m.counter("steal_attempts"), 2);
    }

    #[test]
    fn stolen_windows_are_never_split() {
        // 70 same-key requests queued at the victim: the thief's
        // admission must stop at the 64-lane fused-walk cap, exactly
        // like an owner dispatch — a steal moves whole windows.
        let m = Metrics::new();
        let config = ShardConfig {
            fusion_window: Duration::from_secs(10),
            max_batch: 1 << 20,
            ..ShardConfig::default()
        };
        let coord = Coordinator::new();
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..70u64 {
            tx.send(req(i, "g", "sssp-rho", 8)).unwrap();
        }
        let shards = Shards {
            rxs: vec![
                Arc::new(Mutex::new(std::sync::mpsc::channel().1)),
                Arc::new(Mutex::new(rx)),
            ],
            depths: vec![
                Arc::new(AtomicUsize::new(0)),
                Arc::new(AtomicUsize::new(70)),
            ],
            states: vec![
                Arc::new(ShardState::new(&config, &coord)),
                Arc::new(ShardState::new(&config, &coord)),
            ],
        };
        let t0 = Instant::now();
        let (_t, batch, victim) = try_steal(0, &shards, &config, &m).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "early dispatch");
        assert_eq!(victim, 1);
        assert_eq!(batch.len(), MAX_FUSE, "stops at 64 same-key lanes");
        assert_eq!(shards.depths[1].load(Ordering::Relaxed), 70 - MAX_FUSE as usize);
        drop(tx);
    }

    #[test]
    fn different_params_do_not_count_toward_the_same_key_cap() {
        // Same graph + spec but a different τ: admitted into the batch
        // (run_batch groups them separately) without counting toward
        // the head's 64-lane same-key cap.
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..4u64 {
            tx.send(req(i, "g", "bfs-vgc", if i % 2 == 0 { 8 } else { 32 }))
                .unwrap();
        }
        drop(tx);
        let mut batch = vec![req(99, "g", "bfs-vgc", 8)];
        admit_batch(&Inbox::new(&rx), &mut batch, 64, Duration::from_secs(10), &m);
        assert_eq!(batch.len(), 5, "all queued requests admitted");
    }
}
