//! `coordinator::shard` — the sharded multi-worker serving subsystem.
//!
//! One serving thread pulling one channel through two global Mutexes
//! caps delivered throughput long before the kernels do, and it only
//! ever fuses requests that happen to be queued at the same instant.
//! This module turns the library into a multi-threaded server:
//!
//! ```text
//!             requests                    results
//!                │                           ▲
//!                ▼                           │
//!            ┌────────┐   hash(graph)   ┌────┴────┐
//!            │ router │ ───────────────▶│ shard i │──┐
//!            └────────┘                 └─────────┘  │ fusion window
//!                                       │ snapshot │ │ → run_batch
//!                                       │ ws pool  │ │ → demux
//!                                       │ metrics  │◀┘
//!                                       └──────────┘   × N workers
//! ```
//!
//! * **Router** — [`ShardServer::serve`] hashes each request's graph
//!   name ([`JobRequest::route_hash`], FNV-1a) and forwards it to one
//!   of N shard workers. Same graph ⇒ same shard, so every request
//!   that *could* fuse is visible to one fusion window, and each
//!   graph's derived views (transpose, symmetrization) and warm
//!   workspace arrays stay hot in one worker's cache.
//! * **Shard worker** — owns everything it touches per request, so
//!   the hot path takes **zero shared Mutex locks** (the shard-local
//!   [`Metrics`] registry locks only its own, uncontended Mutex): a
//!   plain-`Vec` [`WorkspacePool`], a shard-local [`ResultCache`]
//!   answering repeated whole-graph analyses (SCC/CC/k-core/BCC) for
//!   free — valid because the router pins a graph to one shard, so
//!   that shard's cache sees every request that could hit — shard-
//!   local metrics (merged into the coordinator's global registry
//!   when serving ends), and a [`SnapshotCache`] of the graph
//!   registry refreshed only when the [`GraphDirectory`] version
//!   counter moves (one atomic load per dispatch; `load_graph`
//!   publishes new snapshots without ever blocking request execution,
//!   and its version bump is what invalidates cached results).
//! * **Fusion-window admission** ([`admit_batch`]) — when the head
//!   request's registry spec has a batch engine and the window is
//!   nonzero, the worker keeps draining its inbox until the window
//!   deadline, the batch cap, or 64 same-(graph, spec id, params)
//!   lanes accumulate — then dispatches one
//!   [`ExecCore::run_batch_from`], which fuses the group into batched
//!   multi-source walks and demultiplexes per-lane results in
//!   submission order. Non-fusable heads fall through immediately
//!   (they only pick up what is already queued). When the request
//!   channel closes mid-window, the partial batch still executes:
//!   accepted work is never dropped. Every accepted request is also
//!   *answered* — failures come back on the result channel as
//!   [`Failed`](super::job::JobOutput::Failed) outputs carrying the
//!   request id (with the `errors` counter bumped), so clients
//!   correlating responses by id never hang on an error.
//!
//! The serve path is **fault-tolerant** (see [`super::faults`] and the
//! crate-level "Failure semantics" section):
//!
//! * **Bounded inboxes / load shedding** — the router tracks each
//!   shard's queue depth with a per-shard atomic gauge ([`Inbox`]
//!   decrements it on every successful receive). Past
//!   [`ShardConfig::inbox_cap`] queued requests, new arrivals for that
//!   shard are *shed*: answered immediately with a typed
//!   [`Overloaded`](super::faults::FailKind::Overloaded) failure
//!   (`shed` counter) instead of growing an unbounded queue and
//!   dragging every queued request's latency with it.
//! * **Deadlines** — already-expired requests are answered
//!   [`DeadlineExceeded`](super::faults::FailKind::DeadlineExceeded)
//!   at the router, and an expired head never opens a fusion window
//!   (`deadline_exceeded` counter).
//! * **Panic isolation** — engine panics are caught inside
//!   [`ExecCore`], answered as typed failures, and counted by a
//!   worker-owned per-`(graph, spec)` circuit breaker (valid for the
//!   same graph→shard-affinity reason the result cache is): after
//!   [`BREAKER_TRIP`](super::faults::BREAKER_TRIP) consecutive panics
//!   the breaker fails identical requests fast until the graph is
//!   republished. No shard worker dies; the corrupt workspace is
//!   dropped, never checked back into the pool.
//!
//! Per-shard counters: `shard_dispatches`, `window_waits`,
//! `window_timeouts`, `registry_snapshots`, `graph_seen/<name>`, plus
//! everything [`ExecCore`] meters (`queries_fused`, `jobs_executed`,
//! `engine_panics`, ...). [`Metrics::merge`] folds them into the
//! global registry (router-side `shed`/`deadline_exceeded` land in the
//! global registry directly); [`ShardServer::serve`] also returns the
//! per-shard registries so callers can inspect placement and balance.
//!
//! [`ExecCore`]: super::server::ExecCore
//! [`ExecCore::run_batch_from`]: super::server::ExecCore::run_batch_from
//! [`GraphDirectory`]: super::directory::GraphDirectory

use super::directory::{ResultCache, SnapshotCache};
use super::faults::{self, PanicBreaker};
use super::job::{JobRequest, JobResult};
use super::metrics::Metrics;
use super::server::{
    answer, BreakerHandle, CacheHandle, Coordinator, ExecCore, Guards, MAX_FUSE,
};
use crate::algo::workspace::WorkspacePool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvError, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Tuning knobs for the sharded server.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Number of shard workers (default: the worker-pool width).
    pub shards: usize,
    /// Fusion-window deadline: how long a shard waits for more
    /// fusable requests before dispatching (default 200µs; zero
    /// disables waiting entirely).
    pub fusion_window: Duration,
    /// Most requests admitted into one dispatched batch.
    pub max_batch: usize,
    /// Most requests queued per shard before the router sheds new
    /// arrivals for that shard with a typed
    /// [`Overloaded`](super::faults::FailKind::Overloaded) failure
    /// (default 1024; `0` disables shedding — unbounded queues, the
    /// pre-backpressure behavior).
    pub inbox_cap: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: crate::parallel::num_threads(),
            fusion_window: Duration::from_micros(200),
            max_batch: 64,
            inbox_cap: 1024,
        }
    }
}

/// A worker's receiving end of a request channel, with an optional
/// shared depth gauge: every successful receive decrements the gauge
/// the router increments on send, so `gauge == requests queued but
/// not yet picked up` and the router's shed decision reads one atomic.
/// The single-threaded serve loops wrap their receiver with
/// [`Inbox::new`] (no gauge, zero cost).
pub(crate) struct Inbox<'a> {
    rx: &'a Receiver<JobRequest>,
    depth: Option<&'a AtomicUsize>,
}

impl<'a> Inbox<'a> {
    pub(crate) fn new(rx: &'a Receiver<JobRequest>) -> Self {
        Inbox { rx, depth: None }
    }

    pub(crate) fn with_depth(rx: &'a Receiver<JobRequest>, depth: &'a AtomicUsize) -> Self {
        Inbox {
            rx,
            depth: Some(depth),
        }
    }

    fn took(&self) {
        if let Some(d) = self.depth {
            d.fetch_sub(1, Ordering::Relaxed);
        }
    }

    pub(crate) fn recv(&self) -> Result<JobRequest, RecvError> {
        let r = self.rx.recv();
        if r.is_ok() {
            self.took();
        }
        r
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<JobRequest, RecvTimeoutError> {
        let r = self.rx.recv_timeout(timeout);
        if r.is_ok() {
            self.took();
        }
        r
    }

    fn try_recv(&self) -> Result<JobRequest, TryRecvError> {
        let r = self.rx.try_recv();
        if r.is_ok() {
            self.took();
        }
        r
    }
}

/// The sharded serving front end over a [`Coordinator`]'s registry,
/// engine and metrics (see module docs).
pub struct ShardServer {
    coord: Arc<Coordinator>,
    config: ShardConfig,
}

impl ShardServer {
    pub fn new(coord: Arc<Coordinator>, config: ShardConfig) -> Self {
        ShardServer { coord, config }
    }

    /// The active configuration.
    pub fn config(&self) -> &ShardConfig {
        &self.config
    }

    /// Serve until the request channel closes: route every request to
    /// its graph's shard, run N shard workers with fusion-window
    /// admission, and answer on `tx` (shards interleave, so results
    /// are unordered across graphs; per-shard they follow dispatch
    /// order). Returns the per-shard metrics registries after merging
    /// each into the coordinator's global metrics.
    pub fn serve(&self, rx: Receiver<JobRequest>, tx: Sender<JobResult>) -> Vec<Metrics> {
        let n = self.config.shards.max(1);
        let coord = &*self.coord;
        let config = &self.config;
        let per_shard: Vec<Metrics> = std::thread::scope(|s| {
            let mut inboxes = Vec::with_capacity(n);
            let mut depths: Vec<Arc<AtomicUsize>> = Vec::with_capacity(n);
            let mut workers = Vec::with_capacity(n);
            for _ in 0..n {
                let (shard_tx, shard_rx) = std::sync::mpsc::channel::<JobRequest>();
                let depth = Arc::new(AtomicUsize::new(0));
                let res_tx = tx.clone();
                inboxes.push(shard_tx);
                depths.push(Arc::clone(&depth));
                workers.push(s.spawn(move || {
                    let metrics = Metrics::new();
                    shard_loop(coord, config, shard_rx, &depth, res_tx, &metrics);
                    metrics
                }));
            }
            // The router: one hash (plus one atomic depth load) per
            // request, no locks held. It answers shed and
            // already-expired requests itself on its own result-sender
            // clone — every accepted request is answered exactly once,
            // shed or not. The workers hold their own clones; the
            // router's drops after the loop, so the result channel
            // still closes when the last shard finishes.
            let cap = config.inbox_cap;
            for req in rx {
                let t0 = Instant::now();
                if req.expired() {
                    coord.metrics.bump("deadline_exceeded", 1);
                    let err = faults::deadline_error(&req.graph, req.algo.label);
                    if tx.send(answer(&req, Err(err), t0, &coord.metrics)).is_err() {
                        break;
                    }
                    continue;
                }
                let shard = (req.route_hash() % n as u64) as usize;
                if cap > 0 && depths[shard].load(Ordering::Relaxed) >= cap {
                    coord.metrics.bump("shed", 1);
                    let err = faults::overload_error(shard, cap);
                    if tx.send(answer(&req, Err(err), t0, &coord.metrics)).is_err() {
                        break;
                    }
                    continue;
                }
                depths[shard].fetch_add(1, Ordering::Relaxed);
                if inboxes[shard].send(req).is_err() {
                    break; // shard died (results receiver hung up)
                }
            }
            drop(tx);
            drop(inboxes);
            workers
                .into_iter()
                .map(|w| w.join().expect("shard worker panicked"))
                .collect()
        });
        for m in &per_shard {
            self.coord.metrics.merge(m);
        }
        per_shard
    }
}

/// One shard worker: fusion-window admission over its inbox, batch
/// execution against shard-local state, results answered in dispatch
/// order. Exits when the inbox closes (after draining it) or when the
/// result channel hangs up.
fn shard_loop(
    coord: &Coordinator,
    config: &ShardConfig,
    rx: Receiver<JobRequest>,
    depth: &AtomicUsize,
    tx: Sender<JobResult>,
    metrics: &Metrics,
) {
    let mut cache = SnapshotCache::new();
    let mut pool = WorkspacePool::new();
    // Shard-local result cache: graph→shard affinity means every
    // duplicate whole-graph query for a graph lands here, so a
    // worker-owned (lock-free) cache sees the full hit rate.
    let mut results_cache = ResultCache::new();
    // Worker-owned panic breaker, valid for the same affinity reason:
    // this worker sees every request — and so every consecutive
    // panic — for its graphs.
    let mut breaker = PanicBreaker::new();
    let core = ExecCore {
        engine: coord.engine(),
        metrics,
        faults: coord.fault_plan(),
    };
    let max_batch = config.max_batch.max(1);
    let inbox = Inbox::with_depth(&rx, depth);
    while let Ok(first) = inbox.recv() {
        // Latency epoch: the head request waits from here on, so the
        // fusion-window wait counts toward reported latency.
        let t0 = Instant::now();
        // An already-expired head never opens a fusion window: answer
        // it dead and move on to live work (the router checks too, but
        // a request can expire while queued).
        if first.expired() {
            metrics.bump("deadline_exceeded", 1);
            let err = faults::deadline_error(&first.graph, first.algo.label);
            if tx.send(answer(&first, Err(err), t0, metrics)).is_err() {
                return;
            }
            continue;
        }
        let mut batch = vec![first];
        admit_batch(&inbox, &mut batch, max_batch, config.fusion_window, metrics);
        metrics.bump("shard_dispatches", 1);
        // One freshness check per dispatch (an atomic load; the
        // registry Mutex only on an actual publish), so the whole
        // batch resolves graphs against one immutable snapshot and
        // request execution stays lock-free.
        if cache.refresh(coord.directory()) {
            metrics.bump("registry_snapshots", 1);
        }
        // Placement counters (`graph_seen/<name>`), once per distinct
        // *registered* graph per dispatch: bounded metric cardinality
        // (client-supplied names that resolve to nothing get no
        // counter) and O(distinct graphs), not O(requests), metric
        // work per batch.
        let mut seen: Vec<(&str, u64)> = Vec::new();
        for r in &batch {
            if let Some(entry) = seen.iter_mut().find(|(g, _)| *g == r.graph.as_str()) {
                entry.1 += 1;
            } else if cache.cached(&r.graph).is_some() {
                seen.push((r.graph.as_str(), 1));
            }
        }
        for (g, count) in seen {
            metrics.bump(&format!("graph_seen/{g}"), count);
        }
        if pool.is_empty() {
            metrics.bump("workspaces_created", 1);
        }
        let mut ws = pool.checkout();
        let results = core.run_batch_from(
            t0,
            &batch,
            |name| cache.cached(name),
            &mut ws,
            &mut Guards {
                cache: CacheHandle::Owned(&mut results_cache),
                breaker: BreakerHandle::Owned(&mut breaker),
            },
        );
        pool.checkin(ws);
        for (req, res) in batch.iter().zip(results) {
            let jr = answer(req, res, t0, metrics);
            if tx.send(jr).is_err() {
                return;
            }
        }
    }
}

/// Fusion-window admission: grow `batch` (which already holds the
/// just-received head request) from `rx`.
///
/// * Fusable head (its registry spec has a batch engine) and a
///   nonzero `window`: block-drain the channel up to the window
///   deadline, stopping early at `max_batch` requests or once
///   [`MAX_FUSE`] requests share the head's `(graph, spec id,
///   params)` registry key — a full fused walk is ready, waiting
///   longer buys nothing.
/// * Otherwise: fall through immediately, picking up only what is
///   already queued (the pre-window behavior).
///
/// If the channel disconnects mid-window, the drained batch is left
/// intact for the caller to execute — shutdown never drops accepted
/// requests.
pub(crate) fn admit_batch(
    rx: &Inbox<'_>,
    batch: &mut Vec<JobRequest>,
    max_batch: usize,
    window: Duration,
    metrics: &Metrics,
) {
    // A window can only open when there is capacity to admit into
    // (max_batch > 1) — otherwise window_waits would count waits that
    // never happen (e.g. the unbatched max_batch=1 baseline).
    if !window.is_zero() && max_batch > 1 && batch[0].algo.fusable() {
        metrics.bump("window_waits", 1);
        let deadline = Instant::now() + window;
        // The grouping key run_batch fuses on: registry spec id +
        // parsed params (+ the graph name) — exactly what the wire
        // request carries.
        let head_key = batch[0].group_key();
        let head_graph = batch[0].graph.clone();
        let mut same_key = 1usize;
        while batch.len() < max_batch && same_key < MAX_FUSE {
            let now = Instant::now();
            if now >= deadline {
                metrics.bump("window_timeouts", 1);
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    if r.group_key() == head_key && r.graph == head_graph {
                        same_key += 1;
                    }
                    batch.push(r);
                }
                Err(RecvTimeoutError::Timeout) => {
                    metrics.bump("window_timeouts", 1);
                    break;
                }
                // Senders gone and the buffer is empty: dispatch what
                // we have (the caller still executes this batch).
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    } else {
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::api::ParseArgs;
    use crate::V;

    fn req(id: u64, graph: &str, algo: &str, tau: usize) -> JobRequest {
        JobRequest::parse(id, graph, algo, &ParseArgs { tau, block: 64 })
            .unwrap()
            .with_source((id % 3) as V)
    }

    #[test]
    fn admit_batch_without_window_takes_only_whats_queued() {
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..3u64 {
            tx.send(req(i, "g", "bfs-vgc", 8)).unwrap();
        }
        let mut batch = vec![req(99, "g", "bfs-vgc", 8)];
        admit_batch(&Inbox::new(&rx), &mut batch, 64, Duration::ZERO, &m);
        assert_eq!(batch.len(), 4);
        assert_eq!(m.counter("window_waits"), 0);
        drop(tx);
    }

    #[test]
    fn admit_batch_nonfusable_head_falls_through() {
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        tx.send(req(1, "g", "bcc-fast", 8)).unwrap();
        let mut batch = vec![req(0, "g", "bcc-fast", 8)];
        let t0 = Instant::now();
        admit_batch(&Inbox::new(&rx), &mut batch, 64, Duration::from_secs(10), &m);
        assert!(t0.elapsed() < Duration::from_secs(5), "no window wait");
        assert_eq!(batch.len(), 2);
        assert_eq!(m.counter("window_waits"), 0);
        drop(tx);
    }

    #[test]
    fn admit_batch_window_stops_at_full_fused_walk() {
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        // 70 same-key requests pre-queued: the window must dispatch at
        // 64 same-key lanes without waiting out a long deadline.
        for i in 0..70u64 {
            tx.send(req(i, "g", "sssp-rho", 8)).unwrap();
        }
        let mut batch = vec![req(99, "g", "sssp-rho", 8)];
        let t0 = Instant::now();
        admit_batch(&Inbox::new(&rx), &mut batch, 1 << 20, Duration::from_secs(10), &m);
        assert!(t0.elapsed() < Duration::from_secs(5), "early dispatch");
        assert_eq!(batch.len(), MAX_FUSE, "stops at 64 same-key lanes");
        assert_eq!(m.counter("window_waits"), 1);
        assert_eq!(m.counter("window_timeouts"), 0);
        drop(tx);
    }

    #[test]
    fn admit_batch_times_out_and_survives_disconnect() {
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel::<JobRequest>();
        tx.send(req(1, "g", "bfs-vgc", 8)).unwrap();
        let mut batch = vec![req(0, "g", "bfs-vgc", 8)];
        admit_batch(&Inbox::new(&rx), &mut batch, 64, Duration::from_millis(5), &m);
        assert_eq!(batch.len(), 2, "drained the queued request");
        assert_eq!(m.counter("window_timeouts"), 1, "then timed out");
        // Disconnected mid-window: batch stays intact, returns fast.
        drop(tx);
        let (tx2, rx2) = std::sync::mpsc::channel::<JobRequest>();
        tx2.send(req(2, "g", "bfs-vgc", 8)).unwrap();
        drop(tx2);
        let mut batch2 = vec![req(0, "g", "bfs-vgc", 8)];
        let t0 = Instant::now();
        admit_batch(&Inbox::new(&rx2), &mut batch2, 64, Duration::from_secs(10), &m);
        assert_eq!(batch2.len(), 2, "buffered request drained after close");
        assert!(t0.elapsed() < Duration::from_secs(5), "no deadline sleep");
    }

    #[test]
    fn inbox_receives_decrement_the_depth_gauge() {
        // The router increments the gauge per send; every receive path
        // (blocking, timed, non-blocking) must decrement it, or the
        // shed decision reads a stale depth forever.
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let depth = AtomicUsize::new(0);
        for i in 0..5u64 {
            tx.send(req(i, "g", "bfs-vgc", 8)).unwrap();
            depth.fetch_add(1, Ordering::Relaxed);
        }
        let inbox = Inbox::with_depth(&rx, &depth);
        let first = inbox.recv().unwrap();
        assert_eq!(depth.load(Ordering::Relaxed), 4, "blocking recv decrements");
        let mut batch = vec![first];
        admit_batch(&inbox, &mut batch, 64, Duration::from_millis(5), &m);
        assert_eq!(batch.len(), 5);
        assert_eq!(
            depth.load(Ordering::Relaxed),
            0,
            "every admission-path receive decrements"
        );
        drop(tx);
    }

    #[test]
    fn different_params_do_not_count_toward_the_same_key_cap() {
        // Same graph + spec but a different τ: admitted into the batch
        // (run_batch groups them separately) without counting toward
        // the head's 64-lane same-key cap.
        let m = Metrics::new();
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..4u64 {
            tx.send(req(i, "g", "bfs-vgc", if i % 2 == 0 { 8 } else { 32 }))
                .unwrap();
        }
        drop(tx);
        let mut batch = vec![req(99, "g", "bfs-vgc", 8)];
        admit_batch(&Inbox::new(&rx), &mut batch, 64, Duration::from_secs(10), &m);
        assert_eq!(batch.len(), 5, "all queued requests admitted");
    }
}
